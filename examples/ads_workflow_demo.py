"""Reproduce the paper's deadline-critical scenario in one script:
sweep schedulers over the medium-load case and print the paper-style
comparison (Fig. 12/13 condensed).

    PYTHONPATH=src python examples/ads_workflow_demo.py
"""
import numpy as np

from repro.core.benchmark import make_ads_benchmark
from repro.core.experiment import ExperimentSpec, run_experiment


def main() -> None:
    reps, ddl = 6, 0.090
    wf = make_ads_benchmark(cockpit_replicas=reps, critical_deadline_s=ddl)
    crit = {c.name: c.critical for c in wf.chains}
    print(f"[demo] medium load: x{reps} cockpit chains, "
          f"{int(ddl*1e3)} ms critical deadline, 400 tiles")
    print(f"{'policy':12s} {'viol%':>6s} {'p99_drv':>8s} {'p99_ck':>8s} "
          f"{'realloc%':>9s} {'n_rch':>6s}")
    for pol, q in (
        ("cyc", 0.95), ("cyc_s", 0.95), ("tp_driven", 0.95),
        ("pglb", 0.95), ("ads_tile", 0.9),
    ):
        r = run_experiment(ExperimentSpec(
            policy=pol, tiles=400, cockpit_replicas=reps, deadline_s=ddl,
            q=q, duration_s=1.5, seed=1,
        ))
        p99d = r.group_p99(crit, True) * 1e3
        p99c = r.group_p99(crit, False) * 1e3
        print(f"{pol:12s} {r.violation_rate*100:6.2f} {p99d:8.1f} "
              f"{p99c:8.1f} {r.realloc_frac*100:9.2f} {r.n_realloc:6d}")

    print("\n[demo] expected signature (paper §V): Cyc misses hard; "
          "Tp-driven burns double-digit capacity on reallocation; "
          "ADS-Tile holds the deadline with <1.2% waste.")


if __name__ == "__main__":
    main()
