"""Serve several real JAX models colocated on one device pool with the
ADS-Tile scheduling mechanisms (ERT admission, variant quotas = DoP
candidates, partition isolation, E2E-deadline slack sharing).

Mirrors the paper's ADS setting: a critical "driving" pipeline
(perception -> planning) colocated with best-effort "cockpit" models.

    PYTHONPATH=src python examples/serve_colocated.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import LM, init_params
from repro.serving import ColocatedServer, ServedModel


def make_model(arch: str, batches=(1, 4)):
    """Build a reduced model with per-batch compiled variants — the
    serving analogue of the paper's pre-compiled DoP candidates."""
    cfg = get_config(arch, reduced=True)
    model = LM(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))

    @jax.jit
    def fwd(tokens):
        x = model.embed(params, {"tokens": tokens})
        x, _ = model.backbone(params, x, positions=jnp.arange(x.shape[1]))
        return model.logits_last(params, x[:, -1])

    variants = {}
    for b in batches:
        toks = jnp.ones((b, 16), jnp.int32)
        fwd(toks).block_until_ready()          # warm the cache
        t0 = time.time()
        for _ in range(3):
            fwd(toks).block_until_ready()
        est = (time.time() - t0) / 3
        variants[f"b{b}"] = (
            (lambda payload, b=b: fwd(jnp.asarray(payload[:b]))),
            est,
        )
    return cfg, variants


def main() -> None:
    print("[serve_colocated] compiling model variants...")
    models = {}
    # partition 0: critical perception+planning; partition 1: cockpit
    for name, arch, part, budget, down in (
        ("perception", "phi4_mini_3p8b", 0, 0.08, 0.05),
        ("planner", "granite_moe_1b", 0, 0.05, 0.0),
        ("cockpit_seg", "gemma3_4b", 1, 0.10, 0.0),
        ("cockpit_depth", "stablelm_12b", 1, 0.10, 0.0),
    ):
        cfg, variants = make_model(arch)
        models[name] = ServedModel(
            name=name, variants=variants, partition=part,
            budget_s=budget, downstream_budget_s=down,
        )
        print(f"  {name:14s} ({arch}) variants: "
              + ", ".join(f"{k}={v[1]*1e3:.1f}ms" for k, v in variants.items()))

    server = ColocatedServer(models, num_partitions=2)
    rng = np.random.RandomState(0)

    # a burst: chained driving jobs (tight E2E ddl) + cockpit background
    for i in range(6):
        toks = rng.randint(0, 100, (4, 16)).astype(np.int32)

        def chain_cb(_out, toks=toks):
            server.submit("planner", toks, deadline_s=0.15)

        server.submit("perception", toks, deadline_s=0.25, done_cb=chain_cb)
        server.submit("cockpit_seg", toks, deadline_s=1.0)
        server.submit("cockpit_depth", toks, deadline_s=1.0)

    log = server.run(duration_s=20.0)
    by_model = {}
    for rec in log:
        by_model.setdefault(rec["model"], []).append(rec)
    print(f"[serve_colocated] executed {len(log)} jobs")
    for name, recs in by_model.items():
        ok = [r for r in recs if not r["dropped"]]
        lat = [r["latency_s"] for r in ok]
        miss = sum(1 for r in ok if r["missed"]) + sum(
            1 for r in recs if r["dropped"]
        )
        print(f"  {name:14s} jobs={len(recs)} p50={np.median(lat)*1e3:6.1f}ms "
              f"missed={miss} variants={sorted({r.get('variant') for r in ok})}")


if __name__ == "__main__":
    main()
