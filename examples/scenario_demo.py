"""Scenario demo: a drive through changing contexts, with and without
online replanning, plus a mini Monte-Carlo fleet sweep.

    PYTHONPATH=src python examples/scenario_demo.py
"""
from repro.scenarios import (
    MODES,
    ScenarioScript,
    ScenarioSpec,
    aggregate_sweep,
    get_scenario,
    run,
    sweep,
)


def main() -> None:
    # 1. the driving-mode registry: each mode rescales every task profile
    print("registered driving modes:")
    for name, mode in sorted(MODES.items()):
        print(f"  {name:16s} work x{mode.work_scale:.2f}  "
              f"io-rate x{mode.io_rate_scale:.2f}  — {mode.description}")

    # 2. a scripted drive: leave the garage into rush hour, then a storm
    scen = get_scenario("calm_to_rush")
    print(f"\nscenario {scen.name!r}: {scen.to_string()} "
          f"({scen.duration_s:.1f} s, modes {', '.join(scen.modes())})")

    # the same timeline can be written as text
    assert ScenarioScript.parse(scen.to_string()).segments == scen.segments

    # 3. run it pinned vs replanned: the pinned run keeps the schedule
    #    compiled for 'parking'; the replanned run hot-swaps per-mode
    #    GHA tables on every mode_change (cost charged as realloc waste)
    print(f"\n{'policy':12s} {'variant':8s} {'viol':>7s} {'miss':>7s} "
          f"{'realloc':>8s} {'swaps':>6s}")
    for policy in ("ads_tile", "tp_driven"):
        for replan in (False, True):
            [r] = run(ScenarioSpec(
                scenario=scen, policy=policy, replan=replan, seed=3,
            ))
            print(f"{policy:12s} {'replan' if replan else 'pinned':8s} "
                  f"{r.violation_rate:7.4f} {r.task_miss_rate:7.4f} "
                  f"{r.realloc_frac:8.5f} {r.n_mode_switches:6d}")
            if replan:
                for m, s in sorted(r.mode_stats.items()):
                    print(f"    {m:16s} span={s.span_s:.2f}s "
                          f"viol={s.violation_rate:.4f} "
                          f"p99={s.p99_s*1e3:6.1f} ms "
                          f"realloc={s.realloc_frac:.5f}")

    # 4. fleet view: Markov-sampled drives x policies on a process pool
    rows = sweep(6, policies=("ads_tile", "tp_driven"),
                 duration_s=1.5, seed=7)
    print("\nMonte-Carlo sweep (6 scenarios x 2 policies):")
    for pol, a in aggregate_sweep(rows).items():
        modes = ", ".join(
            f"{m}={st['violation_rate']:.3f}"
            for m, st in a["per_mode"].items()
        )
        print(f"  {pol:12s} viol={a['violation_rate']:.4f} "
              f"realloc={a['realloc_frac']:.4f}  per-mode viol: {modes}")


if __name__ == "__main__":
    main()
