"""Quickstart: compile an ADS workload with GHA and run every scheduler
on Tile-stream.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.benchmark import make_ads_benchmark
from repro.core.experiment import ExperimentSpec, run_experiment
from repro.core.gha import compile_schedule
from repro.core.hardware import simba_chip
from repro.core.latency_model import LatencyModel, chain_tail_composition


def main() -> None:
    # 1. the paper's 14-task L4 benchmark (Fig. 10)
    wf = make_ads_benchmark(cockpit_replicas=1)
    print(f"workflow: {len(wf.tasks)} tasks, {len(wf.chains)} chains, "
          f"T_hp={wf.hyper_period_s*1e3:.0f} ms")

    # 2. probabilistic latency model on a 400-tile Simba-like chip
    hw = simba_chip(400)
    model = LatencyModel.from_workflow(wf, hw, p99_ratio=3.3)
    chain = next(c for c in wf.chains if c.name == "drv_vision")
    tail = chain_tail_composition(
        model, chain.nodes, {n: 32 for n in chain.nodes}, q=0.95
    )
    print(f"tail-composition headroom on {chain.name}: "
          f"{tail['headroom']*100:.1f}% "
          f"(sum-of-quantiles {tail['sum_of_quantiles_s']*1e3:.1f} ms vs "
          f"MC p95 {tail['mc_quantile_s']*1e3:.1f} ms)")

    # 3. the GHA offline compiler (Phases I-III + guillotine binding)
    sched = compile_schedule(model, wf, q=0.95, num_partitions=4)
    print("GHA schedule:")
    for p in sched.partitions:
        tasks = sched.partition_tasks(p.index)
        print(f"  partition {p.index}: cap={p.capacity:3d} tiles "
              f"rect={p.rect} mc={p.memory_controller} tasks={len(tasks)}")

    # 4. run every scheduling paradigm on Tile-stream
    print(f"{'policy':12s} {'effective':>9s} {'realloc':>8s} {'idle':>6s} "
          f"{'miss':>6s} {'viol':>6s} {'n_realloc':>9s}")
    for pol in ("cyc", "cyc_s", "tp_driven", "pglb", "reserv", "ads_tile"):
        r = run_experiment(ExperimentSpec(
            policy=pol, tiles=400, cockpit_replicas=1, duration_s=1.0, seed=1,
        ))
        print(f"{pol:12s} {r.effective_frac:9.3f} {r.realloc_frac:8.4f} "
              f"{r.idle_frac:6.3f} {r.task_miss_rate:6.3f} "
              f"{r.violation_rate:6.3f} {r.n_realloc:9d}")


if __name__ == "__main__":
    main()
