"""End-to-end training driver: train a ~100M-parameter phi4-style model
for a few hundred steps on CPU, with checkpointing and straggler
monitoring.

    PYTHONPATH=src python examples/train_e2e.py --steps 200
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.distribution.elastic import StragglerMonitor
from repro.training import AdamWConfig, TrainConfig, Trainer
from repro.training.data import DataConfig, Prefetcher, synthetic_stream


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    # ~100M params: phi4-mini geometry scaled down
    cfg = dataclasses.replace(
        get_config("phi4_mini_3p8b"),
        num_layers=6, d_model=768, num_heads=12, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32000, dtype="float32",
    )
    n = cfg.param_count()
    print(f"[train_e2e] model: {n/1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model})")

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    trainer = Trainer(cfg, TrainConfig(
        steps=args.steps, log_every=10, checkpoint_every=50,
        checkpoint_dir=ckpt_dir,
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=20),
    ))
    data = Prefetcher(synthetic_stream(
        cfg, DataConfig(batch=args.batch, seq_len=args.seq_len, seed=0)
    ))
    mon = StragglerMonitor()

    def log(rec):
        mon.observe(rec["step"], rec["dt_s"])
        tok_s = args.batch * args.seq_len / rec["dt_s"]
        print(f"[train_e2e] step {rec['step']:4d} loss={rec['loss']:.4f} "
              f"gnorm={rec['grad_norm']:.2f} {tok_s:,.0f} tok/s")

    out = trainer.fit(data, on_log=log)
    data.close()
    hist = out["history"]
    print(f"[train_e2e] loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {out['final_step']} steps; checkpoints in {ckpt_dir}")
    assert hist[-1]["loss"] < hist[0]["loss"], "training must make progress"


if __name__ == "__main__":
    main()
