# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper artifact (DESIGN.md §7):
  fig6  — Cyc./Tp-driven characterization (paper Fig. 6)
  fig11 — ablations: reservation, partitioning, their interplay (Fig. 11)
  fig12 — E2E tail latency + violation rate vs tiles (Fig. 12)
  fig13 — scaling: max chains / min tiles / waste (Fig. 13)
  figS  — driving scenarios: mode switches, replanning, MC sweeps
  table2 — scheduling-decision vs resharding overhead (Table II)
  roofline — §Roofline table from the dry-run artifacts

``--only fig11`` runs a subset; ``--duration`` scales simulated seconds
(default keeps the full harness under ~15 min on this CPU container);
``--jobs N`` runs independent suites in N worker processes (suite
output is buffered per process and printed in order).
"""
from __future__ import annotations

import argparse
import contextlib
import io
import sys
import time

from . import fig6_casestudy, fig11_ablation, fig12_e2e, fig13_scaling
from . import figS_scenarios, headroom, roofline, table2_overhead

SUITES = {
    "fig6": fig6_casestudy.run,
    "fig11": fig11_ablation.run,
    "fig12": fig12_e2e.run,
    "fig13": fig13_scaling.run,
    "figS": figS_scenarios.run,
    "table2": table2_overhead.run,
    "headroom": headroom.run,
    "roofline": roofline.run,
}


def _suite_worker(args: tuple) -> str:
    """Run one suite with stdout captured (process-pool entry point)."""
    name, duration, seed = args
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        SUITES[name](duration=duration, seed=seed)
    return buf.getvalue()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    ap.add_argument("--duration", type=float, default=1.0,
                    help="simulated seconds per experiment")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--jobs", type=int, default=1,
                    help="run independent suites in N worker processes")
    args = ap.parse_args()

    names = args.only.split(",") if args.only else list(SUITES)
    unknown = [n for n in names if n not in SUITES]
    if unknown:
        ap.error(f"unknown suite(s) {unknown} (choose from {list(SUITES)})")
    print("name,us_per_call,derived")
    if args.jobs > 1 and len(names) > 1:
        from repro.scenarios.runner import parallel_map

        t0 = time.time()
        outs = parallel_map(
            _suite_worker,
            [(n, args.duration, args.seed) for n in names],
            jobs=args.jobs,
        )
        for name, out in zip(names, outs):
            sys.stdout.write(out)
            print(f"# {name} done", file=sys.stderr)
        print(f"# all suites done in {time.time()-t0:.1f}s", file=sys.stderr)
        return
    for name in names:
        t0 = time.time()
        SUITES[name](duration=args.duration, seed=args.seed)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
