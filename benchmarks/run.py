# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper artifact (DESIGN.md §7):
  fig6  — Cyc./Tp-driven characterization (paper Fig. 6)
  fig11 — ablations: reservation, partitioning, their interplay (Fig. 11)
  fig12 — E2E tail latency + violation rate vs tiles (Fig. 12)
  fig13 — scaling: max chains / min tiles / waste (Fig. 13)
  figS  — driving scenarios: mode switches, replanning, MC sweeps
  table2 — scheduling-decision vs resharding overhead (Table II)
  roofline — §Roofline table from the dry-run artifacts

``--only fig11`` runs a subset; ``--duration`` scales simulated seconds
(default keeps the full harness under ~15 min on this CPU container);
``--jobs N`` runs independent suites in N worker processes (suite
output is buffered per process and printed in order).
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import sys
import time
from pathlib import Path

from repro.obs import metrics

from . import fig6_casestudy, fig11_ablation, fig12_e2e, fig13_scaling
from . import figS_budget, figS_degrade, figS_predict, figS_rates
from . import figS_scenarios, headroom, perf_bench, roofline, table2_overhead

SUITES = {
    "fig6": fig6_casestudy.run,
    "fig11": fig11_ablation.run,
    "fig12": fig12_e2e.run,
    "fig13": fig13_scaling.run,
    "figS": figS_scenarios.run,
    "figS_rates": figS_rates.run,
    "figS_predict": figS_predict.run,
    "figS_budget": figS_budget.run,
    "figS_degrade": figS_degrade.run,
    "perf": perf_bench.run,
    "table2": table2_overhead.run,
    "headroom": headroom.run,
    "roofline": roofline.run,
}

#: CLI conveniences: the scenario suites also answer to their module names
ALIASES = {"figS_scenarios": "figS", "rates": "figS_rates",
           "predict": "figS_predict", "budget": "figS_budget",
           "degrade": "figS_degrade", "perf_bench": "perf"}


def _rows_from_csv(text: str) -> list:
    """Parse ``emit`` output back into structured rows (for --out)."""
    rows = []
    for line in text.splitlines():
        parts = line.split(",", 2)
        if len(parts) < 2 or parts[0] == "name":
            continue
        try:
            value = float(parts[1])
        except ValueError:
            continue
        rows.append({
            "name": parts[0],
            "us_per_call": value,
            "derived": parts[2] if len(parts) > 2 else "",
        })
    return rows


def _suite_worker(args: tuple) -> str:
    """Run one suite with stdout captured (process-pool entry point)."""
    name, duration, seed = args
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        SUITES[name](duration=duration, seed=seed)
    return buf.getvalue()


def _export_trace(path_str: str, duration: float, seed: int) -> None:
    """Record one rate_churn run and export a Perfetto/Chrome trace."""
    from repro.obs import TraceRecorder, export_chrome_trace
    from repro.scenarios import ScenarioSpec, get_scenario, run

    rec = TraceRecorder()
    spec = ScenarioSpec(
        scenario=get_scenario("rate_churn"), policy="ads_tile", seed=seed,
        duration_s=max(duration, 1.0),
    )
    [report] = run(spec, recorders={0: rec})
    path = Path(path_str)
    path.parent.mkdir(parents=True, exist_ok=True)
    export_chrome_trace(rec, str(path))
    att = report.attribution or {}
    print(f"# wrote {path} ({len(rec)} events, "
          f"{att.get('n_late', 0)} late chains)", file=sys.stderr)


def _run_campaign_cli(args) -> list:
    """Run (or resume) a sweep campaign from ``--campaign`` and emit
    its aggregate as CSV rows; returns the emitted text's rows.

    ``--campaign`` takes either a campaign-spec JSON or a manifest JSON
    written by a previous (possibly interrupted) invocation — resuming
    is just pointing the flag at the manifest (or rerunning the same
    spec against the same cache): cells with cached rows are not
    re-executed.  This is the entry the weekly extended-sweep CI job
    drives.
    """
    from repro.sweeps.executor import SubprocessShardExecutor
    from repro.sweeps.service import SweepFailure, run_campaign

    executor = None
    if args.campaign_shards and args.campaign_shards > 1:
        executor = SubprocessShardExecutor(
            num_shards=args.campaign_shards,
            jobs_per_shard=max(1, args.jobs),
        )
    try:
        result = run_campaign(
            args.campaign,
            cache_dir=args.campaign_cache,
            manifest_path=args.campaign_manifest,
            executor=executor,
            jobs=args.jobs if args.jobs > 1 else None,
        )
    except SweepFailure as exc:
        result = exc.result
        print(f"# campaign: {exc}", file=sys.stderr)
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        from .common import emit_sweep_aggregate

        emit_sweep_aggregate(result.aggregate, "campaign")
        print(
            f"campaign_cells,{float(result.n_cells):.3f},"
            f"executed={result.n_executed};cached={result.n_cached};"
            f"failed={result.n_failed}"
        )
    out = buf.getvalue()
    sys.stdout.write(out)
    print(
        f"# campaign {result.campaign.name!r}: {result.n_cells} cells "
        f"({result.n_cached} cached, {result.n_executed} executed, "
        f"{result.n_failed} failed)",
        file=sys.stderr,
    )
    return _rows_from_csv(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names ('none' runs no suite "
                         "— useful with --trace-out)")
    ap.add_argument("--duration", type=float, default=1.0,
                    help="simulated seconds per experiment")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--jobs", type=int, default=1,
                    help="run independent suites in N worker processes")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="also write the rows as structured JSON "
                         "(consumed by benchmarks.make_tables)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="record one rate_churn run with the flight "
                         "recorder and write a Perfetto/Chrome trace JSON")
    ap.add_argument("--campaign", default=None, metavar="FILE",
                    help="run/resume a sweep campaign: a campaign-spec "
                         "JSON or a manifest JSON from an earlier "
                         "(interrupted) run (see docs/sweeps.md); "
                         "combine with '--only none' to run it alone")
    ap.add_argument("--campaign-cache", default=".sweep-cache",
                    metavar="DIR",
                    help="content-addressed result cache for --campaign "
                         "(cells with cached rows are not re-executed)")
    ap.add_argument("--campaign-manifest", default=None, metavar="FILE",
                    help="write the resumable campaign manifest here "
                         "(default: <campaign-cache>/manifest.json)")
    ap.add_argument("--campaign-shards", type=int, default=0, metavar="N",
                    help="fan the campaign out over N worker "
                         "subprocesses via the manifest instead of the "
                         "in-process pool")
    args = ap.parse_args()
    if args.campaign and args.campaign_manifest is None:
        args.campaign_manifest = str(
            Path(args.campaign_cache) / "manifest.json"
        )

    if args.only == "none":
        names = []
    else:
        names = args.only.split(",") if args.only else list(SUITES)
    names = [ALIASES.get(n, n) for n in names]
    unknown = [n for n in names if n not in SUITES]
    if unknown:
        ap.error(f"unknown suite(s) {unknown} (choose from {list(SUITES)})")
    if args.out or args.trace_out:
        # self-profiling: compile/sample/engine phase timers land in the
        # JSON "profile" section (parent process only — worker processes
        # profile themselves and are not aggregated here)
        metrics.enable()
    print("name,us_per_call,derived")
    outputs = []
    if args.jobs > 1 and len(names) > 1:
        from repro.scenarios.runner import parallel_map

        t0 = time.time()
        outputs = parallel_map(
            _suite_worker,
            [(n, args.duration, args.seed) for n in names],
            jobs=args.jobs,
        )
        for name, out in zip(names, outputs):
            sys.stdout.write(out)
            print(f"# {name} done", file=sys.stderr)
        print(f"# all suites done in {time.time()-t0:.1f}s", file=sys.stderr)
    else:
        for name in names:
            t0 = time.time()
            if args.out:
                out = _suite_worker((name, args.duration, args.seed))
                sys.stdout.write(out)
                outputs.append(out)
            else:
                SUITES[name](duration=args.duration, seed=args.seed)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)

    campaign_rows = []
    if args.campaign:
        campaign_rows = _run_campaign_cli(args)

    if args.trace_out:
        _export_trace(args.trace_out, args.duration, args.seed)

    if args.out:
        path = Path(args.out)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps({
            "suites": names,
            "duration": args.duration,
            "seed": args.seed,
            "rows": _rows_from_csv("".join(outputs)) + campaign_rows,
            "profile": metrics.snapshot(),
        }, indent=2))
        print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
