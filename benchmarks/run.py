# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper artifact (DESIGN.md §7):
  fig6  — Cyc./Tp-driven characterization (paper Fig. 6)
  fig11 — ablations: reservation, partitioning, their interplay (Fig. 11)
  fig12 — E2E tail latency + violation rate vs tiles (Fig. 12)
  fig13 — scaling: max chains / min tiles / waste (Fig. 13)
  table2 — scheduling-decision vs resharding overhead (Table II)
  roofline — §Roofline table from the dry-run artifacts

``--only fig11`` runs a subset; ``--duration`` scales simulated seconds
(default keeps the full harness under ~15 min on this CPU container).
"""
from __future__ import annotations

import argparse
import sys
import time

from . import fig6_casestudy, fig11_ablation, fig12_e2e, fig13_scaling
from . import headroom, roofline, table2_overhead

SUITES = {
    "fig6": fig6_casestudy.run,
    "fig11": fig11_ablation.run,
    "fig12": fig12_e2e.run,
    "fig13": fig13_scaling.run,
    "table2": table2_overhead.run,
    "headroom": headroom.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    ap.add_argument("--duration", type=float, default=1.0,
                    help="simulated seconds per experiment")
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()

    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        SUITES[name](duration=args.duration, seed=args.seed)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
