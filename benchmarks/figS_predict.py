"""Fig. S-predict — predictive replanning: pre-stage vs react at seams.

Context switches in an ADS are predictable seconds ahead (route
structure, fleet dwell statistics), while a *reactive* runtime can only
detect a shift after a confirmation window — and then pays the whole
weight/feature migration exactly when the new mode's load arrives.
This suite compares three replanning strategies on identical drives
(same seeds, one shared trace per scenario, so every comparison is
paired at the job level):

* ``reactive``   — hot-swap after a ``detection_delay_s`` confirmation
  window past each seam (the honest version of PR-1's oracle swap);
* ``predictive`` — forecast-driven: background-stage the target table's
  weight deltas ahead of the seam, then drain-aware activation (no
  detection delay — the forecast turns detection into confirmation);
* ``blend``      — hedge-only ablation: every staged transition installs
  the slack-blended table, deferring the capacity move to the seam.

Two parts:

1. ``rate_churn`` (night 15 Hz -> urban 30 Hz -> rush-hour 60 Hz
   cameras) over several paired seeds — the hyper-period-changing
   seams where staging matters most.
2. A Markov sweep of random drives (route-informed forecasts over each
   sampled drive: the navigation stack knows its own plan).

Headline metrics per strategy: *post-seam* deadline misses (violations
attributed to every mode after the drive's opening one), reallocation
waste (stall tile-seconds as a capacity fraction), and tiles usefully
busy (``effective_frac``).  ``--duration`` scales the number of seeds /
sampled drives, not the per-drive length.
"""
from __future__ import annotations

import dataclasses

from repro.scenarios import (
    ScenarioSpec,
    default_generator,
    get_mode,
    get_scenario,
)
from repro.scenarios.runner import (
    _run_group,
    build_trace,
    compile_portfolio,
    parallel_map,
    run as run_specs,
)

from .common import emit

REPLAN_MODES = ("reactive", "predictive", "blend")

#: context-shift confirmation window of the reactive baseline (a few
#: 30 Hz frames of observed statistics; predictive pays it only on
#: wrong forecasts)
DETECTION_S = 0.08


def _post_seam(report, initial_mode):
    """(violations, completions) attributed to non-opening modes."""
    post = [s for m, s in report.mode_stats.items() if m != initial_mode]
    return (
        sum(s.n_violations for s in post),
        sum(s.n_completed for s in post),
    )


def _emit_strategy(tag: str, agg) -> None:
    v, c, realloc, eff, n_realloc, n_runs, hits, misses = agg
    rate = v / max(c, 1)
    emit(
        tag,
        rate * 1e6,
        f"post_viol={v};post_n={c};post_rate={rate:.4f};"
        f"realloc={realloc / n_runs:.5f};eff={eff / n_runs:.4f};"
        f"n_realloc={n_realloc};fc_hits={hits};fc_misses={misses}",
    )


def run(duration: float = 1.0, seed: int = 1) -> None:
    # -- part 1: rate_churn, paired seeds -------------------------------
    scen = get_scenario("rate_churn")
    n_seeds = max(2, int(round(3 * duration)))
    base = ScenarioSpec(scenario=scen, policy="ads_tile", seed=seed,
                        detection_delay_s=DETECTION_S)
    pf = compile_portfolio(base)
    agg = {m: [0, 0, 0.0, 0.0, 0, 0, 0, 0] for m in REPLAN_MODES}
    for s in range(seed, seed + n_seeds):
        spec = dataclasses.replace(base, seed=s, portfolio=pf)
        trace = build_trace(spec)
        for mode in REPLAN_MODES:
            [r] = run_specs(
                dataclasses.replace(spec, replan_mode=mode), trace=trace
            )
            v, c = _post_seam(r, scen.segments[0].mode)
            a = agg[mode]
            a[0] += v
            a[1] += c
            a[2] += r.realloc_frac
            a[3] += r.effective_frac
            a[4] += r.n_realloc
            a[5] += 1
            if r.forecast is not None:
                a[6] += r.forecast.n_hits
                a[7] += r.forecast.n_misses
    for mode in REPLAN_MODES:
        _emit_strategy(f"figS_predict_churn_{mode}", agg[mode])
    ra, pr = agg["reactive"], agg["predictive"]
    emit(
        "figS_predict_churn_headline",
        (ra[2] / max(pr[2], 1e-12)) * 1e6,
        f"miss_delta={ra[0] - pr[0]};"
        f"waste_ratio={ra[2] / max(pr[2], 1e-12):.2f};"
        f"seeds={n_seeds}",
    )

    # -- part 2: Markov drives, route-informed forecasts ----------------
    gen = default_generator()
    all_modes = sorted(gen.transitions)
    mode_defs = {m: get_mode(m) for m in all_modes}
    pf_mc = None
    n = max(4, int(round(12 * duration)))
    groups = []
    for i in range(n):
        s_i = seed * 100003 + i
        script = gen.sample(2.0, seed=s_i)
        spec = ScenarioSpec(
            scenario=script, policy="ads_tile", seed=s_i,
            detection_delay_s=DETECTION_S, mode_defs=mode_defs,
        )
        if pf_mc is None:
            pf_mc = compile_portfolio(spec, all_modes)
        groups.append([
            dataclasses.replace(spec, replan_mode=m, portfolio=pf_mc)
            for m in REPLAN_MODES
        ])
    rows = [r for rs in parallel_map(_run_group, groups) for r in rs]
    agg = {m: [0, 0, 0.0, 0.0, 0, 0, 0, 0] for m in REPLAN_MODES}
    for row in rows:
        init = row["script"].split(":")[0]
        a = agg[str(row["replan_mode"])]
        for m, st in row["per_mode"].items():
            if m != init:
                a[0] += st["n_violations"]
                a[1] += st["n_completed"]
        a[2] += row["realloc_frac"]
        a[3] += row["effective_frac"]
        a[4] += row["n_realloc"]
        a[5] += 1
        fc = row["forecast"]
        if fc is not None:
            a[6] += fc["n_hits"]
            a[7] += fc["n_misses"]
    for mode in REPLAN_MODES:
        _emit_strategy(f"figS_predict_markov_{mode}", agg[mode])
    ra, pr = agg["reactive"], agg["predictive"]
    emit(
        "figS_predict_markov_headline",
        (ra[2] / max(pr[2], 1e-12)) * 1e6,
        f"miss_delta={ra[0] - pr[0]};"
        f"waste_ratio={ra[2] / max(pr[2], 1e-12):.2f};"
        f"n={n}",
    )
