"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
the dry-run artifacts, plus the §Scenarios table from any saved
scenario/rate-sweep runs:  PYTHONPATH=src python -m benchmarks.make_tables

Scenario inputs are the JSON files written by
``python -m benchmarks.run
--only figS_scenarios,figS_rates,figS_predict,figS_budget
--out benchmarks/results/scenarios/<name>.json`` (CI uploads one per
run as a workflow artifact — including the weekly extended sweep; drop
downloaded artifacts into that directory to render them alongside the
paper tables).
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"
SCENARIOS = Path(__file__).resolve().parent / "results" / "scenarios"


def fmt_bytes(n):
    if n is None:
        return "-"
    return f"{n/1e9:.2f}"


def _derived_map(derived: str) -> dict:
    """``k1=v1;k2=v2`` -> dict (the emit() convention for figS rows)."""
    out = {}
    for part in derived.split(";"):
        k, sep, v = part.partition("=")
        if sep:
            out[k] = v
    return out


def scenario_tables() -> None:
    files = sorted(SCENARIOS.glob("*.json"))
    if not files:
        return
    print("\n### §Scenarios (figS_* suites: mode switches, replanning, "
          "sensor-rate churn)\n")
    print("| run | suite row | viol | miss | realloc | switches | per-mode viol |")
    print("|---|---|---|---|---|---|---|")
    for p in files:
        d = json.loads(p.read_text())
        for row in d.get("rows", []):
            name = row.get("name", "")
            if not name.startswith("figS"):
                continue
            kv = _derived_map(row.get("derived", ""))
            per_mode = " ".join(
                f"{k[:-5]}={v}" for k, v in sorted(kv.items())
                if k.endswith("_viol")
            )
            print(
                f"| {p.stem} | {name} "
                f"| {kv.get('viol', '-')} | {kv.get('miss', '-')} "
                f"| {kv.get('realloc', '-')} | {kv.get('switches', '-')} "
                f"| {per_mode or '-'} |"
            )


def main() -> None:
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        rows.append(json.loads(p.read_text()))

    print("### §Dry-run (per-device memory, from compiled.memory_analysis())\n")
    print("| arch | shape | mesh | status | args GB | temp GB | out GB |")
    print("|---|---|---|---|---|---|---|")
    for d in rows:
        m = d.get("memory", {})
        print(
            f"| {d['arch']} | {d['shape']} | {d.get('mesh','-')} "
            f"| {d['status']} "
            f"| {fmt_bytes(m.get('argument_bytes_per_device'))} "
            f"| {fmt_bytes(m.get('temp_bytes_per_device'))} "
            f"| {fmt_bytes(m.get('output_bytes_per_device'))} |"
        )

    print("\n### §Roofline (three terms per cell; v5e constants)\n")
    print("| arch | shape | mesh | compute ms | memory ms | collective ms "
          "| dominant | useful/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for d in rows:
        if d.get("status") != "OK":
            print(f"| {d['arch']} | {d['shape']} | {d.get('mesh','-')} "
                  f"| {d['status']} | | | | | |")
            continue
        r = d["roofline"]
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} "
            f"| {r['collective_s']*1e3:.2f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.4f} |"
        )

    scenario_tables()


if __name__ == "__main__":
    main()
