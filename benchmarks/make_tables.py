"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
the dry-run artifacts:  PYTHONPATH=src python -m benchmarks.make_tables
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def fmt_bytes(n):
    if n is None:
        return "-"
    return f"{n/1e9:.2f}"


def main() -> None:
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        rows.append(json.loads(p.read_text()))

    print("### §Dry-run (per-device memory, from compiled.memory_analysis())\n")
    print("| arch | shape | mesh | status | args GB | temp GB | out GB |")
    print("|---|---|---|---|---|---|---|")
    for d in rows:
        m = d.get("memory", {})
        print(
            f"| {d['arch']} | {d['shape']} | {d.get('mesh','-')} "
            f"| {d['status']} "
            f"| {fmt_bytes(m.get('argument_bytes_per_device'))} "
            f"| {fmt_bytes(m.get('temp_bytes_per_device'))} "
            f"| {fmt_bytes(m.get('output_bytes_per_device'))} |"
        )

    print("\n### §Roofline (three terms per cell; v5e constants)\n")
    print("| arch | shape | mesh | compute ms | memory ms | collective ms "
          "| dominant | useful/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for d in rows:
        if d.get("status") != "OK":
            print(f"| {d['arch']} | {d['shape']} | {d.get('mesh','-')} "
                  f"| {d['status']} | | | | | |")
            continue
        r = d["roofline"]
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} "
            f"| {r['collective_s']*1e3:.2f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.4f} |"
        )


if __name__ == "__main__":
    main()
