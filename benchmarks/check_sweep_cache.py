"""CI smoke gate: the content-addressed sweep cache must actually hit.

Usage::

    python -m benchmarks.check_sweep_cache [--cache-dir DIR]

Runs a small pinned campaign (2 Markov-sampled scenarios x 2 policies)
twice against the same cache directory and asserts the redesigned sweep
service's headline contract (docs/sweeps.md):

* the first run executes every cell and caches every row;
* the second, byte-identical campaign executes **zero** cells — all
  rows come back from the content-addressed cache;
* the two runs' row lists compare equal (dict equality, not digests:
  cached rows round-trip through JSON, and JSON float round-trips are
  exact);
* a third run resumed from the first run's manifest also executes
  zero cells and reproduces the same rows.

Cheap enough for the tier-1 PR path (one 2x2 cell grid at 0.5
simulated seconds).  Exit 1 on any violated invariant, 0 otherwise.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
from pathlib import Path

from repro.sweeps import CampaignSpec, run_campaign


def _campaign() -> CampaignSpec:
    return CampaignSpec(
        name="cache-smoke",
        n_scenarios=2,
        policies=("ads_tile", "tp_driven"),
        scenario_duration_s=0.5,
        seed=11,
    )


def check(cache_dir: str) -> int:
    manifest = str(Path(cache_dir) / "manifest.json")
    first = run_campaign(
        _campaign(), cache_dir=cache_dir, manifest_path=manifest
    )
    print(
        f"first run : {first.n_cells} cells, "
        f"{first.n_executed} executed, {first.n_cached} cached"
    )
    second = run_campaign(
        _campaign(), cache_dir=cache_dir, manifest_path=manifest
    )
    print(
        f"second run: {second.n_cells} cells, "
        f"{second.n_executed} executed, {second.n_cached} cached"
    )
    resumed = run_campaign(manifest)
    print(
        f"resumed   : {resumed.n_cells} cells, "
        f"{resumed.n_executed} executed, {resumed.n_cached} cached"
    )

    failures = []
    if first.n_failed or second.n_failed or resumed.n_failed:
        failures.append("campaign reported failed cells")
    if second.n_executed != 0:
        failures.append(
            f"repeat run executed {second.n_executed} cells (want 0): "
            "cell keys are unstable or the cache missed"
        )
    if second.n_cached != second.n_cells:
        failures.append(
            f"repeat run cached {second.n_cached}/{second.n_cells} cells"
        )
    if resumed.n_executed != 0:
        failures.append(
            f"manifest resume executed {resumed.n_executed} cells (want 0)"
        )
    if second.rows != first.rows:
        failures.append("cached rows differ from freshly executed rows")
    if resumed.rows != first.rows:
        failures.append("manifest-resumed rows differ from the first run")

    if failures:
        for f in failures:
            print(f"sweep-cache gate failed: {f}", file=sys.stderr)
        return 1
    print(
        f"sweep-cache gate OK: repeat of {first.n_cells} cells was "
        "100% cache-hit, rows identical"
    )
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--cache-dir",
        default=None,
        metavar="DIR",
        help="cache directory to exercise (default: a fresh temp dir, "
        "so the first run is guaranteed cold)",
    )
    args = ap.parse_args(argv)
    if args.cache_dir:
        return check(args.cache_dir)
    with tempfile.TemporaryDirectory(prefix="sweep-cache-gate-") as tmp:
        return check(tmp)


if __name__ == "__main__":
    sys.exit(main())
