"""Perf — simulator performance benchmark (jobs/s + sweep wall-clock).

Tracks the engine's speed headline over time so perf regressions are
visible in CI artifacts (``BENCH_sim.json`` via ``benchmarks.run
--out``).  Three measurements:

1. **Trace/job construction** — Simulator builds per second on a
   standard heavy workload (cockpit_replicas=4, 2 s horizon), both the
   single-build pattern and the paired-sweep pattern (one sampled
   trace shared across two policies, the steady state of ``sweep()``).
2. **Sampling kernel** — throughput of the batched counter-based trace
   sampler on the standard skeleton (jobs sampled per second; the
   legacy scalar ``RandomState`` reference it was once compared
   against is gone — the counter-based stream contract is the only
   sampling path).
3. **End-to-end sweep** — wall-clock for a pinned Monte-Carlo sweep
   (fixed 6-mode Markov generator, so the workload stays comparable as
   bundled defaults evolve), the figS_scenarios fleet view.
4. **Batched lockstep engine** — B-seed Monte-Carlo batch of one
   pinned Markov scenario through ``run(spec, seeds=...)`` (lockstep
   backend) vs the same seeds through a warm scalar loop
   (``perf_batch_*``; bit-identity between the two paths is asserted
   separately by ``benchmarks.check_equivalence``).
5. **SoA jax backend** — the same pinned scenario through
   ``run(spec, seeds=..., backend="soa")`` at R=8 and R=64
   (``perf_soa_*_r{8,64}``),
   steady-state per-run wall-clock with the jit compile reported
   separately (``check_equivalence --mode distributional`` asserts
   the statistical-equivalence side).

``PREPR_*`` constants are the pre-PR numbers measured on the reference
dev container when this benchmark was introduced (engine @ b7c00aa:
scalar per-job sampling, no skeleton cache); ``speedup_vs_prepr`` is
only meaningful on comparable hardware and is recorded for the PR's
acceptance trail, not as a portable metric.
"""
from __future__ import annotations

import dataclasses
import gc
import time

from repro.core.experiment import ExperimentSpec, build_stack, make_policy
from repro.core.sim import SimConfig, Simulator
from repro.core.sim.trace import build_skeleton, sample_trace
from repro.scenarios import sweep
from repro.scenarios.runner import ScenarioSpec, run as run_specs
from repro.scenarios.script import MarkovScenarioGenerator

from .common import emit

#: pre-PR reference numbers (dev container, engine @ b7c00aa)
PREPR_BUILD_JOBS_PER_S = 60_882.0
PREPR_SWEEP_8X2_S = 3.430

#: pinned 6-mode generator: the e2e workload must not drift when the
#: bundled DEFAULT_TRANSITIONS change
PERF_TRANSITIONS = {
    "urban": {
        "highway": 0.30,
        "parking": 0.13,
        "adverse_weather": 0.14,
        "night": 0.09,
        "rush_hour": 0.12,
        "urban": 0.22,
    },
    "highway": {
        "urban": 0.40,
        "adverse_weather": 0.15,
        "night": 0.10,
        "rush_hour": 0.05,
        "highway": 0.30,
    },
    "parking": {"urban": 0.90, "parking": 0.10},
    "adverse_weather": {"urban": 0.50, "highway": 0.30, "adverse_weather": 0.20},
    "night": {"urban": 0.40, "highway": 0.40, "night": 0.20},
    "rush_hour": {"urban": 0.55, "highway": 0.20, "rush_hour": 0.25},
}
PERF_DWELL = {
    "urban": 0.8,
    "highway": 1.0,
    "parking": 0.5,
    "adverse_weather": 0.7,
    "night": 0.9,
    "rush_hour": 0.6,
}


def _build_benchmark(duration: float, seed: int) -> None:
    spec = ExperimentSpec(
        policy="ads_tile", tiles=400, cockpit_replicas=4, duration_s=2.0, seed=seed
    )
    wf, _hw, model, compiler = build_stack(spec)
    sched = compiler.compile(model, wf)
    pol_a, pol_b = make_policy("ads_tile"), make_policy("tp_driven")
    reps = max(3, int(round(20 * duration)))

    # warm the skeleton/unroll caches (steady state of any sweep)
    Simulator(wf, model, sched, pol_a, SimConfig(duration_s=2.0, seed=0))

    t0 = time.perf_counter()
    n = 0
    for i in range(reps):
        cfg = SimConfig(duration_s=2.0, seed=seed + i)
        n += len(Simulator(wf, model, sched, pol_a, cfg).jobs)
    dt = time.perf_counter() - t0
    jps = n / dt
    emit(
        "perf_build_single",
        dt / reps * 1e6,
        f"jobs_per_s={jps:.0f};"
        f"prepr_ref={PREPR_BUILD_JOBS_PER_S:.0f};"
        f"speedup_vs_prepr={jps / PREPR_BUILD_JOBS_PER_S:.2f}",
    )

    # paired-sweep pattern: one trace, two policies
    t0 = time.perf_counter()
    n = 0
    for i in range(reps):
        skel = build_skeleton(wf, None, 2.0)
        tr = sample_trace(skel, model, None, seed + i)
        for pol in (pol_a, pol_b):
            cfg = SimConfig(duration_s=2.0, seed=seed + i, trace=tr)
            n += len(Simulator(wf, model, sched, pol, cfg).jobs)
    dt = time.perf_counter() - t0
    jps = n / dt
    emit(
        "perf_build_paired",
        dt / (2 * reps) * 1e6,
        f"jobs_per_s={jps:.0f};"
        f"speedup_vs_prepr={jps / PREPR_BUILD_JOBS_PER_S:.2f}",
    )

    # sampling kernel: batched counter-based draws, same skeleton
    skel = build_skeleton(wf, None, 2.0)
    t0 = time.perf_counter()
    for i in range(reps):
        sample_trace(skel, model, None, seed + i)
    dt_batched = time.perf_counter() - t0
    emit(
        "perf_sample_batched",
        dt_batched / reps * 1e6,
        f"jobs_per_s={skel.n * reps / dt_batched:.0f}",
    )


def _recorder_benchmark(duration: float, seed: int) -> None:
    """Flight-recorder cost on a pinned engine run: hooks compiled in
    but recorder detached (``perf_recorder_off``, the default every
    sweep pays) vs a :class:`~repro.obs.TraceRecorder` attached
    (``perf_recorder_on``).  The *off* row is the one the perf gate
    asserts on — the hooks' ``if rec is not None`` guards must stay
    invisible in the wall-clock."""
    from repro.obs import TraceRecorder

    spec = ExperimentSpec(
        policy="ads_tile", tiles=400, cockpit_replicas=4, duration_s=2.0, seed=seed
    )
    wf, _hw, model, compiler = build_stack(spec)
    sched = compiler.compile(model, wf)
    reps = max(3, int(round(10 * duration)))

    def loop(make_rec) -> float:
        t0 = time.perf_counter()
        for i in range(reps):
            pol = make_policy("ads_tile")
            cfg = SimConfig(duration_s=2.0, seed=seed + i, recorder=make_rec())
            Simulator(wf, model, sched, pol, cfg).run()
        return time.perf_counter() - t0

    loop(lambda: None)  # warm caches
    dt_off = loop(lambda: None)
    dt_on = loop(TraceRecorder)
    emit("perf_recorder_off", dt_off / reps * 1e6, f"seconds={dt_off:.3f}")
    emit(
        "perf_recorder_on",
        dt_on / reps * 1e6,
        f"overhead_pct={100.0 * (dt_on - dt_off) / dt_off:.1f}",
    )


def _sweep_benchmark(duration: float, seed: int) -> None:
    gen = MarkovScenarioGenerator(transitions=PERF_TRANSITIONS, mean_dwell_s=PERF_DWELL)
    n = max(2, int(round(8 * duration)))
    gc.collect()
    t0 = time.perf_counter()
    rows = sweep(
        n,
        policies=("ads_tile", "tp_driven"),
        duration_s=2.0,
        seed=seed,
        jobs=1,
        generator=gen,
    )
    dt = time.perf_counter() - t0
    derived = f"runs={len(rows)};seconds={dt:.3f}"
    if n == 8:
        # directly comparable to the recorded pre-PR wall-clock
        derived += (
            f";prepr_ref_s={PREPR_SWEEP_8X2_S:.3f}"
            f";speedup_vs_prepr={PREPR_SWEEP_8X2_S / dt:.2f}"
        )
    emit("perf_sweep_e2e", dt / max(len(rows), 1) * 1e6, derived)


#: lockstep per-run wall-clock measured by ``_batch_benchmark`` this
#: process, keyed by policy — lets ``_soa_benchmark`` derive a
#: same-machine, same-run speedup without re-measuring the baseline
_BATCH_US_PER_RUN: dict = {}


def _batch_benchmark(duration: float, seed: int) -> None:
    """Batched lockstep engine vs a warm scalar loop: one pinned Markov
    scenario (same 6-mode generator as ``perf_sweep_e2e``), B seeds per
    policy, both paths starting from warm skeleton/stack caches.  The
    ``us_per_call`` is the batched per-run wall-clock (the number the
    perf gate regression-checks); ``speedup_vs_scalar`` records how far
    the fused lanes beat the scalar loop on the *same* machine and run,
    so it is portable in a way ``speedup_vs_prepr`` is not.  The
    speedup is bounded well below the lane count by the bit-identity
    contract — every lane must replay the scalar engine's exact event
    stream — see docs/performance.md#batched-monte-carlo-engine for
    the ceiling analysis."""
    gen = MarkovScenarioGenerator(transitions=PERF_TRANSITIONS, mean_dwell_s=PERF_DWELL)
    scen = gen.sample(2.0, seed)
    b = max(2, int(round(8 * duration)))
    seeds = list(range(seed, seed + b))
    for pol, name in (("ads_tile", "perf_batch_ads"), ("tp_driven", "perf_batch_tp")):
        spec = ScenarioSpec(scenario=scen, policy=pol)
        # warm both paths (skeleton, stack, schedule caches)
        run_specs(spec, seeds=seeds[:2])
        run_specs(dataclasses.replace(spec, seed=seeds[0]))
        gc.collect()
        t0 = time.perf_counter()
        for s in seeds:
            run_specs(dataclasses.replace(spec, seed=s))
        dt_scalar = time.perf_counter() - t0
        t0 = time.perf_counter()
        run_specs(spec, seeds=seeds)
        dt_batch = time.perf_counter() - t0
        _BATCH_US_PER_RUN[pol] = dt_batch / b * 1e6
        emit(
            name,
            dt_batch / b * 1e6,
            f"batch={b};speedup_vs_scalar={dt_scalar / dt_batch:.2f};"
            f"scalar_s={dt_scalar:.3f};batch_s={dt_batch:.3f}",
        )


def _soa_benchmark(duration: float, seed: int) -> None:
    """Structure-of-arrays jax backend on the same pinned Markov
    scenario: R-seed cells at R=8 and R=64 through
    ``run(spec, seeds=..., backend="soa")``.  Each cell is measured
    twice — the first call
    pays the jit compile for that (policy, R) shape, the second is the
    steady state — and ``us_per_call`` reports the *steady* per-run
    wall-clock (the regression-gated number) with the compile cost in
    the derived fields, per the warm-up-excluded convention.
    ``speedup_vs_lockstep`` compares against ``_batch_benchmark``'s
    same-process lockstep per-run time; see
    docs/performance.md#soa-backend for why the single-core envelope
    of this ratio is modest (the round kernel's op-dispatch cost does
    not amortize with R on one core) and where the backend does win.
    Skips (emitting nothing) when jax is unavailable."""
    from repro.core.sim.soa import soa_available

    if not soa_available():
        print("perf_soa_*: jax unavailable, skipping SoA rows")
        return
    gen = MarkovScenarioGenerator(transitions=PERF_TRANSITIONS, mean_dwell_s=PERF_DWELL)
    scen = gen.sample(2.0, seed)
    for pol, name in (("ads_tile", "perf_soa_ads"), ("tp_driven", "perf_soa_tp")):
        spec = ScenarioSpec(scenario=scen, policy=pol)
        for runs in (8, 64):
            seeds = list(range(seed, seed + runs))
            gc.collect()
            t0 = time.perf_counter()
            run_specs(spec, seeds=seeds, backend="soa", fallback=False)
            dt_cold = time.perf_counter() - t0
            t0 = time.perf_counter()
            run_specs(spec, seeds=seeds, backend="soa", fallback=False)
            dt_warm = time.perf_counter() - t0
            derived = (
                f"runs={runs};compile_s={max(dt_cold - dt_warm, 0.0):.3f};"
                f"cold_s={dt_cold:.3f};warm_s={dt_warm:.3f}"
            )
            lockstep_us = _BATCH_US_PER_RUN.get(pol)
            if lockstep_us:
                derived += (
                    f";speedup_vs_lockstep="
                    f"{lockstep_us / (dt_warm / runs * 1e6):.2f}"
                )
            emit(f"{name}_r{runs}", dt_warm / runs * 1e6, derived)


def run(duration: float = 1.0, seed: int = 1) -> None:
    _build_benchmark(duration, seed)
    _recorder_benchmark(duration, seed)
    _sweep_benchmark(duration, seed)
    _batch_benchmark(duration, seed)
    _soa_benchmark(duration, seed)
