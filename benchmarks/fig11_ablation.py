"""Fig. 11 — ablation study.

(a) dynamic reservation: Cyc. vs Cyc.(S) over quantiles — Cyc.(S) at a
    *lower* quantile beats Cyc. at a higher one, with idle reduced and
    near-zero realloc overhead (<0.4%);
(b) spatial partitioning: Tp-driven N_partition in {1,2,4,8} — realloc
    *ratio* drops sharply with partitions while N_rch stays comparable;
(c) same sweep, miss/latency side: isolation prevents interference
    cascades under high load, costs idle under low load;
(d) dynamic reservation under partitioning: reserv (=pglb+reservation)
    swept over reservation quantile — the U-shaped interplay.
"""
from __future__ import annotations

from repro.core.experiment import ExperimentSpec, run_experiment

from .common import emit


def run(duration: float = 1.0, seed: int = 1) -> None:
    # (a) Cyc vs Cyc.(S)
    for q in (0.5, 0.6, 0.7, 0.8):
        for pol in ("cyc", "cyc_s"):
            r = run_experiment(ExperimentSpec(
                policy=pol, tiles=400, cockpit_replicas=4, deadline_s=0.09,
                q=q, duration_s=duration, seed=seed,
            ))
            emit(
                f"fig11a_{pol}_q{q}", r.task_miss_rate * 1e6,
                f"miss={r.task_miss_rate:.4f};idle={r.idle_frac:.3f};"
                f"realloc={r.realloc_frac:.4f}",
            )

    # (b, c) partition sweep on the work-conserving runtime
    for load_name, tiles, reps, lf in (
        ("low", 400, 4, 0.5), ("mid", 400, 4, 1.0), ("high", 200, 4, 1.0),
    ):
        for nparts in (1, 2, 4, 8):
            r = run_experiment(ExperimentSpec(
                policy="pglb", tiles=tiles, cockpit_replicas=reps,
                load_factor=lf, deadline_s=0.09, num_partitions=nparts,
                duration_s=duration, seed=seed,
            ))
            emit(
                f"fig11bc_{load_name}_S{nparts}", r.realloc_frac * 1e6,
                f"realloc={r.realloc_frac:.4f};n_rch={r.n_realloc};"
                f"miss={r.task_miss_rate:.4f};idle={r.idle_frac:.3f}",
            )

    # (d) reservation quantile under partitioning (8 partitions)
    for load_name, tiles, reps, lf in (
        ("mid", 400, 4, 1.0), ("high", 200, 4, 1.0),
    ):
        r = run_experiment(ExperimentSpec(
            policy="pglb", tiles=tiles, cockpit_replicas=reps,
            load_factor=lf, deadline_s=0.09, num_partitions=8,
            duration_s=duration, seed=seed,
        ))
        emit(
            f"fig11d_{load_name}_pglb", r.task_miss_rate * 1e6,
            f"miss={r.task_miss_rate:.4f}",
        )
        for q in (0.5, 0.6, 0.7):
            r = run_experiment(ExperimentSpec(
                policy="reserv", tiles=tiles, cockpit_replicas=reps,
                load_factor=lf, deadline_s=0.09, q=q, num_partitions=8,
                duration_s=duration, seed=seed,
            ))
            emit(
                f"fig11d_{load_name}_reserv_q{q}", r.task_miss_rate * 1e6,
                f"miss={r.task_miss_rate:.4f};realloc={r.realloc_frac:.4f}",
            )
