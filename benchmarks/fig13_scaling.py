"""Fig. 13 — scaling performance.

(a) max cockpit chains supported (violation ~0) per tile budget, with
    variation enabled/disabled;
(b) minimum tiles to meet the deadline per workload scale — the paper's
    headline: ADS-Tile ~300 vs Tp-driven ~440 at medium (31.8% fewer);
    at heavy, Tp-driven fails at every tested capacity.  Also reports
    cumulative realloc waste (17-44% -> <1.2%).
"""
from __future__ import annotations

from repro.core.experiment import ExperimentSpec, run_experiment

from .common import emit

TILE_GRID = (225, 260, 300, 355, 400, 430, 500)
VIOL_OK = 0.01      # "meets the latency bound"


def _run(policy, tiles, reps, ddl, q, duration, seed, p99_ratio=3.3):
    return run_experiment(ExperimentSpec(
        policy=policy, tiles=tiles, cockpit_replicas=reps, deadline_s=ddl,
        q=q, duration_s=duration, seed=seed, p99_ratio=p99_ratio,
    ))


def _q_for(policy: str, reps: int) -> float:
    if policy == "ads_tile":
        return 0.95 if reps <= 1 else (0.9 if reps <= 6 else 0.8)
    return 0.95


def run(duration: float = 1.0, seed: int = 1) -> None:
    # (a) max cockpit chains per tile budget (variation on/off)
    for tiles in (300, 400, 500):
        for var, p99 in (("EN", 3.3), ("DIS", 1.0)):
            for policy in ("tp_driven", "ads_tile"):
                best = 0
                for reps in (1, 4, 6, 9):
                    r = _run(policy, tiles, reps, 0.09,
                             _q_for(policy, reps), duration, seed, p99)
                    if r.violation_rate <= VIOL_OK:
                        best = reps
                emit(
                    f"fig13a_t{tiles}_{policy}_var{var}", best * 1e6,
                    f"max_cockpit_chains={best}",
                )

    # (b) min tiles to meet the bound per case + waste comparison
    for case, reps, ddl in (
        ("light", 1, 0.100), ("medium", 6, 0.090), ("heavy", 9, 0.080),
    ):
        mins = {}
        waste = {}
        for policy in ("tp_driven", "ads_tile"):
            found = None
            for tiles in TILE_GRID:
                r = _run(policy, tiles, reps, ddl,
                         _q_for(policy, reps), duration, seed)
                if r.violation_rate <= VIOL_OK:
                    found = tiles
                    waste[policy] = r.realloc_frac
                    break
                waste.setdefault(policy, r.realloc_frac)
            mins[policy] = found
        tp, ad = mins["tp_driven"], mins["ads_tile"]
        saving = (
            f"{(1 - ad / tp) * 100:.1f}%" if tp and ad else
            ("tp_fails_all_capacities" if ad else "both_fail")
        )
        emit(
            f"fig13b_{case}", (ad or 0) * 1e6,
            f"min_tiles_tp={tp};min_tiles_ads={ad};tile_saving={saving};"
            f"waste_tp={waste.get('tp_driven', float('nan')):.4f};"
            f"waste_ads={waste.get('ads_tile', float('nan')):.4f}",
        )
