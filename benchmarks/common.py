"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import Callable, Iterable, List


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """One CSV row: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.3f},{derived}")


def timed(fn: Callable, *args, repeat: int = 1) -> float:
    t0 = time.time()
    for _ in range(repeat):
        fn(*args)
    return (time.time() - t0) / repeat * 1e6
