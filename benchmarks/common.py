"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import Callable, Mapping, Optional


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """One CSV row: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.3f},{derived}")


def attribution_derived(att: Optional[Mapping[str, object]]) -> str:
    """Render a deadline-miss attribution dict (per-run or aggregated)
    into the ``derived`` field's ``late=..;att_*=..`` segment."""
    att = att or {}
    comp = att.get("components_s", {}) or {}
    return (
        f"late={att.get('n_late', 0)};"
        f"att_queue={comp.get('queueing', 0.0):.4f};"
        f"att_stall={comp.get('realloc_stall', 0.0):.4f};"
        f"att_stagger={comp.get('restagger', 0.0):.4f};"
        f"att_tail={comp.get('duration_tail', 0.0):.4f}"
    )


def emit_sweep_aggregate(
    agg: Mapping[str, Mapping[str, object]], prefix: str
) -> None:
    """One :func:`emit` row per policy from a sweep aggregate table
    (``repro.scenarios.aggregate_sweep`` / ``SweepReducer.result()``) —
    shared by the figS sweep suite and the campaign front-end."""
    for pol, a in agg.items():
        per_mode = ";".join(
            f"{m}_viol={st['violation_rate']:.4f}"
            for m, st in a["per_mode"].items()
        )
        emit(
            f"{prefix}_{pol}",
            a["violation_rate"] * 1e6,
            f"n={a['n']};viol={a['violation_rate']:.4f};"
            f"miss={a['task_miss_rate']:.4f};"
            f"realloc={a['realloc_frac']:.4f};"
            f"{attribution_derived(a.get('attribution'))};{per_mode}",
        )


def timed(fn: Callable, *args, repeat: int = 1) -> float:
    t0 = time.time()
    for _ in range(repeat):
        fn(*args)
    return (time.time() - t0) / repeat * 1e6
