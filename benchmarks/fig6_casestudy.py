"""Fig. 6 — characterization of Cyc. and Tp-driven on tile-based ADS.

(a) Cyc.: idle / miss / realloc decomposition swept over quantile q;
    validates "raising q cuts miss rate but inflates idle" and
    "for q >= 0.9 idle far exceeds dropped workload".
(b) Tp-driven: utilization breakdown over hardware scale {200, 400} x
    workload scale {x1, x4, x9} x load factor {0.5, 1.0}; validates
    "realloc waste significant (double digits at scale)" and "larger
    hardware at same load -> more rescheduling overhead".
"""
from __future__ import annotations

from repro.core.experiment import ExperimentSpec, run_experiment

from .common import emit


def run(duration: float = 1.0, seed: int = 1) -> None:
    # (a) Cyc. quantile sweep
    for q in (0.5, 0.7, 0.8, 0.9, 0.95):
        r = run_experiment(ExperimentSpec(
            policy="cyc", tiles=400, cockpit_replicas=4, deadline_s=0.09,
            q=q, duration_s=duration, seed=seed,
        ))
        emit(
            f"fig6a_cyc_q{q}", r.task_miss_rate * 1e6,
            f"idle={r.idle_frac:.3f};miss={r.task_miss_rate:.3f};"
            f"dropped_work={r.dropped_work_frac:.4f};realloc={r.realloc_frac:.4f}",
        )

    # (b) Tp-driven scale sweep
    for tiles in (200, 400):
        for reps, load in ((1, 0.5), (1, 1.0), (4, 1.0), (9, 1.0)):
            r = run_experiment(ExperimentSpec(
                policy="tp_driven", tiles=tiles, cockpit_replicas=reps,
                load_factor=load, deadline_s=0.09,
                duration_s=duration, seed=seed,
            ))
            emit(
                f"fig6b_tp_t{tiles}_x{reps}_l{load}",
                r.realloc_frac * 1e6,
                f"eff={r.effective_frac:.3f};idle={r.idle_frac:.3f};"
                f"realloc={r.realloc_frac:.4f};miss={r.task_miss_rate:.3f};"
                f"n_realloc={r.n_realloc}",
            )
