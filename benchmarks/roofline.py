"""§Roofline — read the dry-run artifacts and print the roofline table:
three terms per (arch x shape x mesh), dominant bottleneck, MODEL_FLOPS
ratio and roofline fraction."""
from __future__ import annotations

import json
from pathlib import Path

from .common import emit

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def run(duration: float = 0.0, seed: int = 0) -> None:
    if not RESULTS.exists():
        emit("roofline_missing", 0.0, "run repro.launch.dryrun first")
        return
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("status") != "OK":
            emit(
                f"roofline_{d['arch']}_{d['shape']}_{d.get('mesh','?')}",
                0.0, d.get("status", "?"),
            )
            continue
        r = d["roofline"]
        emit(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            r["bound_s"] * 1e6 if "bound_s" in r else max(
                r["compute_s"], r["memory_s"], r["collective_s"]
            ) * 1e6,
            f"compute_ms={r['compute_s']*1e3:.2f};"
            f"memory_ms={r['memory_s']*1e3:.2f};"
            f"collective_ms={r['collective_s']*1e3:.2f};"
            f"dominant={r['dominant']};"
            f"useful_flops_ratio={r['useful_flops_ratio']:.3f};"
            f"roofline_fraction={r['roofline_fraction']:.4f}",
        )
