"""Table II — runtime overhead of Algorithm 2: ratio of a single
scheduling-decision latency to the triggered data-resharding latency
(mean / p50 / p99 / max), for 1-partition (glb) and multi-partition
(pglb) configurations.  Paper: mean 7.7% (glb), 4.6% (pglb)."""
from __future__ import annotations

import numpy as np

from repro.core.experiment import ExperimentSpec, run_experiment

from .common import emit


def run(duration: float = 1.0, seed: int = 1) -> None:
    for name, nparts in (("glb_1partition", 1), ("pglb_4partitions", 4)):
        r = run_experiment(ExperimentSpec(
            policy="ads_tile", tiles=400, cockpit_replicas=6,
            deadline_s=0.09, q=0.9, num_partitions=nparts,
            duration_s=duration, seed=seed,
        ))
        ratios = np.asarray(r.decision_ratios) * 100
        if len(ratios) == 0:
            emit(f"table2_{name}", 0.0, "no_reallocations")
            continue
        emit(
            f"table2_{name}", float(np.mean(ratios)) * 1e4,
            f"mean%={np.mean(ratios):.1f};p50%={np.percentile(ratios,50):.1f};"
            f"p99%={np.percentile(ratios,99):.1f};max%={np.max(ratios):.1f};"
            f"n={len(ratios)}",
        )
