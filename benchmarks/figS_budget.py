"""Fig. S-budget — tile-budget autotuner: tiles saved vs work-conserving.

The paper's resource-efficiency headline is that ADS-Tile needs up to
~32 % fewer tiles than work-conserving baselines at the same service
level, because joint (quantile x DoP x partition) search plus isolation
lets it shed the overprovisioning the baselines need against
interference.  This suite reproduces the tiles-saved-vs-baseline curve
on the scenario subsystem:

1. The **work-conserving baseline** (Tp-driven, single shared bin)
   compiles its conservative full-chip portfolio; its simulated
   deadline-miss rate defines the *service target* both systems must
   meet.  (A budget-capped baseline is also swept for transparency —
   work-conserving tables collapse rather than compress: the
   autotuner's relaxed-q single-bin points trade a handful of tiles
   for order-of-magnitude worse miss rates.)
2. **ADS-Tile** walks a grid of predicted-miss targets through the
   autotuner (`SchedulePortfolio.compile(target_miss=...)`), each
   compiling the cheapest frontier point per mode, and keeps the
   fewest-tiles portfolio whose *simulated* miss rate still meets the
   baseline's service target on paired traces.

Two parts, two tile metrics (both reported; each part headlines the
one that matches its structure):

* ``rate_churn`` (scripted night -> urban -> rush-hour rate churn with
  a burst): **peak** reserved tiles — the provisioning headline, the
  scenario-world analogue of the paper's static tiles-saved figure.
* A Markov sweep of bursty congested-commute drives over the same
  sensor-rate-churn mode set: **mean** reserved tiles (time-weighted
  ``peak_tiles`` of the active table).  Per-mode tables release tiles
  during light segments; the work-conserving bin holds its full
  reservation for the whole drive by construction, so the mean is the
  honest fleet-scale comparison when drives are random.

Headline per part: ``saved_frac`` = 1 - ads_tile tiles / baseline
tiles, under ads_tile miss <= baseline miss (exactly paired job-level
traces).  ``--duration`` scales seeds / sampled drives, not per-drive
length.
"""
from __future__ import annotations

import dataclasses

from repro.core.experiment import build_stack
from repro.core.runtime import SchedulePortfolio
from repro.scenarios import ScenarioSpec, get_mode, get_scenario
from repro.scenarios.runner import _run_group, build_trace, run as run_specs
from repro.scenarios.script import MarkovScenarioGenerator

from .common import emit

#: predicted-miss targets walked from cheap to conservative; None is
#: the legacy most-conservative-feasible compile (always meets the
#: baseline target in practice, so the walk cannot come back empty)
TARGET_GRID = (0.45, 0.4, 0.35, 0.3, None)

#: transparency sweep of the capped work-conserving baseline
BASE_TARGETS = (0.45, 0.35)

#: part 2's drive distribution: a bursty congested commute over the
#: rate-churn mode set (15 -> 30 -> 60 Hz camera regimes), the regime
#: where per-mode tile budgets differ enough to matter
COMMUTE_TRANSITIONS = {
    "night": {"urban": 0.7, "rush_hour": 0.3},
    "urban": {"rush_hour": 0.5, "night": 0.5},
    "rush_hour": {"urban": 0.6, "night": 0.4},
}
COMMUTE_DWELL = {"night": 0.6, "urban": 0.6, "rush_hour": 0.8}
COMMUTE_BURST_PROB = 0.5
#: part 2 runs a 35 % heavier deployment: resource efficiency is a
#: statement about the capacity-bound regime — at light load any
#: full-chip baseline meets deadlines and there is nothing to save
COMMUTE_LOAD_FACTOR = 1.35

#: part 3 sweeps the load factor itself to trace the full
#: tiles-saved-vs-load curve (the paper's Fig. 13 analogue): from the
#: light regime (nothing to save) through part 2's operating point
#: into overload.  Cheap on the SoA backend — every grid point is an
#: R-seed cell of one pinned drive, so the jit compile is paid once
#: per policy shape and each point costs R kernel runs.
LOAD_GRID = (1.0, 1.15, 1.35, 1.5)
#: part 3's reduced autotuner walk per load point (the full
#: TARGET_GRID transparency sweep is part 2's job; the curve needs
#: the envelope: one relaxed point + the conservative fallback)
LOAD_TARGETS = (0.35, None)


def _portfolio_tiles(pf: SchedulePortfolio) -> int:
    """Tiles the portfolio provisions: the worst mode's reservation."""
    return max(p.tiles for p in pf.selected.values())


def _compile(spec: ScenarioSpec, mode_names, target) -> SchedulePortfolio:
    wf, _hw, model, compiler = build_stack(spec)
    modes = {m: get_mode(m) for m in mode_names}
    return SchedulePortfolio.compile(
        model, wf, modes, compiler, target_miss=target
    )


def _tag(target) -> str:
    return "cons" if target is None else f"t{int(round(target * 100)):02d}"


def _pick_cheapest(candidates, viol_base):
    """Fewest-tiles candidate ``(tiles, viol, target)`` whose simulated
    miss meets the baseline's.  If none qualifies the *lowest-miss*
    candidate backstops — never a cheap table that trades the service
    level away (the headline must stay an equal-or-better-miss claim)."""
    ok = [c for c in candidates if c[1] <= viol_base + 1e-12]
    if ok:
        return min(ok, key=lambda c: (c[0], c[1]))
    return min(candidates, key=lambda c: (c[1], c[0]))


def run(duration: float = 1.0, seed: int = 1) -> None:
    # -- part 1: rate_churn, paired seeds, peak-reservation metric ------
    scen = get_scenario("rate_churn")
    seeds = tuple(range(seed, seed + max(2, int(round(3 * duration)))))
    spec_ads = ScenarioSpec(scenario=scen, policy="ads_tile", seed=seed)
    spec_tp = ScenarioSpec(scenario=scen, policy="tp_driven", seed=seed)
    traces = {}
    for s in seeds:
        traces[s] = build_trace(dataclasses.replace(spec_ads, seed=s))

    def churn_stats(spec, pf):
        viol, mean_tiles = 0.0, 0.0
        for s in seeds:
            sp = dataclasses.replace(spec, seed=s, portfolio=pf)
            [r] = run_specs(sp, trace=traces[s])
            viol += r.violation_rate
            mean_tiles += r.tiles_reserved_mean
        return viol / len(seeds), mean_tiles / len(seeds)

    pf_base = _compile(spec_tp, scen.modes(), None)
    tiles_base = _portfolio_tiles(pf_base)
    viol_base, mean_base = churn_stats(spec_tp, pf_base)
    emit(
        "figS_budget_churn_base",
        tiles_base,
        f"tiles={tiles_base};mean_tiles={mean_base:.1f};"
        f"viol={viol_base:.4f};seeds={len(seeds)}",
    )
    for t in BASE_TARGETS:
        pf_t = _compile(spec_tp, scen.modes(), t)
        v, _m = churn_stats(spec_tp, pf_t)
        emit(
            f"figS_budget_churn_base_{_tag(t)}",
            _portfolio_tiles(pf_t),
            f"tiles={_portfolio_tiles(pf_t)};viol={v:.4f}",
        )

    candidates = []
    for t in TARGET_GRID:
        pf_t = _compile(spec_ads, scen.modes(), t)
        tiles = _portfolio_tiles(pf_t)
        v, m = churn_stats(spec_ads, pf_t)
        candidates.append((tiles, v, t))
        emit(
            f"figS_budget_churn_ads_{_tag(t)}",
            tiles,
            f"tiles={tiles};mean_tiles={m:.1f};viol={v:.4f}",
        )
    tiles_ads, viol_ads, t_pick = _pick_cheapest(candidates, viol_base)
    saved = 1.0 - tiles_ads / tiles_base
    emit(
        "figS_budget_churn_headline",
        saved * 1e6,
        f"tiles_ads={tiles_ads};tiles_base={tiles_base};"
        f"saved_frac={saved:.3f};viol_ads={viol_ads:.4f};"
        f"viol_base={viol_base:.4f};target={_tag(t_pick)}",
    )

    # -- part 2: bursty commute sweep, mean-reservation metric ----------
    gen = MarkovScenarioGenerator(
        transitions=COMMUTE_TRANSITIONS,
        mean_dwell_s=COMMUTE_DWELL,
        burst_prob=COMMUTE_BURST_PROB,
    )
    all_modes = sorted(gen.transitions)
    mode_defs = {m: get_mode(m) for m in all_modes}
    n = max(4, int(round(8 * duration)))
    base_spec = ScenarioSpec(
        scenario=scen,
        policy="tp_driven",
        seed=seed,
        mode_defs=mode_defs,
        load_factor=COMMUTE_LOAD_FACTOR,
    )
    pf_base = _compile(base_spec, all_modes, None)
    ads_pfs = {
        t: _compile(
            dataclasses.replace(base_spec, policy="ads_tile"), all_modes, t
        )
        for t in TARGET_GRID
    }

    rows = []
    for i in range(n):
        s_i = seed * 100003 + i
        script = gen.sample(2.0, seed=s_i)
        group = [
            ScenarioSpec(
                scenario=script,
                policy="tp_driven",
                seed=s_i,
                mode_defs=mode_defs,
                load_factor=COMMUTE_LOAD_FACTOR,
                portfolio=pf_base,
            )
        ]
        for t in TARGET_GRID:
            group.append(
                ScenarioSpec(
                    scenario=script,
                    policy="ads_tile",
                    seed=s_i,
                    mode_defs=mode_defs,
                    load_factor=COMMUTE_LOAD_FACTOR,
                    portfolio=ads_pfs[t],
                    target_miss=t,
                )
            )
        rows.extend(_run_group(group))

    stats = {}
    for row in rows:
        key = (str(row["policy"]), row["target_miss"])
        stats.setdefault(key, []).append(
            (float(row["violation_rate"]), float(row["tiles_reserved_mean"]))
        )

    def mean(xs):
        return sum(xs) / len(xs)

    viol_base = mean([v for v, _m in stats[("tp_driven", None)]])
    mean_base = mean([m for _v, m in stats[("tp_driven", None)]])
    emit(
        "figS_budget_markov_base",
        mean_base,
        f"tiles={_portfolio_tiles(pf_base)};mean_tiles={mean_base:.1f};"
        f"viol={viol_base:.4f};n={n}",
    )
    candidates = []
    for t in TARGET_GRID:
        v = mean([x for x, _m in stats[("ads_tile", t)]])
        m = mean([x for _v, x in stats[("ads_tile", t)]])
        candidates.append((m, v, t))
        emit(
            f"figS_budget_markov_ads_{_tag(t)}",
            m,
            f"tiles={_portfolio_tiles(ads_pfs[t])};mean_tiles={m:.1f};"
            f"viol={v:.4f}",
        )
    mean_ads, viol_ads, t_pick = _pick_cheapest(candidates, viol_base)
    saved = 1.0 - mean_ads / mean_base
    emit(
        "figS_budget_markov_headline",
        saved * 1e6,
        f"mean_tiles_ads={mean_ads:.1f};mean_tiles_base={mean_base:.1f};"
        f"saved_frac={saved:.3f};viol_ads={viol_ads:.4f};"
        f"viol_base={viol_base:.4f};target={_tag(t_pick)}",
    )

    # -- part 3: tiles-saved-vs-load curve (Fig. 13 analogue) -----------
    from repro.core.sim.soa import soa_available

    script3 = gen.sample(2.0, seed=seed * 100003)  # one pinned bursty drive
    seeds3 = list(range(seed, seed + n))
    backend3 = "soa" if soa_available() else "lockstep"

    def cell_stats(spec):
        """(mean violation rate, mean reserved tiles) over the R-seed
        cell — SoA lanes when jax is present, lockstep lanes otherwise
        via run()'s per-spec fallback (the curve is a statistical
        statement either way)."""
        reports = run_specs(spec, seeds=seeds3, backend=backend3)
        return (
            mean([r.violation_rate for r in reports]),
            mean([r.tiles_reserved_mean for r in reports]),
        )

    curve = []
    for lf in LOAD_GRID:
        base3 = ScenarioSpec(
            scenario=script3,
            policy="tp_driven",
            seed=seed,
            mode_defs=mode_defs,
            load_factor=lf,
        )
        pf_b = _compile(base3, all_modes, None)
        viol_b, mean_b = cell_stats(dataclasses.replace(base3, portfolio=pf_b))
        cands = []
        for t in LOAD_TARGETS:
            pf_t = _compile(dataclasses.replace(base3, policy="ads_tile"), all_modes, t)
            v, m = cell_stats(
                dataclasses.replace(
                    base3, policy="ads_tile", portfolio=pf_t, target_miss=t
                )
            )
            cands.append((m, v, t))
        m_ads, v_ads, t_pick = _pick_cheapest(cands, viol_b)
        saved = 1.0 - m_ads / mean_b
        curve.append((lf, saved))
        emit(
            f"figS_budget_load_{int(round(lf * 100))}",
            saved * 1e6,
            f"load={lf};mean_tiles_ads={m_ads:.1f};"
            f"mean_tiles_base={mean_b:.1f};saved_frac={saved:.3f};"
            f"viol_ads={v_ads:.4f};viol_base={viol_b:.4f};"
            f"target={_tag(t_pick)};n={n};backend={backend3}",
        )
    emit(
        "figS_budget_load_curve",
        max(s for _lf, s in curve) * 1e6,
        "curve="
        + ",".join(f"{lf:g}:{s:.3f}" for lf, s in curve)
        + f";backend={backend3}",
    )
