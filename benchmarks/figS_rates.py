"""Fig. S-rates — sensor-rate churn (per-mode rates, piecewise unroll).

The paper's stressor is that ADS tasks arrive at 10-240 Hz *and the
rates themselves shift with the driving context*: cameras downclock at
night for exposure, upclock in rush-hour density, LiDAR doubles in
rain.  Each rate change alters the hyper-period, forcing the engine to
re-unroll the DAG piecewise and the runtime to swap to a table
compiled for the new release pattern.

Two parts:

1. ``rate_churn`` (night 15 Hz -> urban 30 Hz -> rush-hour 60 Hz
   cameras), each policy replanned vs. pinned.  The headline claim:
   ADS-Tile's gated reallocation keeps realloc waste bounded under
   rate churn, while the work-conserving baseline re-shuffles tiles on
   every (now much more frequent) queue change.
2. Single-seam pairs — a rush-hour camera *upclock* and a night
   *downclock* — isolating one hyper-period change per run.

``--duration`` is accepted for harness uniformity; the scripts here fix
their own timelines.
"""
from __future__ import annotations

import dataclasses

from repro.scenarios import (
    ModeSegment,
    ScenarioScript,
    ScenarioSpec,
    compile_portfolio,
    get_scenario,
    run as run_specs,
)

from .common import emit

#: replanned + pinned variants per policy; ``reserv`` is the
#: reservation-only ablation (partitions, no slack sharing)
POLICIES = ("ads_tile", "tp_driven", "reserv")


def _emit_run(tag: str, r) -> None:
    per_mode = ";".join(
        f"{m}_viol={s.violation_rate:.4f}" for m, s in sorted(r.mode_stats.items())
    )
    emit(
        tag,
        r.violation_rate * 1e6,
        f"viol={r.violation_rate:.4f};miss={r.task_miss_rate:.4f};"
        f"realloc={r.realloc_frac:.4f};n_realloc={r.n_realloc};"
        f"switches={r.n_mode_switches};{per_mode}",
    )


def run(duration: float = 1.0, seed: int = 1) -> None:
    # -- part 1: full churn, replan vs pinned ---------------------------
    churn = get_scenario("rate_churn")
    waste = {}
    for policy in POLICIES:
        base = ScenarioSpec(scenario=churn, policy=policy, seed=seed)
        base = dataclasses.replace(base, portfolio=compile_portfolio(base))
        for replan in (True, False):
            [r] = run_specs(dataclasses.replace(base, replan=replan))
            tag = "replan" if replan else "pinned"
            _emit_run(f"figS_rates_churn_{policy}_{tag}", r)
            if replan:
                waste[policy] = r.realloc_frac
    # headline: realloc waste under rate churn, ADS-Tile vs the
    # work-conserving baseline (×1e6 so the ratio survives the us column)
    ratio = waste["tp_driven"] / max(waste["ads_tile"], 1e-12)
    emit(
        "figS_rates_waste_ratio",
        ratio * 1e6,
        f"tp_driven_realloc={waste['tp_driven']:.4f};"
        f"ads_tile_realloc={waste['ads_tile']:.4f};ratio={ratio:.2f}",
    )

    # -- part 2: single-seam upclock / downclock ------------------------
    pairs = {
        # 30 -> 60 Hz cameras halfway through the drive
        "upclock": ScenarioScript(
            name="upclock",
            segments=(ModeSegment("urban", 0.8), ModeSegment("rush_hour", 0.8)),
        ),
        # 30 -> 15 Hz cameras at dusk
        "downclock": ScenarioScript(
            name="downclock",
            segments=(ModeSegment("urban", 0.8), ModeSegment("night", 0.8)),
        ),
    }
    for name, scen in pairs.items():
        for policy in ("ads_tile", "tp_driven"):
            spec = ScenarioSpec(scenario=scen, policy=policy, seed=seed)
            [r] = run_specs(spec)
            _emit_run(f"figS_rates_{name}_{policy}", r)
