"""CI equivalence gate: batched lockstep engine vs the scalar engine.

Usage::

    python -m benchmarks.check_equivalence \
        [--mode bitwise|distributional] \
        [--seeds 0 7 123] [--policies cyc tp_driven ads_tile] \
        [--scenarios all] [--min-speedup 1.1] [--ks-tol 0.08]

For every bundled scenario x policy x pinned seed, the same run is
executed twice through :func:`repro.scenarios.runner.run` — once with
``backend="scalar"`` (the scalar reference engine) and once with
``backend="lockstep"`` (the lockstep batch engine, all seeds of a cell
in one batch) — and the two
:class:`~repro.core.sim.engine.SimReport` objects are compared through
:func:`repro.core.sim.batch.report_digest`.  The digest covers every
float in the report (latencies, violations, utilization, per-mode
tails), so a pass means **bit-identical** results, not "close enough":
any divergence in event ordering, rate arithmetic, or policy decisions
inside the fused lanes shows up here.

``--min-speedup`` additionally times one warm pinned batch (the
``perf_bench`` 6-mode Markov scenario, B=8, ads_tile) against the same
seeds through the scalar loop and fails when the batched path does not
clear the floor.  The floor is deliberately conservative (default
1.1x): shared CI runners are noisy and single-core, and the honest
fused-lane speedup envelope is documented in
``docs/performance.md#batched-monte-carlo-engine`` — this assertion
exists to catch the batched path silently degrading into
"scalar-with-overhead", not to certify a marketing number.

``--mode distributional`` gates the structure-of-arrays jax backend
instead: the SoA kernels replace the event heap with discrete
scheduling rounds, so bit-identity is out of reach *by design* and the
contract is statistical (docs/performance.md#soa-backend).  Per
scenario x policy cell, the pinned seed set runs through both the
lockstep engine (bit-identical to scalar, cheaper to drive) and
``run(spec, seeds=..., backend="soa", fallback=False)``, and the gate
asserts:

* **structural invariants** (job universe, seam spans, chain universe,
  reservation footprint) match exactly, per seed;
* the pooled chain-latency **KS statistic** stays under ``--ks-tol``
  (default 0.08 — the measured dt=1e-3 approximation envelope is
  0.01-0.06 with the tp_driven quota walk the worst cell, so the gate
  trips on regression, not on the known round-coalescing bias);
* per-cell **CI overlap** on violation rate, realloc waste and mean
  reserved tiles (normal-approximation intervals across seeds).

A pass/fail table is written to ``$GITHUB_STEP_SUMMARY`` when that
environment variable is set (the GitHub Actions job-summary panel) and
always printed to stdout.  Exit 1 on any mismatch or a missed speedup
floor, 0 otherwise.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
from typing import List, Sequence

from repro.core.sim.batch import report_digest
from repro.scenarios.runner import ScenarioSpec, run as run_specs
from repro.scenarios.script import (
    BUNDLED_SCENARIOS,
    MarkovScenarioGenerator,
    get_scenario,
)

DEFAULT_SEEDS = (0, 7, 123)
DEFAULT_POLICIES = ("cyc", "tp_driven", "ads_tile")


def run_cell(scenario: str, policy: str, seeds: Sequence[int]) -> List[bool]:
    """Per-seed bit-identity verdicts for one scenario x policy cell."""
    spec = ScenarioSpec(scenario=get_scenario(scenario), policy=policy)
    batched = run_specs(spec, seeds=list(seeds), backend="lockstep")
    out = []
    for s, rb in zip(seeds, batched):
        [rs] = run_specs(dataclasses.replace(spec, seed=int(s)), backend="scalar")
        out.append(report_digest(rs) == report_digest(rb))
    return out


def run_cell_distributional(
    scenario: str, policy: str, seeds: Sequence[int], ks_tol: float
) -> dict:
    """SoA-vs-scalar statistical verdicts for one scenario x policy
    cell: exact structural invariants, pooled chain-latency KS, and CI
    overlap on the summary rates.  The scalar side is driven through
    the lockstep engine, whose bit-identity to the scalar backend the
    bitwise mode of this gate pins separately."""
    from repro.core.sim.soa import (
        intervals_overlap,
        ks_statistic,
        mean_ci,
        structural_invariants,
    )

    spec = ScenarioSpec(scenario=get_scenario(scenario), policy=policy)
    ref = run_specs(spec, seeds=list(seeds), backend="lockstep")
    soa = run_specs(spec, seeds=list(seeds), backend="soa", fallback=False)
    struct_ok = all(
        structural_invariants(a) == structural_invariants(b) for a, b in zip(ref, soa)
    )
    lat_ref = [x for r in ref for ls in r.chain_latencies.values() for x in ls]
    lat_soa = [x for r in soa for ls in r.chain_latencies.values() for x in ls]
    ks = ks_statistic(lat_ref, lat_soa)
    ci_ok = True
    for metric in ("violation_rate", "realloc_frac", "tiles_reserved_mean"):
        ci_ref = mean_ci([getattr(r, metric) for r in ref])
        ci_soa = mean_ci([getattr(r, metric) for r in soa])
        # zero-width intervals (deterministic metrics, single seeds)
        # still must touch: pad by a rounding epsilon only
        ci_ok = ci_ok and intervals_overlap(ci_ref, ci_soa, pad=1e-9)
    return {
        "struct_ok": struct_ok,
        "ks": ks,
        "ks_ok": ks <= ks_tol,
        "ci_ok": ci_ok,
        "n": (len(lat_ref), len(lat_soa)),
    }


def measure_speedup(seeds: Sequence[int]) -> tuple:
    """``(scalar_s, batch_s)`` for the pinned perf-bench scenario."""
    from .perf_bench import PERF_DWELL, PERF_TRANSITIONS

    gen = MarkovScenarioGenerator(transitions=PERF_TRANSITIONS, mean_dwell_s=PERF_DWELL)
    spec = ScenarioSpec(scenario=gen.sample(2.0, 1), policy="ads_tile")
    run_specs(spec, seeds=list(seeds)[:2])  # warm caches for both paths
    run_specs(dataclasses.replace(spec, seed=int(seeds[0])))
    t0 = time.perf_counter()
    for s in seeds:
        run_specs(dataclasses.replace(spec, seed=int(s)))
    scalar_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_specs(spec, seeds=list(seeds))
    batch_s = time.perf_counter() - t0
    return scalar_s, batch_s


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--mode",
        choices=("bitwise", "distributional"),
        default="bitwise",
        help="bitwise: lockstep engine vs scalar (digest identity); "
        "distributional: SoA jax backend vs scalar (KS + CI overlap + "
        "structural invariants)",
    )
    ap.add_argument(
        "--ks-tol",
        type=float,
        default=0.08,
        help="distributional mode: max pooled chain-latency KS statistic "
        "(default 0.08)",
    )
    ap.add_argument(
        "--seeds",
        type=int,
        nargs="+",
        default=list(DEFAULT_SEEDS),
        help="pinned seeds per cell (default: 0 7 123)",
    )
    ap.add_argument(
        "--policies",
        nargs="+",
        default=list(DEFAULT_POLICIES),
        help="policies to sweep (default: all three)",
    )
    ap.add_argument(
        "--scenarios",
        nargs="+",
        default=["all"],
        help="bundled scenario names, or 'all'",
    )
    ap.add_argument(
        "--min-speedup",
        type=float,
        default=None,
        help="also assert batched/scalar wall-clock speedup "
        "on the pinned B=8 perf scenario (ads_tile)",
    )
    args = ap.parse_args(argv)

    scenarios = (
        sorted(BUNDLED_SCENARIOS) if args.scenarios == ["all"] else args.scenarios
    )

    if args.mode == "distributional":
        from repro.core.sim.soa import soa_available

        if not soa_available():
            print(
                "distributional mode needs jax (the SoA backend); "
                "skipping gate",
                file=sys.stderr,
            )
            return 0
        lines = [
            "| scenario | policy | struct | KS (tol) | CI overlap |",
            "|---|---|---|---|---|",
        ]
        fails = 0
        total = 0
        for scen in scenarios:
            if get_scenario(scen).has_degradations:
                # the SoA kernels do not model degradation seams
                # (``soa_usable`` rejects these scripts); the bitwise
                # mode still covers them through the scalar lane
                lines.append(f"| {scen} | — | skipped (degradations) | — | — |")
                continue
            for pol in args.policies:
                v = run_cell_distributional(scen, pol, args.seeds, args.ks_tol)
                ok = v["struct_ok"] and v["ks_ok"] and v["ci_ok"]
                fails += 0 if ok else 1
                total += 1
                lines.append(
                    f"| {scen} | {pol} "
                    f"| {'OK' if v['struct_ok'] else '**FAIL**'} "
                    f"| {v['ks']:.4f} ({args.ks_tol}) "
                    f"{'OK' if v['ks_ok'] else '**FAIL**'} "
                    f"| {'OK' if v['ci_ok'] else '**FAIL**'} |"
                )
        lines.append("")
        lines.append(
            f"**{total - fails}/{total}** SoA-vs-scalar cells "
            f"distributionally equivalent (seeds {args.seeds})"
        )
        table = "\n".join(lines)
        print(table)
        summary = os.environ.get("GITHUB_STEP_SUMMARY")
        if summary:
            with open(summary, "a") as fh:
                fh.write("## SoA-backend distributional equivalence gate\n\n")
                fh.write(table + "\n")
        if fails:
            print(
                f"distributional gate failed: {fails} cell(s) out of the "
                "SoA equivalence envelope",
                file=sys.stderr,
            )
            return 1
        return 0

    seed_cols = " | ".join(f"seed {s}" for s in args.seeds)
    lines = [
        f"| scenario | policy | {seed_cols} |",
        "|---|---|" + "---|" * len(args.seeds),
    ]
    fails = 0
    for scen in scenarios:
        for pol in args.policies:
            verdicts = run_cell(scen, pol, args.seeds)
            fails += verdicts.count(False)
            cells = " | ".join("OK" if v else "**FAIL**" for v in verdicts)
            lines.append(f"| {scen} | {pol} | {cells} |")

    total = len(scenarios) * len(args.policies) * len(args.seeds)
    lines.append("")
    lines.append(f"**{total - fails}/{total}** scalar-vs-batched runs bit-identical")

    speed_ok = True
    if args.min_speedup is not None:
        scalar_s, batch_s = measure_speedup([1 + i for i in range(8)])
        speedup = scalar_s / batch_s
        speed_ok = speedup >= args.min_speedup
        verdict = "OK" if speed_ok else "**FAIL**"
        lines.append("")
        lines.append(
            f"Pinned B=8 ads_tile sweep: scalar {scalar_s:.3f}s, "
            f"batched {batch_s:.3f}s — **{speedup:.2f}x** "
            f"(floor {args.min_speedup:.2f}x) {verdict}"
        )

    table = "\n".join(lines)
    print(table)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as fh:
            fh.write("## Batched-engine equivalence gate\n\n")
            fh.write(table + "\n")

    if fails:
        print(
            f"equivalence gate failed: {fails} run(s) diverged from the "
            "scalar engine",
            file=sys.stderr,
        )
        return 1
    if not speed_ok:
        print(
            "equivalence gate failed: batched sweep below the speedup "
            "floor (see docs/performance.md#batched-monte-carlo-engine)",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
