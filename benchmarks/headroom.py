"""§II-C3 scope-note quantification: tail-composition headroom per
chain (sum of per-task q-quantile budgets vs the Monte-Carlo E2E
quantile) and the chunk-boundary reallocation fidelity ablation
(§IV-D2 unpreemptable chunks)."""
from __future__ import annotations

import dataclasses

from repro.core.benchmark import make_ads_benchmark
from repro.core.experiment import ExperimentSpec, run_experiment
from repro.core.gha.phase1 import run_phase1
from repro.core.hardware import simba_chip
from repro.core.latency_model import LatencyModel, chain_tail_composition
from repro.core.sim import SimConfig, Simulator
from repro.core.gha import GHACompiler
from repro.core.runtime import AdsTilePolicy

from .common import emit


def run(duration: float = 1.0, seed: int = 1) -> None:
    wf = make_ads_benchmark()
    model = LatencyModel.from_workflow(wf, simba_chip(400))
    p1 = run_phase1(model, wf, q=0.95)
    dops = {t: c for t, (c, _) in p1.shapes.items()}
    for chain in wf.chains:
        out = chain_tail_composition(
            model, chain.nodes, dops, q=0.95, num_samples=20000, seed=seed
        )
        emit(
            f"headroom_{chain.name}", out["headroom"] * 1e6,
            f"headroom={out['headroom']:.3f};"
            f"sum_q_ms={out['sum_of_quantiles_s']*1e3:.1f};"
            f"mc_q_ms={out['mc_quantile_s']*1e3:.1f}",
        )

    # chunk-boundary reallocation fidelity (§IV-D2)
    for flag in (False, True):
        wf6 = make_ads_benchmark(cockpit_replicas=6, critical_deadline_s=0.09)
        lm = LatencyModel.from_workflow(wf6, simba_chip(400))
        sched = GHACompiler(q=0.9, num_partitions=4).compile(lm, wf6)
        sim = Simulator(
            wf6, lm, sched, AdsTilePolicy(),
            SimConfig(duration_s=duration, seed=seed, n_chunks=32,
                      drop_policy="soft", chunk_boundary_realloc=flag),
        )
        r = sim.run()
        emit(
            f"chunk_boundary_{'on' if flag else 'off'}",
            r.realloc_frac * 1e6,
            f"realloc={r.realloc_frac:.4f};miss={r.task_miss_rate:.4f};"
            f"n_realloc={r.n_realloc}",
        )
