"""Fig. S — driving-scenario suite (beyond the paper's stationary runs).

Two parts:

1. Bundled scenarios, replanned vs. pinned: the same policy either
   hot-swaps per-mode GHA schedules on ``mode_change`` or keeps the
   table compiled for the scenario's opening mode.  Validates that
   online replanning lowers the deadline-violation rate when the
   context shifts away from the initial mode (``calm_to_rush``) and
   that the swap cost stays inside the reallocation-waste budget.
2. A Monte-Carlo sweep of Markov-sampled scenarios across policies,
   fanned out over a process pool with deterministic per-scenario
   seeds — the fleet-scale view.

``--duration`` scales the sweep size, not the per-scenario length
(bundled scripts fix their own timelines).
"""
from __future__ import annotations

import dataclasses

from repro.scenarios import (
    ScenarioSpec,
    aggregate_sweep,
    build_trace,
    compile_portfolio,
    get_scenario,
    run_scenario,
    sweep,
)

from .common import emit


def run(duration: float = 1.0, seed: int = 1) -> None:
    # -- part 1: bundled scenarios, replan vs pinned --------------------
    for name in ("calm_to_rush", "commute", "night_storm"):
        scen = get_scenario(name)
        # one sampled trace per scenario: every policy/replan variant
        # sees identical per-job draws (and pays no re-sampling)
        trace = build_trace(ScenarioSpec(scenario=scen, policy="ads_tile",
                                         seed=seed))
        for policy in ("ads_tile", "tp_driven"):
            # one portfolio per (scenario, policy): the replanned and
            # pinned variants start from the identical table
            # record=True: every run carries the flight recorder, so
            # the rows also report the deadline-miss decomposition
            base = ScenarioSpec(scenario=scen, policy=policy, seed=seed,
                                record=True)
            base = dataclasses.replace(base, portfolio=compile_portfolio(base))
            for replan in (True, False):
                r = run_scenario(dataclasses.replace(base, replan=replan),
                                 trace=trace)
                per_mode = ";".join(
                    f"{m}_viol={s.violation_rate:.4f}"
                    for m, s in sorted(r.mode_stats.items())
                )
                att = r.attribution or {}
                comp = att.get("components_s", {})
                att_str = (
                    f"late={att.get('n_late', 0)};"
                    f"att_queue={comp.get('queueing', 0.0):.4f};"
                    f"att_stall={comp.get('realloc_stall', 0.0):.4f};"
                    f"att_stagger={comp.get('restagger', 0.0):.4f};"
                    f"att_tail={comp.get('duration_tail', 0.0):.4f}"
                )
                tag = "replan" if replan else "pinned"
                emit(
                    f"figS_{name}_{policy}_{tag}",
                    r.violation_rate * 1e6,
                    f"viol={r.violation_rate:.4f};miss={r.task_miss_rate:.4f};"
                    f"realloc={r.realloc_frac:.4f};"
                    f"switches={r.n_mode_switches};{att_str};{per_mode}",
                )

    # -- part 2: Monte-Carlo sweep of random drives ---------------------
    n = max(4, int(round(20 * duration)))
    rows = sweep(
        n, policies=("ads_tile", "tp_driven"),
        duration_s=2.0, seed=seed, record=True,
    )
    agg = aggregate_sweep(rows)
    for pol, a in agg.items():
        per_mode = ";".join(
            f"{m}_viol={st['violation_rate']:.4f}"
            for m, st in a["per_mode"].items()
        )
        att = a.get("attribution") or {}
        comp = att.get("components_s", {})
        att_str = (
            f"late={att.get('n_late', 0)};"
            f"att_queue={comp.get('queueing', 0.0):.4f};"
            f"att_stall={comp.get('realloc_stall', 0.0):.4f};"
            f"att_stagger={comp.get('restagger', 0.0):.4f};"
            f"att_tail={comp.get('duration_tail', 0.0):.4f}"
        )
        emit(
            f"figS_sweep_{pol}",
            a["violation_rate"] * 1e6,
            f"n={a['n']};viol={a['violation_rate']:.4f};"
            f"miss={a['task_miss_rate']:.4f};"
            f"realloc={a['realloc_frac']:.4f};{att_str};{per_mode}",
        )
