"""Fig. S — driving-scenario suite (beyond the paper's stationary runs).

Two parts:

1. Bundled scenarios, replanned vs. pinned: the same policy either
   hot-swaps per-mode GHA schedules on ``mode_change`` or keeps the
   table compiled for the scenario's opening mode.  Validates that
   online replanning lowers the deadline-violation rate when the
   context shifts away from the initial mode (``calm_to_rush``) and
   that the swap cost stays inside the reallocation-waste budget.
2. A Monte-Carlo sweep of Markov-sampled scenarios across policies,
   fanned out over a process pool with deterministic per-scenario
   seeds — the fleet-scale view.

``--duration`` scales the sweep size, not the per-scenario length
(bundled scripts fix their own timelines).
"""
from __future__ import annotations

import dataclasses

from repro.scenarios import (
    ScenarioSpec,
    aggregate_sweep,
    build_trace,
    compile_portfolio,
    get_scenario,
    run as run_specs,
    sweep,
)

from .common import attribution_derived, emit, emit_sweep_aggregate


def run(duration: float = 1.0, seed: int = 1) -> None:
    # -- part 1: bundled scenarios, replan vs pinned --------------------
    for name in ("calm_to_rush", "commute", "night_storm"):
        scen = get_scenario(name)
        # one sampled trace per scenario: every policy/replan variant
        # sees identical per-job draws (and pays no re-sampling)
        trace = build_trace(ScenarioSpec(scenario=scen, policy="ads_tile",
                                         seed=seed))
        for policy in ("ads_tile", "tp_driven"):
            # one portfolio per (scenario, policy): the replanned and
            # pinned variants start from the identical table
            # record=True: every run carries the flight recorder, so
            # the rows also report the deadline-miss decomposition
            base = ScenarioSpec(scenario=scen, policy=policy, seed=seed,
                                record=True)
            base = dataclasses.replace(base, portfolio=compile_portfolio(base))
            for replan in (True, False):
                [r] = run_specs(dataclasses.replace(base, replan=replan),
                                trace=trace)
                per_mode = ";".join(
                    f"{m}_viol={s.violation_rate:.4f}"
                    for m, s in sorted(r.mode_stats.items())
                )
                att_str = attribution_derived(r.attribution)
                tag = "replan" if replan else "pinned"
                emit(
                    f"figS_{name}_{policy}_{tag}",
                    r.violation_rate * 1e6,
                    f"viol={r.violation_rate:.4f};miss={r.task_miss_rate:.4f};"
                    f"realloc={r.realloc_frac:.4f};"
                    f"switches={r.n_mode_switches};{att_str};{per_mode}",
                )

    # -- part 2: Monte-Carlo sweep of random drives ---------------------
    n = max(4, int(round(20 * duration)))
    rows = sweep(
        n, policies=("ads_tile", "tp_driven"),
        duration_s=2.0, seed=seed, record=True,
    )
    emit_sweep_aggregate(aggregate_sweep(rows), "figS_sweep")
