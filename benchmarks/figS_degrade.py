"""Fig. S-degrade — degraded operation: faults, throttles, storms, BW loss.

The paper's scheduling claims are strongest exactly when the platform
is *not* nominal: a partition losing tiles, a thermal envelope
clamping throughput, a sensor storm dropping frames, the memory
fabric losing bandwidth.  This suite injects the bundled
``degraded_commute`` fault timeline (one event of each kind) and
compares how the policies ride through it on identical drives (same
seeds, one shared trace per seed, so every comparison is paired at
the job level):

* ``cyc``       — static cyclic executive (work-conserving baseline);
* ``tp_driven`` — throughput-driven partitioning baseline;
* ``ads_tile``  — the paper's isolation-aware policy with online
  replanning: on a ``tile_fault`` the replanner re-selects a
  ``ModeFrontier`` point that fits the surviving tiles and hot-swaps
  to it (an online partition morph when the point's partition count
  differs), restoring the nominal table when the fault lifts.

Per policy and per event kind the rows report the two headline
recovery metrics (``SimReport.degrade``): **misses-during** (chain
deadline violations inside the degradation window, until recovered)
and **time-to-recover** (seconds past the event's end until the first
on-time chain completion; NaN windows never recovered).  The headline
row asserts the acceptance comparison: ads_tile must take strictly
fewer fault-window misses than the work-conserving baseline.

Part 2 isolates the cost of the degradations themselves: the same
drives with the fault timeline stripped (``degradations=()``), ads_tile
only — the delta is what the injected events cost end-to-end.

``--duration`` scales the number of paired seeds, not the per-drive
length (the bundled script fixes its own 2 s timeline).
"""
from __future__ import annotations

import dataclasses
import math

from repro.scenarios import ScenarioSpec, get_scenario
from repro.scenarios.runner import (
    build_trace,
    compile_portfolio,
    run as run_specs,
)

from .common import emit

POLICIES = ("cyc", "tp_driven", "ads_tile")

#: the work-conserving baseline the acceptance headline compares against
BASELINE = "tp_driven"


def _fold(agg: dict, report) -> None:
    """Fold one run's degradation windows into a per-kind aggregate."""
    agg["viol"] += report.violation_rate
    agg["realloc"] += report.realloc_frac
    agg["n_runs"] += 1
    for st in report.degrade:
        k = agg["kinds"].setdefault(
            st.kind, {"misses": 0, "n": 0, "recovered": 0, "recover_s": 0.0}
        )
        k["misses"] += st.misses_during
        k["n"] += 1
        if not math.isnan(st.recover_s):
            k["recovered"] += 1
            k["recover_s"] += st.recover_s


def _kind_str(kinds: dict) -> str:
    parts = []
    for kind in sorted(kinds):
        k = kinds[kind]
        rec = k["recover_s"] / k["recovered"] if k["recovered"] else float("nan")
        parts.append(
            f"{kind}_miss={k['misses']};{kind}_rec_s={rec:.4f};"
            f"{kind}_recovered={k['recovered']}/{k['n']}"
        )
    return ";".join(parts)


def run(duration: float = 1.0, seed: int = 1) -> None:
    # -- part 1: bundled fault timeline, paired seeds, all policies -----
    scen = get_scenario("degraded_commute")
    n_seeds = max(2, int(round(3 * duration)))
    pf = {
        pol: compile_portfolio(ScenarioSpec(scenario=scen, policy=pol, seed=seed))
        for pol in POLICIES
    }
    agg = {
        pol: {"viol": 0.0, "realloc": 0.0, "n_runs": 0, "kinds": {}}
        for pol in POLICIES
    }
    for s in range(seed, seed + n_seeds):
        trace = build_trace(ScenarioSpec(scenario=scen, policy="ads_tile", seed=s))
        for pol in POLICIES:
            spec = ScenarioSpec(
                scenario=scen, policy=pol, seed=s, portfolio=pf[pol]
            )
            [r] = run_specs(spec, trace=trace)
            _fold(agg[pol], r)
    for pol in POLICIES:
        a = agg[pol]
        emit(
            f"figS_degrade_{pol}",
            (a["viol"] / a["n_runs"]) * 1e6,
            f"viol={a['viol'] / a['n_runs']:.4f};"
            f"realloc={a['realloc'] / a['n_runs']:.5f};"
            f"seeds={n_seeds};{_kind_str(a['kinds'])}",
        )

    def _fault_misses(pol: str) -> int:
        k = agg[pol]["kinds"].get("tile_fault")
        return k["misses"] if k else 0

    ads, base = _fault_misses("ads_tile"), _fault_misses(BASELINE)
    emit(
        "figS_degrade_headline",
        float(base - ads) * 1e6,
        f"ads_fault_miss={ads};{BASELINE}_fault_miss={base};"
        f"ads_recovers_with_fewer_misses={ads < base};seeds={n_seeds}",
    )

    # -- part 2: ablation — same drives, fault timeline stripped --------
    clean_scen = dataclasses.replace(scen, degradations=())
    clean_spec = ScenarioSpec(scenario=clean_scen, policy="ads_tile", seed=seed)
    pf_clean = compile_portfolio(clean_spec)
    viol = 0.0
    for s in range(seed, seed + n_seeds):
        spec = dataclasses.replace(clean_spec, seed=s, portfolio=pf_clean)
        [r] = run_specs(spec, trace=build_trace(spec))
        viol += r.violation_rate
    degraded = agg["ads_tile"]["viol"] / agg["ads_tile"]["n_runs"]
    clean = viol / n_seeds
    emit(
        "figS_degrade_ablation",
        max(degraded - clean, 0.0) * 1e6,
        f"degraded_viol={degraded:.4f};clean_viol={clean:.4f};"
        f"degrade_cost={degraded - clean:.4f};seeds={n_seeds}",
    )
