"""CI perf-regression gate: fresh ``perf_bench`` JSON vs the committed
baseline.

Usage::

    python -m benchmarks.check_perf BENCH_sim.json BENCH_sim-py3.12.json \
        [--metric perf_sweep_e2e] [--threshold 1.5]

Both files are ``benchmarks.run --out`` artifacts.  The gate compares
the per-run wall-clock (``us_per_call``) of ``--metric`` — by default
``perf_sweep_e2e``, the pinned 8x2 Monte-Carlo sweep that exercises the
whole engine — and **fails (exit 2) when the fresh number regresses by
more than ``--threshold``x** over the committed baseline.

The committed ``BENCH_sim.json`` was measured on the reference dev
container; CI runners are not identical hardware, which is why the
default threshold is a generous 1.5x — it exists to catch
order-of-magnitude engine regressions (an accidentally quadratic loop,
a lost cache), not single-digit percentages.  When a PR legitimately
changes the perf envelope, refresh the baseline (see
``docs/performance.md``; CI's ``refresh-baseline`` job measures a
candidate on a hosted runner) in the same PR.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_METRIC = "perf_sweep_e2e"
DEFAULT_THRESHOLD = 1.5


def load_metric(path: Path, metric: str) -> dict:
    """The named row of a ``benchmarks.run --out`` JSON file."""
    data = json.loads(path.read_text())
    for row in data.get("rows", []):
        if row.get("name") == metric:
            return row
    raise KeyError(f"{path}: no row named {metric!r}")


def new_rows(baseline: Path, fresh: Path) -> list:
    """Row names present in ``fresh`` but absent from ``baseline``.

    A PR that adds a benchmark row without refreshing the committed
    baseline leaves the new row un-gated — the next regression in it
    would sail through CI.  That is worth a loud warning but not a
    failure: the refresh procedure needs a quiet reference machine
    (docs/performance.md#refreshing-the-baseline), so the row may land
    one PR before its baseline does.
    """
    names = {
        row.get("name") for row in json.loads(baseline.read_text()).get("rows", [])
    }
    return [
        row.get("name")
        for row in json.loads(fresh.read_text()).get("rows", [])
        if row.get("name") not in names
    ]


def check(
    baseline: Path,
    fresh: Path,
    metric: str = DEFAULT_METRIC,
    threshold: float = DEFAULT_THRESHOLD,
) -> tuple:
    """``(ratio, ok)`` — fresh/baseline per-call wall-clock vs gate."""
    base = load_metric(baseline, metric)
    new = load_metric(fresh, metric)
    base_us = float(base["us_per_call"])
    new_us = float(new["us_per_call"])
    if base_us <= 0:
        raise ValueError(f"{baseline}: non-positive baseline {base_us}")
    ratio = new_us / base_us
    return ratio, ratio <= threshold


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", type=Path, help="committed reference (BENCH_sim.json)")
    ap.add_argument("fresh", type=Path, help="freshly measured perf-smoke artifact")
    ap.add_argument(
        "--metric",
        default=DEFAULT_METRIC,
        help=f"row to compare (default {DEFAULT_METRIC})",
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=DEFAULT_THRESHOLD,
        help=f"fail when fresh/baseline exceeds this (default {DEFAULT_THRESHOLD})",
    )
    args = ap.parse_args(argv)

    for name in new_rows(args.baseline, args.fresh):
        print(
            f"WARNING: row {name!r} is measured fresh but absent from "
            f"{args.baseline} — it is not perf-gated until the committed "
            "baseline is refreshed "
            "(docs/performance.md#refreshing-the-baseline)",
            file=sys.stderr,
        )

    ratio, ok = check(args.baseline, args.fresh, args.metric, args.threshold)
    verdict = "OK" if ok else "REGRESSION"
    print(
        f"{args.metric}: fresh/baseline = {ratio:.2f}x "
        f"(threshold {args.threshold}x) -> {verdict}"
    )
    if not ok:
        print(
            "perf gate failed: either fix the regression or, if the "
            "change is intentional, refresh the committed baseline "
            "(docs/performance.md#refreshing-the-baseline)",
            file=sys.stderr,
        )
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
