"""Fig. 12 — end-to-end evaluation: p99 E2E tail latency and violation
rate vs tile count, under light/medium/heavy workloads, with hard/soft
drop policies for Tp-driven and ADS-Tile (no-drop).

Validates: violation rate falls with tiles for every policy; ADS-Tile's
tail-latency curve is *flat near the deadline bound* while Tp-driven's
dives only with excess hardware; ADS-Tile meets the bound with fewer
tiles at medium/heavy load.
"""
from __future__ import annotations

import numpy as np

from repro.core.benchmark import make_ads_benchmark
from repro.core.experiment import ExperimentSpec, run_experiment

from .common import emit

CASES = (
    ("light", 1, 0.100, (225, 260, 300, 355)),
    ("medium", 6, 0.090, (260, 300, 355, 400, 440)),
    ("heavy", 9, 0.080, (300, 355, 400, 430, 500)),
)


def _q_for(policy: str, reps: int) -> float:
    # quantile per the paper's two-step guideline (§V-B): conservative for
    # light loads, relaxed under pressure (tail-composition headroom)
    if policy.startswith("ads") or policy == "reserv":
        return 0.95 if reps <= 1 else (0.9 if reps <= 6 else 0.8)
    return 0.95


def run(duration: float = 1.0, seed: int = 1) -> None:
    wf = make_ads_benchmark()
    crit = {c.name: c.critical for c in wf.chains}

    for case, reps, ddl, tile_grid in CASES:
        for tiles in tile_grid:
            for policy, drop in (
                ("tp_driven", "soft"),
                ("tp_driven_hard", "hard"),
                ("ads_tile", "soft"),
            ):
                r = run_experiment(ExperimentSpec(
                    policy=policy, tiles=tiles, cockpit_replicas=reps,
                    deadline_s=ddl, q=_q_for(policy, reps),
                    duration_s=duration, seed=seed, drop_policy=drop,
                ))
                # split driving vs cockpit p99 like the paper
                drv, ck = [], []
                for ch, lats in r.chain_latencies.items():
                    (drv if crit.get(ch.split("#")[0], ch.startswith("drv"))
                     else ck).extend(lats)
                p99d = float(np.percentile(drv, 99)) if drv else float("nan")
                p99c = float(np.percentile(ck, 99)) if ck else float("nan")
                emit(
                    f"fig12_{case}_t{tiles}_{policy}",
                    r.violation_rate * 1e6,
                    f"viol={r.violation_rate:.4f};p99_drv_ms={p99d*1e3:.1f};"
                    f"p99_ck_ms={p99c*1e3:.1f};realloc={r.realloc_frac:.4f}",
                )
