#!/usr/bin/env python3
"""Check relative links in the project's markdown docs.

Walks ``README.md`` plus ``docs/*.md`` and verifies that every
relative markdown link — ``[text](path)`` and ``[text](path#anchor)``
— resolves to an existing file or directory, and that in-page /
cross-page ``#anchor`` fragments match a heading in the target file
(GitHub-style slugs).  External links (``http(s)://``, ``mailto:``)
are ignored: this is a repo-consistency check, not a crawler.

Dependency-free by design so it can run in the CI lint job (and
pre-commit) without installing anything:

    python scripts/check_docs_links.py

Exit status 0 when every link resolves, 1 otherwise (each broken link
is reported as ``file:line: message``).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

#: files scanned: the project front door plus the docs tree
DOC_GLOBS = ("README.md", "docs/*.md")

#: inline markdown links; [text](target) with no nested parens in target
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

SKIP_SCHEMES = ("http://", "https://", "mailto:", "#!")


def _slugify(heading: str) -> str:
    """GitHub-style anchor slug for a markdown heading."""
    text = heading.strip().lower()
    # drop inline markup that does not survive into the anchor
    text = re.sub(r"[`*_]", "", text)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # link -> text
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _anchors(md_path: Path) -> set[str]:
    """All heading anchors defined in a markdown file."""
    anchors: set[str] = set()
    in_fence = False
    for line in md_path.read_text(encoding="utf-8").splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = re.match(r"\s{0,3}(#{1,6})\s+(.*)", line)
        if m:
            slug = _slugify(m.group(2))
            # GitHub de-duplicates repeats as slug-1, slug-2, ...
            candidate, n = slug, 1
            while candidate in anchors:
                candidate = f"{slug}-{n}"
                n += 1
            anchors.add(candidate)
    return anchors


def _iter_links(md_path: Path):
    """Yield ``(lineno, target)`` for each link, skipping code fences."""
    in_fence = False
    for lineno, line in enumerate(
        md_path.read_text(encoding="utf-8").splitlines(), start=1
    ):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        # inline code spans frequently hold (...) that isn't a link
        line = re.sub(r"`[^`]*`", "", line)
        for m in LINK_RE.finditer(line):
            yield lineno, m.group(1)


def check() -> list[str]:
    errors: list[str] = []
    anchor_cache: dict[Path, set[str]] = {}

    def anchors_of(path: Path) -> set[str]:
        if path not in anchor_cache:
            anchor_cache[path] = _anchors(path)
        return anchor_cache[path]

    files = sorted(
        p for glob in DOC_GLOBS for p in REPO.glob(glob) if p.is_file()
    )
    if not files:
        return [f"{REPO}: no markdown files matched {DOC_GLOBS}"]

    for md in files:
        rel_md = md.relative_to(REPO)
        for lineno, target in _iter_links(md):
            if target.startswith(SKIP_SCHEMES):
                continue
            path_part, _, fragment = target.partition("#")
            if path_part:
                dest = (md.parent / path_part).resolve()
                try:
                    dest.relative_to(REPO)
                except ValueError:
                    errors.append(
                        f"{rel_md}:{lineno}: link escapes the repo: {target}"
                    )
                    continue
                if not dest.exists():
                    errors.append(
                        f"{rel_md}:{lineno}: broken link target: {target}"
                    )
                    continue
            else:
                dest = md  # pure in-page anchor
            if fragment and dest.suffix == ".md" and dest.is_file():
                if fragment.lower() not in anchors_of(dest):
                    errors.append(
                        f"{rel_md}:{lineno}: missing anchor "
                        f"#{fragment} in {dest.relative_to(REPO)}"
                    )
    return errors


def main() -> int:
    errors = check()
    for err in errors:
        print(err, file=sys.stderr)
    n_files = len([p for g in DOC_GLOBS for p in REPO.glob(g)])
    if errors:
        print(f"# {len(errors)} broken link(s) across {n_files} files",
              file=sys.stderr)
        return 1
    print(f"# docs link check: {n_files} files OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
