"""Degraded operation: fault seams, recovery accounting, partition morphs.

Three contracts under test (docs/degradation.md):

1. **Isolation of the new seams.**  Scenarios without degradations
   draw nothing from the degrade stream and execute the exact
   pre-degradation arithmetic — pinned 8-byte digests of every bundled
   scenario x policy cell must not move, and recorder-off degraded
   runs are bit-reproducible.
2. **Recovery accounting.**  Each injected event opens a
   :class:`~repro.core.sim.engine.DegradeStats` window reporting
   misses-during-degradation and time-to-recover, identically across
   the scalar and lockstep backends (the lockstep engine routes
   degraded lanes through its bit-identical scalar lane; the SoA
   backend refuses them by name).
3. **Online partition morphing.**  ``hotswap_schedule`` across
   partition counts retires/creates partitions without losing jobs or
   accounting, and the fault-responding replanner swaps to a
   frontier point that fits the surviving tiles, restoring the
   nominal table when the fault lifts.
"""
import dataclasses
import hashlib
import math

import pytest

from repro.core.experiment import build_stack, make_policy
from repro.core.runtime import OnlineReplanner, SchedulePortfolio
from repro.core.sim import SimConfig, Simulator
from repro.core.sim.batch import report_digest, reports_identical
from repro.obs import TraceRecorder
from repro.scenarios import (
    DEGRADATION_TYPES,
    BandwidthLoss,
    ScenarioScript,
    ScenarioSpec,
    SensorDropoutStorm,
    ThermalThrottle,
    TileFault,
    get_mode,
    get_scenario,
    run,
)
from repro.scenarios.runner import build_trace, compile_portfolio, soa_usable

POLICIES = ("cyc", "tp_driven", "ads_tile")


def _digest8(report) -> str:
    return hashlib.blake2b(
        repr(report_digest(report)).encode(), digest_size=8
    ).hexdigest()


def _spec(name="degraded_commute", policy="ads_tile", seed=7, **kw):
    return ScenarioSpec(
        scenario=get_scenario(name), policy=policy, seed=seed, **kw
    )


# ---------------------------------------------------------------------------
# 1. isolation: degradation-free runs must not move
# ---------------------------------------------------------------------------
#: 8-byte digests of every pre-degradation bundled cell at seed 7,
#: scalar backend — captured before the degradation seams landed.  A
#: change here means the seams leak into nominal runs (new stream
#: draws, capacity arithmetic, accounting) and is a regression.
PINNED_NOMINAL = {
    ("calm_to_rush", "cyc"): "d960b7459dd59a40",
    ("calm_to_rush", "tp_driven"): "e41e101689dbf9ca",
    ("calm_to_rush", "ads_tile"): "e205c0044b6c8ecd",
    ("commute", "cyc"): "8d4e5ba160077904",
    ("commute", "tp_driven"): "5e20090dd4ab4b1a",
    ("commute", "ads_tile"): "4158beb6dc54a345",
    ("night_storm", "cyc"): "8461b339650e9c41",
    ("night_storm", "tp_driven"): "e06de63b75cbf92c",
    ("night_storm", "ads_tile"): "182c9eed9cabb780",
    ("rate_churn", "cyc"): "b537be5ea2f89c9c",
    ("rate_churn", "tp_driven"): "b27c0e055a044d59",
    ("rate_churn", "ads_tile"): "4391f2129609a33c",
}


@pytest.mark.parametrize(("scenario", "policy"), sorted(PINNED_NOMINAL))
def test_nominal_scenarios_pinned(scenario, policy):
    [r] = run(_spec(scenario, policy), backend="scalar")
    assert not r.degrade
    assert _digest8(r) == PINNED_NOMINAL[(scenario, policy)]
    assert "degrade" not in report_digest(r)


def test_degraded_runs_deterministic_and_recorder_transparent():
    spec = _spec()
    trace = build_trace(spec)
    [a] = run(spec, trace=trace, backend="scalar")
    [b] = run(spec, trace=trace, backend="scalar")
    assert reports_identical(a, b)
    assert "degrade" in report_digest(a)
    rec = TraceRecorder()
    [c] = run(spec, trace=trace, recorders={0: rec}, backend="scalar")
    d_a, d_c = dataclasses.asdict(a), dataclasses.asdict(c)
    assert d_a.pop("attribution") is None
    assert d_c.pop("attribution") is not None
    assert d_a == d_c


# ---------------------------------------------------------------------------
# 2. recovery accounting + backend parity
# ---------------------------------------------------------------------------
def test_degrade_windows_report_recovery_metrics():
    scen = get_scenario("degraded_commute")
    [r] = run(_spec(), backend="scalar")
    assert [st.kind for st in r.degrade] == [
        d.kind for d in sorted(scen.degradations, key=lambda d: d.start_s)
    ]
    for st in r.degrade:
        assert 0.0 <= st.t_start < st.t_end <= scen.duration_s
        assert st.misses_during >= 0
        assert st.completions_during >= st.misses_during
        assert math.isnan(st.recover_s) or st.recover_s >= 0.0
    # the chain accounting still reconciles across the seams
    assert sum(s.n_completed for s in r.mode_stats.values()) == sum(
        r.chain_count.values()
    )


def test_ads_tile_recovers_with_fewer_misses_than_baseline():
    """Acceptance: on the bundled fault scenario, isolation-aware
    scheduling rides through the tile fault with strictly fewer
    misses-during-degradation than the work-conserving baseline."""
    spec = _spec()
    trace = build_trace(spec)
    misses = {}
    for policy in ("ads_tile", "tp_driven"):
        [r] = run(
            dataclasses.replace(spec, policy=policy), trace=trace,
            backend="scalar",
        )
        misses[policy] = {st.kind: st.misses_during for st in r.degrade}
    assert misses["ads_tile"]["tile_fault"] < misses["tp_driven"]["tile_fault"]


@pytest.mark.parametrize("policy", POLICIES)
def test_lockstep_bit_identical_under_degradations(policy):
    spec = _spec(policy=policy, seed=0)
    seeds = [0, 7]
    fan = run(spec, seeds=seeds, backend="lockstep")
    for s, rb in zip(seeds, fan):
        [rs] = run(
            dataclasses.replace(spec, seed=int(s)), backend="scalar"
        )
        assert rb.degrade and reports_identical(rs, rb), (policy, s)


def test_soa_backend_refuses_degraded_scenarios():
    ok, why = soa_usable(_spec())
    assert not ok and "degrad" in why


def test_degrade_events_recorded():
    spec = _spec(record=False)
    rec = TraceRecorder()
    run(spec, recorders={0: rec}, backend="scalar")
    counts = rec.counts()
    n_events = len(spec.scenario.degradations)
    assert counts.get("degrade_begin") == n_events
    # every bundled event ends inside the 2 s horizon
    assert counts.get("degrade_end") == n_events
    kinds = {e.info for e in rec.by_kind("degrade_begin")}
    assert kinds == {d.kind for d in spec.scenario.degradations}


# ---------------------------------------------------------------------------
# 3. morphing + fault-aware replanning
# ---------------------------------------------------------------------------
def test_portfolio_harmonization_flag():
    """The legacy harmonized compile stays pinned behind the flag; the
    morphing path compiles per-mode counts unharmonized."""
    scen = get_scenario("rate_churn")
    spec = ScenarioSpec(scenario=scen, policy="ads_tile", seed=2)
    wf, _hw, model, compiler = build_stack(spec)
    modes = {m: get_mode(m) for m in scen.modes()}
    kw = dict(target_miss=0.4, partition_span=1)
    pf_harm = SchedulePortfolio.compile(model, wf, modes, compiler, **kw)
    counts = {len(s.partitions) for s in pf_harm.schedules.values()}
    assert len(counts) == 1
    pf_free = SchedulePortfolio.compile(
        model, wf, modes, compiler, harmonize_partitions=False, **kw
    )
    # unharmonized selection keeps each mode's own best point...
    for m, point in pf_free.selected.items():
        assert point.tiles <= pf_harm.selected[m].tiles, m
    # ...and the engine runs it even when the counts differ
    [r] = run(
        dataclasses.replace(spec, portfolio=pf_free), backend="scalar"
    )
    assert r.n_mode_switches == len(scen.segments) - 1
    # the spec flag threads through the runner's own compile
    pf_spec = compile_portfolio(
        dataclasses.replace(spec, harmonize_partitions=False),
    )
    assert {m: p.tiles for m, p in pf_spec.selected.items()}


def _morph_portfolio(spec, counts):
    """A per-mode portfolio with *differing* partition counts (the
    autotuner harmonizes by default, so build one directly)."""
    wf, _hw, model, compiler = build_stack(spec)
    scheds = {}
    for mode, n in zip(spec.scenario.modes(), counts):
        mm = get_mode(mode).transform_model(model)
        scheds[mode] = dataclasses.replace(compiler, num_partitions=n).compile(
            mm, wf
        )
    return SchedulePortfolio(schedules=scheds)


def test_online_morph_conserves_jobs_and_accounting():
    scen = ScenarioScript.parse("urban:0.5 rush_hour:0.4 urban:0.4")
    spec = ScenarioSpec(scenario=scen, policy="ads_tile", seed=5)
    pf = _morph_portfolio(spec, (4, 2))
    assert {len(s.partitions) for s in pf.schedules.values()} == {2, 4}
    spec = dataclasses.replace(spec, portfolio=pf)
    rec = TraceRecorder()
    [r] = run(spec, recorders={0: rec}, backend="scalar")
    morphs = list(rec.by_kind("morph"))
    # urban->rush_hour shrinks 4->2, rush_hour->urban grows 2->4
    assert [int(m.value) for m in morphs] == [2, 4]
    assert r.n_mode_switches == 2
    # no jobs lost or double-counted across the morphs: every released
    # chain is accounted once, and per-mode stats cover the horizon
    assert sum(s.n_completed for s in r.mode_stats.values()) == sum(
        r.chain_count.values()
    )
    for m in scen.modes():
        assert r.mode_stats[m].n_completed > 0, m
    # retired-partition work stays in the report: tiles were busy in
    # every segment, including after the shrink
    assert r.effective_frac > 0
    # morphing runs are deterministic, and the lockstep fast lane
    # (which drives morphs through the engine's own hotswap verb)
    # stays bit-identical to the scalar reference
    [r2] = run(dataclasses.replace(spec), backend="scalar")
    assert reports_identical(r, r2)
    [rl] = run(dataclasses.replace(spec), seeds=[5], backend="lockstep")
    assert reports_identical(r2, rl)


def test_morph_seam_integrity_no_job_leaks():
    """Across a shrink morph, every job released before the seam either
    finishes or is dropped — none vanish into a retired partition."""
    scen = ScenarioScript.parse("urban:0.5 rush_hour:0.5")
    spec = ScenarioSpec(scenario=scen, policy="ads_tile", seed=3)
    spec = dataclasses.replace(spec, portfolio=_morph_portfolio(spec, (4, 2)))
    rec = TraceRecorder()
    run(spec, recorders={0: rec}, backend="scalar")
    assert rec.counts().get("morph") == 1
    started = {e.jid for e in rec.by_kind("job_start")}
    finished = {e.jid for e in rec.by_kind("job_finish")}
    dropped = {e.jid for e in rec.by_kind("job_drop")}
    # a job resolves at most one way
    assert not (finished & dropped)
    # after the shrink no job finishes on a retired partition
    n_after = len(spec.portfolio.schedules["rush_hour"].partitions)
    for e in rec.by_kind("job_finish"):
        if e.t > 0.5 + 1e-9:
            assert e.partition < n_after, (e.jid, e.partition, e.t)
    # jobs preempted by the morph were running, and none vanish: each
    # restarts, finishes or is deadline-dropped after the seam
    morph_preempts = {
        e.jid for e in rec.by_kind("job_preempt") if e.info == "morph_retire"
    }
    assert morph_preempts <= started
    touched_after = {
        e.jid for e in rec.events
        if e.t > 0.5 - 1e-9
        and e.kind in ("job_start", "job_finish", "job_drop")
    }
    assert morph_preempts <= touched_after


def test_fault_replanner_swaps_and_restores():
    """On a tile fault the replanner installs a frontier point fitting
    the surviving tiles; when the fault lifts it restores the mode's
    nominal table.  A targeted compile keeps a rich frontier, so a
    fitting point exists (the default q-ladder's conservative points
    may all exceed the surviving budget — then the replanner rides the
    fault out, which the ``respond_to_faults=False`` leg pins too)."""
    spec = _spec(target_miss=0.4)
    wf, _hw, model, _compiler = build_stack(spec)
    portfolio = compile_portfolio(spec)
    scen = spec.scenario
    sched = portfolio.schedules[scen.segments[0].mode]
    pol = make_policy("ads_tile")
    pol.replanner = OnlineReplanner(portfolio)
    sim = Simulator(
        wf, model, sched, pol,
        SimConfig(duration_s=scen.duration_s, seed=7, scenario=scen),
    )
    sim.run()
    assert pol.replanner.n_degrade_swaps >= 1
    assert not sim.fault_tiles_lost  # the bundled fault lifted in-run
    # a replanner told to ride faults out never swaps for them
    pol2 = make_policy("ads_tile")
    pol2.replanner = OnlineReplanner(portfolio, respond_to_faults=False)
    sim2 = Simulator(
        wf, model, sched, pol2,
        SimConfig(duration_s=scen.duration_s, seed=7, scenario=scen),
    )
    sim2.run()
    assert pol2.replanner.n_degrade_swaps == 0


def test_select_within_tiles_contract():
    spec = ScenarioSpec(
        scenario=get_scenario("rate_churn"), policy="ads_tile", seed=1
    )
    pf = compile_portfolio(spec)
    frontier = next(iter(pf.frontiers.values()))
    tiles = sorted(p.tiles for p in frontier.points)
    assert frontier.select_within_tiles(0) is None
    for cap in (tiles[0], tiles[len(tiles) // 2], tiles[-1]):
        point = frontier.select_within_tiles(cap)
        assert point is not None and point.tiles <= cap
    # a target_miss keeps the cheapest point meeting it under the cap
    top = frontier.select_within_tiles(tiles[-1], target_miss=1.0)
    assert top is not None and top.tiles == tiles[0]


def test_degradation_dsl_types():
    scen = get_scenario("degraded_commute")
    assert scen.has_degradations
    assert {type(d) for d in scen.degradations} == set(DEGRADATION_TYPES)
    fault = next(d for d in scen.degradations if isinstance(d, TileFault))
    assert fault.k_tiles > 0 and fault.end_s(scen.duration_s) > fault.start_s
    throttle = next(
        d for d in scen.degradations if isinstance(d, ThermalThrottle)
    )
    assert scen.throttle_factor(throttle.start_s + throttle.ramp_s) > 1.0
    storm = next(
        d for d in scen.degradations if isinstance(d, SensorDropoutStorm)
    )
    assert 0.0 < storm.drop_frac <= 1.0
    bw = next(d for d in scen.degradations if isinstance(d, BandwidthLoss))
    mid = (bw.start_s + bw.end_s(scen.duration_s)) / 2.0
    assert scen.bandwidth_scale(mid) < 1.0
    assert scen.bandwidth_scale(scen.duration_s + 1.0) == 1.0
