"""Per-mode sensor rates + piecewise hyper-period re-unrolling tests:
workflow re-derivation, segment unrolling, seam integrity (no
double-released or lost jobs), determinism, and the per-mode portfolio
hyper-periods."""
import numpy as np
import pytest

from repro.core.benchmark import make_ads_benchmark
from repro.core.experiment import build_stack, make_policy
from repro.core.hardware import simba_chip
from repro.core.latency_model import LatencyModel
from repro.core.runtime import SchedulePortfolio
from repro.core.sim import SimConfig, Simulator
from repro.core.workload import unroll_hyperperiod
from repro.scenarios import (
    MODES,
    DrivingMode,
    ScenarioScript,
    ScenarioSpec,
    default_generator,
    get_mode,
    get_scenario,
    register_mode,
    run,
    sweep,
)


# ---------------------------------------------------------------------------
# Workflow.with_sensor_rates
# ---------------------------------------------------------------------------
def test_with_sensor_rates_rederives_hyperperiod():
    wf = make_ads_benchmark()
    assert np.isclose(wf.hyper_period_s, 0.1)
    wf2 = wf.with_sensor_rates({"cam_multi": 1.0 / 15.0})
    assert np.isclose(wf2.tasks["cam_multi"].period_s, 1.0 / 15.0)
    assert np.isclose(wf2.hyper_period_s, 0.2)
    # untouched: the DAG, chains, and the original workflow
    assert wf2.edges == wf.edges
    assert [c.name for c in wf2.chains] == [c.name for c in wf.chains]
    assert np.isclose(wf.tasks["cam_multi"].period_s, 1.0 / 30.0)


def test_with_sensor_rates_identity_and_validation():
    wf = make_ads_benchmark()
    assert wf.with_sensor_rates({"cam_multi": 1.0 / 30.0}) is wf
    assert wf.with_sensor_rates({}) is wf
    with pytest.raises(ValueError):
        wf.with_sensor_rates({"img_backbone": 0.1})   # not a sensor
    with pytest.raises(ValueError):
        wf.with_sensor_rates({"cam_multi": 0.0})


# ---------------------------------------------------------------------------
# segment unrolling
# ---------------------------------------------------------------------------
def test_unroll_segment_matches_default_on_one_hyperperiod():
    wf = make_ads_benchmark()
    assert unroll_hyperperiod(wf) == unroll_hyperperiod(
        wf, 0.0, wf.hyper_period_s
    )


def test_unroll_segment_absolute_releases_and_phase():
    wf = make_ads_benchmark()
    insts = unroll_hyperperiod(wf, t0=1.0, t1=1.25)
    assert all(1.0 - 1e-12 <= i.release_s < 1.25 for i in insts)
    cam = sorted(i.release_s for i in insts if i.task == "cam_multi")
    assert len(cam) == 8                      # 1.0 + k/30 < 1.25
    assert np.allclose(np.diff(cam), 1.0 / 30.0)
    # dependencies stay event-time consistent inside the segment
    by_key = {(i.task, i.index): i for i in insts}
    for i in insts:
        for p in i.preds:
            assert by_key[p].release_s <= i.release_s + 1e-9
    shifted = unroll_hyperperiod(wf, t0=1.0, t1=1.25, phase_s=0.01)
    cam_s = sorted(i.release_s for i in shifted if i.task == "cam_multi")
    assert np.isclose(cam_s[0], 1.01)


# ---------------------------------------------------------------------------
# mode-level rate modulation
# ---------------------------------------------------------------------------
def test_bundled_modes_modulate_rates():
    wf = make_ads_benchmark()
    night = get_mode("night").transform_workflow(wf)
    assert np.isclose(1.0 / night.tasks["cam_multi"].period_s, 15.0)
    rush = get_mode("rush_hour").transform_workflow(wf)
    assert np.isclose(1.0 / rush.tasks["cam_multi"].period_s, 60.0)
    storm = get_mode("adverse_weather").transform_workflow(wf)
    assert np.isclose(1.0 / storm.tasks["lidar"].period_s, 20.0)
    # a rate-free mode returns the workflow untouched
    assert get_mode("urban").transform_workflow(wf) is wf
    # a typo'd sensor key fails fast instead of silently modulating nothing
    bad = DrivingMode(name="typo", sensor_rate_hz={"camera": 60.0})
    with pytest.raises(ValueError):
        bad.transform_workflow(wf)


def test_rate_regimes_merge_equal_rates():
    wf = make_ads_benchmark()
    # urban/highway modulate no rate: one regime despite a mode switch
    s = ScenarioScript.parse("urban:0.5 highway:0.5")
    regimes = s.rate_regimes(wf, 1.0)
    assert len(regimes) == 1
    assert regimes[0][:2] == (0.0, 1.0)
    assert not s.modulates_rates(wf)
    # a night seam re-anchors at 0.5
    s2 = ScenarioScript.parse("urban:0.5 night:0.5")
    regimes = s2.rate_regimes(wf, 1.0)
    assert len(regimes) == 2
    assert regimes[1][0] == 0.5
    assert np.isclose(regimes[1][2].hyper_period_s, 0.2)
    assert s2.modulates_rates(wf)


# ---------------------------------------------------------------------------
# seam integrity in the engine
# ---------------------------------------------------------------------------
@pytest.fixture
def cam24_mode():
    """A mode with a non-integer rate ratio vs. the 30 Hz base camera
    (30 -> 24 Hz: neither hyper-period divides the other)."""
    register_mode(DrivingMode(
        name="cam24", sensor_rate_hz={"cam_multi": 24.0},
        description="test: 24 Hz cameras",
    ), overwrite=True)
    yield "cam24"
    del MODES["cam24"]


def _build_sim(script, seed=1, duration=1.0):
    spec = ScenarioSpec(scenario=script, policy="ads_tile", replan=False,
                        seed=seed)
    wf, _hw, model, compiler = build_stack(spec)
    sched = compiler.compile(model, wf)
    return Simulator(
        wf, model, sched, make_policy("ads_tile"),
        SimConfig(duration_s=duration, seed=seed, scenario=script),
    )


def test_non_integer_rate_seam_no_double_or_lost_jobs(cam24_mode):
    script = ScenarioScript.parse("urban:0.5 cam24:0.5")
    sim = _build_sim(script)
    cam = sorted(j.release for j in sim.jobs if j.task == "cam_multi")
    # regime 1: k/30 in [0, 0.5) -> 15 releases; regime 2 re-anchors at
    # 0.5: 0.5 + k/24 in [0.5, 1.0) -> 12 releases.  Exactly one release
    # at the seam, none duplicated, none lost.
    assert len(cam) == 15 + 12
    assert len(set(round(r, 9) for r in cam)) == len(cam)
    assert min(np.diff(cam)) > 1e-9
    assert any(np.isclose(r, 0.5) for r in cam)
    assert np.allclose(np.diff(cam[:15]), 1.0 / 30.0)
    assert np.allclose(np.diff(cam[15:]), 1.0 / 24.0)
    # the camera-gated DNN task follows the same piecewise release grid
    flow = sorted(j.release for j in sim.jobs if j.task == "optical_flow")
    assert flow == cam
    # and the run completes with reconciling per-mode accounting
    r = sim.run()
    assert r.n_mode_switches == 1
    assert (
        sum(s.n_completed for s in r.mode_stats.values())
        == sum(r.chain_count.values())
    )


def test_rate_seam_preserves_unmodulated_sensor_phase(cam24_mode):
    """Only the *modulated* sensor re-anchors at a rate seam: a seam at
    0.45 s is off-grid for the 10 Hz lidar, whose hardware timer nothing
    restarted — its releases must stay on the k * 0.1 grid across the
    seam instead of snapping to 0.45 + k * 0.1."""
    script = ScenarioScript.parse("urban:0.45 cam24:0.55")
    sim = _build_sim(script)
    # the final full cycle may overshoot the horizon (the engine skips
    # those events); only releases inside it are the seam's business
    lidar = sorted(j.release for j in sim.jobs
                   if j.task == "lidar" and j.release < 1.0 - 1e-9)
    # continuous 10 Hz cadence over the whole second, no seam artifact
    assert len(lidar) == 10
    assert np.allclose(lidar, np.arange(10) * 0.1, atol=1e-9)
    # the modulated camera does re-anchor: k/30 in [0, 0.45), then
    # 0.45 + k/24 in [0.45, 1.0)
    cam = sorted(j.release for j in sim.jobs
                 if j.task == "cam_multi" and j.release < 1.0 - 1e-9)
    assert len(cam) == 14 + 14
    assert np.allclose(np.diff(cam[:14]), 1.0 / 30.0)
    assert np.isclose(cam[14], 0.45)
    assert np.allclose(np.diff(cam[14:]), 1.0 / 24.0)
    # no duplicated or lost releases on either grid
    assert min(np.diff(cam)) > 1e-9
    # and the run still completes with reconciling accounting
    r = sim.run()
    assert r.n_mode_switches == 1
    assert (
        sum(s.n_completed for s in r.mode_stats.values())
        == sum(r.chain_count.values())
    )


def test_on_grid_seam_unrolls_identically_to_legacy_phase0():
    """Every bundled scenario's seams land on multiples of the
    unmodulated sensor periods; the phase map must then collapse to the
    legacy scalar 0.0 (same unroll-cache key, bit-identical releases)."""
    from repro.core.sim.trace import build_skeleton, clear_skeleton_cache

    wf = make_ads_benchmark()
    scen = get_scenario("rate_churn")
    clear_skeleton_cache()
    skel = build_skeleton(wf, scen, scen.duration_s)
    # unmodulated sensors stay on their own grid AND that grid equals
    # the seam-anchored one (the seams are on-grid), so both readings
    # of the releases agree
    rel = {}
    for jid, t in enumerate(skel.tasks):
        if skel.is_sensor[jid]:
            rel.setdefault(t, []).append(skel.release_list[jid])
    lidar = np.sort(rel["lidar"])
    assert np.allclose(lidar, np.arange(len(lidar)) * 0.1, atol=1e-9)
    imu = np.sort(rel["imu"])
    assert np.allclose(np.diff(imu), 1.0 / 240.0, atol=1e-9)


def test_horizon_shorter_than_script_builds_no_future_regimes():
    # a 0.2 s run over a 2.0 s script must not materialise jobs for
    # regimes (or cycles) beyond the horizon
    sim = _build_sim(get_scenario("rate_churn"), duration=0.2)
    assert len(sim._regimes) == 1            # night regime only
    assert max(j.release for j in sim.jobs) < 0.2
    r = sim.run()
    assert r.n_mode_switches == 0            # no seam inside the horizon


def test_piecewise_reunroll_deterministic():
    spec = ScenarioSpec(scenario=get_scenario("rate_churn"),
                        policy="ads_tile", seed=7)
    [a] = run(spec, backend="scalar")
    [b] = run(spec, backend="scalar")
    assert a.effective_frac == b.effective_frac
    assert a.realloc_frac == b.realloc_frac
    assert a.chain_violations == b.chain_violations
    assert {m: s.n_completed for m, s in a.mode_stats.items()} == \
           {m: s.n_completed for m, s in b.mode_stats.items()}


def test_rate_churn_per_mode_accounting_and_replanning():
    """Acceptance: a scenario whose modes change sensor rates runs
    end-to-end — the engine re-unrolls at each seam, every regime
    completes chains, and per-mode counts reconcile with the global
    chain accounting."""
    scen = get_scenario("rate_churn")
    [r] = run(ScenarioSpec(scenario=scen, policy="ads_tile",
                           replan=True, seed=3))
    assert r.n_mode_switches == len(scen.segments) - 1
    assert set(r.mode_stats) == set(scen.modes())
    assert np.isclose(sum(s.span_s for s in r.mode_stats.values()),
                      scen.duration_s)
    for s in r.mode_stats.values():
        assert s.n_completed > 0
    assert (
        sum(s.n_completed for s in r.mode_stats.values())
        == sum(r.chain_count.values())
    )
    # the camera upclock must actually raise the completion *rate* in
    # rush_hour vs night (60 Hz vs 15 Hz source over equal-ish spans)
    per_s = {m: s.n_completed / s.span_s for m, s in r.mode_stats.items()}
    assert per_s["rush_hour"] > per_s["night"]


def test_rate_churn_ads_tile_bounds_realloc_waste():
    """Acceptance: under rate churn ADS-Tile's gated reallocation beats
    the work-conserving baseline on realloc waste."""
    scen = get_scenario("rate_churn")
    waste = {}
    for policy in ("ads_tile", "tp_driven"):
        [r] = run(ScenarioSpec(scenario=scen, policy=policy,
                               replan=True, seed=1))
        waste[policy] = r.realloc_frac
    assert waste["ads_tile"] < waste["tp_driven"]


def test_sweep_ships_custom_modes_to_spawn_workers(cam24_mode):
    """Pool workers re-import a fresh mode registry; specs must carry
    custom mode definitions so rate-modulating custom modes survive."""
    gen = default_generator(
        transitions={"urban": {"cam24": 1.0}, "cam24": {"urban": 1.0}},
        mean_dwell_s={"urban": 0.3, "cam24": 0.3},
    )
    rows = sweep(2, policies=("ads_tile",), duration_s=0.6, seed=5,
                 jobs=2, generator=gen)
    assert len(rows) == 2
    assert all(0.0 <= r["violation_rate"] <= 1.0 for r in rows)


# ---------------------------------------------------------------------------
# per-mode schedule portfolio
# ---------------------------------------------------------------------------
def test_portfolio_compiles_per_mode_hyperperiod():
    wf = make_ads_benchmark()
    model = LatencyModel.from_workflow(wf, simba_chip(400))
    pf = SchedulePortfolio.compile(
        model, wf, {m: get_mode(m) for m in ("urban", "night")},
    )
    assert np.isclose(pf.schedules["urban"].meta["hyper_period_s"], 0.1)
    assert np.isclose(pf.schedules["night"].meta["hyper_period_s"], 0.2)
    assert pf.schedules["night"].meta["mode"] == "night"
