"""Unit tests for the probabilistic latency model (paper Eq. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.benchmark import make_ads_benchmark
from repro.core.hardware import simba_chip, tpu_pod
from repro.core.latency_model import (
    LatencyModel,
    LogNormal,
    ShiftedExponential,
    TaskLatencyProfile,
    chain_tail_composition,
    prune_dop_candidates,
)


def test_lognormal_moments():
    d = LogNormal(mean=100.0, p99_ratio=3.3)
    samples = d.sample(jax.random.PRNGKey(0), (200_000,))
    assert np.isclose(float(jnp.mean(samples)), 100.0, rtol=0.05)
    p99 = float(jnp.percentile(samples, 99))
    assert np.isclose(p99 / 100.0, 3.3, rtol=0.1)


def test_lognormal_quantile_matches_samples():
    d = LogNormal(mean=10.0, p99_ratio=2.0)
    samples = d.sample(jax.random.PRNGKey(1), (200_000,))
    for q in (0.5, 0.9, 0.99):
        emp = float(jnp.percentile(samples, q * 100))
        assert np.isclose(d.quantile(q), emp, rtol=0.05)


def test_shifted_exponential_quantile():
    d = ShiftedExponential(base=1.0, rate=2.0)
    # P[X <= base - ln(1-q)/rate] = q
    assert np.isclose(d.quantile(0.5), 1.0 + np.log(2) / 2)
    samples = d.sample(jax.random.PRNGKey(2), (100_000,))
    assert np.isclose(float(jnp.percentile(samples, 90)), d.quantile(0.9), rtol=0.05)


def test_latency_bound_probability():
    """Pr[L <= L(q, c)] >= q — the defining guarantee of Eq. 1."""
    prof = TaskLatencyProfile(
        name="t",
        work=LogNormal(1e12, 3.3),
        io=ShiftedExponential(5e-6, 1e4),
        sync_per_tile_s=1e-7,
    )
    P = 1.024e12
    for q in (0.5, 0.9, 0.95):
        for c in (2, 8, 32):
            bound = prof.latency_bound(q, c, P)
            lat = prof.sample_latency(jax.random.PRNGKey(3), c, P, (50_000,))
            frac = float(jnp.mean(lat <= bound))
            assert frac >= q - 0.02, (q, c, frac)


def test_bound_monotone_then_sync_dominated():
    prof = TaskLatencyProfile(
        name="t", work=LogNormal(1e12, 2.0),
        io=ShiftedExponential(1e-6, 1e5), sync_per_tile_s=2e-5,
    )
    P = 1.024e12
    bounds = [prof.latency_bound(0.95, c, P) for c in (1, 2, 4, 8)]
    assert bounds[1] < bounds[0]
    # with a strong sync term, very large DoP stops helping
    # (optimum c* = sqrt(W_q / (P * sync)) ~ 285 here)
    big = [prof.latency_bound(0.95, c, P) for c in (512, 4096)]
    assert big[1] > big[0]


def test_prune_dop_candidates():
    prof = TaskLatencyProfile(
        name="t", work=LogNormal(1e12, 2.0),
        io=ShiftedExponential(1e-6, 1e5), sync_per_tile_s=0.0,
    )
    kept = prune_dop_candidates(prof, 1.024e12, [1, 2, 3, 4, 8, 16], q=0.95,
                                improvement_threshold=0.3)
    assert kept[0] == 1
    assert all(a < b for a, b in zip(kept, kept[1:]))
    assert set(kept) <= {1, 2, 3, 4, 8, 16}


def test_tail_composition_headroom_positive():
    """The paper's §II-C3 scope note: summing per-task tail budgets
    overestimates the observed E2E tail."""
    wf = make_ads_benchmark()
    model = LatencyModel.from_workflow(wf, simba_chip(400))
    chain = next(c for c in wf.chains if c.name == "drv_vision")
    dops = {n: 8 for n in chain.nodes}
    out = chain_tail_composition(model, chain.nodes, dops, q=0.95)
    assert out["headroom"] > 0.05
    assert out["mc_quantile_s"] < out["sum_of_quantiles_s"]


def test_fitquota_helper():
    wf = make_ads_benchmark()
    model = LatencyModel.from_workflow(wf, simba_chip(400))
    task = wf.tasks["img_backbone"]
    c = model.min_dop_for_budget(task, 0.95, 0.050)
    assert c is not None
    # minimality: no smaller candidate meets the budget
    for smaller in task.dop_candidates():
        if smaller >= c:
            break
        assert model.bound("img_backbone", 0.95, smaller) > 0.050


def test_hardware_models():
    hw = simba_chip()
    assert hw.num_tiles == 128
    assert np.isclose(hw.tile_flops, 1.024e12)
    big = simba_chip(400)
    assert big.num_tiles == 400
    # realloc: hundreds of microseconds for MB-scale checkpoints
    lat = hw.realloc_latency(16e6, 64)
    assert 1e-4 < lat < 1e-3
    pod = tpu_pod(256)
    assert pod.num_tiles == 256


def test_bound_ladder_and_batch_match_scalar_bounds():
    """The ladder and vectorized-batch evaluations of Eq. (1) must stay
    in lockstep with the scalar `bound()` path — including the edge
    cases (sensor tasks, zero-sigma work, rate<=0 I/O).  The autotuner
    ranks frontiers with the batch path while the compiler budgets with
    the scalar one; any drift silently desynchronizes them."""
    wf = make_ads_benchmark()
    model = LatencyModel.from_workflow(wf, simba_chip(400))
    # hand-built edge-case profiles alongside the benchmark's
    model.profiles["zero_sigma"] = TaskLatencyProfile(
        name="zero_sigma",
        work=LogNormal(2.0e9, 1.0),              # sigma == 0
        io=ShiftedExponential(5e-6, 0.0),        # rate <= 0
        sync_per_tile_s=1e-7,
    )
    names = tuple(model.profiles)
    for q in (0.5, 0.9, 0.95, 0.999):
        for c in (1, 2, 8, 32):
            scal = [model.bound(t, q, c) for t in names]
            batch = model.bound_batch(names, q, np.full(len(names), c))
            assert np.allclose(batch, scal, rtol=1e-12, atol=0.0), (q, c)
        for t in names:
            task = wf.tasks.get(t)
            cands = task.dop_candidates() if task is not None else (1, 4, 16)
            ladder = model.bound_ladder(t, q, tuple(cands))
            scal = tuple(
                model.profiles[t].latency_bound(q, c, model.hw.tile_flops)
                for c in cands
            )
            assert np.allclose(ladder, scal, rtol=1e-12, atol=0.0), (t, q)
