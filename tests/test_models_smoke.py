"""Per-architecture smoke tests: reduced config, one forward/train step
on CPU, output shapes + no NaNs; decode/prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, runnable_cells
from repro.models import LM, init_params

B, S = 2, 32


def _batch(cfg, key, seq=S):
    kt, kl, kp = jax.random.split(key, 3)
    if cfg.num_codebooks:
        return {
            "tokens": jax.random.randint(kt, (B, cfg.num_codebooks, seq), 0, cfg.vocab_size),
            "labels": jax.random.randint(kl, (B, cfg.num_codebooks, seq), 0, cfg.vocab_size),
        }
    if cfg.num_patches:
        text = seq - cfg.num_patches
        return {
            "tokens": jax.random.randint(kt, (B, text), 0, cfg.vocab_size),
            "labels": jax.random.randint(kl, (B, text), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(kp, (B, cfg.num_patches, cfg.d_model)),
        }
    return {
        "tokens": jax.random.randint(kt, (B, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(kl, (B, seq), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    model = LM(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(7))

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert jnp.isfinite(loss), arch
    # loss near ln(V) at random init (healthy scales)
    assert float(loss) < np.log(cfg.vocab_size) * 2.5, float(loss)
    gn = sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn), arch
    # at least one grad is nonzero for every top-level group
    flat = jax.tree.leaves(grads)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    model = LM(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = model.init_cache(B, max_len=64)
    if cfg.num_codebooks:
        tok = jnp.ones((B, cfg.num_codebooks, 1), jnp.int32)
        vshape = (B, cfg.num_codebooks, cfg.vocab_size)
    else:
        tok = jnp.ones((B, 1), jnp.int32)
        vshape = (B, cfg.vocab_size)
    logits, cache2 = jax.jit(model.decode_step)(params, {"tokens": tok}, cache, 3)
    assert logits.shape == vshape, arch
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["gemma2_27b", "mamba2_2p7b", "recurrentgemma_9b",
                                  "deepseek_v2_236b", "phi4_mini_3p8b"])
def test_prefill_decode_matches_forward(arch):
    """Serving-path correctness: prefill(t[:n]) then decode(t[n]) must
    agree with a longer prefill on the final-position logits.

    MoE capacity factor is raised so no token drops: token-choice
    capacity dropping legitimately depends on the co-batched token set,
    which differs between a 1-token decode and an 18-token forward."""
    import dataclasses
    cfg = get_config(arch, reduced=True)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    model = LM(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, 9), 0, cfg.vocab_size)

    cache = model.init_cache(B, max_len=32)
    _, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :8]}, cache)
    step_logits, _ = jax.jit(model.decode_step)(
        params, {"tokens": toks[:, 8:9]}, cache, 8
    )

    cache2 = model.init_cache(B, max_len=32)
    full_logits, _ = jax.jit(model.prefill)(params, {"tokens": toks}, cache2)

    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-3,
    )


def test_param_counts_sane():
    """Full-config analytic parameter counts are in the advertised
    ballpark (name says 2.7b/27b/...)."""
    expect = {
        "mamba2_2p7b": 2.7e9,
        "gemma2_27b": 27e9,
        "gemma3_4b": 4e9,
        "phi4_mini_3p8b": 3.8e9,
        "stablelm_12b": 12e9,
        "recurrentgemma_9b": 9e9,
        "granite_moe_1b": 1.3e9,
        "deepseek_v2_236b": 236e9,
        "phi3_vision_4p2b": 4.2e9,
        "musicgen_large": 3.3e9,
    }
    for arch, n in expect.items():
        cfg = get_config(arch)
        got = cfg.param_count()
        assert 0.5 * n < got < 1.7 * n, (arch, got, n)


def test_runnable_cells_long_context_rule():
    for arch in ARCHS:
        cfg = get_config(arch)
        cells = runnable_cells(cfg)
        has_long = any(s == "long_500k" for _, s in cells)
        assert has_long == (cfg.family in ("ssm", "hybrid")), arch
    total = sum(len(runnable_cells(get_config(a))) for a in ARCHS)
    assert total == 32  # 30 common + 2 long-context
