"""Tile-budget autotuner tests: frontier determinism, Pareto
monotonicity, exact equivalence with the legacy q-relaxation ladder at
a pinned partition count, targeted selection semantics, and the
paired-trace acceptance that a frontier compile never reserves more
tiles than the ladder compile at the same service level."""

import dataclasses

import numpy as np

from repro.core.experiment import build_stack, make_policy
from repro.core.runtime import (
    SchedulePortfolio,
    autotune_mode,
    blend_schedules,
    most_urgent_plan,
    predict_miss,
)
from repro.core.runtime.autotune import clear_frontier_cache
from repro.core.sim import SimConfig, Simulator
from repro.obs import metrics
from repro.scenarios import ScenarioSpec, get_mode, get_scenario
from repro.scenarios.runner import build_trace, compile_portfolio, run

Q_LADDER = (0.9, 0.8, 0.7, 0.6, 0.5)


def _stack(policy="ads_tile", **kw):
    spec = ScenarioSpec(
        scenario=get_scenario("rate_churn"), policy=policy, seed=1, **kw
    )
    return build_stack(spec)


def _mode_stack(mode_name, policy="ads_tile"):
    """(model, workflow) transformed for one driving mode."""
    wf, _hw, model, compiler = _stack(policy)
    mode = get_mode(mode_name)
    m_model = mode.transform_model(model)
    transform_wf = getattr(mode, "transform_workflow", None)
    m_wf = transform_wf(wf) if transform_wf is not None else wf
    return m_model, m_wf, compiler


def _ladder_reference(model, wf, modes, compiler, q_ladder=Q_LADDER):
    """The legacy per-mode q-relaxation ladder, reproduced verbatim:
    walk q down from the compiler's, keep the first feasible compile,
    fall back to the last (lowest-q) one."""
    out = {}
    for name, mode in modes.items():
        m_model = mode.transform_model(model)
        transform_wf = getattr(mode, "transform_workflow", None)
        m_wf = transform_wf(wf) if transform_wf is not None else wf
        for q in (compiler.q,) + tuple(x for x in q_ladder if x < compiler.q):
            sched = dataclasses.replace(compiler, q=q).compile(m_model, m_wf)
            if (
                not sched.meta["phase1_infeasible"]
                and not sched.meta["phase3_violations"]
            ):
                break
        out[name] = sched
    return out


# ---------------------------------------------------------------------------
# frontier structure
# ---------------------------------------------------------------------------
def test_frontier_deterministic_across_fresh_stacks():
    """Equal-valued inputs produce identical frontiers, with and
    without the memo (the search has no hidden state)."""
    m1, w1, c1 = _mode_stack("urban")
    fr1 = autotune_mode(m1, w1, c1, q_grid=Q_LADDER, mode_name="urban")
    clear_frontier_cache()
    m2, w2, c2 = _mode_stack("urban")
    fr2 = autotune_mode(m2, w2, c2, q_grid=Q_LADDER, mode_name="urban")
    assert [p.key() for p in fr1.points] == [p.key() for p in fr2.points]
    assert [p.feasible for p in fr1.points] == [p.feasible for p in fr2.points]
    # and the memo serves the identical object for an equal-valued stack
    m3, w3, c3 = _mode_stack("urban")
    assert autotune_mode(m3, w3, c3, q_grid=Q_LADDER, mode_name="urban") is fr2


def test_pareto_frontier_is_monotone():
    """More tiles never increases the predicted miss probability along
    the frontier, and every feasible point is dominated by (or on) it."""
    model, wf, compiler = _mode_stack("urban")
    fr = autotune_mode(
        model,
        wf,
        compiler,
        q_grid=Q_LADDER,
        partition_grid=(3, 4, 5),
        budget_fracs=(0.85, 0.7),
        mode_name="urban",
    )
    pareto = fr.pareto()
    assert len(pareto) >= 2
    tiles = [p.tiles for p in pareto]
    misses = [p.miss for p in pareto]
    assert tiles == sorted(tiles)
    assert all(a > b for a, b in zip(misses, misses[1:]))
    for p in fr.feasible_points():
        assert any(
            f.tiles <= p.tiles and f.miss <= p.miss for f in pareto
        ), p.key()


def test_predict_miss_monotone_in_dop():
    """Doubling every DoP can only lower the analytic miss bound."""
    model, wf, compiler = _mode_stack("urban")
    sched = compiler.compile(model, wf)
    slack = predict_miss(model, wf, sched)
    shrunk = dataclasses.replace(
        sched,
        plans={
            t: dataclasses.replace(p, dop=max(1, p.dop // 2))
            for t, p in sched.plans.items()
        },
    )
    assert predict_miss(model, wf, shrunk) >= slack


# ---------------------------------------------------------------------------
# equivalence with the legacy q-relaxation ladder
# ---------------------------------------------------------------------------
def test_pinned_partition_frontier_reproduces_ladder_quantiles():
    """With the partition count pinned and no miss target, every mode
    of the portfolio must keep exactly the quantile the legacy ladder
    chose (the acceptance criterion for replacing it)."""
    scen = get_scenario("rate_churn")
    wf, _hw, model, compiler = _stack()
    modes = {m: get_mode(m) for m in scen.modes()}
    reference = _ladder_reference(model, wf, modes, compiler)
    pf = SchedulePortfolio.compile(model, wf, modes, compiler)
    for name, ref in reference.items():
        assert pf.schedules[name].q == ref.q, name
        assert pf.schedules[name].peak_tiles == ref.peak_tiles, name
        assert pf.selected[name].num_partitions == len(ref.partitions), name


def test_frontier_never_beats_ladder_tiles_at_equal_q():
    """For every quantile the ladder could have chosen, the frontier's
    cheapest feasible same-q point reserves at most the ladder
    compile's tiles (it includes that compile)."""
    model, wf, compiler = _mode_stack("urban")
    fr = autotune_mode(
        model,
        wf,
        compiler,
        q_grid=Q_LADDER,
        budget_fracs=(0.85, 0.7),
        mode_name="urban",
    )
    by_q = {}
    for p in fr.feasible_points():
        by_q.setdefault(p.q, []).append(p.tiles)
    assert by_q
    for q, tiles in by_q.items():
        ladder = dataclasses.replace(compiler, q=q).compile(model, wf)
        assert min(tiles) <= ladder.peak_tiles, q


def test_paired_trace_frontier_compile_uses_no_more_tiles():
    """Acceptance: on one shared trace, the targeted frontier portfolio
    reserves no more tiles than the ladder portfolio while meeting its
    own predicted-miss target."""
    scen = get_scenario("rate_churn")
    spec = ScenarioSpec(scenario=scen, policy="ads_tile", seed=3)
    wf, _hw, model, compiler = build_stack(spec)
    modes = {m: get_mode(m) for m in scen.modes()}
    ladder_pf = SchedulePortfolio.compile(model, wf, modes, compiler)
    target = max(p.miss for p in ladder_pf.selected.values())
    frontier_pf = SchedulePortfolio.compile(
        model, wf, modes, compiler, target_miss=target, partition_span=0
    )
    for name, point in frontier_pf.selected.items():
        assert point.tiles <= ladder_pf.selected[name].tiles, name
        assert point.miss <= target + 1e-12, name
    trace = build_trace(spec)
    [r_ladder] = run(
        dataclasses.replace(spec, portfolio=ladder_pf), trace=trace,
        backend="scalar",
    )
    [r_frontier] = run(
        dataclasses.replace(spec, portfolio=frontier_pf), trace=trace,
        backend="scalar",
    )
    assert r_frontier.tiles_used <= r_ladder.tiles_used
    assert 0 < r_frontier.tiles_reserved_mean <= r_frontier.tiles_used


# ---------------------------------------------------------------------------
# targeted selection + runtime plumbing
# ---------------------------------------------------------------------------
def test_targeted_selection_picks_cheapest_meeting_target():
    model, wf, compiler = _mode_stack("urban")
    fr = autotune_mode(
        model,
        wf,
        compiler,
        q_grid=Q_LADDER,
        budget_fracs=(0.85, 0.7),
        mode_name="urban",
    )
    pareto = fr.pareto()
    mid = pareto[len(pareto) // 2]
    pick = fr.select(target_miss=mid.miss)
    assert pick.feasible and pick.miss <= mid.miss
    assert pick.tiles == min(
        p.tiles for p in fr.feasible_points() if p.miss <= mid.miss
    )
    # an unreachable target degrades to the lowest-miss point, never to
    # a cheap table that ignores the service level
    strict = fr.select(target_miss=0.0)
    assert strict.miss == min(p.miss for p in fr.feasible_points())


def test_portfolio_harmonizes_partition_counts():
    """A targeted compile explores partition counts but every mode must
    land on one shared count — the engine only hot-swaps between
    tables with equal partition counts."""
    scen = get_scenario("rate_churn")
    wf, _hw, model, compiler = _stack()
    modes = {m: get_mode(m) for m in scen.modes()}
    pf = SchedulePortfolio.compile(
        model, wf, modes, compiler, target_miss=0.4, partition_span=1
    )
    counts = {len(s.partitions) for s in pf.schedules.values()}
    assert len(counts) == 1
    [r] = run(
        ScenarioSpec(scenario=scen, policy="ads_tile", seed=2, portfolio=pf),
        backend="scalar",
    )
    assert r.tiles_used == max(p.tiles for p in pf.selected.values())
    assert r.frontier_meta["tiles"] == pf.selected[scen.segments[0].mode].tiles


def test_blend_draws_conservative_plan_from_frontier():
    """With a budget-tightened portfolio, the transition hedge may pick
    a task's plan from the mode's most conservative same-count frontier
    point, and every chosen plan is the most urgent candidate."""
    scen = get_scenario("rate_churn")
    wf, _hw, model, compiler = _stack()
    modes = {m: get_mode(m) for m in scen.modes()}
    pf = SchedulePortfolio.compile(
        model, wf, modes, compiler, target_miss=0.45, partition_span=0
    )
    old = pf.schedules["urban"]
    new = pf.schedules["rush_hour"]
    alt = pf.blend_alternative("rush_hour", len(old.partitions))
    assert alt is not None and alt.q > new.q
    blend = blend_schedules(old, new, wf, alt=alt)
    caps = {p.index: p.capacity for p in old.partitions}
    for task, plan in blend.plans.items():
        cands = [old.plans[task], new.plans[task], alt.plans[task]]
        want = most_urgent_plan(cands, wf.deadline_offset(task))
        assert plan.partition == want.partition, task
        assert plan.dop == max(1, min(want.dop, caps[want.partition])), task


def test_dop_prune_meta_reaches_the_scheduler():
    """An autotuned table compiled with DoP pruning restricts the
    runtime's candidate ladder to the compiled multi-version set."""
    scen = get_scenario("rate_churn")
    spec = ScenarioSpec(scenario=scen, policy="ads_tile", seed=1)
    wf, _hw, model, compiler = build_stack(spec)
    modes = {m: get_mode(m) for m in scen.modes()}
    pf = SchedulePortfolio.compile(model, wf, modes, compiler, dop_prune=0.05)
    sched = pf.schedules[scen.segments[0].mode]
    meta = sched.meta["task_dop_candidates"]
    assert meta and all(len(v) >= 1 for v in meta.values())
    policy = make_policy("ads_tile")
    sim = Simulator(
        wf, model, sched, policy, SimConfig(duration_s=0.4, seed=1)
    )
    policy.setup(sim)
    for task, cands in meta.items():
        assert policy._cands[task] == tuple(cands), task
        full = wf.tasks[task].dop_candidates()
        assert set(cands) <= set(full), task
    # a table without the meta restores the workflow-derived ladder
    plain = compiler.compile(model, wf)
    sim.schedule = plain
    policy.setup(sim)
    for task in meta:
        assert policy._cands[task] == wf.tasks[task].dop_candidates(), task


def test_target_miss_threads_through_scenario_spec():
    scen = get_scenario("rate_churn")
    spec = ScenarioSpec(
        scenario=scen, policy="ads_tile", seed=1, target_miss=0.45
    )
    pf = compile_portfolio(spec)
    pf_cons = compile_portfolio(dataclasses.replace(spec, target_miss=None))
    assert max(p.tiles for p in pf.selected.values()) < max(
        p.tiles for p in pf_cons.selected.values()
    )
    [r] = run(spec, backend="scalar")
    assert r.tiles_used <= max(p.tiles for p in pf.selected.values())
    assert np.isfinite(r.tiles_reserved_mean)


# ---------------------------------------------------------------------------
# Phase II warm start
# ---------------------------------------------------------------------------
def test_budget_recompiles_warm_start_phase2():
    """Budget-shrunk cells seed Phase II from the full-budget compile's
    partitioning; full-budget compiles stay cold (ladder equivalence)."""
    model, wf, compiler = _mode_stack("urban")
    clear_frontier_cache()
    metrics.reset()
    metrics.enable()
    try:
        fr = autotune_mode(
            model,
            wf,
            compiler,
            q_grid=Q_LADDER,
            budget_fracs=(0.85, 0.7),
            mode_name="urban",
        )
        snap = metrics.snapshot()
    finally:
        metrics.enable(False)
        metrics.reset()
    counters = snap["counters"]
    assert counters.get("phase2_warm_start", 0) > 0
    # every full-budget cell compiled cold
    full_cells = {
        (p.q, p.num_partitions) for p in fr.points if p.budget == model.hw.num_tiles
    }
    assert counters.get("phase2_cold_start", 0) >= len(full_cells)
    assert "autotune_search" in snap["phases"]


def test_warm_started_frontier_matches_cold_validity():
    """A warm-started search still produces a valid, deterministic
    frontier: every point's schedule validates and reruns reproduce the
    same keys (warm start is itself deterministic)."""
    m1, w1, c1 = _mode_stack("urban")
    clear_frontier_cache()
    fr1 = autotune_mode(m1, w1, c1, q_grid=Q_LADDER, mode_name="urban")
    for p in fr1.points:
        p.schedule.validate()
    clear_frontier_cache()
    m2, w2, c2 = _mode_stack("urban")
    fr2 = autotune_mode(m2, w2, c2, q_grid=Q_LADDER, mode_name="urban")
    assert [p.key() for p in fr1.points] == [p.key() for p in fr2.points]


def test_run_phase2_warm_start_fallback():
    """Invalid warm assignments (wrong task set or group count) fall
    back to the cold construction and reproduce its result exactly."""
    from repro.core.gha.phase1 import run_phase1
    from repro.core.gha.phase2 import run_phase2

    model, wf, compiler = _mode_stack("urban")
    p1 = run_phase1(model, wf, compiler.q, tile_cap=model.hw.num_tiles)
    n_parts = max(1, min(compiler.num_partitions, len(wf.dnn_tasks)))
    cold = run_phase2(wf, p1, n_parts, compiler.phase2_weights)
    # wrong task set: missing one task
    bad1 = dict(cold.assignment)
    bad1.pop(next(iter(bad1)))
    # wrong group count: everything in one bin (n_parts > 1 here)
    bad2 = {t: 0 for t in cold.assignment}
    assert n_parts > 1
    for bad in (bad1, bad2):
        again = run_phase2(wf, p1, n_parts, compiler.phase2_weights, warm_start=bad)
        assert again.assignment == cold.assignment
        assert again.capacities == cold.capacities
    # a valid warm start (the cold fixed point itself) is stable
    warm = run_phase2(
        wf, p1, n_parts, compiler.phase2_weights, warm_start=cold.assignment
    )
    assert set(warm.assignment) == set(cold.assignment)
    assert warm.num_partitions == cold.num_partitions
    assert warm.score <= cold.score + 1e-9
