"""Deep-path attention correctness: ring-buffer window caches vs a full
linear cache, and long multi-step decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM, init_params
from repro.models.attention import attn_apply, attn_init


def _mini_cfg(window):
    return dataclasses.replace(
        get_config("recurrentgemma_9b", reduced=True),
        window=window,
    )


def test_ring_cache_matches_linear_cache():
    """Decoding with a W-slot ring buffer must equal decoding with an
    unbounded linear cache under a W-token sliding window."""
    W = 8
    cfg = _mini_cfg(W)
    key = jax.random.PRNGKey(0)
    params = attn_init(key, cfg)
    b, steps = 2, 20
    xs = jax.random.normal(jax.random.PRNGKey(1), (b, steps, cfg.d_model))

    # linear (large) cache
    lin_k = jnp.zeros((b, cfg.num_kv_heads, steps, cfg.head_dim))
    lin_v = jnp.zeros_like(lin_k)
    # ring cache of exactly W slots
    ring_k = jnp.zeros((b, cfg.num_kv_heads, W, cfg.head_dim))
    ring_v = jnp.zeros_like(ring_k)

    for t in range(steps):
        x_t = xs[:, t:t + 1]
        pos = jnp.asarray(t, jnp.int32)
        positions = jnp.asarray([t])
        out_lin, (lin_k, lin_v) = attn_apply(
            params, x_t, cfg, positions=positions,
            window=jnp.asarray(W), theta=cfg.rope_theta,
            cache=(lin_k, lin_v), cache_pos=pos,
        )
        out_ring, (ring_k, ring_v) = attn_apply(
            params, x_t, cfg, positions=positions,
            window=jnp.asarray(W), theta=cfg.rope_theta,
            cache=(ring_k, ring_v), cache_pos=pos, ring=True,
        )
        np.testing.assert_allclose(
            np.asarray(out_lin, np.float32),
            np.asarray(out_ring, np.float32),
            rtol=2e-4, atol=2e-5,
            err_msg=f"step {t}",
        )


def test_hybrid_long_decode_stays_finite_and_consistent():
    """recurrentgemma: decode far past the window size (the long_500k
    regime, scaled down) — state stays finite and two identical runs
    agree exactly."""
    cfg = get_config("recurrentgemma_9b", reduced=True)
    model = LM(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 40), 0, cfg.vocab_size)

    def run():
        cache = model.init_cache(1, max_len=cfg.window * 4)
        outs = []
        step = jax.jit(model.decode_step)
        for t in range(40):
            logits, cache = step(
                params, {"tokens": toks[:, t:t + 1]}, cache, t
            )
            outs.append(np.asarray(logits, np.float32))
        return np.stack(outs)

    a = run()
    b = run()
    assert np.all(np.isfinite(a))
    np.testing.assert_array_equal(a, b)


def test_mla_absorbed_decode_matches_prefill_logits():
    """The absorbed MLA decode path (Perf iteration 7) must agree with a
    fresh full prefill at every step of a short generation."""
    cfg = dataclasses.replace(
        get_config("deepseek_v2_236b", reduced=True),
        num_experts=0, num_shared_experts=0, first_dense_layers=0, d_ff=64,
    )
    model = LM(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0, cfg.vocab_size)

    cache = model.init_cache(2, max_len=16)
    _, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :6]}, cache)
    step = jax.jit(model.decode_step)
    for t in range(6, 12):
        dec_logits, cache = step(params, {"tokens": toks[:, t:t + 1]}, cache, t)
        ref_cache = model.init_cache(2, max_len=16)
        ref_logits, _ = jax.jit(model.prefill)(
            params, {"tokens": toks[:, :t + 1]}, ref_cache
        )
        np.testing.assert_allclose(
            np.asarray(dec_logits, np.float32),
            np.asarray(ref_logits, np.float32),
            rtol=2e-2, atol=2e-3, err_msg=f"pos {t}",
        )
