"""Substrate tests: checkpointing (fault tolerance), data pipeline,
optimizer, serving engine, elastic helpers, sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distribution.elastic import StragglerMonitor
from repro.distribution.sharding import batch_specs, cache_specs, param_specs
from repro.models import LM, init_params
from repro.serving import EngineConfig, Request, ServingEngine
from repro.training import AdamWConfig, TrainConfig, Trainer, adamw_init, adamw_update
from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, synthetic_stream
from repro.training.optimizer import (
    compress_grads_int8,
    decompress_grads_int8,
    global_norm,
)


# ---------------------------------------------------------------------------
def test_adamw_reduces_loss():
    cfg = get_config("phi4_mini_3p8b", reduced=True)
    model = LM(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    acfg = AdamWConfig(lr=3e-3, warmup_steps=1)
    data = synthetic_stream(cfg, DataConfig(batch=4, seq_len=32, seed=3))
    batch = next(data)  # overfit one batch

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        p2, o2, _ = adamw_update(acfg, params, grads, opt)
        return p2, o2, loss

    losses = []
    for _ in range(20):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_grad_compression_roundtrip():
    tree = {"a": jnp.linspace(-3, 3, 1000).reshape(10, 100),
            "b": {"c": jnp.ones((7,)) * 0.01}}
    comp = compress_grads_int8(tree)
    back = decompress_grads_int8(comp)
    for k, orig in (("a", tree["a"]), ):
        err = float(jnp.max(jnp.abs(back["a"] - orig)))
        assert err <= float(jnp.max(jnp.abs(orig))) / 127 + 1e-6


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {
        "params": {"w": np.arange(6.0).reshape(2, 3), "b": np.zeros(3)},
        "opt_state": {"m": {"w": np.ones((2, 3))}},
        "step": 7,
    }
    mgr.save(7, state)
    mgr.save(14, state)
    mgr.save(21, state)
    assert mgr.all_steps() == [14, 21]  # keep=2 garbage-collects
    back = mgr.restore(21)
    np.testing.assert_array_equal(back["params"]["w"], state["params"]["w"])
    assert int(back["step"]) == 7


def test_trainer_resume_determinism(tmp_path):
    """Fault tolerance: crash-and-restore reproduces the uninterrupted
    run exactly (same data stream, same final loss)."""
    cfg = get_config("granite_moe_1b", reduced=True)
    dcfg = DataConfig(batch=4, seq_len=16, seed=11)

    def run(steps, ckpt_dir, resume=False):
        t = Trainer(cfg, TrainConfig(
            steps=steps, log_every=1, checkpoint_every=2,
            checkpoint_dir=ckpt_dir,
        ), seed=1)
        if resume:
            assert t.restore_if_available()
        data = synthetic_stream(cfg, dcfg, start_step=t.step)
        return t.fit(data)

    full = run(6, str(tmp_path / "a"))
    run(4, str(tmp_path / "b"))                 # "crash" after step 4
    resumed = run(6, str(tmp_path / "b"), resume=True)
    f = {r["step"]: r["loss"] for r in full["history"]}
    r = {r["step"]: r["loss"] for r in resumed["history"]}
    for s in (5, 6):
        assert np.isclose(f[s], r[s], rtol=1e-5), (s, f[s], r[s])


def test_data_stream_deterministic():
    cfg = get_config("phi4_mini_3p8b", reduced=True)
    a = next(synthetic_stream(cfg, DataConfig(seed=5), start_step=3))
    b = next(synthetic_stream(cfg, DataConfig(seed=5), start_step=3))
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_serving_engine_drains():
    cfg = get_config("phi4_mini_3p8b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=3, max_len=64))
    rng = np.random.RandomState(0)
    reqs = [
        Request(rid=i, prompt=rng.randint(0, cfg.vocab_size, (8,)).astype(np.int32),
                max_new_tokens=5)
        for i in range(7)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    for r in reqs:
        assert len(r.generated) >= r.max_new_tokens
        assert all(0 <= t < cfg.vocab_size for t in r.generated)


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=3.0)
    flagged = [mon.observe(i, 0.1 + 0.001 * (i % 3)) for i in range(30)]
    assert not any(flagged)
    assert mon.observe(31, 1.5)   # 15x normal -> straggler


# ---------------------------------------------------------------------------
def test_param_specs_structure():
    cfg = get_config("deepseek_v2_236b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    specs = param_specs(cfg, params, fsdp=True)
    assert jax.tree.structure(
        params
    ) == jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P))

    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P)
    )[0]
    by_name = {}
    for path, spec in flat:
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        by_name.setdefault(name, spec)
    # experts sharded over model (EP), norms replicated
    moe_wg = [
        s for p, s in flat
        if any(getattr(x, "key", "") == "moe" for x in p)
        and getattr(p[-1], "key", "") == "wg"
        and not any(getattr(x, "key", "") == "shared" for x in p)
    ]
    assert moe_wg and all("model" in str(s) for s in moe_wg)
    assert by_name["final_norm"] == P(None)


def test_cache_specs_fallbacks():
    cfg = get_config("gemma3_4b")  # kv=4, not divisible by 16
    model = LM(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 1024))
    specs = cache_specs(cfg, cache, batch_shardable=True, model_size=16)
    # heads can't shard 16-way -> sequence dim takes 'model'
    assert specs["k"] == P(None, ("pod", "data"), None, "model", None)

    cfg2 = get_config("gemma2_27b")  # kv=16 divides
    model2 = LM(cfg2)
    cache2 = jax.eval_shape(lambda: model2.init_cache(128, 1024))
    specs2 = cache_specs(cfg2, cache2, batch_shardable=True, model_size=16)
    assert specs2["k"] == P(None, ("pod", "data"), "model", None, None)


def test_dryrun_filter_spec():
    from types import SimpleNamespace
    from repro.launch import dryrun
    # _filter_spec only reads axis names/sizes; a stub avoids needing
    # 4 real devices inside the single-device test env
    mesh = SimpleNamespace(
        axis_names=("data", "model"), shape={"data": 2, "model": 2}
    )
    # non-divisible dim drops the axis
    s = dryrun._filter_spec(P("model", None), mesh, (5, 4))
    assert s == P(None, None)
    s = dryrun._filter_spec(P(("pod", "data"), None), mesh, (4, 4))
    assert s == P(("data",), None)
    s = dryrun._filter_spec(P("model", "data"), mesh, (4, 4))
    assert s == P("model", "data")
