"""End-to-end behaviour tests for the paper's system (ADS-Tile).

These assert the paper's headline claims hold on the regenerated
benchmark (DESIGN.md §7): bounded reallocation waste, the
isolation/sharing trade-off, and E2E deadline behaviour.
"""
import numpy as np
import pytest

from repro.core.benchmark import make_ads_benchmark
from repro.core.experiment import ExperimentSpec, run_experiment
from repro.core.gha import compile_schedule
from repro.core.hardware import simba_chip
from repro.core.latency_model import LatencyModel


def test_e2e_light_load_everyone_healthy():
    """x1 cockpit, 100 ms, 400 tiles: every dynamic policy meets the
    deadline; ADS-Tile does it with <1.2% realloc waste."""
    for pol in ("tp_driven", "pglb", "ads_tile"):
        r = run_experiment(ExperimentSpec(
            policy=pol, tiles=400, cockpit_replicas=1, duration_s=0.8, seed=2,
        ))
        assert r.violation_rate < 0.02, pol
    ads = run_experiment(ExperimentSpec(
        policy="ads_tile", tiles=400, cockpit_replicas=1, duration_s=0.8, seed=2,
    ))
    assert ads.realloc_frac < 0.012


def test_e2e_medium_load_headline():
    """x6 cockpit, 90 ms: ADS-Tile keeps realloc waste <1.2% while the
    work-conserving baseline wastes >10% (paper: 17-44% vs <1.2%), and
    reallocations are far fewer."""
    ads = run_experiment(ExperimentSpec(
        policy="ads_tile", tiles=400, cockpit_replicas=6, deadline_s=0.09,
        q=0.9, duration_s=0.8, seed=2,
    ))
    tp = run_experiment(ExperimentSpec(
        policy="tp_driven", tiles=400, cockpit_replicas=6, deadline_s=0.09,
        duration_s=0.8, seed=2,
    ))
    assert ads.realloc_frac < 0.012
    assert tp.realloc_frac > 0.10
    assert ads.n_realloc < tp.n_realloc


def test_e2e_chain_latency_accounting():
    """Chain p99s are finite, ordered sensibly, and the E2E metric sees
    the full sensing->sink path (>= sensor latency)."""
    r = run_experiment(ExperimentSpec(
        policy="ads_tile", tiles=400, cockpit_replicas=1,
        duration_s=0.8, seed=3,
    ))
    wf = make_ads_benchmark()
    for ch in wf.chains:
        lats = r.chain_latencies[ch.name]
        assert lats, ch.name
        assert min(lats) > 1e-3       # at least the sensing stage
        assert np.percentile(lats, 99) < 0.25


def test_static_plan_fits_capacity_budget():
    wf = make_ads_benchmark(cockpit_replicas=6, critical_deadline_s=0.09)
    lm = LatencyModel.from_workflow(wf, simba_chip(300))
    s = compile_schedule(lm, wf, q=0.9, num_partitions=4)
    assert s.peak_tiles <= 300
    # physical binding covers every partition
    for p in s.partitions:
        assert p.rect is not None
        assert p.area >= p.capacity
        assert p.memory_controller is not None


def test_decision_overhead_small():
    """Table II: scheduling-decision latency is a small fraction of the
    resharding latency."""
    r = run_experiment(ExperimentSpec(
        policy="ads_tile", tiles=400, cockpit_replicas=6, deadline_s=0.09,
        q=0.9, duration_s=0.8, seed=2,
    ))
    if r.decision_ratios:
        assert float(np.mean(r.decision_ratios)) < 0.25
