"""Driving-scenario subsystem tests: modes, scripts, engine integration,
online replanning, Monte-Carlo sweeps."""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.latency_model import LatencyModel
from repro.core.benchmark import make_ads_benchmark
from repro.core.hardware import simba_chip
from repro.scenarios import (
    BUNDLED_SCENARIOS,
    Burst,
    ModeSegment,
    ScenarioScript,
    ScenarioSpec,
    SensorDropout,
    aggregate_sweep,
    default_generator,
    get_mode,
    run,
    sweep,
)


# ---------------------------------------------------------------------------
# modes
# ---------------------------------------------------------------------------
def test_mode_transform_scales_profiles():
    wf = make_ads_benchmark()
    model = LatencyModel.from_workflow(wf, simba_chip(400))
    mode = get_mode("adverse_weather")
    prof = model.profiles["img_backbone"]
    tp = mode.transform_profile(prof)
    assert np.isclose(tp.work.mean, prof.work.mean * mode.work_scale)
    assert tp.work.p99_ratio > prof.work.p99_ratio      # widened tail
    assert tp.io.rate < prof.io.rate                     # heavier queueing
    sensor = mode.transform_profile(model.profiles["cam_multi"])
    assert np.isclose(
        sensor.sensor_latency.mean,
        model.profiles["cam_multi"].sensor_latency.mean
        * mode.sensor_latency_scale,
    )


def test_mode_task_overrides_apply_to_replicas():
    mode = get_mode("urban")
    # cockpit replica names inherit the base task's override
    assert mode._task_scale("traj_pred#r3") == mode._task_scale("traj_pred")
    assert mode._task_scale("traj_pred") > mode.work_scale


def test_unknown_mode_raises():
    with pytest.raises(KeyError):
        get_mode("wormhole")
    with pytest.raises(KeyError):
        ScenarioScript(name="x", segments=(ModeSegment("wormhole", 1.0),))


# ---------------------------------------------------------------------------
# scripts
# ---------------------------------------------------------------------------
def test_script_timeline_queries():
    s = ScenarioScript(
        name="t",
        segments=(
            ModeSegment("urban", 0.5),
            ModeSegment("highway", 1.0),
            ModeSegment("urban", 0.5),
        ),
        bursts=(Burst(start_s=0.6, duration_s=0.2, work_scale=2.0,
                      tasks=("img_backbone",)),),
        dropouts=(SensorDropout("lidar", 1.6, 0.2),),
    )
    assert np.isclose(s.duration_s, 2.0)
    assert s.modes() == ("urban", "highway")
    assert s.mode_at(0.0) == "urban"
    assert s.mode_at(0.7) == "highway"
    assert s.mode_at(99.0) == "urban"          # clamps to last segment
    assert [m for _t, m in s.boundaries()] == ["urban", "highway", "urban"]
    assert s.burst_scale("img_backbone", 0.7) == 2.0
    assert s.burst_scale("img_backbone#r2", 0.7) == 2.0   # replica inherits
    assert s.burst_scale("lidar_det", 0.7) == 1.0
    assert s.burst_scale("img_backbone", 0.3) == 1.0
    assert s.dropped("lidar", 1.7) and not s.dropped("lidar", 1.0)
    assert not s.dropped("cam_multi", 1.7)


def test_script_parse_roundtrip():
    s = ScenarioScript.parse("urban:0.5 highway:1.0, urban:0.5")
    assert [seg.mode for seg in s.segments] == ["urban", "highway", "urban"]
    assert ScenarioScript.parse(s.to_string()).segments == s.segments
    with pytest.raises(ValueError):
        ScenarioScript.parse("urban")


def test_markov_generator_deterministic_and_covering():
    gen = default_generator()
    a = gen.sample(3.0, seed=42)
    b = gen.sample(3.0, seed=42)
    assert a == b
    assert gen.sample(3.0, seed=43) != a
    assert np.isclose(a.duration_s, 3.0)
    # self-transitions merge into longer dwells, never adjacent
    # equal-mode segments
    for seed in range(20):
        s = gen.sample(3.0, seed=seed)
        for s1, s2 in zip(s.segments, s.segments[1:]):
            assert s1.mode != s2.mode


def test_equal_adjacent_segments_are_not_switches():
    script = ScenarioScript.parse("urban:0.2 urban:0.2 highway:0.2")
    [r] = run(ScenarioSpec(scenario=script, policy="ads_tile",
                                  replan=False, seed=1))
    assert r.n_mode_switches == 1   # urban->urban is not a context change


# ---------------------------------------------------------------------------
# engine integration + replanning (shared runs: they are expensive)
# ---------------------------------------------------------------------------
SCEN = BUNDLED_SCENARIOS["calm_to_rush"]   # 3 segments, 3 distinct modes


@pytest.fixture(scope="module")
def scenario_reports():
    out = {}
    for policy, replan in (
        ("ads_tile", True), ("ads_tile", False), ("tp_driven", True),
    ):
        out[(policy, replan)] = run(ScenarioSpec(
            scenario=SCEN, policy=policy, replan=replan, seed=3,
        ))[0]
    return out


def test_scenario_runs_yield_per_mode_accounting(scenario_reports):
    for (policy, _replan), r in scenario_reports.items():
        assert r.n_mode_switches == len(SCEN.segments) - 1
        assert set(r.mode_stats) == set(SCEN.modes()), policy
        spans = sum(s.span_s for s in r.mode_stats.values())
        assert np.isclose(spans, SCEN.duration_s)
        for s in r.mode_stats.values():
            assert s.n_completed > 0
            assert 0.0 <= s.violation_rate <= 1.0
            assert 0.0 <= s.realloc_frac <= 1.0
            assert s.effective_frac > 0.0
        # per-mode sink counts add up to the global chain accounting
        assert (
            sum(s.n_completed for s in r.mode_stats.values())
            == sum(r.chain_count.values())
        )


def test_replan_swaps_charge_realloc(scenario_reports):
    replan = scenario_reports[("ads_tile", True)]
    pinned = scenario_reports[("ads_tile", False)]
    # hot-swaps go through the bounded-reallocation path: the replanned
    # run must record the two schedule swaps as reallocation events
    assert replan.n_realloc > 0
    assert replan.realloc_frac > 0.0
    # and the waste stays within the paper's headline budget
    assert replan.realloc_frac < 0.012
    assert pinned.realloc_frac < 0.012


def test_replanning_beats_pinned_schedule(scenario_reports):
    """Acceptance: on a drive that leaves its opening mode, hot-swapping
    per-mode schedules strictly lowers the violation rate vs. staying
    pinned to the initial single-mode table."""
    replan = scenario_reports[("ads_tile", True)]
    pinned = scenario_reports[("ads_tile", False)]
    assert replan.violation_rate < pinned.violation_rate


def test_mode_switch_determinism():
    """Same seed + same scenario script => identical SimReport."""
    script = ScenarioScript.parse("parking:0.3 urban:0.3 highway:0.3")
    spec = ScenarioSpec(scenario=script, policy="ads_tile", seed=11)
    [a] = run(spec, backend="scalar")
    [b] = run(spec, backend="scalar")
    assert a.task_miss_rate == b.task_miss_rate
    assert a.effective_frac == b.effective_frac
    assert a.realloc_frac == b.realloc_frac
    assert a.n_realloc == b.n_realloc
    assert a.chain_violations == b.chain_violations
    assert {
        m: (s.n_completed, s.n_violations, s.effective_frac)
        for m, s in a.mode_stats.items()
    } == {
        m: (s.n_completed, s.n_violations, s.effective_frac)
        for m, s in b.mode_stats.items()
    }


def test_sensor_dropout_degrades_downstream():
    clean = ScenarioScript(
        name="clean", segments=(ModeSegment("urban", 0.6),),
    )
    dropped = dataclasses.replace(
        clean, name="dropped",
        dropouts=(SensorDropout("cam_multi", 0.1, 0.3),),
    )
    [r_clean] = run(ScenarioSpec(scenario=clean, policy="ads_tile",
                                        replan=False, seed=5))
    [r_drop] = run(ScenarioSpec(scenario=dropped, policy="ads_tile",
                                       replan=False, seed=5))
    # dropped frames surface as chain violations, not silent success
    assert r_drop.violation_rate > r_clean.violation_rate


def test_decision_ratios_all_positive(scenario_reports):
    for r in scenario_reports.values():
        assert all(x > 0.0 for x in r.decision_ratios)


# ---------------------------------------------------------------------------
# Monte-Carlo sweep
# ---------------------------------------------------------------------------
def test_sweep_deterministic_and_aggregates():
    kw = dict(policies=("ads_tile", "tp_driven"), duration_s=0.6,
              seed=9, jobs=2, tiles=400)
    rows = sweep(2, **kw)
    assert len(rows) == 4      # 2 scenarios x 2 policies
    # paired seeds: both policies see the same drives
    by_pol = {}
    for r in rows:
        by_pol.setdefault(r["policy"], []).append((r["seed"], r["script"]))
    assert by_pol["ads_tile"] == by_pol["tp_driven"]
    # deterministic: re-running the sweep reproduces every row
    again = sweep(2, **kw)
    assert rows == again
    agg = aggregate_sweep(rows)
    assert set(agg) == {"ads_tile", "tp_driven"}
    for a in agg.values():
        assert a["n"] == 2
        assert 0.0 <= a["violation_rate"] <= 1.0
        assert a["per_mode"]
