"""Benchmark-harness CLI tests: ``run.py --out`` path handling / row
parsing, and the CI perf-regression gate (``benchmarks.check_perf``)."""
import json

import pytest

from benchmarks import check_perf
from benchmarks import run as bench_run


# ---------------------------------------------------------------------------
# benchmarks.run --out
# ---------------------------------------------------------------------------
def _dummy_suite(duration: float = 1.0, seed: int = 1) -> None:
    print(f"dummy_row,{123.0 * duration:.3f},seed={seed}")


def test_run_out_creates_missing_parent_dirs(tmp_path, monkeypatch):
    out = tmp_path / "deeply" / "nested" / "dir" / "bench.json"
    monkeypatch.setitem(bench_run.SUITES, "dummy", _dummy_suite)
    monkeypatch.setattr(
        "sys.argv",
        ["run.py", "--only", "dummy", "--duration", "2.0", "--out", str(out)],
    )
    bench_run.main()
    data = json.loads(out.read_text())
    assert data["suites"] == ["dummy"]
    assert data["duration"] == 2.0
    assert data["rows"] == [
        {"name": "dummy_row", "us_per_call": 246.0, "derived": "seed=1"}
    ]


def test_run_trace_out_writes_perfetto_json(tmp_path, monkeypatch):
    out = tmp_path / "trace.json"
    monkeypatch.setattr(
        "sys.argv", ["run.py", "--only", "none", "--trace-out", str(out)]
    )
    bench_run.main()
    data = json.loads(out.read_text())
    assert data["displayTimeUnit"] == "ms"
    assert len(data["traceEvents"]) > 0


def test_rows_from_csv_skips_headers_and_junk():
    text = (
        "name,us_per_call,derived\n"
        "row_a,1.500,x=1\n"
        "# comment done in 3s\n"
        "row_b,2.000,\n"
        "not_a_row\n"
    )
    rows = bench_run._rows_from_csv(text)
    assert [r["name"] for r in rows] == ["row_a", "row_b"]
    assert rows[0]["derived"] == "x=1"


def test_unknown_suite_errors(monkeypatch, capsys):
    monkeypatch.setattr("sys.argv", ["run.py", "--only", "no_such_suite"])
    with pytest.raises(SystemExit):
        bench_run.main()
    assert "no_such_suite" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# benchmarks.check_perf (CI perf gate)
# ---------------------------------------------------------------------------
def _bench_json(path, us, name="perf_sweep_e2e"):
    path.write_text(json.dumps({
        "suites": ["perf"], "duration": 1.0, "seed": 1,
        "rows": [{"name": name, "us_per_call": us, "derived": ""}],
    }))
    return path


def test_check_perf_passes_within_threshold(tmp_path):
    base = _bench_json(tmp_path / "base.json", 100_000.0)
    fresh = _bench_json(tmp_path / "fresh.json", 140_000.0)
    ratio, ok = check_perf.check(base, fresh)
    assert ok and ratio == pytest.approx(1.4)
    assert check_perf.main([str(base), str(fresh)]) == 0


def test_check_perf_fails_on_regression(tmp_path):
    base = _bench_json(tmp_path / "base.json", 100_000.0)
    fresh = _bench_json(tmp_path / "fresh.json", 151_000.0)
    ratio, ok = check_perf.check(base, fresh)
    assert not ok and ratio == pytest.approx(1.51)
    assert check_perf.main([str(base), str(fresh)]) == 2
    # a looser explicit threshold lets the same pair through
    assert check_perf.main(
        [str(base), str(fresh), "--threshold", "2.0"]
    ) == 0


def test_check_perf_missing_metric_raises(tmp_path):
    base = _bench_json(tmp_path / "base.json", 100_000.0, name="other_row")
    fresh = _bench_json(tmp_path / "fresh.json", 100_000.0)
    with pytest.raises(KeyError):
        check_perf.check(base, fresh)


def test_committed_baseline_has_the_gated_metric():
    """The gate in ci.yml compares against the committed BENCH_sim.json;
    that file must keep the pinned-sweep row."""
    from pathlib import Path

    repo = Path(__file__).resolve().parent.parent
    row = check_perf.load_metric(
        repo / "BENCH_sim.json", check_perf.DEFAULT_METRIC
    )
    assert row["us_per_call"] > 0
