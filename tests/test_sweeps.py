"""Fleet-scale sweep service: cell keys, cache, manifests, reducer,
and the redesigned ``run()`` entry point.

Contracts under test (docs/sweeps.md):

* cell keys move when any row-relevant input moves and hold still
  under recomputation and derived attachments (portfolio, mode_defs);
* a repeated identical campaign is 100% cache-hit (zero cells
  executed) and serves rows equal to the fresh ones;
* an interrupted campaign resumed from its manifest equals the
  uninterrupted run row for row;
* a crashing cell is captured per cell — finished rows persist, the
  manifest lists the failed keys, and rerunning retries failures only;
* ``SweepReducer`` streaming equals batch ``aggregate_sweep``;
* the deprecated entry points delegate to ``run()`` bit-identically
  while warning.
"""
import dataclasses
import json

import pytest

from repro.core.sim.batch import reports_identical
from repro.scenarios import aggregate_sweep, sweep
from repro.scenarios.runner import (
    SWEEP_BACKENDS,
    ScenarioSpec,
    parallel_map,
    run,
    summarize,
)
from repro.scenarios.script import default_generator, get_scenario
from repro.sweeps import (
    CONTRACT_VERSION,
    CampaignSpec,
    ItemFailure,
    ResultCache,
    SweepFailure,
    SweepReducer,
    SweepRow,
    build_cells,
    cell_key,
    run_campaign,
)
from repro.sweeps.manifest import CampaignManifest, CellRecord
from repro.sweeps.worker import run_shard

SPEC = ScenarioSpec(scenario=get_scenario("calm_to_rush"),
                    policy="ads_tile", seed=3)

CAMPAIGN_KW = dict(
    name="t", n_scenarios=2, policies=("ads_tile", "tp_driven"),
    scenario_duration_s=0.4, seed=5,
)


# ---------------------------------------------------------------------------
# cell keys
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("change", [
    {"seed": 99},
    {"policy": "tp_driven"},
    {"replan": False},
    {"replan_mode": "predictive"},
    {"target_miss": 0.05},
    {"tiles": 256},
    {"load_factor": 1.2},
    {"drop_policy": "hard"},
    {"duration_s": 0.9},
    {"record": True},
    {"scenario": get_scenario("commute")},
])
def test_cell_key_moves_with_row_relevant_fields(change):
    assert cell_key(dataclasses.replace(SPEC, **change)) != cell_key(SPEC)


def test_cell_key_stable_under_recompute_and_derived_fields():
    base = cell_key(SPEC)
    assert cell_key(SPEC) == base
    # attached portfolio and mode_defs are derived, not row inputs
    from repro.scenarios.modes import get_mode
    from repro.scenarios.runner import compile_portfolio

    derived = dataclasses.replace(
        SPEC,
        portfolio=compile_portfolio(SPEC),
        mode_defs={m: get_mode(m) for m in SPEC.scenario.modes()},
    )
    assert cell_key(derived) == base


def test_cell_key_backend_equivalence_classes():
    # scalar/lockstep/auto are bit-identical: one cache class
    exact = {cell_key(SPEC, backend=b) for b in ("auto", "scalar", "lockstep")}
    assert len(exact) == 1
    # soa is distributional: its own class
    assert cell_key(SPEC, backend="soa") not in exact
    with pytest.raises(ValueError):
        cell_key(SPEC, backend="warp")


def test_cell_key_moves_with_contract_version(monkeypatch):
    from repro.sweeps import cellkey as ck

    base = cell_key(SPEC)
    monkeypatch.setattr(ck, "CONTRACT_VERSION", CONTRACT_VERSION + 1)
    assert cell_key(SPEC) != base


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------
def test_backend_registry_metadata():
    assert set(SWEEP_BACKENDS.names()) == {"scalar", "lockstep", "soa"}
    assert "soa" in SWEEP_BACKENDS
    assert SWEEP_BACKENDS["scalar"].kind == "exact"
    assert SWEEP_BACKENDS["lockstep"].kind == "exact"
    assert SWEEP_BACKENDS["soa"].kind == "distributional"
    # exact backends support every spec; the SoA probe names its reason
    assert SWEEP_BACKENDS["lockstep"].supports(SPEC)[0]
    ok, why = SWEEP_BACKENDS["soa"].supports(
        dataclasses.replace(SPEC, replan_mode="predictive")
    )
    assert not ok and why


# ---------------------------------------------------------------------------
# run() entry point
# ---------------------------------------------------------------------------
def test_run_validations():
    with pytest.raises(ValueError, match="seeds"):
        run([SPEC, SPEC], seeds=[0, 1])
    with pytest.raises(ValueError, match="trace"):
        run(SPEC, seeds=[0, 1], trace=object())
    with pytest.raises(ValueError, match="backend"):
        run(SPEC, backend="warp")


def test_removed_shims_stay_gone():
    """The one-release deprecation window for the four historical entry
    points is over; the names must not quietly come back."""
    import repro.scenarios as scenarios
    import repro.scenarios.runner as runner

    for name in ("run_scenario", "run_scenario_batch",
                 "run_scenario_soa", "run_scenario_group"):
        assert not hasattr(runner, name), name
        assert not hasattr(scenarios, name), name
        assert name not in runner.__all__
        assert name not in scenarios.__all__

    # the run() call shapes the shims delegated to remain bit-identical
    [r_single] = run(SPEC)
    fan = run(SPEC, seeds=[3])
    specs = [SPEC, dataclasses.replace(SPEC, policy="tp_driven")]
    group = run(specs, backend="lockstep")
    assert reports_identical(r_single, fan[0])
    assert reports_identical(r_single, group[0])


# ---------------------------------------------------------------------------
# typed rows + streaming reducer
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def sweep_rows():
    return sweep(2, policies=("ads_tile", "tp_driven"),
                 duration_s=0.4, seed=5, jobs=1, record=True)


def test_sweep_row_dict_shape_and_round_trip(sweep_rows):
    [r] = run(SPEC)
    row = SweepRow.from_report(SPEC, r)
    legacy = summarize(SPEC, r)
    assert row.to_dict() == legacy
    assert list(row.to_dict()) == list(legacy)          # field order too
    assert SweepRow.from_dict(row.to_dict()).to_dict() == legacy
    for swept in sweep_rows:
        assert SweepRow.from_dict(swept).to_dict() == swept


def test_reducer_streaming_equals_batch_aggregate(sweep_rows):
    red = SweepReducer()
    for row in sweep_rows:
        red.update(row)
    assert red.result() == aggregate_sweep(sweep_rows)


# ---------------------------------------------------------------------------
# campaigns: cache hits, manifest resume, failure capture
# ---------------------------------------------------------------------------
def test_campaign_repeat_is_all_cache_hits(tmp_path):
    cache = tmp_path / "cache"
    first = run_campaign(CampaignSpec(**CAMPAIGN_KW),
                         cache_dir=cache, jobs=1)
    assert (first.n_cells, first.n_executed, first.n_cached) == (4, 4, 0)
    again = run_campaign(CampaignSpec(**CAMPAIGN_KW),
                         cache_dir=cache, jobs=1)
    assert (again.n_executed, again.n_cached) == (0, 4)
    assert again.rows == first.rows
    assert again.aggregate == first.aggregate
    # the campaign is sweep()'s durable form: same rows as the direct
    # process-pool sweep with the same arguments
    direct = sweep(CAMPAIGN_KW["n_scenarios"],
                   policies=CAMPAIGN_KW["policies"],
                   duration_s=CAMPAIGN_KW["scenario_duration_s"],
                   seed=CAMPAIGN_KW["seed"], jobs=1)
    assert first.rows == direct


def test_interrupted_campaign_resumes_row_for_row(tmp_path):
    ref = run_campaign(CampaignSpec(**CAMPAIGN_KW),
                       cache_dir=tmp_path / "ref", jobs=1)

    cache = tmp_path / "cache"
    manifest = tmp_path / "manifest.json"
    spec = CampaignSpec(**CAMPAIGN_KW)
    cells = build_cells(spec)
    CampaignManifest(
        campaign=spec.to_dict(),
        cells=[
            CellRecord(index=c.index, key=c.key,
                       scenario_index=c.scenario_index,
                       policy=str(c.spec.policy), seed=int(c.spec.seed),
                       backend=c.backend_class)
            for c in cells
        ],
        cache_dir=str(cache),
    ).save(manifest)
    # simulate an interruption: one scenario group executes, then stop
    report = run_shard(manifest, cache, max_groups=1)
    assert 0 < report["n_executed"] < 4

    resumed = run_campaign(str(manifest), jobs=1)
    assert resumed.n_cached == report["n_executed"]
    assert resumed.n_executed == 4 - report["n_executed"]
    assert resumed.rows == ref.rows


def test_failed_cells_are_captured_not_fatal(tmp_path):
    cache = tmp_path / "cache"
    bad = CampaignSpec(**{**CAMPAIGN_KW,
                          "policies": ("ads_tile", "no_such_policy")})
    with pytest.raises(SweepFailure) as ei:
        run_campaign(bad, cache_dir=cache,
                     manifest_path=tmp_path / "m.json", jobs=1)
    result = ei.value.result
    assert result.n_failed == 2 and len(ei.value.failed_keys) == 2
    assert result.n_executed == 2          # good cells ran and persisted
    manifest = CampaignManifest.load(tmp_path / "m.json")
    assert sorted(manifest.failed_keys()) == sorted(ei.value.failed_keys)
    # the completed cells are in the cache: the good-policy campaign
    # over the same scenarios re-executes nothing
    good = run_campaign(
        CampaignSpec(**{**CAMPAIGN_KW, "policies": ("ads_tile",)}),
        cache_dir=cache, jobs=1,
    )
    assert (good.n_executed, good.n_cached) == (0, 2)
    # allow_failures returns the partial result instead of raising
    partial = run_campaign(bad, cache_dir=cache, jobs=1,
                           allow_failures=True)
    assert partial.n_failed == 2 and len(partial.rows) == 2


def test_campaign_streaming_matches_kept_rows(tmp_path):
    spec = CampaignSpec(**CAMPAIGN_KW)
    kept = run_campaign(spec, cache_dir=tmp_path / "c", jobs=1)
    streamed = run_campaign(spec, cache_dir=tmp_path / "c", jobs=1,
                            keep_rows=False)
    assert streamed.rows is None
    assert streamed.aggregate == kept.aggregate


def test_campaign_spec_json_round_trip():
    gen = default_generator()
    spec = CampaignSpec(**CAMPAIGN_KW, generator=gen,
                        spec_kw={"record": True, "tiles": 256})
    d = json.loads(json.dumps(spec.to_dict()))
    back = CampaignSpec.from_dict(d)
    assert back.policies == spec.policies
    assert back.spec_kw == spec.spec_kw
    assert back.generator.transitions == gen.transitions
    assert back.to_dict() == spec.to_dict()


def test_manifest_round_trip_and_version_guard(tmp_path):
    spec = CampaignSpec(**CAMPAIGN_KW)
    res = run_campaign(spec, cache_dir=tmp_path / "c",
                       manifest_path=tmp_path / "m.json", jobs=1)
    loaded = CampaignManifest.load(tmp_path / "m.json")
    assert loaded.counts() == res.manifest.counts()
    assert [c.key for c in loaded.cells] == [c.key for c in res.manifest.cells]
    d = json.loads((tmp_path / "m.json").read_text())
    assert CampaignManifest.is_manifest(d)
    d["version"] = 99
    (tmp_path / "m.json").write_text(json.dumps(d))
    with pytest.raises(ValueError, match="version"):
        CampaignManifest.load(tmp_path / "m.json")


def test_cache_treats_corruption_as_miss(tmp_path):
    cache = ResultCache(tmp_path)
    cache.put("ab" * 32, {"x": 1.5})
    assert cache.get("ab" * 32) == {"x": 1.5}
    path = tmp_path / ("ab" * 32)[:2] / (("ab" * 32) + ".json")
    path.write_text("{truncated")
    assert cache.get("ab" * 32) is None
    assert cache.get("cd" * 32) is None


# ---------------------------------------------------------------------------
# parallel_map failure semantics (the satellite bugfix)
# ---------------------------------------------------------------------------
def _square(x):
    return x * x


def _boom(x):
    if x == 2:
        raise ValueError("boom on 2")
    return x


def test_parallel_map_return_errors_in_place():
    out = parallel_map(_boom, [1, 2, 3], jobs=1, return_errors=True)
    assert out[0] == 1 and out[2] == 3
    assert isinstance(out[1], ItemFailure)
    assert "boom on 2" in out[1].error


def test_parallel_map_reraises_after_full_pass():
    with pytest.raises(ValueError, match="boom on 2"):
        parallel_map(_boom, [1, 2, 3], jobs=1)
    assert parallel_map(_square, [1, 2, 3], jobs=1) == [1, 4, 9]
