"""Batched trace-generation tests: the counter-based stream contract,
skeleton caching, distribution equivalence with the legacy scalar
sampler, and exact job-level pairing across policies."""
import numpy as np
import pytest

from repro.core.benchmark import make_ads_benchmark
from repro.core.experiment import ExperimentSpec, build_stack, make_policy
from repro.core.hardware import simba_chip
from repro.core.latency_model import LatencyModel, LogNormal, ndtri
from repro.core.sim import SimConfig, Simulator
from repro.core.sim.trace import (
    STREAM_IO,
    STREAM_WORK,
    build_skeleton,
    chain_sources,
    clear_skeleton_cache,
    counter_uniforms,
    sample_trace,
)
from repro.core.workload import unroll_hyperperiod
from repro.scenarios import ScenarioSpec, get_scenario, run


def _stack(**kw):
    spec = ExperimentSpec(policy="ads_tile", tiles=400, **kw)
    wf, _hw, model, compiler = build_stack(spec)
    return wf, model, compiler.compile(model, wf)


# ---------------------------------------------------------------------------
# counter_uniforms: the stream contract primitive
# ---------------------------------------------------------------------------
def test_counter_uniforms_pure_and_open_interval():
    u1 = counter_uniforms(7, "img_backbone", STREAM_WORK,
                          np.zeros(64, np.uint64),
                          np.arange(64, dtype=np.uint64),
                          np.arange(64, dtype=np.uint64))
    u2 = counter_uniforms(7, "img_backbone", STREAM_WORK,
                          np.zeros(64, np.uint64),
                          np.arange(64, dtype=np.uint64),
                          np.arange(64, dtype=np.uint64))
    assert np.array_equal(u1, u2)                    # pure function
    assert np.all((u1 > 0.0) & (u1 < 1.0))           # open interval
    # every key component matters
    for variant in (
        counter_uniforms(8, "img_backbone", STREAM_WORK,
                         np.zeros(64, np.uint64),
                         np.arange(64, dtype=np.uint64),
                         np.arange(64, dtype=np.uint64)),
        counter_uniforms(7, "lidar_det", STREAM_WORK,
                         np.zeros(64, np.uint64),
                         np.arange(64, dtype=np.uint64),
                         np.arange(64, dtype=np.uint64)),
        counter_uniforms(7, "img_backbone", STREAM_IO,
                         np.zeros(64, np.uint64),
                         np.arange(64, dtype=np.uint64),
                         np.arange(64, dtype=np.uint64)),
        counter_uniforms(7, "img_backbone", STREAM_WORK,
                         np.ones(64, np.uint64),
                         np.arange(64, dtype=np.uint64),
                         np.arange(64, dtype=np.uint64)),
    ):
        assert not np.array_equal(u1, variant)


def test_counter_uniforms_are_uniform():
    """KS test of the counter stream against U(0, 1)."""
    scipy_stats = pytest.importorskip("scipy.stats")
    n = 20000
    u = counter_uniforms(3, "vis_det", STREAM_WORK,
                         np.zeros(n, np.uint64),
                         np.zeros(n, np.uint64),
                         np.arange(n, dtype=np.uint64))
    stat = scipy_stats.kstest(u, "uniform")
    assert stat.pvalue > 0.01, stat


def test_ndtri_vectorized_matches_scalar():
    qs = np.concatenate([
        np.linspace(1e-6, 1 - 1e-6, 101), [0.001, 0.02425, 0.5, 0.97575]
    ])
    vec = ndtri(qs)
    scal = np.asarray([ndtri(float(q)) for q in qs])
    assert np.array_equal(vec, scal)
    assert ndtri(0.0) == -np.inf and ndtri(1.0) == np.inf
    # round-trips a couple of known quantiles
    assert abs(ndtri(0.975) - 1.959964) < 1e-4
    assert abs(ndtri(0.5)) < 1e-12


# ---------------------------------------------------------------------------
# trace determinism: build order / horizon / policy independence
# ---------------------------------------------------------------------------
def test_draws_independent_of_horizon():
    """Shortening the run must not shift the draws of shared jobs."""
    wf, model, sched = _stack()
    a = Simulator(wf, model, sched, make_policy("ads_tile"),
                  SimConfig(duration_s=0.4, seed=11))
    b = Simulator(wf, model, sched, make_policy("ads_tile"),
                  SimConfig(duration_s=0.8, seed=11))
    by_key = {(j.task, j.cycle, j.idx): j for j in b.jobs}
    assert len(a.jobs) < len(b.jobs)
    for j in a.jobs:
        other = by_key[(j.task, j.cycle, j.idx)]
        assert j.work_flops == other.work_flops
        assert j.io_s == other.io_s


def test_paired_policies_identical_draws():
    """Acceptance: for one scenario seed, every policy sees bit-identical
    work_flops/io_s per job — comparisons are paired at the job level."""
    wf, model, sched_ads = _stack()
    spec_tp = ExperimentSpec(policy="tp_driven", tiles=400)
    _wf2, _hw, model_tp, compiler_tp = build_stack(spec_tp)
    sched_tp = compiler_tp.compile(model_tp, _wf2)
    a = Simulator(wf, model, sched_ads, make_policy("ads_tile"),
                  SimConfig(duration_s=0.6, seed=5))
    b = Simulator(_wf2, model_tp, sched_tp, make_policy("tp_driven"),
                  SimConfig(duration_s=0.6, seed=5))
    assert len(a.jobs) == len(b.jobs)
    for x, y in zip(a.jobs, b.jobs):
        assert (x.task, x.cycle, x.idx) == (y.task, y.cycle, y.idx)
        assert x.work_flops == y.work_flops
        assert x.io_s == y.io_s


def test_draws_stable_across_regime_splits():
    """A scenario's regime list is duration-independent for shared
    prefixes: draws of regime-0 jobs agree between horizons that cut
    the script at different points."""
    scen = get_scenario("rate_churn")          # night:0.6 urban:0.6 rush:0.8
    spec = ScenarioSpec(scenario=scen, policy="ads_tile", replan=False, seed=9)
    wf, _hw, model, compiler = build_stack(spec)
    sched = compiler.compile(model, wf)
    short = Simulator(wf, model, sched, make_policy("ads_tile"),
                      SimConfig(duration_s=0.5, seed=9, scenario=scen))
    full = Simulator(wf, model, sched, make_policy("ads_tile"),
                     SimConfig(duration_s=scen.duration_s, seed=9, scenario=scen))
    # release times identify a job uniquely across the whole run (the
    # (cycle, idx) pair repeats across regimes)
    by_key = {(j.task, round(j.release, 12)): j for j in full.jobs}
    assert len(by_key) == len(full.jobs)
    for j in short.jobs:
        other = by_key.get((j.task, round(j.release, 12)))
        assert other is not None
        assert j.work_flops == other.work_flops
        assert j.io_s == other.io_s


def test_shared_trace_reproduces_internal_sampling():
    """run(trace=...) must equal the trace-less run exactly."""
    scen = get_scenario("commute")
    spec = ScenarioSpec(scenario=scen, policy="ads_tile", seed=4)
    from repro.scenarios import build_trace
    [r_implicit] = run(spec, backend="scalar")
    [r_explicit] = run(spec, trace=build_trace(spec), backend="scalar")
    assert r_implicit.effective_frac == r_explicit.effective_frac
    assert r_implicit.realloc_frac == r_explicit.realloc_frac
    assert r_implicit.chain_violations == r_explicit.chain_violations


def test_mismatched_trace_rejected():
    wf, model, sched = _stack()
    skel = build_skeleton(wf, None, 0.4)
    tr = sample_trace(skel, model, None, 3)
    with pytest.raises(ValueError):
        Simulator(wf, model, sched, make_policy("ads_tile"),
                  SimConfig(duration_s=0.8, seed=3, trace=tr))


# ---------------------------------------------------------------------------
# distributional correctness of the counter-based stream contract
# ---------------------------------------------------------------------------
def test_sampled_streams_match_analytic_distributions():
    """KS tests pin each stream of the counter-based sampler directly
    against the analytic distributions it inverts: lognormal work,
    shifted-exponential I/O, (range-clamped) lognormal sensor latency.
    This is the contract the retired scalar ``RandomState`` reference
    implementation used to witness indirectly."""
    scipy_stats = pytest.importorskip("scipy.stats")
    wf = make_ads_benchmark()
    model = LatencyModel.from_workflow(wf, simba_chip(400))
    skel = build_skeleton(wf, None, 30.0)       # ~300 cycles of samples
    batched = sample_trace(skel, model, None, 2)
    tasks = np.asarray(skel.tasks)
    for name in ("img_backbone", "traj_pred", "lidar_det"):
        prof = model.profiles[name]
        ix = np.flatnonzero(tasks == name)
        assert len(ix) >= 200
        work = scipy_stats.kstest(
            batched.work[ix],
            lambda x, p=prof.work: scipy_stats.lognorm.cdf(
                x, p.sigma, scale=float(np.exp(p.mu))
            ),
        )
        assert work.pvalue > 0.005, (name, "work", work)
        io = scipy_stats.kstest(
            batched.io[ix],
            lambda x, p=prof.io: scipy_stats.expon.cdf(
                x, loc=p.base, scale=1.0 / p.rate
            ),
        )
        assert io.pvalue > 0.005, (name, "io", io)
    # sensor latency stream: lognormal through the legacy-range clamp
    # (uniforms mapped into (0.001, 0.999) before the inverse CDF)
    prof = model.profiles["cam_multi"].sensor_latency
    ix = np.flatnonzero(tasks == "cam_multi")
    sen = scipy_stats.kstest(
        batched.sensor_lat[ix],
        lambda x, p=prof: scipy_stats.lognorm.cdf(
            x, p.sigma, scale=float(np.exp(p.mu))
        ),
    )
    assert sen.pvalue > 0.005, sen


def test_lognormal_quantiles_match_scalar():
    ln = LogNormal(2.5e9, 3.3)
    qs = np.linspace(0.001, 0.999, 97)
    vec = ln.quantiles(qs)
    scal = np.asarray([ln.quantile(float(q)) for q in qs])
    assert np.allclose(vec, scal, rtol=1e-12)


# ---------------------------------------------------------------------------
# skeleton structure + caching
# ---------------------------------------------------------------------------
def test_skeleton_matches_unroll_structure():
    wf = make_ads_benchmark()
    skel = build_skeleton(wf, None, wf.hyper_period_s)
    insts = unroll_hyperperiod(wf)
    assert skel.n == len(insts)
    assert skel.tasks == [i.task for i in insts]
    assert np.array_equal(skel.release, [i.release_s for i in insts])
    # dependency counts survive the CSR round-trip
    assert skel.deps_remaining == [len(i.preds) for i in insts]
    n_edges = sum(len(i.preds) for i in insts)
    assert sum(len(s) for s in skel.succs) == n_edges
    # chain sources agree with a direct computation
    src = chain_sources(wf, insts)
    assert len(skel.sink_src) == len(src)


def test_skeleton_cached_and_cleared():
    wf = make_ads_benchmark()
    clear_skeleton_cache()
    a = build_skeleton(wf, None, 0.5)
    b = build_skeleton(wf, None, 0.5)
    assert a is b
    # an equal-structure workflow hits the same entry (mode transforms
    # build new Workflow objects per call)
    wf2 = make_ads_benchmark()
    assert build_skeleton(wf2, None, 0.5) is a
    clear_skeleton_cache()
    assert build_skeleton(wf, None, 0.5) is not a


def test_reregistered_mode_profiles_invalidate_param_memo():
    """A mode re-registered with different *profile* transforms (same
    rates, so the structural skeleton stays valid) must change the
    sampled draws — the per-(skeleton, model) parameter memo may not
    serve stale arrays."""
    from repro.scenarios import MODES, DrivingMode, register_mode
    from repro.scenarios.script import ScenarioScript
    register_mode(DrivingMode(name="tuned", work_scale=1.0), overwrite=True)
    try:
        scen = ScenarioScript.parse("tuned:0.4", name="tuned-run")
        wf = make_ads_benchmark()
        model = LatencyModel.from_workflow(wf, simba_chip(400))
        skel = build_skeleton(wf, scen, 0.4)
        base = sample_trace(skel, model, scen, 7)
        register_mode(DrivingMode(name="tuned", work_scale=2.0),
                      overwrite=True)
        assert build_skeleton(wf, scen, 0.4) is skel  # structure unchanged
        doubled = sample_trace(skel, model, scen, 7)
        dnn = skel.dnn_ix
        assert np.allclose(doubled.work[dnn], 2.0 * base.work[dnn])
    finally:
        del MODES["tuned"]


def test_sensor_latency_positive_and_bounded():
    wf, model, sched = _stack()
    sim = Simulator(wf, model, sched, make_policy("ads_tile"),
                    SimConfig(duration_s=0.5, seed=1))
    sensors = [j for j in sim.jobs if j.is_sensor]
    assert sensors
    for j in sensors:
        assert j.io_s > 0.0
        assert np.isfinite(j.io_s)
        assert j.sub_ddl > j.release
