"""Batched lockstep engine vs the scalar reference engine.

The contract under test is bit-identity: every lane of a seed-fan or
group ``run(..., backend="lockstep")`` must produce a
:class:`~repro.core.sim.engine.SimReport` exactly equal (via
``report_digest``, every float verbatim) to the same run through the
scalar backend.  The full bundled-scenario sweep runs in
CI as its own gate (``benchmarks.check_equivalence``); here a fast
subset pins the contract into tier-1, plus the de-batching edge cases
(unsupported lane, attached recorder) and a property test over random
scenarios/workloads.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.sim import batch as batch_mod
from repro.core.sim.batch import reports_identical
from repro.obs import TraceRecorder
from repro.scenarios.runner import ScenarioSpec, run
from repro.scenarios.script import default_generator, get_scenario

SEEDS = [0, 7]


def _scalar(spec: ScenarioSpec, seed: int):
    return run(dataclasses.replace(spec, seed=int(seed)), backend="scalar")[0]


def _spy_scalar_lanes(monkeypatch):
    """Record every sim that de-batches to the scalar fallback lane."""
    seen = []
    orig = batch_mod._ScalarLane
    monkeypatch.setattr(
        batch_mod,
        "_ScalarLane",
        lambda sim: seen.append(sim) or orig(sim),
    )
    return seen


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["cyc", "tp_driven", "ads_tile"])
@pytest.mark.parametrize("scenario", ["calm_to_rush", "rate_churn"])
def test_batched_reports_bit_identical(scenario, policy):
    spec = ScenarioSpec(scenario=get_scenario(scenario), policy=policy)
    reports = run(spec, seeds=SEEDS, backend="lockstep")
    for s, rb in zip(SEEDS, reports):
        assert reports_identical(_scalar(spec, s), rb), (scenario, policy, s)


def test_divergent_lane_falls_back_to_scalar(monkeypatch):
    # a predictive replanner is outside the fused cores' support set:
    # its lane must de-batch to the scalar driver (and only its lane),
    # while the whole batch stays bit-identical to per-run execution
    scen = get_scenario("calm_to_rush")
    specs = [
        ScenarioSpec(scenario=scen, policy="ads_tile", seed=3),
        ScenarioSpec(
            scenario=scen, policy="ads_tile", seed=3, replan_mode="predictive"
        ),
    ]
    seen = _spy_scalar_lanes(monkeypatch)
    reports = run(specs, backend="lockstep")
    assert len(seen) == 1
    assert seen[0].cfg.seed == 3
    assert not batch_mod.fast_lane_supported(seen[0])
    for spec, rb in zip(specs, reports):
        assert reports_identical(run(spec, backend="scalar")[0], rb)


def test_recorder_lane_debatches(monkeypatch):
    # recorder hooks live on engine paths the fused loop elides, so a
    # recorded lane runs scalar inside the lockstep loop — without
    # perturbing its own results or any other lane's
    spec = ScenarioSpec(scenario=get_scenario("calm_to_rush"), policy="ads_tile")
    seen = _spy_scalar_lanes(monkeypatch)
    reports = run(spec, seeds=SEEDS, backend="lockstep",
                  recorders={1: TraceRecorder()})
    assert [sim.cfg.recorder is not None for sim in seen] == [True]
    assert reports[0].attribution is None
    assert reports[1].attribution is not None
    for s, rb in zip(SEEDS, reports):
        assert reports_identical(_scalar(spec, s), rb)


def test_mixed_skeleton_batch_rejected():
    a = ScenarioSpec(scenario=get_scenario("calm_to_rush"), policy="cyc")
    b = ScenarioSpec(scenario=get_scenario("commute"), policy="cyc")
    with pytest.raises(ValueError, match="skeleton"):
        run([a, b], backend="lockstep")


# ---------------------------------------------------------------------------
# property test: random scenarios/workloads, scalar-vs-batched equality.
@pytest.mark.skipif(not batch_mod._HAS_JAX, reason="jax not installed")
def test_ndtri_jnp_matches_numpy_at_stream_boundaries():
    """The stream contract's uniforms are ``(m + 0.5) * 2**-53``; the
    top draw's real value ``1 - 2**-54`` rounds to exactly 1.0 in
    binary64, where the NumPy ``ndtri`` array path returns ``+inf`` —
    the device mirror must agree on every reachable input, boundary
    included (not clip it to a finite tail value)."""
    from repro.core.latency_model import ndtri
    from repro.core.sim.batch import _enable_x64, _jnp, _ndtri_jnp

    top = (np.float64((1 << 53) - 1) + 0.5) * 2.0**-53
    assert top == 1.0  # the binary64 fact the boundary branch exists for
    bot = 0.5 * 2.0**-53  # the stream's smallest draw
    qs = np.array([bot, 1e-12, 0.02, 0.3, 0.99, 1.0 - 2.0**-52, top])
    with _enable_x64():
        got = np.asarray(_ndtri_jnp(_jnp.asarray(qs)))
    want = ndtri(qs)
    assert want[-1] == np.inf and got[-1] == np.inf
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-15)


# Guarded import (not importorskip) so a missing hypothesis skips only
# this test, never the pinned equivalence tests above.
try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_random_scenarios_match_scalar():
        pass
else:
    @given(
        gen_seed=st.integers(0, 1_000),
        run_seed=st.integers(0, 10_000),
        duration=st.floats(0.3, 0.6),
        policy=st.sampled_from(["cyc", "tp_driven", "ads_tile"]),
        replicas=st.integers(1, 2),
    )
    @settings(
        deadline=None,
        max_examples=8,
        suppress_health_check=[HealthCheck.too_slow],
    )
    def test_property_random_scenarios_match_scalar(
        gen_seed, run_seed, duration, policy, replicas
    ):
        scen = default_generator().sample(duration, gen_seed)
        spec = ScenarioSpec(scenario=scen, policy=policy, cockpit_replicas=replicas)
        seeds = [run_seed, run_seed + 1]
        reports = run(spec, seeds=seeds, backend="lockstep")
        for s, rb in zip(seeds, reports):
            assert reports_identical(_scalar(spec, s), rb), (gen_seed, policy, s)
