"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in
interpret=True mode (the kernel body executes on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("b,hq,hkv,l,d", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 8, 2, 96, 32),      # GQA, ragged length
    (1, 4, 1, 256, 128),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (32, 0.0), (0, 50.0)])
def test_flash_attention_sweep(b, hq, hkv, l, d, dtype, window, softcap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, l, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, l, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, l, d), dtype)
    out = ops.flash_attention(
        q, k, v, causal=True, window=window, softcap=softcap,
        block_q=64, block_k=64, interpret=True,
    )
    want = ref.attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        causal=True, window=window, softcap=softcap,
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("b,l,h,p,n,chunk", [
    (1, 64, 4, 16, 16, 16),
    (2, 96, 8, 32, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(b, l, h, p, n, chunk, dtype):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, l, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, l, 1, n), dtype)
    Cm = jax.random.normal(ks[0], (b, l, 1, n), dtype)

    y, fin = ops.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    from repro.models.mamba2 import ssd_chunked as oracle
    y2, fin2 = oracle(
        x.astype(jnp.float32), dt, A,
        Bm.astype(jnp.float32), Cm.astype(jnp.float32), chunk=chunk,
    )
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y2, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
        atol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(fin, np.float32), np.asarray(fin2, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
        atol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
    )


def test_ssd_kernel_matches_sequential_recurrence():
    """The chunked algorithm equals the naive per-step SSM recurrence."""
    b, l, h, p, n = 1, 32, 2, 8, 8
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, l, 1, n))
    Cm = jax.random.normal(ks[0], (b, l, 1, n))

    y, fin = ops.ssd_chunked(x, dt, A, Bm, Cm, chunk=8, interpret=True)

    state = np.zeros((b, h, p, n), np.float32)
    ys = np.zeros((b, l, h, p), np.float32)
    xn, dtn, An = map(np.asarray, (x, dt, A))
    Bn, Cn = np.asarray(Bm)[:, :, 0], np.asarray(Cm)[:, :, 0]
    for t in range(l):
        decay = np.exp(dtn[:, t] * An)                       # (b,h)
        upd = np.einsum("bh,bn,bhp->bhpn", dtn[:, t], Bn[:, t], xn[:, t])
        state = state * decay[:, :, None, None] + upd
        ys[:, t] = np.einsum("bhpn,bn->bhp", state, Cn[:, t])
    np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fin), state, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,l,w,wb", [(1, 64, 64, 32), (2, 48, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_kernel_sweep(b, l, w, wb, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, w), dtype)
    r = jax.random.normal(ks[1], (b, l, w), dtype)
    i = jax.random.normal(ks[2], (b, l, w), dtype)
    lam = jax.random.normal(ks[3], (w,))
    h0 = jax.random.normal(ks[4], (b, w), dtype)
    hs, hT = ops.rglru_scan(x, r, i, lam, h0, width_block=wb, interpret=True)
    hs2, hT2 = ref.rglru_scan_ref(x, r, i, lam, h0)
    np.testing.assert_allclose(
        np.asarray(hs, np.float32), np.asarray(hs2, np.float32), **_tol(dtype)
    )
    np.testing.assert_allclose(
        np.asarray(hT, np.float32), np.asarray(hT2, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("e,c,d,f,bc", [(4, 64, 32, 64, 32), (8, 96, 16, 32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_sweep(e, c, d, f, bc, dtype):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (e, c, d), dtype)
    wg = (jax.random.normal(ks[1], (e, d, f)) * 0.1).astype(dtype)
    wu = (jax.random.normal(ks[2], (e, d, f)) * 0.1).astype(dtype)
    wd = (jax.random.normal(ks[3], (e, f, d)) * 0.1).astype(dtype)
    out = ops.moe_gmm(x, wg, wu, wd, block_c=bc, interpret=True)
    want = ref.moe_gmm_ref(
        x.astype(jnp.float32), wg.astype(jnp.float32),
        wu.astype(jnp.float32), wd.astype(jnp.float32),
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_flash_attention_vjp_vs_oracle():
    """The custom VJP used by the model path matches autodiff through
    the naive oracle."""
    from repro.models.common import chunked_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 4, 37, 16))
    k = jax.random.normal(ks[1], (2, 2, 37, 16))
    v = jax.random.normal(ks[2], (2, 2, 37, 16))
    for window, cap in [(0, 0.0), (9, 50.0)]:
        g1 = jax.grad(
            lambda q, k, v: chunked_attention(
                q, k, v, causal=True, window=window, softcap=cap, block=16
            ).sum(), argnums=(0, 1, 2),
        )(q, k, v)
        g2 = jax.grad(
            lambda q, k, v: ref.attention_ref(
                q, k, v, causal=True, window=window, softcap=cap
            ).sum(), argnums=(0, 1, 2),
        )(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5
            )
