"""Predictive replanning tests: forecaster structure, route-informed
forecasts, schedule blending by slack, background pre-staging,
rate-aware ERT re-staggering at hot-swap, wrong-forecast reverts (no
double charge), and the paired reactive-vs-predictive acceptance on
``rate_churn``."""
import dataclasses
import math

import numpy as np

from repro.core.experiment import build_stack, make_policy
from repro.core.runtime import (
    ModeForecaster,
    OnlineReplanner,
    PredictiveReplanner,
    SchedulePortfolio,
    blend_schedules,
    plan_slack,
)
from repro.core.sim import SimConfig, Simulator
from repro.scenarios import (
    ScenarioScript,
    ScenarioSpec,
    get_mode,
    get_scenario,
    run,
)
from repro.scenarios.runner import build_trace, compile_portfolio


# ---------------------------------------------------------------------------
# forecast hooks on ScenarioScript
# ---------------------------------------------------------------------------
def test_script_next_switch_and_empirical_structure():
    s = ScenarioScript.parse("urban:0.5 highway:1.0 urban:0.5")
    assert s.next_switch(0.0) == (0.5, "highway")
    assert s.next_switch(0.2) == (0.5, "highway")
    assert s.next_switch(0.7) == (1.5, "urban")
    assert s.next_switch(1.6) is None
    trans, dwell = s.empirical_transitions()
    assert trans["urban"] == {"highway": 1.0}
    assert trans["highway"] == {"urban": 1.0}
    assert np.isclose(dwell["urban"], 0.5)
    assert np.isclose(dwell["highway"], 1.0)


def test_route_informed_forecaster_pins_switch_times():
    s = ScenarioScript.parse("urban:0.5 highway:1.0 urban:0.5")
    fc = s.forecaster()
    f = fc.forecast("urban", entered_at_s=0.0, now_s=0.2)
    assert f.target_mode == "highway"
    assert np.isclose(f.switch_at_s, 0.5)
    assert f.confidence >= 0.95
    assert np.isclose(f.horizon_s, 0.3)
    # past the last seam the route has nothing to predict
    assert fc.forecast("urban", entered_at_s=1.5, now_s=1.6) is None


def test_markov_forecaster_prediction_and_dwell_learning():
    fc = ModeForecaster(
        transitions={"urban": {"highway": 0.7, "parking": 0.2, "urban": 0.1}},
        mean_dwell_s={"urban": 0.8},
    )
    f = fc.forecast("urban", entered_at_s=1.0)
    assert f.target_mode == "highway"          # most likely non-self successor
    assert np.isclose(f.switch_at_s, 1.8)      # prior mean dwell
    assert 0.0 < f.confidence < 1.0
    # an overdue segment predicts an imminent switch, never one in the past
    late = fc.forecast("urban", entered_at_s=0.0, now_s=5.0)
    assert late.switch_at_s > 5.0
    # observed dwells pull the estimate off the prior
    for _ in range(20):
        fc.observe_switch("urban", "highway", 0.4)
    mean, _cv = fc.dwell_estimate("urban")
    assert 0.4 < mean < 0.6
    # observed transitions reshape the successor distribution
    for _ in range(50):
        fc.observe_switch("urban", "parking", 0.4)
    assert fc.forecast("urban", entered_at_s=0.0).target_mode == "parking"


def test_forecaster_absorbing_mode_returns_none():
    fc = ModeForecaster(transitions={"urban": {}}, mean_dwell_s={"urban": 1.0})
    assert fc.forecast("urban", entered_at_s=0.0) is None


# ---------------------------------------------------------------------------
# schedule blending by slack
# ---------------------------------------------------------------------------
def _portfolio_for(script, policy="ads_tile", seed=1, **kw):
    spec = ScenarioSpec(scenario=script, policy=policy, seed=seed, **kw)
    wf, _hw, model, compiler = build_stack(spec)
    pf = compile_portfolio(spec, script.modes())
    return spec, wf, model, pf


def test_blend_schedules_per_task_choice_by_slack():
    script = ScenarioScript.parse("urban:0.8 night:0.8")
    _spec, wf, _model, pf = _portfolio_for(script)
    old, new = pf.schedules["urban"], pf.schedules["night"]
    blend = blend_schedules(old, new, wf)
    # partitions: the old capacities, untouched (no capacity move yet)
    assert [p.capacity for p in blend.partitions] == \
           [p.capacity for p in old.partitions]
    caps = {p.index: p.capacity for p in blend.partitions}
    for task, plan in blend.plans.items():
        op, np_ = old.plans[task], new.plans[task]
        e2e = wf.deadline_offset(task)
        want = np_ if plan_slack(np_, e2e) > plan_slack(op, e2e) else op
        assert plan.partition == want.partition
        # the chosen plan's sub-deadline is the more urgent of the two
        assert plan.subdeadline_s == min(op.subdeadline_s, np_.subdeadline_s)
        assert plan.dop <= caps[plan.partition]
    assert blend.meta["blend_of"] == ("urban", "night")
    # the blend carries the *outgoing* regime's periods so a later full
    # swap still detects the rate change at the real seam
    assert blend.meta["task_period_s"] == old.meta["task_period_s"]


# ---------------------------------------------------------------------------
# rate-aware hot-swap: ERT re-stagger + background pre-staging
# ---------------------------------------------------------------------------
def _seam_sim(script, duration=1.6, seed=3):
    spec = ScenarioSpec(scenario=script, policy="ads_tile", seed=seed)
    wf, _hw, model, _compiler = build_stack(spec)
    pf = compile_portfolio(spec, script.modes())
    init = script.segments[0].mode
    sim = Simulator(
        wf, model, pf.schedules[init], make_policy("ads_tile"),
        SimConfig(duration_s=duration, seed=seed, scenario=script),
    )
    return sim, pf


def test_hotswap_restaggers_straddler_erts_onto_new_grid():
    script = ScenarioScript.parse("urban:0.8 rush_hour:0.8")
    sim, pf = _seam_sim(script)
    old, new = sim.schedule, pf.schedules["rush_hour"]
    changed = {
        t: p_new for t, p_new in new.meta["task_period_s"].items()
        if not math.isclose(p_new, old.meta["task_period_s"][t], rel_tol=1e-9)
    }
    assert "optical_flow" in changed            # camera-gated: 30 -> 60 Hz
    seam = 0.8
    legacy = {
        j.jid: j.release + new.plans[j.task].ert_s
        for j in sim.jobs if not j.is_sensor and j.task in changed
    }
    sim.now = seam
    sim.hotswap_schedule(new, regime_anchor_s=seam)
    straddlers = 0
    for j in sim.jobs:
        if j.is_sensor or j.task not in changed:
            continue
        if j.release < seam - 1e-12 and legacy[j.jid] > seam + 1e-12:
            # straddler: released on the old cadence, admitted after the
            # seam -> ERT lands exactly on the new release grid, at or
            # after its legacy offset
            k = (j.ert - seam) / changed[j.task]
            assert abs(k - round(k)) < 1e-6, (j.task, j.ert)
            assert j.ert >= legacy[j.jid] - 1e-9
            assert j.ert - legacy[j.jid] < changed[j.task] + 1e-9
            straddlers += 1
        else:
            # post-seam releases already sit on the new grid: legacy
            # retarget applies
            assert np.isclose(j.ert, legacy[j.jid])
    assert straddlers > 0


def test_prestage_charges_bytes_but_touches_nothing():
    script = ScenarioScript.parse("urban:0.8 rush_hour:0.8")
    sim, pf = _seam_sim(script)
    new = pf.schedules["rush_hour"]
    before = [(j.state, j.ert, j.partition, j.n_resizes) for j in sim.jobs]
    sim.now = 0.7
    staged = sim.prestage_schedule(new, window_s=0.1)
    assert staged > 0
    assert sum(p.realloc_bytes for p in sim.parts) == staged
    assert sum(p.n_realloc for p in sim.parts) == 0      # no stall event
    assert not any(p.stalled for p in sim.parts)
    assert sim.schedule is not new                       # table untouched
    assert before == [
        (j.state, j.ert, j.partition, j.n_resizes) for j in sim.jobs
    ]
    # activation now finds the weights resident: zero staged volume, so
    # the swap stall is the bare control-plane constant
    sim.now = 0.8
    stall = sim.hotswap_schedule(new, regime_anchor_s=0.8)
    assert sum(p.realloc_bytes for p in sim.parts) == staged  # not re-charged
    bare = sum(
        sim.hw.realloc_latency(0.0, max(p.capacity, 1)) for p in new.partitions
    )
    assert np.isclose(stall, bare)


def test_prestage_respects_background_budget():
    script = ScenarioScript.parse("urban:0.8 rush_hour:0.8")
    sim, pf = _seam_sim(script)
    new = pf.schedules["rush_hour"]
    assert sim.prestage_schedule(new, window_s=0.0) == 0.0
    tiny = sim.prestage_schedule(new, window_s=1e-7)     # ~10 KB budget
    full_sim, _ = _seam_sim(script)
    full = full_sim.prestage_schedule(new, window_s=10.0)
    assert tiny < full


# ---------------------------------------------------------------------------
# wrong forecasts: reverts, and nothing double-charged
# ---------------------------------------------------------------------------
def test_wrong_forecast_reverts_without_touching_jobs():
    # the script never leaves urban, but the forecaster is convinced a
    # rush-hour seam is imminent: stages fire, seams never come, reverts
    # follow.  A full pre-stage never touches the active table, so the
    # wrong forecast costs background traffic only - no swap, no stall,
    # no job charged.
    script = ScenarioScript.parse("urban:1.6")
    sim, pf = _seam_sim(script)
    pf.schedules["rush_hour"] = compile_portfolio(
        ScenarioSpec(scenario=ScenarioScript.parse("rush_hour:1.6"),
                     policy="ads_tile", seed=3),
        ("rush_hour",),
    ).schedules["rush_hour"]
    fc = ModeForecaster(
        transitions={"urban": {"rush_hour": 1.0}, "rush_hour": {"urban": 1.0}},
        mean_dwell_s={"urban": 0.4, "rush_hour": 0.4},
    )
    rep = PredictiveReplanner(pf, forecaster=fc, confidence_hi=0.0,
                              confidence_lo=0.0)
    sim.policy.replanner = rep
    urban_table = sim.schedule
    r = sim.run()
    assert r.forecast is rep.forecast_stats
    assert rep.forecast_stats.n_reverts >= 1
    assert rep.forecast_stats.n_hits == 0
    assert rep.forecast_stats.n_preswaps >= 1
    assert rep.forecast_stats.prestage_bytes > 0
    # the wrong stages charged traffic but never swapped or stalled
    assert sim.schedule is urban_table
    assert rep.n_swaps == 0
    assert rep.total_stall_s == 0.0


def test_stale_detect_event_cannot_clobber_a_later_seam():
    """A predictive miss arms a detection event; if the next seam
    arrives (and activates correctly) before that event fires, the
    stale detect must die with its epoch instead of installing the
    old target over the correct table."""
    script = ScenarioScript.parse("urban:0.4 night:0.4 rush_hour:0.8")
    sim, pf = _seam_sim(script)
    rep = PredictiveReplanner(pf, forecaster=None, detection_delay_s=0.1)
    sim.policy.replanner = rep
    rep.on_run_start(sim, "urban", 0.0)
    # seam 1 (no stage -> miss path): arms detect("night") at 0.5
    sim.now = 0.4
    rep.on_mode_change(sim, "night", 0.4)
    e1 = rep._epoch
    assert sim.schedule is pf.schedules["urban"]     # not yet detected
    # seam 2 lands before that detect fires
    sim.now = 0.45
    rep.on_mode_change(sim, "rush_hour", 0.45)
    e2 = rep._epoch
    # the stale detect fires: epoch mismatch, must not swap to night
    sim.now = 0.5
    rep.on_forecast(sim, ("detect", e1, "night", 0.4), 0.5)
    assert sim.schedule is not pf.schedules["night"]
    # the live detect installs the correct table
    sim.now = 0.55
    rep.on_forecast(sim, ("detect", e2, "rush_hour", 0.45), 0.55)
    assert sim.schedule is pf.schedules["rush_hour"]


def test_drain_aware_activation_rides_finish_events():
    """A drain-deferred activation arms the engine's drain watch (plus
    one forced deadline) instead of polling: the swap lands at the
    exact instant a finish frees the over-capacity allocation."""
    script = ScenarioScript.parse("urban:0.8 parking:0.8")
    sim, pf = _seam_sim(script)
    rep = PredictiveReplanner(pf, forecaster=None, max_drain_s=0.1)
    sim.policy.replanner = rep
    sim._ready_sets = [set() for _ in sim.parts]
    rep.on_run_start(sim, "urban", 0.0)
    target = pf.schedules["parking"]
    # occupy partition 0 beyond the parking table's capacity so the
    # activation must wait for stragglers to drain
    over = next(
        p for p in sim.parts
        if p.capacity > target.partitions[p.idx].capacity
    )
    job = next(j for j in sim.jobs if not j.is_sensor and j.partition == over.idx)
    over.running[job.jid] = over.capacity
    over.alloc = over.capacity
    sim.now = 0.8
    rep._staged = ModeForecaster(
        transitions={"urban": {"parking": 1.0}},
        mean_dwell_s={"urban": 0.8},
    ).forecast("urban", 0.0)
    rep.on_mode_change(sim, "parking", 0.8)
    # deferred: the drain watch is armed, the active table unchanged
    assert sim._drain_watch == ("drain", rep._epoch)
    assert sim.schedule is pf.schedules["urban"]
    assert rep._pending_act is not None
    # a finish in another partition that does not clear the overflow:
    # the watch re-checks and stays armed
    sim.now = 0.82
    sim.policy.on_forecast(sim, sim._drain_watch, sim.now)
    assert sim.schedule is pf.schedules["urban"]
    assert sim._drain_watch == ("drain", rep._epoch)
    # the straggler drains: the very next watch delivery activates
    over.alloc -= over.running.pop(job.jid)
    sim.now = 0.85
    sim.policy.on_forecast(sim, sim._drain_watch, sim.now)
    assert sim.schedule is target
    assert sim._drain_watch is None
    assert rep._pending_act is None


def test_reactive_detection_delay_defers_the_swap():
    script = ScenarioScript.parse("urban:0.5 night:0.5")
    sim, pf = _seam_sim(script, duration=1.0)
    rep = OnlineReplanner(pf, detection_delay_s=0.1)
    sim.policy.replanner = rep
    swaps = []
    orig = Simulator.hotswap_schedule

    def record(self, *a, **kw):
        swaps.append((self.now, kw.get("regime_anchor_s")))
        return orig(self, *a, **kw)

    Simulator.hotswap_schedule = record
    try:
        sim.run()
    finally:
        Simulator.hotswap_schedule = orig
    assert swaps and np.isclose(swaps[0][0], 0.6)   # seam 0.5 + 0.1
    # the deferred swap still anchors the rate-aware ERT re-stagger at
    # the *seam* — the regime's sensor timers re-anchored there, not at
    # the detection instant
    assert swaps[0][1] is not None and np.isclose(swaps[0][1], 0.5)


# ---------------------------------------------------------------------------
# end-to-end predictive runs
# ---------------------------------------------------------------------------
def test_predictive_run_reports_forecast_stats():
    scen = get_scenario("rate_churn")
    [r] = run(ScenarioSpec(scenario=scen, policy="ads_tile", seed=3,
                                  replan_mode="predictive"))
    assert r.forecast is not None
    assert r.forecast.n_hits == len(scen.segments) - 1
    assert r.forecast.n_misses == 0
    assert r.forecast.prestage_bytes > 0
    assert r.n_mode_switches == len(scen.segments) - 1
    # reactive and pinned runs carry no forecast accounting
    [r2] = run(ScenarioSpec(scenario=scen, policy="ads_tile", seed=3),
               backend="scalar")
    assert r2.forecast is None


def test_predictive_determinism():
    spec = ScenarioSpec(scenario=get_scenario("rate_churn"), policy="ads_tile",
                        seed=5, replan_mode="predictive",
                        detection_delay_s=0.08)
    [a], [b] = run(spec, backend="scalar"), run(spec, backend="scalar")
    assert a.violation_rate == b.violation_rate
    assert a.realloc_frac == b.realloc_frac
    assert dataclasses.asdict(a.forecast) == dataclasses.asdict(b.forecast)


def test_predictive_beats_reactive_on_rate_churn():
    """Acceptance: over paired seeds of ``rate_churn`` with a realistic
    detection window, predictive pre-staging strictly reduces post-seam
    deadline misses and realloc waste vs reactive replanning."""
    scen = get_scenario("rate_churn")
    base = ScenarioSpec(scenario=scen, policy="ads_tile", seed=1,
                        detection_delay_s=0.08)
    pf = compile_portfolio(base)
    tot = {m: [0, 0.0] for m in ("reactive", "predictive")}
    for seed in (1, 2, 3):
        spec = dataclasses.replace(base, seed=seed, portfolio=pf)
        trace = build_trace(spec)
        init = scen.segments[0].mode
        for mode in tot:
            [r] = run(dataclasses.replace(spec, replan_mode=mode),
                             trace=trace)
            tot[mode][0] += sum(
                s.n_violations for m, s in r.mode_stats.items() if m != init
            )
            tot[mode][1] += r.realloc_frac
    assert tot["predictive"][0] < tot["reactive"][0]
    assert tot["predictive"][1] < tot["reactive"][1]


def test_portfolio_meta_records_task_periods():
    wf, _hw, model, _compiler = build_stack(
        ScenarioSpec(scenario=get_scenario("rate_churn"), policy="ads_tile")
    )
    pf = SchedulePortfolio.compile(
        model, wf, {m: get_mode(m) for m in ("urban", "rush_hour")},
    )
    per = pf.schedules["rush_hour"].meta["task_period_s"]
    assert np.isclose(per["optical_flow"], 1.0 / 60.0)
    assert np.isclose(
        pf.schedules["urban"].meta["task_period_s"]["optical_flow"], 1.0 / 30.0
    )
