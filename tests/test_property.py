"""Hypothesis property tests on the system's invariants."""
import math

import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.analysis.hlo import collective_bytes, parse_hlo_collectives
from repro.core.gha.guillotine import guillotine_cut
from repro.core.latency_model import LogNormal, ShiftedExponential
from repro.core.runtime import fit_quota
from repro.core.sim.engine import Job
from repro.core.workload import Chain, DnnTask, SensorTask, Workflow, unroll_hyperperiod

SET = settings(
    deadline=None, max_examples=40,
    suppress_health_check=[HealthCheck.too_slow],
)


# ---------------------------------------------------------------------------
@given(
    mean=st.floats(1e3, 1e15),
    ratio=st.floats(1.0, 3.3),
    q=st.floats(0.01, 0.99),
)
@SET
def test_lognormal_quantile_monotone_and_positive(mean, ratio, q):
    d = LogNormal(mean, ratio)
    v = d.quantile(q)
    assert v > 0
    assert d.quantile(min(q + 0.005, 0.995)) >= v - 1e-9


@given(
    base=st.floats(0, 1e-3),
    rate=st.floats(1.0, 1e7),
    q1=st.floats(0.01, 0.5),
    q2=st.floats(0.5, 0.99),
)
@SET
def test_shifted_exp_quantile_monotone(base, rate, q1, q2):
    d = ShiftedExponential(base, rate)
    assert d.quantile(q2) >= d.quantile(q1) >= base


# ---------------------------------------------------------------------------
@given(
    rows=st.integers(2, 12),
    cols=st.integers(2, 16),
    n=st.integers(1, 6),
    data=st.data(),
)
@SET
def test_guillotine_always_partitions(rows, cols, n, data):
    # random areas filling at most ~85% of the mesh (integer guillotine
    # cuts cannot always realise near-100% packings; the GHA compiler
    # keeps slack and falls back to logical binding when cutting fails)
    total = int(rows * cols * 0.85)
    n = min(n, total)
    areas = []
    left = total
    for i in range(n):
        hi = max(1, left - (n - i - 1))
        a = data.draw(st.integers(1, hi))
        areas.append(a)
        left -= a
    rects = guillotine_cut((rows, cols), areas)
    grid = np.zeros((rows, cols), int)
    for (r0, c0, h, w), need in zip(rects, areas):
        assert h * w >= need
        assert 0 <= r0 and 0 <= c0 and r0 + h <= rows and c0 + w <= cols
        grid[r0:r0 + h, c0:c0 + w] += 1
    assert grid.max() == 1  # disjoint; leftover tiles may stay idle


# ---------------------------------------------------------------------------
@given(
    work=st.floats(1e9, 1e14),
    io=st.floats(0, 1e-3),
    target=st.floats(1e-4, 1.0),
    cap=st.integers(0, 64),
)
@SET
def test_fit_quota_is_minimal_feasible(work, io, target, cap):
    job = Job(
        jid=0, task="t", cycle=0, idx=0, release=0.0, is_sensor=False,
        work_flops=work, io_s=io, sync_s=0.0, partition=0,
        ert=0.0, sub_ddl=1.0, e2e_ddl=2.0, plan_dop=4,
    )
    cands = (1, 2, 4, 8, 16, 32, 64)
    tf = 1.024e12
    c = fit_quota(job, cands, target, 0.0, tf, cap)
    feasible = [x for x in cands if x <= cap]
    if not feasible:
        assert c == 0
        return
    meeting = [x for x in feasible if job.remaining(x, tf) <= target]
    if meeting:
        assert c == min(meeting)        # minimum quota (reservation!)
    else:
        assert c == max(feasible)       # best effort


# ---------------------------------------------------------------------------
@given(
    r1=st.sampled_from([10, 20, 30, 60]),
    r2=st.sampled_from([10, 20, 30, 60]),
)
@SET
def test_unroll_instance_counts(r1, r2):
    wf = Workflow(
        tasks={
            "s1": SensorTask(name="s1", period_s=1.0 / r1),
            "s2": SensorTask(name="s2", period_s=1.0 / r2),
            "a": DnnTask(name="a", mean_flops=1e9, compiled_dops=(1, 2)),
            "b": DnnTask(name="b", mean_flops=1e9, compiled_dops=(1, 2)),
        },
        edges=[("s1", "a"), ("s2", "b"), ("a", "b")],
        chains=[Chain("c", ("s1", "a", "b"), 0.2)],
    )
    thp = wf.hyper_period_s
    assert np.isclose(thp * math.gcd(r1, r2), 1.0)
    insts = unroll_hyperperiod(wf)
    count = {}
    for i in insts:
        count[i.task] = count.get(i.task, 0) + 1
    assert count["s1"] == round(thp * r1)
    assert count["a"] == count["s1"]          # gated by s1
    assert count["b"] == round(thp * min(r1, r2))
    # dependency sanity
    by_key = {(i.task, i.index): i for i in insts}
    for i in insts:
        for dep in i.preds:
            assert by_key[dep].release_s <= i.release_s + 1e-12


# ---------------------------------------------------------------------------
@given(
    n=st.integers(1, 5),
    dt=st.sampled_from(["f32", "bf16"]),
    dims=st.tuples(st.integers(1, 64), st.integers(1, 128)),
)
@SET
def test_hlo_collective_parser(n, dt, dims):
    a, b = dims
    nbytes = a * b * (4 if dt == "f32" else 2)
    lines = ["HloModule m", "ENTRY %main {"]
    lines.append(f"  %p0 = {dt}[{a},{b}]{{1,0}} parameter(0)")
    for i in range(n):
        lines.append(
            f"  %all-reduce.{i} = {dt}[{a},{b}]{{1,0}} all-reduce(%p0), "
            "replica_groups={}, to_apply=%add"
        )
    lines.append(f"  ROOT %t = ({dt}[{a},{b}]{{1,0}}) tuple(%all-reduce.0)")
    lines.append("}")
    agg = collective_bytes("\n".join(lines))
    assert agg["all-reduce"] == n * nbytes
    assert agg["total"] == n * nbytes
    recs = parse_hlo_collectives("\n".join(lines))
    assert len(recs) == n
