"""Workload model + GHA compiler tests (paper §II-C2, §III-B)."""
import numpy as np
import pytest

from repro.core.benchmark import COCKPIT_CHAINS, make_ads_benchmark
from repro.core.gha import GHACompiler, Schedule, compile_schedule
from repro.core.gha.guillotine import bind_memory_controllers, guillotine_cut
from repro.core.gha.phase1 import run_phase1
from repro.core.hardware import simba_chip
from repro.core.latency_model import LatencyModel
from repro.core.workload import (
    Chain,
    DnnTask,
    SensorTask,
    Workflow,
    unroll_hyperperiod,
)


@pytest.fixture(scope="module")
def wf():
    return make_ads_benchmark()


@pytest.fixture(scope="module")
def model(wf):
    return LatencyModel.from_workflow(wf, simba_chip(400))


def test_hyper_period(wf):
    # lcm(1/30, 1/20, 1/10, 1/240) = 1/gcd(30,20,10,240) = 0.1 s
    assert np.isclose(wf.hyper_period_s, 0.1)


def test_unroll_counts(wf):
    insts = unroll_hyperperiod(wf)
    per_task = {}
    for i in insts:
        per_task[i.task] = per_task.get(i.task, 0) + 1
    assert per_task["cam_multi"] == 3     # 30 Hz over 100 ms
    assert per_task["cam_stereo"] == 2
    assert per_task["lidar"] == 1
    assert per_task["imu"] == 24
    assert per_task["img_backbone"] == 3  # gated by cam_multi
    assert per_task["traj_pred"] == 1     # gated by lidar (slowest pred)


def test_unroll_dep_releases(wf):
    insts = unroll_hyperperiod(wf)
    by_key = {(i.task, i.index): i for i in insts}
    for i in insts:
        for (pt, pj) in i.preds:
            assert by_key[(pt, pj)].release_s <= i.release_s + 1e-12


def test_cockpit_replication_shares_backbone():
    wf9 = make_ads_benchmark(cockpit_replicas=9)
    names = set(wf9.tasks)
    # shared stages exist exactly once
    assert "img_backbone" in names and "img_backbone#r1" not in names
    # replicated heads exist 9x
    assert sum(1 for n in names if n.startswith("depth_est")) == 9
    assert len(wf9.chains) == 9 + 8 * len(COCKPIT_CHAINS)


def test_cockpit_replication_chain_edge_task_counts():
    base = make_ads_benchmark()
    for factor in (4, 6):
        wf = make_ads_benchmark(cockpit_replicas=factor)
        extra = factor - 1
        # each cockpit chain replica adds exactly one private head task
        # and the one edge feeding it from its (shared) upstream stage
        n_cockpit = len(COCKPIT_CHAINS)
        assert len(wf.tasks) == len(base.tasks) + n_cockpit * extra
        assert len(wf.edges) == len(base.edges) + n_cockpit * extra
        assert len(wf.chains) == len(base.chains) + n_cockpit * extra
        # every replica chain reuses the shared upstream stages verbatim
        for chain in wf.chains:
            if "#r" not in chain.name:
                continue
            orig = next(
                c for c in base.chains if c.name == chain.name.split("#")[0]
            )
            assert chain.nodes[:-1] == orig.nodes[:-1]     # shared prefix
            assert chain.nodes[-1].startswith(orig.nodes[-1])
        # shared backbone fans out to every replica head
        assert (
            len(wf.succs("img_backbone"))
            == len(base.succs("img_backbone")) + 2 * extra
        )   # drivable_seg + semantic_seg replicas


def test_unroll_non_integral_periods():
    # periods that are not integral in any fixed time unit: 1/30 s with
    # 1/10 s (T_hp = 0.1 s) and 1/30 s with 1/25 s (T_hp = 0.2 s)
    for r1, r2, thp in ((30, 10, 0.1), (30, 25, 0.2)):
        wf = Workflow(
            tasks={
                "s1": SensorTask(name="s1", period_s=1.0 / r1),
                "s2": SensorTask(name="s2", period_s=1.0 / r2),
                "a": DnnTask(name="a", mean_flops=1e9, compiled_dops=(1, 2)),
                "b": DnnTask(name="b", mean_flops=1e9, compiled_dops=(1, 2)),
            },
            edges=[("s1", "a"), ("s2", "b"), ("a", "b")],
            chains=[Chain("c", ("s1", "a", "b"), 0.5)],
        )
        assert np.isclose(wf.hyper_period_s, thp)
        insts = unroll_hyperperiod(wf)
        count = {}
        for i in insts:
            count[i.task] = count.get(i.task, 0) + 1
        assert count["s1"] == count["a"] == round(thp * r1)
        assert count["s2"] == round(thp * r2)
        assert count["b"] == round(thp * min(r1, r2))  # gated by slowest
        # dependencies always point backwards in release time
        by_key = {(i.task, i.index): i for i in insts}
        for i in insts:
            for dep in i.preds:
                assert by_key[dep].release_s <= i.release_s + 1e-12


def test_phase1_meets_deadlines(wf, model):
    p1 = run_phase1(model, wf, q=0.95)
    assert not p1.infeasible_chains
    for chain in wf.chains:
        total = sum(p1.budget(n) for n in chain.nodes)
        assert total <= chain.deadline_s + 1e-9, chain.name
        # topological consistency of offsets
        for a, b in zip(chain.nodes, chain.nodes[1:]):
            assert (
                p1.start_offsets[b] + 1e-12
                >= p1.start_offsets[a] + p1.budget(a)
            )


def test_compile_schedule_valid(wf, model):
    for nparts in (1, 4, None):
        s = compile_schedule(model, wf, q=0.95, num_partitions=nparts)
        s.validate()
        assert s.peak_tiles <= 400
        # every DNN task planned, no sensor plans
        assert set(s.plans) == {t.name for t in wf.dnn_tasks}


def test_schedule_roundtrip(wf, model):
    s = compile_schedule(model, wf, q=0.95, num_partitions=4)
    s2 = Schedule.from_json(s.to_json())
    assert s2.plans.keys() == s.plans.keys()
    for t in s.plans:
        assert s2.plans[t].dop == s.plans[t].dop
        assert np.isclose(s2.plans[t].budget_s, s.plans[t].budget_s)


def test_guillotine_properties():
    rects = guillotine_cut((8, 16), [40, 30, 30, 20])
    # disjointness + per-bin area guarantee
    cells = np.zeros((8, 16), int)
    for i, (r0, c0, h, w) in enumerate(rects):
        assert h * w >= [40, 30, 30, 20][i]
        assert 0 <= r0 and 0 <= c0 and r0 + h <= 8 and c0 + w <= 16
        cells[r0:r0 + h, c0:c0 + w] += 1
    assert cells.max() == 1  # no overlap (leftover tiles may stay idle)

    mcs = bind_memory_controllers(rects, simba_chip(128))
    assert all(0 <= m < 4 for m in mcs)


def test_guillotine_rejects_oversubscription():
    with pytest.raises(ValueError):
        guillotine_cut((4, 4), [10, 10])
