"""Tile-stream simulator + scheduling-policy behaviour tests (§III-C,
§IV, §V-B)."""
import numpy as np
import pytest

from repro.core.experiment import ExperimentSpec, POLICIES, run_experiment
from repro.core.runtime import fit_quota
from repro.core.runtime.l2p import L2PMap
from repro.core.sim.engine import Job


@pytest.fixture(scope="module")
def light_reports():
    out = {}
    for pol in ("cyc", "cyc_s", "tp_driven", "pglb", "reserv", "ads_tile"):
        out[pol] = run_experiment(ExperimentSpec(
            policy=pol, tiles=400, cockpit_replicas=1, duration_s=0.6, seed=1,
        ))
    return out


def test_capacity_decomposition(light_reports):
    for pol, r in light_reports.items():
        total = r.effective_frac + r.realloc_frac + r.idle_frac
        assert np.isclose(total, 1.0, atol=1e-6), pol
        assert 0 <= r.violation_rate <= 1
        assert 0 <= r.task_miss_rate <= 1


def test_cyc_never_reallocates(light_reports):
    assert light_reports["cyc"].n_realloc == 0
    assert light_reports["cyc"].realloc_frac == 0.0
    assert light_reports["cyc_s"].n_realloc == 0


def test_elastic_cyc_reduces_misses(light_reports):
    """Fig. 11a: slack sharing improves reliability at equal resources."""
    assert (
        light_reports["cyc_s"].task_miss_rate
        <= light_reports["cyc"].task_miss_rate
    )


def test_ads_tile_bounds_realloc_waste(light_reports):
    """Headline: wasted processing capacity < 1.2% for ADS-Tile while
    work-conserving realloc waste is markedly higher."""
    ads = light_reports["ads_tile"]
    tp = light_reports["tp_driven"]
    assert ads.realloc_frac < 0.012
    assert ads.realloc_frac < tp.realloc_frac


def test_partitioning_cuts_realloc_cost(light_reports):
    """Fig. 11b: same work-conserving policy, partition-local stalls."""
    assert (
        light_reports["pglb"].realloc_frac
        < light_reports["tp_driven"].realloc_frac
    )


def test_heavy_load_tp_collapses():
    """§III-C2 / Fig. 13: at heavy load the work-conserving scheduler
    wastes double-digit capacity on reallocation."""
    tp = run_experiment(ExperimentSpec(
        policy="tp_driven", tiles=400, cockpit_replicas=6,
        deadline_s=0.09, duration_s=0.6, seed=1,
    ))
    ads = run_experiment(ExperimentSpec(
        policy="ads_tile", tiles=400, cockpit_replicas=6,
        deadline_s=0.09, q=0.9, duration_s=0.6, seed=1,
    ))
    assert tp.realloc_frac > 0.10
    assert ads.realloc_frac < 0.012
    assert ads.task_miss_rate <= tp.task_miss_rate + 0.05


def test_seed_determinism():
    a = run_experiment(ExperimentSpec(policy="ads_tile", duration_s=0.4, seed=7))
    b = run_experiment(ExperimentSpec(policy="ads_tile", duration_s=0.4, seed=7))
    assert a.task_miss_rate == b.task_miss_rate
    assert a.n_realloc == b.n_realloc
    assert a.effective_frac == b.effective_frac


def test_all_policy_names_construct():
    from repro.core.experiment import make_policy
    for name in POLICIES:
        assert make_policy(name) is not None
    with pytest.raises(ValueError):
        make_policy("nope")


# ---------------------------------------------------------------------------
# runtime primitives
# ---------------------------------------------------------------------------
def _job(work=1e12, io=1e-4, sync=0.0):
    return Job(
        jid=0, task="t", cycle=0, idx=0, release=0.0, is_sensor=False,
        work_flops=work, io_s=io, sync_s=sync, partition=0,
        ert=0.0, sub_ddl=1.0, e2e_ddl=2.0, plan_dop=4,
    )


def test_fit_quota_minimal():
    job = _job()
    tf = 1.024e12
    cands = (1, 2, 4, 8, 16)
    # generous target: pick the smallest candidate that fits
    c = fit_quota(job, cands, target_t=2.0, now=0.0, tile_flops=tf, cap=16)
    assert c == 1
    # tight target: escalate
    need = job.duration(4, tf)
    c = fit_quota(job, cands, target_t=need * 1.01, now=0.0, tile_flops=tf, cap=16)
    assert c == 4
    # impossible target: best effort = largest within cap
    c = fit_quota(job, cands, target_t=1e-6, now=0.0, tile_flops=tf, cap=8)
    assert c == 8
    # nothing fits the cap
    c = fit_quota(job, cands, target_t=1.0, now=0.0, tile_flops=tf, cap=0)
    assert c == 0


def test_l2p_minimal_moves():
    m = L2PMap(16)
    first = m.allocate(1, 8)
    assert len(first) == 8
    # shrink: keeps a subset, moves |8-4| tiles of state
    assert m.moved_tiles(1, 4) == 4
    second = m.allocate(1, 4)
    assert second < first
    # grow back: reuses its 4 + takes 4 free
    third = m.allocate(1, 8)
    assert second <= third
    m.release(1)
    assert len(m.free_tiles()) == 16
    m.allocate(2, 16)
    with pytest.raises(ValueError):
        m.allocate(3, 1)
