"""Observability tests (`repro.obs`).

Covers the flight recorder's four contracts:

* **Non-perturbation** — a run with a recorder attached produces a
  `SimReport` bit-identical to the same pinned run without one (the
  hooks observe, they never steer), and recorder-off runs are
  deterministic.
* **Export round-trip** — a recorded rate_churn run exports to a
  Chrome/Perfetto trace that validates against the checked-in
  `trace_schema.json`, survives a JSON round-trip, and keeps its
  structural invariants (paired flows, matched slice lanes, counters).
* **Attribution exactness** — for every missed chain of every bundled
  scenario, the four lateness components sum to the observed lateness
  to float tolerance.
* **Plumbing** — `summarize` rows and `aggregate_sweep` carry the
  attribution summary for recorded runs.

Plus unit tests for the dependency-free JSON-schema subset validator
and the metrics registry.
"""
import dataclasses
import json

import pytest

from repro.core.experiment import build_stack, make_policy
from repro.core.runtime import OnlineReplanner
from repro.core.sim import SimConfig, Simulator
from repro.obs import (
    EVENT_KINDS,
    SchemaError,
    TraceRecorder,
    attribute_misses,
    attribution_report,
    chrome_trace,
    export_chrome_trace,
    metrics,
    validate_trace,
)
from repro.obs.schema import load_schema, validate
from repro.scenarios import ScenarioSpec, get_scenario
from repro.scenarios.runner import (
    aggregate_sweep,
    build_trace,
    compile_portfolio,
    run,
    summarize,
    sweep,
)

BUNDLED = ("calm_to_rush", "commute", "night_storm", "rate_churn")


def _spec(name="rate_churn", policy="ads_tile", seed=1, **kw):
    return ScenarioSpec(
        scenario=get_scenario(name), policy=policy, seed=seed, **kw
    )


def _recorded_sim(name="rate_churn", policy="ads_tile", seed=1):
    """A finished scenario Simulator with its recorder (mirrors
    the runner's reactive-replan construction, which returns only the
    report)."""
    spec = _spec(name, policy, seed)
    wf, _hw, model, _compiler = build_stack(spec)
    portfolio = compile_portfolio(spec)
    sched = portfolio.schedules[spec.scenario.segments[0].mode]
    pol = make_policy(policy)
    pol.replanner = OnlineReplanner(portfolio)
    rec = TraceRecorder()
    sim = Simulator(
        wf, model, sched, pol,
        SimConfig(
            duration_s=spec.scenario.duration_s, seed=seed,
            scenario=spec.scenario, recorder=rec,
        ),
    )
    sim.run()
    return sim, rec


# ---------------------------------------------------------------------------
# non-perturbation
# ---------------------------------------------------------------------------
def test_recorder_does_not_perturb_pinned_reports():
    """Recorder attached vs detached: bit-identical `SimReport`s on the
    same pinned trace (the attribution field is runner-added metadata,
    not simulation output)."""
    spec = _spec("rate_churn")
    trace = build_trace(spec)
    spec = dataclasses.replace(spec, portfolio=compile_portfolio(spec))
    [off] = run(spec, trace=trace, backend="scalar")
    rec = TraceRecorder()
    [on] = run(spec, trace=trace, recorders={0: rec}, backend="scalar")
    assert len(rec) > 0
    d_off = dataclasses.asdict(off)
    d_on = dataclasses.asdict(on)
    assert d_off.pop("attribution") is None
    assert d_on.pop("attribution") is not None
    assert d_off == d_on


def test_disabled_recorder_runs_are_deterministic():
    """Two fresh recorder-off runs of one pinned spec agree bitwise."""
    spec = _spec("commute", seed=3)
    spec = dataclasses.replace(spec, portfolio=compile_portfolio(spec))
    a = dataclasses.asdict(run(spec, backend="scalar")[0])
    b = dataclasses.asdict(run(spec, backend="scalar")[0])
    assert a == b


# ---------------------------------------------------------------------------
# export round-trip
# ---------------------------------------------------------------------------
def test_trace_round_trips_through_schema(tmp_path):
    _sim, rec = _recorded_sim("rate_churn")
    assert all(e.kind in EVENT_KINDS for e in rec.events)
    path = tmp_path / "trace.json"
    doc = export_chrome_trace(rec, str(path))
    validate_trace(doc)  # in-memory form
    reloaded = json.loads(path.read_text())
    validate_trace(reloaded)  # disk round-trip
    assert reloaded["displayTimeUnit"] == "ms"

    evs = reloaded["traceEvents"]
    # every duration slice is non-negative and closed
    for e in evs:
        if e["ph"] == "X":
            assert e["dur"] >= 0
            assert e["ts"] >= 0
    # flow starts and ends come in matched pairs per id
    starts = {e["id"] for e in evs if e["ph"] == "s"}
    ends = {e["id"] for e in evs if e["ph"] == "f"}
    assert starts and starts == ends
    # counter tracks exist for tiles and realloc traffic
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert any(c.startswith("tiles alloc p") for c in counters)
    assert "tiles reserved" in counters
    # per-partition lanes got thread metadata
    named = {e["args"]["name"] for e in evs if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert any(n.startswith("partition") for n in named)


def test_chrome_trace_meta_carries_run_context():
    _sim, rec = _recorded_sim("rate_churn")
    doc = chrome_trace(rec)
    meta = doc["otherData"]
    assert float(meta["duration_s"]) > 0
    assert int(meta["seed"]) == 1
    seams = list(rec.by_kind("rate_seam"))
    assert len(seams) == 2  # rate_churn: night -> urban -> rush_hour


# ---------------------------------------------------------------------------
# attribution
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", BUNDLED)
@pytest.mark.parametrize("policy", ("ads_tile", "tp_driven"))
def test_attribution_components_sum_to_lateness(name, policy):
    sim, rec = _recorded_sim(name, policy)
    misses = attribute_misses(sim, rec)
    late = list(rec.by_kind("deadline_miss"))
    assert len(misses) == len(late)
    for m in misses:
        assert m.lateness_s > 0
        total = (m.queueing_s + m.realloc_stall_s + m.restagger_s
                 + m.duration_tail_s)
        assert total == pytest.approx(m.lateness_s, abs=1e-9), m.chain
        # waiting components cannot be negative (only the tail can)
        assert m.queueing_s >= -1e-9
        assert m.realloc_stall_s >= -1e-9
        assert m.restagger_s >= -1e-9
        assert m.path[-1] == m.sink_jid


def test_attribution_report_totals_match_misses():
    sim, rec = _recorded_sim("rate_churn")
    misses = attribute_misses(sim, rec)
    rep = attribution_report(sim, rec)
    assert rep["n_late"] == len(misses)
    assert rep["lateness_s"] == pytest.approx(
        sum(m.lateness_s for m in misses)
    )
    comp = rep["components_s"]
    assert sum(comp.values()) == pytest.approx(rep["lateness_s"], abs=1e-6)
    if misses:
        worst = max(misses, key=lambda m: m.lateness_s)
        assert rep["worst_chain"] == worst.chain
        assert set(rep["by_chain"]) == {m.chain for m in misses}


def test_attribute_misses_requires_a_recorder():
    spec = _spec("rate_churn")
    wf, _hw, model, compiler = build_stack(spec)
    sched = compiler.compile(model, wf)
    sim = Simulator(wf, model, sched, make_policy("ads_tile"),
                    SimConfig(duration_s=0.2, seed=1))
    sim.run()
    with pytest.raises(ValueError):
        attribute_misses(sim)


# ---------------------------------------------------------------------------
# plumbing: summarize / sweep aggregation
# ---------------------------------------------------------------------------
def test_recorded_rows_aggregate_attribution():
    spec = _spec("rate_churn", record=True)
    [report] = run(spec, backend="scalar")
    assert report.attribution is not None
    row = summarize(spec, report)
    assert row["attribution"]["n_late"] == report.attribution["n_late"]

    rows = sweep(2, policies=("ads_tile",), duration_s=1.0, seed=1,
                 jobs=1, record=True)
    agg = aggregate_sweep(rows)["ads_tile"]
    att = agg["attribution"]
    assert att["n_recorded"] == 2
    assert att["n_late"] == sum(r["attribution"]["n_late"] for r in rows)
    assert set(att["components_s"]) == {
        "queueing", "realloc_stall", "restagger", "duration_tail"
    }
    # unrecorded sweeps carry no attribution block
    plain = aggregate_sweep(
        sweep(2, policies=("ads_tile",), duration_s=1.0, seed=1, jobs=1)
    )["ads_tile"]
    assert "attribution" not in plain


# ---------------------------------------------------------------------------
# the schema subset validator
# ---------------------------------------------------------------------------
def test_schema_validator_accepts_minimal_trace():
    validate_trace({
        "traceEvents": [
            {"ph": "i", "name": "x", "pid": 1, "ts": 0.0, "s": "g"},
        ],
        "displayTimeUnit": "ms",
    })


@pytest.mark.parametrize("doc", [
    {},                                           # missing required keys
    {"traceEvents": [], "displayTimeUnit": "ms"},  # minItems
    {"traceEvents": [{"ph": "i", "name": "x", "pid": 1}],
     "displayTimeUnit": "parsec"},                # enum
    {"traceEvents": [{"ph": "Z", "name": "x", "pid": 1}],
     "displayTimeUnit": "ms"},                    # ph enum
    {"traceEvents": [{"ph": "i", "name": "x", "pid": True}],
     "displayTimeUnit": "ms"},                    # bool is not an integer
    {"traceEvents": [{"ph": "i", "name": 3, "pid": 1}],
     "displayTimeUnit": "ms"},                    # name type
    {"traceEvents": [{"ph": "i", "pid": 1}],
     "displayTimeUnit": "ms"},                    # event missing required
    {"traceEvents": [{"ph": "i", "name": "x", "pid": 1}],
     "displayTimeUnit": "ms",
     "otherData": {"k": 3}},                      # additionalProperties type
])
def test_schema_validator_rejects(doc):
    with pytest.raises(SchemaError):
        validate_trace(doc)


def test_schema_validator_reports_paths():
    try:
        validate({"a": [1, "x"]},
                 {"type": "object",
                  "properties": {"a": {"type": "array",
                                       "items": {"type": "integer"}}}})
    except SchemaError as err:
        assert "$.a[1]" in str(err)
    else:  # pragma: no cover
        pytest.fail("expected SchemaError")


def test_checked_in_schema_loads():
    schema = load_schema()
    assert schema["required"] == ["traceEvents", "displayTimeUnit"]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_metrics_disabled_is_a_noop():
    # other tests (e.g. the benchmark-CLI ones) may leave the
    # process-global registry enabled; this test owns its state
    metrics.enable(False)
    metrics.reset()
    assert not metrics.enabled()
    metrics.count("x")
    with metrics.phase("p"):
        pass
    snap = metrics.snapshot()
    assert snap == {"counters": {}, "phases": {}}


def test_metrics_counts_and_phases():
    metrics.reset()
    metrics.enable()
    try:
        metrics.count("hits")
        metrics.count("hits", 2)
        with metrics.phase("work"):
            pass
        with metrics.phase("work"):
            pass
        snap = metrics.snapshot(reset_after=True)
    finally:
        metrics.enable(False)
    assert snap["counters"] == {"hits": 3}
    work = snap["phases"]["work"]
    assert work["n"] == 2
    assert work["total_s"] >= 0
    assert work["mean_s"] == pytest.approx(work["total_s"] / 2)
    assert metrics.snapshot() == {"counters": {}, "phases": {}}
