"""Structure-of-arrays jax backend vs the scalar reference engine.

The contract under test is *distributional* equivalence, not
bit-identity (``docs/performance.md#soa-backend``): the SoA kernels
replace the event heap with discrete scheduling rounds, so individual
event timestamps shift at round granularity while the statistics the
paper's claims rest on must agree.  Per cell the tests assert

* exact equality of structural invariants (job universe, seam spans,
  chain universe, reservation footprint) per seed,
* a pooled chain-latency KS statistic inside the measured dt=1e-3
  approximation envelope (worst cell tp_driven at ~0.06),
* CI overlap on violation rate and realloc waste.

The full bundled-scenario sweep runs in CI as its own gate
(``benchmarks.check_equivalence --mode distributional``); here one
scenario pins the contract into tier-1 per policy, plus support
predicates, the device sampling path, the allocator reference kernel,
and a property test over random Markov scenarios mirroring
``test_batch.py``.  Everything needing jax skips cleanly without it.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.sim import soa
from repro.core.sim import soa_kernels as K
from repro.core.sim.batch import sample_trace_batch
from repro.scenarios.runner import ScenarioSpec, run
from repro.scenarios.script import default_generator, get_scenario

needs_jax = pytest.mark.skipif(
    not soa.soa_available(), reason="jax not installed (SoA backend unavailable)"
)

SEEDS = [0, 1, 2, 3]

#: KS gate for the tier-1 subset: the measured dt=1e-3 envelope across
#: all bundled cells is 0.01-0.06 (tp_driven's recomputed quota walk is
#: the worst); 0.08 trips on regression, not on the known bias
KS_TOL = 0.08


def _cell(scenario: str, policy: str, seeds=SEEDS):
    spec = ScenarioSpec(scenario=get_scenario(scenario), policy=policy)
    ref = [r for s in seeds for r in
           run(dataclasses.replace(spec, seed=int(s)), backend="scalar")]
    got = run(spec, seeds=seeds, backend="soa", fallback=False)
    return ref, got


def _pooled_latencies(reports):
    return [x for r in reports for ls in r.chain_latencies.values() for x in ls]


# ---------------------------------------------------------------------------
# equivalence contract, per policy
# ---------------------------------------------------------------------------
@needs_jax
@pytest.mark.parametrize("policy", ["cyc", "tp_driven", "ads_tile"])
def test_soa_distributionally_equivalent(policy):
    ref, got = _cell("commute", policy)
    for a, b in zip(ref, got):
        ia, ib = soa.structural_invariants(a), soa.structural_invariants(b)
        assert ia == ib, {f: (ia[f], ib[f]) for f in ia if ia[f] != ib[f]}
    ks = soa.ks_statistic(_pooled_latencies(ref), _pooled_latencies(got))
    assert ks <= KS_TOL, f"{policy}: pooled chain-latency KS {ks:.4f} > {KS_TOL}"
    for metric in ("violation_rate", "realloc_frac"):
        ci_ref = soa.mean_ci([getattr(r, metric) for r in ref])
        ci_got = soa.mean_ci([getattr(r, metric) for r in got])
        assert soa.intervals_overlap(ci_ref, ci_got, pad=1e-9), (
            metric, ci_ref, ci_got)


# ---------------------------------------------------------------------------
# compile-cache identity: same shapes, different schedule constants
# ---------------------------------------------------------------------------
@needs_jax
def test_kernel_cache_distinguishes_const_content():
    """Two cells over the same skeleton (same array shapes) but with
    different schedule constants must not share a compiled loop: the
    jit closure bakes the const arrays in at trace time, so a
    shape-only cache key silently replays the first cell's schedule
    (the figS_budget part-3 shape: one pinned drive, several
    portfolios/load factors in one process)."""
    from repro.scenarios.runner import _make_run_policy, _prepare_run

    spec_a = ScenarioSpec(scenario=get_scenario("commute"), policy="ads_tile")
    spec_b = dataclasses.replace(spec_a, load_factor=1.4)

    def _problem(spec):
        wf, model, sched, portfolio = _prepare_run(spec)
        return soa.build_problem(
            wf, model, sched, portfolio, _make_run_policy(spec, portfolio),
            spec.scenario, spec.scenario.duration_s, n_lanes=len(SEEDS),
        )

    pa, pb = _problem(spec_a), _problem(spec_b)
    # potency: the cells collide on a shape-only key...
    assert {k: v.shape for k, v in pa.const.items()} == {
        k: v.shape for k, v in pb.const.items()
    }
    assert pa.cfg == pb.cfg
    # ...and only the content digest tells them apart
    assert K._const_digest(pa.const) != K._const_digest(pb.const)

    K.clear_kernel_cache()
    fresh = run(spec_b, seeds=SEEDS, backend="soa", fallback=False)
    K.clear_kernel_cache()
    run(spec_a, seeds=SEEDS, backend="soa", fallback=False)  # warm the cache with A's consts
    got = run(spec_b, seeds=SEEDS, backend="soa", fallback=False)  # must not reuse A's loop
    for f, g in zip(fresh, got):
        assert f.chain_latencies == g.chain_latencies
        assert f.violation_rate == g.violation_rate
        assert f.effective_frac == g.effective_frac
        assert f.realloc_frac == g.realloc_frac


# ---------------------------------------------------------------------------
# window-lifetime overflow: detect, refuse, retry wider
# ---------------------------------------------------------------------------
@needs_jax
def test_window_overflow_detected_and_retried():
    """A job that slides out of the job window unresolved (overload
    queueing past the E2E-deadline lifetime bound under the soft drop
    policy) must surface as SoaWindowOverflow, never as silently
    truncated reports; the runner retries with a wider window."""
    from repro.core.sim.trace import build_skeleton
    from repro.scenarios.runner import _prepare_run

    spec = ScenarioSpec(scenario=get_scenario("commute"), policy="tp_driven")
    wf, model, sched, portfolio = _prepare_run(spec)
    scen = spec.scenario
    duration = scen.duration_s

    base = soa.build_problem(
        wf, model, sched, portfolio, "tp_driven", scen, duration,
        n_lanes=len(SEEDS),
    )
    # shrink the window to ~4 ms: normal jobs outlive it, so they slide
    # out unresolved — the forced analogue of overload queueing delay
    tight = soa.SoaOptions(life_pad_s=-(base.life - 4e-3))
    problem = soa.build_problem(
        wf, model, sched, portfolio, "tp_driven", scen, duration,
        n_lanes=len(SEEDS), options=tight,
    )
    assert problem.life < base.life
    skel = build_skeleton(wf, scen, duration)
    btrace = sample_trace_batch(skel, model, scen, SEEDS, device=True)
    with pytest.raises(soa.SoaWindowOverflow):
        soa.run_problem(problem, btrace, SEEDS)

    # the runner widens and converges to non-truncated reports
    with pytest.warns(RuntimeWarning, match="SoA job window"):
        got = run(spec, seeds=SEEDS, backend="soa", fallback=False,
                  options=tight)
    want = run(spec, seeds=SEEDS, backend="soa", fallback=False)
    assert len(got) == len(SEEDS)
    for a, b in zip(want, got):
        assert soa.structural_invariants(a) == soa.structural_invariants(b)
        # truncation starves whole chains (violation rate ~1); the
        # widened rerun must sit at the default window's level
        assert abs(a.violation_rate - b.violation_rate) <= 0.05
        assert np.isclose(a.effective_frac, b.effective_frac, rtol=1e-2)


# ---------------------------------------------------------------------------
# support predicates + clean degradation without jax
# ---------------------------------------------------------------------------
def test_soa_supported_predicate():
    assert soa.soa_supported("cyc")
    assert soa.soa_supported("tp_driven", drop_policy="hard")
    assert not soa.soa_supported("unknown_policy")
    assert not soa.soa_supported("cyc", replan_mode="predictive")
    assert not soa.soa_supported("cyc", detection_delay_s=0.02)
    assert not soa.soa_supported("cyc", record=True)


def test_run_problem_raises_without_jax(monkeypatch):
    """A jax-less platform degrades to a typed error, not an
    ImportError from kernel internals."""
    monkeypatch.setattr(K, "HAS_JAX", False)
    assert not soa.soa_available()
    with pytest.raises(soa.SoaUnsupported):
        soa.run_problem(None, None, [0])
    spec = ScenarioSpec(scenario=get_scenario("commute"), policy="cyc")
    with pytest.raises(soa.SoaUnsupported):
        run(spec, seeds=[0], backend="soa", fallback=False)


@needs_jax
def test_soa_backend_rejects_unsupported_spec():
    spec = ScenarioSpec(
        scenario=get_scenario("commute"), policy="cyc", replan_mode="predictive"
    )
    with pytest.raises(soa.SoaUnsupported):
        run(spec, seeds=[0], backend="soa", fallback=False)


# ---------------------------------------------------------------------------
# device sampling path (stream contract on jnp)
# ---------------------------------------------------------------------------
@needs_jax
def test_device_sampling_matches_numpy_path():
    spec = ScenarioSpec(scenario=get_scenario("commute"), policy="cyc")
    from repro.core.sim.trace import build_skeleton
    from repro.scenarios.runner import _prepare_run

    wf, model, _sched, _pf = _prepare_run(spec)
    skel = build_skeleton(wf, spec.scenario, spec.scenario.duration_s)
    host = sample_trace_batch(skel, model, spec.scenario, SEEDS)
    dev = sample_trace_batch(skel, model, spec.scenario, SEEDS, device=True)
    for field in ("work", "io", "sensor_lat"):
        a, b = getattr(host, field), getattr(dev, field)
        # integer hash is bit-identical; the float quantile transforms
        # may differ in the last ulp (XLA exp/log are not libm)
        assert np.allclose(a, b, rtol=1e-12, atol=1e-15), field


# ---------------------------------------------------------------------------
# allocator kernel vs the NumPy oracle
# ---------------------------------------------------------------------------
@needs_jax
def test_ladder_grant_matches_reference():
    rng = np.random.default_rng(0)
    limit = rng.integers(0, 9, size=(5, 16)).astype(np.float32)
    cand = np.sort(rng.integers(0, 9, size=(5, 16, 4)), axis=-1).astype(np.float32)
    cand[..., 0] = 0.0
    want = K.ladder_grant_reference(limit, cand)
    import jax.numpy as jnp

    got = np.asarray(K._ladder_grant(jnp.asarray(limit), jnp.asarray(cand)))
    np.testing.assert_array_equal(want, got)
    if K.HAS_PALLAS:
        got_p = np.asarray(
            K._ladder_grant_pallas(
                jnp.asarray(limit), jnp.asarray(cand), interpret=True
            )
        )
        np.testing.assert_array_equal(want, got_p)


# ---------------------------------------------------------------------------
# property test over random Markov scenarios (mirrors test_batch.py)
# ---------------------------------------------------------------------------
try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_random_scenarios_structurally_match():
        pass

else:

    @needs_jax
    @given(
        gen_seed=st.integers(0, 1_000),
        run_seed=st.integers(0, 10_000),
        duration=st.floats(0.3, 0.6),
        policy=st.sampled_from(["cyc", "tp_driven", "ads_tile"]),
    )
    @settings(
        deadline=None,
        max_examples=4,
        suppress_health_check=[HealthCheck.too_slow],
    )
    def test_property_random_scenarios_structurally_match(
        gen_seed, run_seed, duration, policy
    ):
        """Random scenario shapes keep the *exact* half of the
        contract: structural invariants match per seed (the KS half
        needs latency mass a 2-seed cell does not have)."""
        scen = default_generator().sample(duration, gen_seed)
        spec = ScenarioSpec(scenario=scen, policy=policy)
        seeds = [run_seed, run_seed + 1]
        got = run(spec, seeds=seeds, backend="soa", fallback=False)
        for s, rb in zip(seeds, got):
            [ra] = run(dataclasses.replace(spec, seed=int(s)),
                       backend="scalar")
            ia = soa.structural_invariants(ra)
            ib = soa.structural_invariants(rb)
            assert ia == ib, (gen_seed, policy, s)
