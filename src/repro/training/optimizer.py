"""AdamW in pure JAX (no external deps), with optional int8 gradient
compression for the cross-pod all-reduce (distributed-optimization
trick; see DESIGN.md §5 and the §Perf log)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "compress_grads_int8",
    "decompress_grads_int8",
    "global_norm",
]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    #: dtype of the m/v moments.  'bfloat16' halves optimizer HBM (the
    #: dominant per-chip state for deepseek-class models) at a small
    #: quality cost — §Perf iteration 3.
    state_dtype: str = "float32"


def adamw_init(params, state_dtype: str = "float32") -> Dict[str, Any]:
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    lr = _schedule(cfg, step)

    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m2 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m2.astype(sdt), v2.astype(sdt),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gn


# ---------------------------------------------------------------------------
# gradient compression (cross-pod): int8 with per-tensor scale
# ---------------------------------------------------------------------------
def compress_grads_int8(grads):
    def enc(g):
        g32 = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale}

    return jax.tree.map(enc, grads)


def decompress_grads_int8(comp):
    def dec(leaf):
        return leaf["q"].astype(jnp.float32) * leaf["scale"]

    return jax.tree.map(
        dec, comp, is_leaf=lambda x: isinstance(x, dict) and "q" in x
    )
