"""Training substrate: optimizer, train step, checkpointing, data."""
from .optimizer import AdamWConfig, adamw_init, adamw_update
from .trainer import Trainer, TrainConfig

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "Trainer",
    "TrainConfig",
]
