"""Training loop: jit'd train step with sharding, gradient accumulation,
checkpoint/restore-based fault tolerance, and elastic re-meshing.

Used by ``examples/train_e2e.py`` (a ~100M model for a few hundred
steps on CPU) and by ``launch/train.py`` at production scale.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..distribution.sharding import param_specs
from ..models import LM, init_params
from ..models.config import ModelConfig
from .checkpoint import CheckpointManager
from .optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainConfig", "Trainer"]


@dataclasses.dataclass
class TrainConfig:
    steps: int = 200
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    grad_accum: int = 1
    fsdp: bool = False
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        mesh=None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.model = LM(cfg)
        key = jax.random.PRNGKey(seed)
        self.params = init_params(cfg, key)
        self.opt_state = adamw_init(self.params)
        self.step = 0
        self.ckpt = (
            CheckpointManager(tcfg.checkpoint_dir)
            if tcfg.checkpoint_dir else None
        )
        self._build_step()

    # ------------------------------------------------------------------
    def _build_step(self):
        model, acfg, accum = self.model, self.tcfg.optimizer, self.tcfg.grad_accum

        def one_loss(params, batch):
            return model.loss(params, batch)

        def train_step(params, opt, batch):
            if accum > 1:
                # micro-batch scan: batch leading dim is (accum, b/accum, ...)
                def micro(carry, mb):
                    g_acc, l_acc = carry
                    l, g = jax.value_and_grad(one_loss)(params, mb)
                    g_acc = jax.tree.map(jnp.add, g_acc, g)
                    return (g_acc, l_acc + l), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), batch)
                grads = jax.tree.map(lambda g: g / accum, grads)
                loss = loss / accum
            else:
                loss, grads = jax.value_and_grad(one_loss)(params, batch)
            new_p, new_o, gn = adamw_update(acfg, params, grads, opt)
            return new_p, new_o, loss, gn

        if self.mesh is not None:
            p_specs = param_specs(self.cfg, self.params, fsdp=self.tcfg.fsdp)
            shard = lambda t: jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), t,
                is_leaf=lambda x: isinstance(x, P),
            )
            o_specs = {"m": p_specs, "v": p_specs, "step": P()}
            self._step_fn = jax.jit(
                train_step,
                in_shardings=(shard(p_specs), shard(o_specs), None),
                donate_argnums=(0, 1),
            )
            self.params = jax.device_put(self.params, shard(p_specs))
            self.opt_state = jax.device_put(self.opt_state, shard(o_specs))
        else:
            self._step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def restore_if_available(self) -> bool:
        """Fault tolerance: resume from the latest checkpoint."""
        if self.ckpt is None:
            return False
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        state = self.ckpt.restore(latest)
        self.params = jax.tree.map(
            lambda a, b: jnp.asarray(b, a.dtype), self.params, state["params"]
        )
        self.opt_state = jax.tree.map(
            lambda a, b: jnp.asarray(b, a.dtype),
            self.opt_state, state["opt_state"],
        )
        self.step = int(state["step"])
        return True

    def fit(self, data: Iterator[Dict[str, jax.Array]],
            on_log: Optional[Callable] = None) -> Dict[str, Any]:
        history = []
        ctx = jax.set_mesh(self.mesh) if self.mesh is not None else _nullcontext()
        with ctx:
            while self.step < self.tcfg.steps:
                batch = next(data)
                t0 = time.time()
                self.params, self.opt_state, loss, gn = self._step_fn(
                    self.params, self.opt_state, batch
                )
                self.step += 1
                if self.step % self.tcfg.log_every == 0 or self.step == 1:
                    loss_f = float(loss)
                    rec = {
                        "step": self.step,
                        "loss": loss_f,
                        "grad_norm": float(gn),
                        "dt_s": time.time() - t0,
                    }
                    history.append(rec)
                    if on_log:
                        on_log(rec)
                if (
                    self.ckpt is not None
                    and self.step % self.tcfg.checkpoint_every == 0
                ):
                    self.ckpt.save(
                        self.step,
                        {
                            "params": self.params,
                            "opt_state": self.opt_state,
                            "step": self.step,
                        },
                    )
        return {"history": history, "final_step": self.step}


class _nullcontext:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
