"""Checkpoint save/restore (fault tolerance).

Atomic-write msgpack-free format: numpy ``.npz`` per step + a JSON
manifest, with tree structure recorded as flattened key paths.  Works
for any pytree of arrays; restores host-side (the trainer re-shards on
load).  Crash-safe: writes to a temp name then renames.
"""
from __future__ import annotations

import os
import tempfile
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    def _path(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}.npz"

    def save(self, step: int, state: Dict[str, Any]) -> Path:
        flat = _flatten(state)
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        os.close(fd)
        try:
            np.savez(tmp, **flat)
            # np.savez appends .npz to a name without it
            produced = tmp if tmp.endswith(".npz") else tmp + ".npz"
            os.replace(produced, self._path(step))
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)
        self._gc()
        return self._path(step)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self) -> List[int]:
        return sorted(
            int(p.stem.split("_")[1]) for p in self.dir.glob("step_*.npz")
        )

    def restore(self, step: int) -> Dict[str, Any]:
        """Returns a nested dict tree rebuilt from flattened keys."""
        data = np.load(self._path(step))
        tree: Dict[str, Any] = {}
        for key in data.files:
            parts = key.split("/")
            node = tree
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = data[key]
        return tree

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            self._path(s).unlink(missing_ok=True)
