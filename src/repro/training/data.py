"""Synthetic sharded data pipeline.

Deterministic per-step token streams (seeded by (epoch, step, shard))
with host-side prefetch — the structure a real loader would have, minus
storage I/O.  Each host produces only its shard of the global batch;
``make_global_batch`` assembles a device-sharded global array when a
mesh is given.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator

import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig

__all__ = ["DataConfig", "synthetic_stream", "Prefetcher"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int = 8
    seq_len: int = 128
    seed: int = 0
    num_shards: int = 1
    shard: int = 0


def _batch_for(cfg: ModelConfig, dcfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    rng = np.random.RandomState(
        (dcfg.seed * 1_000_003 + step * 131 + dcfg.shard) % (2**31 - 1)
    )
    b = dcfg.batch // dcfg.num_shards
    s = dcfg.seq_len
    if cfg.num_codebooks:
        toks = rng.randint(0, cfg.vocab_size, (b, cfg.num_codebooks, s + 1))
        return {
            "tokens": toks[:, :, :-1].astype(np.int32),
            "labels": toks[:, :, 1:].astype(np.int32),
        }
    if cfg.num_patches:
        text = s - cfg.num_patches
        toks = rng.randint(0, cfg.vocab_size, (b, text + 1))
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "patch_embeds": rng.randn(b, cfg.num_patches, cfg.d_model)
            .astype(np.float32),
        }
    toks = rng.randint(0, cfg.vocab_size, (b, s + 1))
    return {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }


def synthetic_stream(
    cfg: ModelConfig, dcfg: DataConfig, start_step: int = 0
) -> Iterator[Dict[str, jnp.ndarray]]:
    """Deterministic: restarting from a checkpointed step reproduces the
    exact remaining stream (fault-tolerance invariant, tested)."""
    step = start_step
    while True:
        yield {k: jnp.asarray(v) for k, v in _batch_for(cfg, dcfg, step).items()}
        step += 1


class Prefetcher:
    """Background-thread prefetch of ``depth`` batches."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = False
        self._t = threading.Thread(target=self._fill, daemon=True)
        self._t.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
                if self._done:
                    return
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._done = True
