"""RG-LRU recurrent blocks (recurrentgemma / Griffin [arXiv:2402.19427]).

Gated linear recurrence::

    r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses ``jax.lax.associative_scan`` (parallel over L — this is
the SP-friendly form); decode is a single fused step.  The recurrence
is elementwise over the width, so it shards perfectly over 'model'.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import BATCH_AXES, ashard, dense_init
from .config import ModelConfig

__all__ = ["rglru_init", "rglru_apply", "init_lru_state"]

_C = 8.0  # Griffin's fixed temperature


def rglru_init(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        # linear block in/out (Griffin recurrent block: proj -> conv ->
        # rg-lru -> proj, with a gated branch)
        "in_x": dense_init(ks[0], (d, w), cfg.jnp_dtype),
        "in_gate": dense_init(ks[1], (d, w), cfg.jnp_dtype),
        "conv": dense_init(ks[2], (cfg.conv_width, w), cfg.jnp_dtype, scale=0.5),
        "w_r": dense_init(ks[3], (w, w), cfg.jnp_dtype, scale=0.02),
        "w_i": dense_init(ks[4], (w, w), cfg.jnp_dtype, scale=0.02),
        # Lambda parameterised so a^c in (0.9, 0.999) at init
        "lam": jnp.asarray(
            jnp.log(jnp.expm1(jnp.linspace(0.35, 0.9, w))), jnp.float32
        ),
        "out": dense_init(ks[5], (w, d), cfg.jnp_dtype),
    }


def _conv1d(x, w, state=None):
    width = w.shape[0]
    if state is None:
        ctx = jnp.concatenate([jnp.zeros_like(x[:, : width - 1]), x], axis=1)
    else:
        ctx = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(ctx[:, i: i + x.shape[1]] * w[i] for i in range(width))
    new_state = ctx[:, -(width - 1):] if width > 1 else None
    return out, new_state


def rglru_apply(
    params: Dict,
    x: jax.Array,                    # (B, L, D)
    cfg: ModelConfig,
    state: Optional[Dict] = None,    # {"h": (B, W), "conv": (B, cw-1, W)}
) -> Tuple[jax.Array, Optional[Dict]]:
    xb = jnp.einsum("bld,dw->blw", x, params["in_x"])
    gate = jax.nn.gelu(jnp.einsum("bld,dw->blw", x, params["in_gate"]))
    xb = ashard(xb, BATCH_AXES, None, "model")

    conv_state = state["conv"] if state is not None else None
    xb, new_conv = _conv1d(xb, params["conv"], conv_state)

    r = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", xb, params["w_r"]))
    i = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", xb, params["w_i"]))
    log_a = (
        -_C * jax.nn.softplus(params["lam"]) * r.astype(jnp.float32)
    )  # (B, L, W) <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i.astype(jnp.float32) * xb.astype(jnp.float32)
    )

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    if state is None:
        # parallel linear recurrence: h_t = a_t h_{t-1} + b_t
        _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
        new_state = None
    elif x.shape[1] == 1:
        h0 = state["h"].astype(jnp.float32)
        h = a[:, 0] * h0 + gated[:, 0]
        new_state = {"h": h.astype(cfg.jnp_dtype), "conv": new_conv}
        h = h[:, None]
    else:
        # stateful prefill: fold h0 into the first step, then scan
        h0 = state["h"].astype(jnp.float32)
        gated = gated.at[:, 0].add(a[:, 0] * h0)
        _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
        new_state = {"h": h[:, -1].astype(cfg.jnp_dtype), "conv": new_conv}

    out = jnp.einsum("blw,wd->bld", h.astype(x.dtype) * gate, params["out"])
    return ashard(out, BATCH_AXES, None, None), new_state


def init_lru_state(cfg: ModelConfig, batch: int, layers: int) -> Dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((layers, batch, w), cfg.jnp_dtype),
        "conv": jnp.zeros((layers, batch, cfg.conv_width - 1, w), cfg.jnp_dtype),
    }
