"""Multi-head Latent Attention (deepseek-v2 [arXiv:2405.04434]).

Queries go through a low-rank bottleneck (q_lora); keys/values are
reconstructed from a compressed latent ``c_kv`` (kv_lora_rank) plus a
shared rope key.  The decode cache stores only ``(c_kv, k_rope)`` —
(512 + 64) per token instead of ``2 * H * d_h`` — MLA's raison d'etre.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import BATCH_AXES, ashard, chunked_attention, dense_init, rms_norm, rope
from .config import ModelConfig

__all__ = ["mla_init", "mla_apply", "init_mla_cache"]


def mla_init(key, cfg: ModelConfig) -> Dict:
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "q_down": dense_init(ks[0], (d, qr), cfg.jnp_dtype),
        "q_norm": jnp.ones((qr,), cfg.jnp_dtype),
        "q_up": dense_init(ks[1], (qr, h * (dn + dr)), cfg.jnp_dtype),
        "kv_down": dense_init(ks[2], (d, kvr), cfg.jnp_dtype),
        "kv_norm": jnp.ones((kvr,), cfg.jnp_dtype),
        "k_rope": dense_init(ks[3], (d, dr), cfg.jnp_dtype),
        "k_up": dense_init(ks[4], (kvr, h * dn), cfg.jnp_dtype),
        "v_up": dense_init(ks[5], (kvr, h * dv), cfg.jnp_dtype),
        "wo": dense_init(ks[6], (h * dv, d), cfg.jnp_dtype),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, layers: int):
    return {
        "c_kv": jnp.zeros((layers, batch, max_len, cfg.kv_lora_rank), cfg.jnp_dtype),
        "k_rope": jnp.zeros((layers, batch, max_len, cfg.qk_rope_dim), cfg.jnp_dtype),
    }


def _absorbed_decode(params, cfg, q_nope, q_rope, c_kv, k_rope, pos,
                     b, h, dn, dr, dv):
    """Latent-space MLA decode: one query token against the compressed
    cache.  q_nope (B,1,H,dn), q_rope (B,1,H,dr) post-rope;
    c_kv (B,Lmax,r), k_rope (B,Lmax,dr)."""
    r = cfg.kv_lora_rank
    lmax = c_kv.shape[1]
    k_up = params["k_up"].reshape(r, h, dn)
    v_up = params["v_up"].reshape(r, h, dv)

    # fold k_up into the query: q_lat (B, H, r)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], k_up)
    s = jnp.einsum(
        "bhr,blr->bhl", q_lat, c_kv, preferred_element_type=jnp.float32
    ) + jnp.einsum(
        "bhd,bld->bhl", q_rope[:, 0], k_rope,
        preferred_element_type=jnp.float32,
    )
    s = s / math.sqrt(dn + dr)
    mask = jnp.arange(lmax)[None, None, :] <= pos
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum(
        "bhl,blr->bhr", p.astype(c_kv.dtype), c_kv,
        preferred_element_type=jnp.float32,
    )
    out_h = jnp.einsum("bhr,rhd->bhd", ctx.astype(v_up.dtype), v_up)
    return out_h.reshape(b, 1, h * dv)


def mla_apply(
    params: Dict,
    x: jax.Array,                  # (B, L, D)
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (c_kv, k_rope): (B,Lmax,r)
    cache_pos: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    b, l, d = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    # queries
    cq = rms_norm(jnp.einsum("bld,dr->blr", x, params["q_down"]), params["q_norm"])
    q = jnp.einsum("blr,rh->blh", cq, params["q_up"]).reshape(b, l, h, dn + dr)
    q = ashard(q, BATCH_AXES, None, "model", None)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(
        q_rope.transpose(0, 2, 1, 3), positions, cfg.rope_theta
    ).transpose(0, 2, 1, 3)
    q = jnp.concatenate([q_nope, q_rope], axis=-1).transpose(0, 2, 1, 3)

    # compressed KV latent + shared rope key
    c_kv = rms_norm(
        jnp.einsum("bld,dr->blr", x, params["kv_down"]), params["kv_norm"]
    )
    k_r = jnp.einsum("bld,dr->blr", x, params["k_rope"])        # (B, L, dr)
    k_r = rope(k_r, positions, cfg.rope_theta)

    kv_valid = None
    if cache is not None:
        cc, cr = cache
        pos = cache_pos if cache_pos is not None else jnp.asarray(0)
        cc = jax.lax.dynamic_update_slice(cc, c_kv, (0, pos, 0))
        cr = jax.lax.dynamic_update_slice(cr, k_r, (0, pos, 0))
        c_kv, k_r = cc, cr
        new_cache = (cc, cr)
        kv_valid = pos + l
        q_offset = pos
        if l == 1:
            # absorbed decode (§Perf iteration 7): fold k_up into the
            # query and v_up into the output so attention scores run
            # directly against the (L, r) latent — per-step FLOPs drop
            # from O(L*H*r*(dn+dv)) (reconstructing every cached k/v) to
            # O(L*H*r), and the (B, L, H, dn+dr) k tensor never exists
            out = _absorbed_decode(
                params, cfg, q_nope, q_rope, cc, cr, pos, b, h, dn, dr, dv
            )
            out = jnp.einsum("blh,hd->bld", out, params["wo"])
            return ashard(out, BATCH_AXES, None, None), new_cache
    else:
        new_cache = None
        q_offset = 0

    lk = c_kv.shape[1]
    # reconstruct per-head keys/values from the latent
    k_nope = jnp.einsum("blr,rh->blh", c_kv, params["k_up"]).reshape(b, lk, h, dn)
    v = jnp.einsum("blr,rh->blh", c_kv, params["v_up"]).reshape(b, lk, h, dv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_r[:, :, None, :], (b, lk, h, dr))], axis=-1
    ).transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    # pad v head dim up to the qk head dim for the shared attention core
    out = chunked_attention(
        q, k,
        jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv))),
        causal=True, window=0, softcap=0.0,
        q_offset=q_offset, kv_offset=0, kv_valid_len=kv_valid,
        scale=1.0 / math.sqrt(dn + dr),
    )[..., :dv]

    out = out.transpose(0, 2, 1, 3).reshape(b, l, h * dv)
    out = jnp.einsum("blh,hd->bld", out, params["wo"])
    return ashard(out, BATCH_AXES, None, None), new_cache
