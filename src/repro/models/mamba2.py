"""Mamba-2 blocks — SSD (state-space duality) [arXiv:2405.21060].

Chunked SSD algorithm in pure jnp (the Pallas kernel in
``repro/kernels/ssd.py`` accelerates the intra-chunk matmuls; this
module is also its oracle).  Decode keeps an O(1) recurrent state
(B, H, P, N) + a conv ring buffer, which is what makes the
``long_500k`` cell runnable for this family.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import BATCH_AXES, ashard, dense_init, rms_norm
from .config import ModelConfig

__all__ = [
    "mamba_init",
    "mamba_apply",
    "mamba_decode_step",
    "init_ssm_state",
    "ssd_chunked",
]


# ---------------------------------------------------------------------------
# SSD core (chunked; faithful to the Mamba-2 minimal listing)
# ---------------------------------------------------------------------------
def ssd_chunked(
    x: jax.Array,      # (B, L, H, P)
    dt: jax.Array,     # (B, L, H)   softplus-activated step sizes
    A: jax.Array,      # (H,)        negative decay rates
    Bm: jax.Array,     # (B, L, G, N)
    Cm: jax.Array,     # (B, L, G, N)
    chunk: int = 256,
    init_state: Optional[jax.Array] = None,   # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,H,P), final_state (B,H,P,N)).

    Within each chunk the quadratic "attention-like" form is used;
    states are carried across chunks with a scan (linear in L).
    """
    b, l, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert h % g == 0
    hpg = h // g
    chunk = min(chunk, l)
    nb = -(-l // chunk)
    pad = nb * chunk - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # chunk-major layout for the scan: (nb, B, C, ...)
    xc = jnp.moveaxis(x.reshape(b, nb, chunk, h, p), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(b, nb, chunk, h), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(b, nb, chunk, g, n), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(b, nb, chunk, g, n), 1, 0)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    s0 = (
        init_state.astype(jnp.float32) if init_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )

    def step(state, xs):
        xk, dtk, Bk, Ck = xs                       # (B,C,H,P) (B,C,H) (B,C,G,N)
        ack = jnp.cumsum(dtk.astype(jnp.float32) * A, axis=1)     # (B,C,H)
        # intra-chunk quadratic form: weight_{t,s} = C_t.B_s *
        #   exp(acum_t - acum_s) * dt_s   for s <= t
        seg = ack[:, :, None, :] - ack[:, None, :, :]             # (B,C,C,H)
        # mask INSIDE the exp: masked entries are positive-large, and
        # where(mask, exp(seg), 0) NaNs the gradient (0 * inf)
        seg = jnp.where(causal[None, :, :, None], seg, -jnp.inf)
        decay = jnp.exp(seg)
        cb = jnp.einsum("bcgn,bsgn->bcsg", Ck, Bk,
                        preferred_element_type=jnp.float32)
        cb = jnp.repeat(cb, hpg, axis=-1)                          # (B,C,C,H)
        w = cb * decay * dtk[:, None, :, :]
        y_intra = jnp.einsum("bcsh,bshp->bchp", w, xk.astype(jnp.float32))
        # inter-chunk: y += C_t exp(acum_t) state_in
        Ch = jnp.repeat(Ck, hpg, axis=2) if g != h else Ck         # (B,C,H,N)
        y_inter = jnp.einsum(
            "bchn,bhpn,bch->bchp", Ch.astype(jnp.float32), state,
            jnp.exp(ack),
        )
        # state update: state' = exp(acum_C) state + sum_s decay_to_end dt B x
        d2e = jnp.exp(ack[:, -1:, :] - ack)                        # (B,C,H)
        Bh = jnp.repeat(Bk, hpg, axis=2) if g != h else Bk
        contrib = jnp.einsum(
            "bch,bchn,bchp->bhpn",
            dtk * d2e, Bh.astype(jnp.float32), xk.astype(jnp.float32),
        )
        new_state = state * jnp.exp(ack[:, -1, :])[:, :, None, None] + contrib
        return new_state, (y_intra + y_inter).astype(x.dtype)

    final, ys = jax.lax.scan(step, s0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nb * chunk, h, p)
    if pad:
        y = y[:, :l]
    return y, final.astype(x.dtype)


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------
def mamba_init(key, cfg: ModelConfig) -> Dict:
    d, din = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 5)
    in_dim = 2 * din + 2 * g * n + h
    return {
        "in_proj": dense_init(ks[0], (d, in_dim), cfg.jnp_dtype),
        "conv": dense_init(ks[1], (cfg.conv_width, din + 2 * g * n), cfg.jnp_dtype,
                           scale=0.5),
        "A_log": jnp.zeros((h,), jnp.float32) + jnp.log(
            jnp.linspace(1.0, 16.0, h)
        ),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((din,), cfg.jnp_dtype),
        "out_proj": dense_init(ks[2], (din, d), cfg.jnp_dtype),
    }


def _split_in(cfg: ModelConfig, zxbcdt: jax.Array):
    din, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din: 2 * din + 2 * g * n]
    dt = zxbcdt[..., 2 * din + 2 * g * n:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv1d; ``state`` is the (B, W-1, C) ring buffer
    for decode.  Returns (out, new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(xbc[:, : width - 1])
        ctx = jnp.concatenate([pad, xbc], axis=1)
    else:
        ctx = jnp.concatenate([state.astype(xbc.dtype), xbc], axis=1)
    out = sum(
        ctx[:, i: i + xbc.shape[1]] * w[i] for i in range(width)
    )
    new_state = ctx[:, -(width - 1):] if width > 1 else None
    return jax.nn.silu(out), new_state


def mamba_apply(
    params: Dict,
    x: jax.Array,                     # (B, L, D)
    cfg: ModelConfig,
    state: Optional[Dict] = None,     # decode: {"ssm": (B,H,P,N), "conv": (B,W-1,C)}
) -> Tuple[jax.Array, Optional[Dict]]:
    b, l, d = x.shape
    din, g, n, h, p = (
        cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    )
    zxbcdt = jnp.einsum("bld,de->ble", x, params["in_proj"])
    zxbcdt = ashard(zxbcdt, BATCH_AXES, None, "model")
    z, xbc, dt = _split_in(cfg, zxbcdt)

    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv"], conv_state)

    xs = xbc[..., :din].reshape(b, l, h, p)
    Bm = xbc[..., din: din + g * n].reshape(b, l, g, n)
    Cm = xbc[..., din + g * n:].reshape(b, l, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    if state is None:
        y, final = ssd_chunked(xs, dt, A, Bm, Cm, chunk=cfg.ssd_chunk)
        new_state = None
    elif l == 1:
        y, final = _ssm_step(xs, dt, A, Bm, Cm, state["ssm"], h // g)
        new_state = {"ssm": final, "conv": new_conv}
    else:  # stateful prefill: chunked scan seeded with the carried state
        y, final = ssd_chunked(
            xs, dt, A, Bm, Cm, chunk=cfg.ssd_chunk,
            init_state=state["ssm"],
        )
        new_state = {"ssm": final, "conv": new_conv}

    y = y + xs * params["D"][None, None, :, None]
    y = y.reshape(b, l, din).astype(x.dtype)   # D is f32; keep model dtype
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    if state is None:
        return ashard(out, BATCH_AXES, None, None), None
    return ashard(out, BATCH_AXES, None, None), new_state


def _ssm_step(xs, dt, A, Bm, Cm, ssm, hpg):
    """Single-token recurrence: h' = exp(dt*A) h + dt * B x^T; y = C h."""
    # shapes: xs (B,1,H,P), dt (B,1,H), Bm/Cm (B,1,G,N), ssm (B,H,P,N)
    x0 = xs[:, 0]                       # (B,H,P)
    d0 = dt[:, 0]                       # (B,H)
    B0 = jnp.repeat(Bm[:, 0], hpg, axis=1)  # (B,H,N)
    C0 = jnp.repeat(Cm[:, 0], hpg, axis=1)
    decay = jnp.exp(d0 * A)             # (B,H)
    upd = jnp.einsum("bh,bhn,bhp->bhpn", d0, B0, x0)
    new = ssm * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new, C0)
    return y[:, None], new


def init_ssm_state(cfg: ModelConfig, batch: int, layers: int) -> Dict:
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm": jnp.zeros((layers, batch, h, p, n), cfg.jnp_dtype),
        "conv": jnp.zeros((layers, batch, cfg.conv_width - 1, conv_ch), cfg.jnp_dtype),
    }


def mamba_decode_step(params, x, cfg, state_layer):
    return mamba_apply(params, x, cfg, state=state_layer)
