"""Model zoo: the ten assigned architectures as composable JAX modules.

One decoder-LM substrate (``common.py``) covers the dense transformers;
family modules add Mamba-2 SSD blocks, RG-LRU hybrid blocks, MoE layers
(token-choice GShard-style dispatch) and DeepSeek MLA attention.  All
stacks scan over homogeneous pattern units so a 60-layer model compiles
one unit; per-layer attention patterns (local/global alternation) ride
through the scan as per-layer window arrays.
"""
from .config import ModelConfig
from .lm import LM, init_params, train_step_fn, prefill_fn, decode_step_fn

__all__ = [
    "ModelConfig",
    "LM",
    "init_params",
    "train_step_fn",
    "prefill_fn",
    "decode_step_fn",
]
