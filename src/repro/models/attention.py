"""GQA attention block with KV cache, sliding-window/global alternation,
logit softcap and optional per-head QK-norm."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import BATCH_AXES, ashard, chunked_attention, dense_init, rms_norm, rope
from .config import ModelConfig

__all__ = ["attn_init", "attn_apply", "init_kv_cache"]


def attn_init(key, cfg: ModelConfig) -> Dict:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), cfg.jnp_dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), cfg.jnp_dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), cfg.jnp_dtype),
        "wo": dense_init(ks[3], (hq * hd, d), cfg.jnp_dtype),
    }
    if cfg.qk_norm:
        p["qn"] = jnp.ones((hd,), cfg.jnp_dtype)
        p["kn"] = jnp.ones((hd,), cfg.jnp_dtype)
    return p


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, layers: int):
    """Stacked KV cache: (layers, B, Hkv, max_len, head_dim)."""
    shape = (layers, batch, cfg.num_kv_heads, max_len, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, cfg.jnp_dtype),
        "v": jnp.zeros(shape, cfg.jnp_dtype),
    }


def attn_apply(
    params: Dict,
    x: jax.Array,                      # (B, L, D)
    cfg: ModelConfig,
    *,
    positions: jax.Array,              # (L,) absolute positions
    window,                            # traced scalar; <=0 global
    theta,                             # traced scalar rope base
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (k,v): (B,Hkv,Lmax,D)
    cache_pos: Optional[jax.Array] = None,  # scalar: #valid entries already
    ring: bool = False,                     # bounded-window ring cache
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    b, l, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    q = jnp.einsum("bld,dh->blh", x, params["wq"])
    k = jnp.einsum("bld,dh->blh", x, params["wk"])
    v = jnp.einsum("bld,dh->blh", x, params["wv"])
    q = ashard(q, BATCH_AXES, None, "model")
    k = ashard(k, BATCH_AXES, None, "model")
    v = ashard(v, BATCH_AXES, None, "model")
    q = q.reshape(b, l, hq, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, l, hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, l, hkv, hd).transpose(0, 2, 1, 3)

    if cfg.qk_norm:
        q = rms_norm(q, params["qn"])
        k = rms_norm(k, params["kn"])
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)

    new_cache = None
    if cache is not None:
        ck, cv = cache
        pos = cache_pos if cache_pos is not None else jnp.asarray(0)
        cache_len = ck.shape[2]
        if ring and l == 1:
            # ring buffer (bounded window cache, long_500k decode):
            # slot i holds absolute position  pos - ((pos - i) mod W)
            slot = jnp.mod(pos, cache_len)
            ck = jax.lax.dynamic_update_slice(ck, k, (0, 0, slot, 0))
            cv = jax.lax.dynamic_update_slice(cv, v, (0, 0, slot, 0))
            new_cache = (ck, cv)
            idx = jnp.arange(cache_len)
            kpos = pos - jnp.mod(pos - idx, cache_len)             # <= pos
            out = chunked_attention(
                q, ck, cv,
                causal=True, window=window,
                softcap=cfg.attn_logit_softcap,
                q_offset=pos, kv_positions=kpos,
            )
        elif ring:
            # windowed prefill: attend over the computed sequence, then
            # fold the last W positions into the ring
            out = chunked_attention(
                q, k, v,
                causal=True, window=window,
                softcap=cfg.attn_logit_softcap,
                q_offset=pos, kv_offset=pos,
            )
            take = min(l, cache_len)
            k_tail, v_tail = k[:, :, -take:], v[:, :, -take:]
            first = pos + l - take                   # abs position of tail[0]
            if take == cache_len:
                shift = jnp.mod(first, cache_len)
                ck = jnp.roll(k_tail, shift, axis=2)
                cv = jnp.roll(v_tail, shift, axis=2)
            else:
                # short prefill from scratch: slots = positions directly
                ck = jax.lax.dynamic_update_slice(
                    ck, k_tail, (0, 0, jnp.mod(first, cache_len), 0)
                )
                cv = jax.lax.dynamic_update_slice(
                    cv, v_tail, (0, 0, jnp.mod(first, cache_len), 0)
                )
            new_cache = (ck, cv)
        else:
            ck = jax.lax.dynamic_update_slice(ck, k, (0, 0, pos, 0))
            cv = jax.lax.dynamic_update_slice(cv, v, (0, 0, pos, 0))
            new_cache = (ck, cv)
            out = chunked_attention(
                q, ck, cv,
                causal=True, window=window,
                softcap=cfg.attn_logit_softcap,
                q_offset=pos, kv_offset=0, kv_valid_len=pos + l,
            )
    else:
        out = chunked_attention(
            q, k, v,
            causal=True, window=window,
            softcap=cfg.attn_logit_softcap,
            q_offset=0, kv_offset=0,
        )

    out = out.transpose(0, 2, 1, 3).reshape(b, l, hq * hd)
    out = jnp.einsum("blh,hd->bld", out, params["wo"])
    # NOTE (§Perf iteration 6, REFUTED & reverted): forcing a
    # sequence-sharded output here doubled collective bytes — GSPMD
    # inserts head->seq resharding transposes each layer, and the
    # backward pass mirrors them.  Replicated output lets the partitioner
    # pick the cheaper all-reduce placement.
    return ashard(out, BATCH_AXES, None, None), new_cache
