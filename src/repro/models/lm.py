"""Unified decoder-LM: builds any of the ten assigned architectures from
its :class:`ModelConfig` and exposes ``loss`` / ``prefill`` /
``decode_step``.

Layer stacks run under ``jax.lax.scan`` over *stacked* parameters so a
60-layer model compiles a single layer body.  Per-layer attention
patterns (local/global windows, rope bases) ride through the scan as
per-layer arrays; training wraps the body in ``jax.checkpoint``.

Modality frontends are stubs per the assignment: phi-3-vision consumes
precomputed CLIP patch embeddings; musicgen consumes EnCodec codebook
tokens (4 codebooks, summed embeddings, 4 output heads).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .attention import attn_apply, attn_init
from .common import (
    BATCH_AXES,
    ashard,
    chunked_xent,
    dense_init,
    gated_mlp,
    gated_mlp_init,
    rms_norm,
)
from .config import ModelConfig
from .mamba2 import init_ssm_state, mamba_apply, mamba_init
from .mla import mla_apply, mla_init
from .moe import moe_apply, moe_init
from .rglru import rglru_apply, rglru_init

__all__ = ["LM", "init_params", "train_step_fn", "prefill_fn", "decode_step_fn"]


# ---------------------------------------------------------------------------
# per-layer pattern tables (static numpy, turned into scan xs)
# ---------------------------------------------------------------------------
def _layer_windows(cfg: ModelConfig) -> np.ndarray:
    return np.asarray(
        [0 if cfg.is_global_layer(l) else cfg.window
         for l in range(cfg.num_layers)],
        np.int32,
    )


def _layer_thetas(cfg: ModelConfig) -> np.ndarray:
    local = cfg.rope_theta_local or cfg.rope_theta
    return np.asarray(
        [cfg.rope_theta if cfg.is_global_layer(l) else local
         for l in range(cfg.num_layers)],
        np.float32,
    )


def _hybrid_layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(#lru layers, #attention layers) for the 1:k hybrid pattern."""
    k = cfg.lru_blocks_per_attn
    unit = k + 1
    n_units = cfg.num_layers // unit
    rem = cfg.num_layers - n_units * unit   # trailing lru blocks
    n_lru = n_units * k + rem
    n_att = n_units
    return n_lru, n_att


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _stack_init(fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    d = cfg.d_model
    keys = jax.random.split(key, 8)
    # embed rows ~ N(0, 1/d): unit-variance inputs after the sqrt(d)
    # input scaling and O(1) logits through the tied output head
    emb_scale = d ** -0.5

    if cfg.num_codebooks:
        emb = dense_init(
            keys[0], (cfg.num_codebooks, cfg.vocab_size, d), cfg.jnp_dtype,
            scale=emb_scale,
        )
    else:
        emb = dense_init(keys[0], (cfg.vocab_size, d), cfg.jnp_dtype, scale=emb_scale)
    params: Dict[str, Any] = {"embed": emb, "final_norm": jnp.ones((d,), cfg.jnp_dtype)}

    L = cfg.num_layers
    if cfg.family == "ssm":
        params["layers"] = _stack_init(
            lambda k: {
                "norm": jnp.ones((d,), cfg.jnp_dtype),
                "mamba": mamba_init(k, cfg),
            },
            keys[1], L,
        )
    elif cfg.family == "hybrid":
        n_lru, n_att = _hybrid_layout(cfg)
        params["lru_layers"] = _stack_init(
            lambda k: _mlp_block_init(k, cfg, core=("lru", rglru_init)),
            keys[1], n_lru,
        )
        params["attn_layers"] = _stack_init(
            lambda k: _mlp_block_init(k, cfg, core=("attn", attn_init)),
            keys[2], n_att,
        )
    elif cfg.num_experts:
        n_dense = cfg.first_dense_layers
        if n_dense:
            params["dense_layers"] = _stack_init(
                lambda k: _dense_block_init(k, cfg), keys[1], n_dense
            )
        params["layers"] = _stack_init(
            lambda k: _moe_block_init(k, cfg), keys[2], L - n_dense
        )
    else:
        params["layers"] = _stack_init(
            lambda k: _dense_block_init(k, cfg), keys[1], L
        )
    return params


def _dense_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    attn = mla_init(k1, cfg) if cfg.mla else attn_init(k1, cfg)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.jnp_dtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.jnp_dtype),
        "attn": attn,
        "mlp": gated_mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.jnp_dtype),
    }


def _moe_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    attn = mla_init(k1, cfg) if cfg.mla else attn_init(k1, cfg)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.jnp_dtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.jnp_dtype),
        "attn": attn,
        "moe": moe_init(k2, cfg),
    }


def _mlp_block_init(key, cfg: ModelConfig, core):
    name, fn = core
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.jnp_dtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.jnp_dtype),
        name: fn(k1, cfg),
        "mlp": gated_mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.jnp_dtype),
    }


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LM:
    cfg: ModelConfig

    # -- embedding front ----------------------------------------------------
    def embed(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        emb = params["embed"]
        scale = math.sqrt(cfg.d_model)
        if cfg.num_codebooks:
            toks = batch["tokens"]                     # (B, K, S)
            x = sum(
                jnp.take(emb[k], toks[:, k], axis=0)
                for k in range(cfg.num_codebooks)
            ) * scale
        else:
            x = jnp.take(emb, batch["tokens"], axis=0) * scale  # (B, S, D)
        if cfg.num_patches and "patch_embeds" in batch:
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(x.dtype), x], axis=1
            )
        return ashard(x, BATCH_AXES, None, None)

    # -- backbone ------------------------------------------------------------
    def backbone(
        self,
        params,
        x: jax.Array,
        *,
        positions: jax.Array,
        cache: Optional[Dict] = None,
        cache_pos=None,
        train: bool = False,
    ) -> Tuple[jax.Array, Optional[Dict]]:
        cfg = self.cfg
        if cfg.family == "ssm":
            x, cache = self._ssm_stack(params, x, cache, train)
        elif cfg.family == "hybrid":
            x, cache = self._hybrid_stack(
                params, x, positions, cache, cache_pos, train
            )
        else:
            x, cache = self._attn_stack(
                params, x, positions, cache, cache_pos, train
            )
        x = rms_norm(x, params["final_norm"])
        return x, cache

    def _maybe_ckpt(self, fn, train: bool):
        return jax.checkpoint(fn) if (train and self.cfg.remat) else fn

    # .. dense / moe transformer stack ........................................
    def _attn_stack(self, params, x, positions, cache, cache_pos, train):
        cfg = self.cfg
        windows = jnp.asarray(_layer_windows(cfg))
        thetas = jnp.asarray(_layer_thetas(cfg))
        n_dense = cfg.first_dense_layers if cfg.num_experts else 0

        def block(x, layer, window, theta, ck, cv, moe: bool):
            # sequence-parallel residual carry: the remat-saved per-layer
            # activations (and their grads) shard over 'model' — without
            # this the stacked (L, B, S, D) carries alone exceed HBM
            if x.shape[1] > 1:
                x = ashard(x, BATCH_AXES, "model", None)
            h = rms_norm(x, layer["ln1"])
            if cfg.mla:
                out, new_c = mla_apply(
                    layer["attn"], h, cfg, positions=positions,
                    cache=(ck, cv) if ck is not None else None,
                    cache_pos=cache_pos,
                )
            else:
                out, new_c = attn_apply(
                    layer["attn"], h, cfg, positions=positions,
                    window=window, theta=theta,
                    cache=(ck, cv) if ck is not None else None,
                    cache_pos=cache_pos,
                )
            x = x + out
            h = rms_norm(x, layer["ln2"])
            if moe:
                x = x + moe_apply(layer["moe"], h, cfg)
            else:
                x = x + gated_mlp(layer["mlp"], h)
            return x, new_c

        # explicit leading dense layers (deepseek)
        for i in range(n_dense):
            lyr = jax.tree.map(lambda a: a[i], params["dense_layers"])
            ck = cv = None
            if cache is not None:
                ck, cv = cache["k0"][i], cache["v0"][i]
            x, new_c = block(x, lyr, windows[i], thetas[i], ck, cv, moe=False)
            if cache is not None:
                cache["k0"] = cache["k0"].at[i].set(new_c[0])
                cache["v0"] = cache["v0"].at[i].set(new_c[1])

        moe = bool(cfg.num_experts)

        def scan_body(x, xs):
            layer, window, theta, ck, cv = xs
            x, new_c = block(x, layer, window, theta, ck, cv, moe=moe)
            if new_c is None:
                new_c = (jnp.zeros((0,), x.dtype),) * 2
            return x, new_c

        body = self._maybe_ckpt(scan_body, train)
        nl = cfg.num_layers - n_dense
        if cache is not None:
            kk, vv = ("c_kv", "k_rope") if cfg.mla else ("k", "v")
            xs = (
                params["layers"], windows[n_dense:], thetas[n_dense:],
                cache[kk], cache[vv],
            )
            x, (new_k, new_v) = jax.lax.scan(body, x, xs)
            cache = dict(cache)
            cache[kk], cache[vv] = new_k, new_v
        else:
            def body_nc(x, xs2):
                layer, window, theta = xs2
                x, _ = block(x, layer, window, theta, None, None, moe=moe)
                return x, None

            body_nc = self._maybe_ckpt(body_nc, train)
            xs_all = (params["layers"], windows[n_dense:], thetas[n_dense:])
            x = self._grouped_scan(body_nc, x, xs_all, nl, train)
        return x, cache

    def _grouped_scan(self, body, x, xs_all, n_layers: int, train: bool):
        """sqrt-schedule nested remat (§Perf iteration 2): an outer scan
        over layer groups checkpoints only G ~ sqrt(L) carries instead of
        L; layers inside a group are recomputed group-at-a-time in the
        backward pass.  Falls back to a flat scan for short stacks or
        non-train paths."""
        import math as _m

        # §Perf iteration 2 (REFUTED, gated off): combined with per-layer
        # checkpointing this recomputes the forward twice in backward
        # (+70% compute term) for <3% temp reduction — XLA hoists the
        # carry-stack f32 convert out of the loop either way.
        use_sqrt = getattr(self.cfg, "sqrt_remat", False)
        g = int(_m.sqrt(n_layers)) if (train and self.cfg.remat and use_sqrt) else 0
        if g < 2 or n_layers < 8:
            out, _ = jax.lax.scan(body, x, xs_all)
            return out
        n_groups = n_layers // g
        rem = n_layers - n_groups * g
        head = jax.tree.map(
            lambda a: a[:n_groups * g].reshape((n_groups, g) + a.shape[1:]),
            xs_all,
        )

        @jax.checkpoint
        def group_body(x, group_xs):
            out, _ = jax.lax.scan(body, x, group_xs)
            return out, None

        x, _ = jax.lax.scan(group_body, x, head)
        if rem:
            tail = jax.tree.map(lambda a: a[n_groups * g:], xs_all)
            x, _ = jax.lax.scan(body, x, tail)
        return x

    # .. mamba stack ...........................................................
    def _ssm_stack(self, params, x, cache, train):
        cfg = self.cfg

        def body(x, xs):
            if x.shape[1] > 1:
                x = ashard(x, BATCH_AXES, "model", None)
            if cache is not None:
                layer, ssm, conv = xs
                h = rms_norm(x, layer["norm"])
                out, new_state = mamba_apply(
                    layer["mamba"], h, cfg,
                    state={"ssm": ssm, "conv": conv},
                )
                return x + out, (new_state["ssm"], new_state["conv"])
            layer, = xs if isinstance(xs, tuple) else (xs,)
            h = rms_norm(x, layer["norm"])
            out, _ = mamba_apply(layer["mamba"], h, cfg, state=None)
            return x + out, None

        body = self._maybe_ckpt(body, train)
        if cache is not None:
            x, (new_ssm, new_conv) = jax.lax.scan(
                body, x, (params["layers"], cache["ssm"], cache["conv"])
            )
            cache = {"ssm": new_ssm, "conv": new_conv}
        else:
            x, _ = jax.lax.scan(body, x, (params["layers"],))
        return x, cache

    # .. hybrid (recurrentgemma) stack ..........................................
    def _hybrid_stack(self, params, x, positions, cache, cache_pos, train):
        cfg = self.cfg
        k = cfg.lru_blocks_per_attn
        n_lru, n_att = _hybrid_layout(cfg)
        n_units = n_att
        tail = n_lru - n_units * k

        def lru_block(x, layer, h_state, conv_state):
            h = rms_norm(x, layer["ln1"])
            state = (
                {"h": h_state, "conv": conv_state} if h_state is not None else None
            )
            out, new_state = rglru_apply(layer["lru"], h, cfg, state)
            x = x + out
            x = x + gated_mlp(layer["mlp"], rms_norm(x, layer["ln2"]))
            return x, new_state

        def att_block(x, layer, ck, cv):
            h = rms_norm(x, layer["ln1"])
            out, new_c = attn_apply(
                layer["attn"], h, cfg, positions=positions,
                window=jnp.asarray(cfg.window), theta=cfg.rope_theta,
                cache=(ck, cv) if ck is not None else None,
                cache_pos=cache_pos,
                ring=True,  # bounded-window ring cache (O(1) in context)
            )
            x = x + out
            x = x + gated_mlp(layer["mlp"], rms_norm(x, layer["ln2"]))
            return x, new_c

        # scan over units of (k lru blocks + 1 attn block)
        lru_params = params["lru_layers"]
        head = jax.tree.map(lambda a: a[: n_units * k].reshape(
            (n_units, k) + a.shape[1:]
        ), lru_params)

        def unit_body(x, xs):
            lru_unit, att_layer, hs, cs, ck, cv = xs
            if x.shape[1] > 1:
                x = ashard(x, BATCH_AXES, "model", None)
            new_h, new_conv = [], []
            for i in range(k):
                lyr = jax.tree.map(lambda a: a[i], lru_unit)
                hi = hs[i] if hs is not None else None
                ci = cs[i] if cs is not None else None
                x, st = lru_block(x, lyr, hi, ci)
                if st is not None:
                    new_h.append(st["h"])
                    new_conv.append(st["conv"])
            x, new_c = att_block(x, att_layer, ck, cv)
            if hs is None:
                return x, None
            return x, (
                jnp.stack(new_h), jnp.stack(new_conv), new_c[0], new_c[1]
            )

        unit_body_ck = self._maybe_ckpt(unit_body, train)
        if cache is not None:
            hs = cache["h"][: n_units * k].reshape(
                (n_units, k) + cache["h"].shape[1:]
            )
            cs = cache["conv"][: n_units * k].reshape(
                (n_units, k) + cache["conv"].shape[1:]
            )
            x, ys = jax.lax.scan(
                unit_body_ck, x,
                (head, params["attn_layers"], hs, cs, cache["k"], cache["v"]),
            )
            new_h, new_conv, new_k, new_v = ys
            cache = dict(cache)
            cache["k"], cache["v"] = new_k, new_v
            flat_h = new_h.reshape((n_units * k,) + new_h.shape[2:])
            flat_c = new_conv.reshape((n_units * k,) + new_conv.shape[2:])
        else:
            def unit_nc(x, xs):
                lru_unit, att_layer = xs
                x, _ = unit_body((x), (lru_unit, att_layer, None, None, None, None))
                return x, None

            unit_nc = self._maybe_ckpt(unit_nc, train)
            x, _ = jax.lax.scan(unit_nc, x, (head, params["attn_layers"]))
            flat_h = flat_c = None

        # trailing lru blocks (pattern remainder)
        tail_states = []
        for i in range(tail):
            lyr = jax.tree.map(lambda a, i=i: a[n_units * k + i], lru_params)
            if cache is not None:
                hi = cache["h"][n_units * k + i]
                ci = cache["conv"][n_units * k + i]
                x, st = lru_block(x, lyr, hi, ci)
                tail_states.append(st)
            else:
                x, _ = lru_block(x, lyr, None, None)
        if cache is not None:
            if tail_states:
                flat_h = jnp.concatenate(
                    [flat_h] + [st["h"][None] for st in tail_states]
                )
                flat_c = jnp.concatenate(
                    [flat_c] + [st["conv"][None] for st in tail_states]
                )
            cache["h"], cache["conv"] = flat_h, flat_c
        return x, cache

    # -- heads ---------------------------------------------------------------
    def loss(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        x = self.embed(params, batch)
        positions = jnp.arange(x.shape[1])
        x, _ = self.backbone(params, x, positions=positions, train=True)
        if cfg.num_codebooks:
            labels = batch["labels"]       # (B, K, S)
            losses = [
                chunked_xent(
                    x, params["embed"][k], labels[:, k],
                    softcap=cfg.final_logit_softcap,
                )
                for k in range(cfg.num_codebooks)
            ]
            return sum(losses) / cfg.num_codebooks
        labels = batch["labels"]
        if cfg.num_patches and "patch_embeds" in batch:
            # patch positions carry no next-token loss
            pad = jnp.full(
                (labels.shape[0], cfg.num_patches), -1, labels.dtype
            )
            labels = jnp.concatenate([pad, labels], axis=1)
        return chunked_xent(
            x, params["embed"], labels, softcap=cfg.final_logit_softcap
        )

    def logits_last(self, params, x_last: jax.Array) -> jax.Array:
        """(B, D) -> (B, V) (or (B, K, V) for codebooks)."""
        cfg = self.cfg
        emb = params["embed"]
        if cfg.num_codebooks:
            out = jnp.einsum("bd,kvd->bkv", x_last, emb)
        else:
            out = jnp.einsum("bd,vd->bv", x_last, emb)
        if cfg.final_logit_softcap:
            out = cfg.final_logit_softcap * jnp.tanh(
                out / cfg.final_logit_softcap
            )
        return out

    # -- serving -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        if cfg.family == "ssm":
            return init_ssm_state(cfg, batch, cfg.num_layers)
        if cfg.family == "hybrid":
            n_lru, n_att = _hybrid_layout(cfg)
            w = cfg.lru_width or cfg.d_model
            win = min(max_len, cfg.window) if cfg.window else max_len
            return {
                "h": jnp.zeros((n_lru, batch, w), cfg.jnp_dtype),
                "conv": jnp.zeros(
                    (n_lru, batch, cfg.conv_width - 1, w), cfg.jnp_dtype
                ),
                "k": jnp.zeros(
                    (n_att, batch, cfg.num_kv_heads, win, cfg.head_dim),
                    cfg.jnp_dtype,
                ),
                "v": jnp.zeros(
                    (n_att, batch, cfg.num_kv_heads, win, cfg.head_dim),
                    cfg.jnp_dtype,
                ),
            }
        if cfg.mla:
            n_dense = cfg.first_dense_layers
            cache = {
                "c_kv": jnp.zeros(
                    (cfg.num_layers - n_dense, batch, max_len, cfg.kv_lora_rank),
                    cfg.jnp_dtype,
                ),
                "k_rope": jnp.zeros(
                    (cfg.num_layers - n_dense, batch, max_len, cfg.qk_rope_dim),
                    cfg.jnp_dtype,
                ),
            }
            if n_dense:
                # deepseek's leading dense layers still use MLA attention
                cache["k0"] = jnp.zeros(
                    (n_dense, batch, max_len, cfg.kv_lora_rank), cfg.jnp_dtype
                )
                cache["v0"] = jnp.zeros(
                    (n_dense, batch, max_len, cfg.qk_rope_dim), cfg.jnp_dtype
                )
            return cache
        n_dense = cfg.first_dense_layers if cfg.num_experts else 0
        return {
            "k": jnp.zeros(
                (cfg.num_layers - n_dense, batch, cfg.num_kv_heads, max_len,
                 cfg.head_dim), cfg.jnp_dtype,
            ),
            "v": jnp.zeros(
                (cfg.num_layers - n_dense, batch, cfg.num_kv_heads, max_len,
                 cfg.head_dim), cfg.jnp_dtype,
            ),
        }

    def prefill(self, params, batch, cache) -> Tuple[jax.Array, Dict]:
        x = self.embed(params, batch)
        positions = jnp.arange(x.shape[1])
        x, cache = self.backbone(
            params, x, positions=positions, cache=cache,
            cache_pos=jnp.asarray(0, jnp.int32), train=False,
        )
        return self.logits_last(params, x[:, -1]), cache

    def decode_step(self, params, batch, cache, pos) -> Tuple[jax.Array, Dict]:
        """One new token against an existing cache filled to ``pos``."""
        x = self.embed(params, batch)
        positions = jnp.asarray(pos)[None]
        x, cache = self.backbone(
            params, x, positions=positions, cache=cache,
            cache_pos=jnp.asarray(pos, jnp.int32), train=False,
        )
        return self.logits_last(params, x[:, -1]), cache


# ---------------------------------------------------------------------------
# functional entry points (used by launch/dryrun and tests)
# ---------------------------------------------------------------------------
def train_step_fn(cfg: ModelConfig):
    model = LM(cfg)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    return loss_fn


def prefill_fn(cfg: ModelConfig):
    model = LM(cfg)

    def fn(params, batch, cache):
        return model.prefill(params, batch, cache)

    return fn


def decode_step_fn(cfg: ModelConfig):
    model = LM(cfg)

    def fn(params, batch, cache, pos):
        return model.decode_step(params, batch, cache, pos)

    return fn
