"""Model configuration shared by every architecture in the zoo."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | ssm | hybrid | moe | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # -- attention pattern -------------------------------------------------
    #: sliding-window size for local layers (0 = every layer global)
    window: int = 0
    #: local:global alternation — a layer l is global iff
    #: (l % pattern_period) in global_layer_ids; empty = all global
    pattern_period: int = 1
    global_layer_ids: Tuple[int, ...] = (0,)
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_theta_local: float = 0.0      # gemma3 uses a different local base

    # -- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    moe_capacity_factor: float = 1.25

    # -- Mamba-2 (SSD) -------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 256

    # -- RG-LRU hybrid (recurrentgemma) ---------------------------------------
    #: number of recurrent blocks per attention block (0 = no recurrence)
    lru_blocks_per_attn: int = 0
    lru_width: int = 0

    # -- MLA (deepseek-v2) -----------------------------------------------------
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # -- modality frontends (stubs) ---------------------------------------------
    num_patches: int = 0          # vlm: precomputed CLIP patch embeddings
    num_codebooks: int = 0        # audio: EnCodec codebooks

    # -- misc ---------------------------------------------------------------
    tie_embeddings: bool = True
    dtype: str = "float32"
    remat: bool = True

    # ------------------------------------------------------------------
    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def is_global_layer(self, layer: int) -> bool:
        if self.window <= 0:
            return True
        return (layer % self.pattern_period) in self.global_layer_ids

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is O(1) in context (SSM / hybrid with
        bounded-window attention only)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # -- parameter count (for roofline MODEL_FLOPS) -----------------------
    def param_count(self, active_only: bool = False) -> int:
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (self.num_codebooks or 1)
        out = 0 if self.tie_embeddings else self.vocab_size * d * (self.num_codebooks or 1)
        per_layer = 0
        if self.family == "ssm":
            din, g, n, h = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
            per_layer = d * (2 * din + 2 * g * n + h) + din * d + d
        else:
            if self.mla:
                attn = (
                    d * self.q_lora_rank
                    + self.q_lora_rank * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                    + self.num_heads * self.v_head_dim * d
                )
            else:
                attn = d * self.num_heads * self.head_dim \
                    + 2 * d * self.num_kv_heads * self.head_dim \
                    + self.num_heads * self.head_dim * d
            if self.num_experts:
                n_dense = self.first_dense_layers
                dense_ffn = 3 * d * self.d_ff if self.d_ff else 0
                moe_ffn = (
                    (self.num_experts + self.num_shared_experts)
                    * 3 * d * self.moe_d_ff
                    + d * self.num_experts
                )
                per_layer = attn  # averaged below
                total_ffn = n_dense * dense_ffn + (L - n_dense) * moe_ffn
                return emb + out + L * attn + total_ffn + 2 * L * d
            ffn = 3 * d * self.d_ff
            if self.family == "hybrid" and self.lru_blocks_per_attn:
                # mix of attention and LRU blocks
                k = self.lru_blocks_per_attn
                n_lru = (L * k) // (k + 1)
                n_att = L - n_lru
                w = self.lru_width or d
                lru = d * 2 * w + w * d + 2 * w * 4  # in/out proj + gates (conv folded)
                return emb + out + n_att * (attn + ffn) + n_lru * (lru + ffn) + 2 * L * d
            per_layer = attn + ffn
        return emb + out + L * per_layer + 2 * L * d

    def active_param_count(self) -> int:
        """MoE: params touched per token (6*N_active*D convention)."""
        if not self.num_experts:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d
        if self.mla:
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                + d * (self.kv_lora_rank + self.qk_rope_dim)
                + self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                + self.num_heads * self.v_head_dim * d
            )
        else:
            attn = d * self.num_heads * self.head_dim \
                + 2 * d * self.num_kv_heads * self.head_dim \
                + self.num_heads * self.head_dim * d
        n_dense = self.first_dense_layers
        dense_ffn = 3 * d * self.d_ff if self.d_ff else 0
        active_ffn = (
            (self.experts_per_token + self.num_shared_experts) * 3 * d * self.moe_d_ff
        )
        return (
            emb + L * attn + n_dense * dense_ffn
            + (L - n_dense) * active_ffn + 2 * L * d
        )
