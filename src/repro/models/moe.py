"""Mixture-of-Experts layers (granite-moe, deepseek-v2).

Token-choice top-k routing with capacity buckets.  Expert parallelism:
experts are sharded over the 'model' mesh axis via ``jax.shard_map`` —
each model-rank dispatches the (replicated-over-model) token set to its
local expert slice, runs the batched expert FFN, and a single ``psum``
over 'model' combines partial outputs (EP with TP-equivalent comm
volume; see DESIGN.md §5).  Outside a mesh the same code runs with a
single "shard" holding all experts.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import BATCH_AXES, ashard, dense_init
from .config import ModelConfig

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg: ModelConfig) -> Dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "wg": dense_init(ks[1], (e, d, f), cfg.jnp_dtype),
        "wu": dense_init(ks[2], (e, d, f), cfg.jnp_dtype),
        "wd": dense_init(ks[3], (e, f, d), cfg.jnp_dtype),
    }
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": dense_init(k1, (d, fs), cfg.jnp_dtype),
            "wu": dense_init(k2, (d, fs), cfg.jnp_dtype),
            "wd": dense_init(k3, (fs, d), cfg.jnp_dtype),
        }
    return p


def _expert_compute(tokens, gates, expert_ids, wg, wu, wd, cf, e_total, e_base, e_local):
    """Dispatch ``tokens`` (T, D) to the local expert slice and combine.

    ``expert_ids``/(T, k) global ids; experts [e_base, e_base+e_local)
    live here.  Buckets sized ``cf * k * T / E`` per (local) expert.

    Memory note (§Perf iteration 1): dispatch/combine run per *choice
    column* — each (expert, position) slot receives exactly one token,
    so a scatter-SET per column suffices and the (T*k, D) gathered-token
    tensor (8 GB/device for deepseek train_4k) never materialises.
    """
    t, d = tokens.shape
    k = expert_ids.shape[1]
    capacity = max(8, int(cf * k * t / e_total))
    local = expert_ids - e_base                       # (T, k)
    in_range = (local >= 0) & (local < e_local)
    flat_e = jnp.where(in_range, local, e_local)       # overflow bucket

    # global rank of each (token, choice) within its expert bucket
    onehot = jax.nn.one_hot(flat_e.reshape(-1), e_local + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) * onehot                       # rank+1
    pos = (pos.sum(axis=1) - 1).reshape(t, k)
    keep = (pos < capacity) & in_range
    slot = jnp.where(
        keep, flat_e * capacity + pos, e_local * capacity
    )                                                               # (T, k)

    # scatter tokens into buckets, one choice column at a time
    buckets = jnp.zeros((e_local * capacity + 1, d), tokens.dtype)
    for j in range(k):
        buckets = buckets.at[slot[:, j]].set(tokens)
    be = buckets[:-1].reshape(e_local, capacity, d)

    # batched expert FFN
    h = jnp.einsum("ecd,edf->ecf", be, wg)
    u = jnp.einsum("ecd,edf->ecf", be, wu)
    out_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, wd)
    flat_out = jnp.concatenate(
        [out_e.reshape(e_local * capacity, d),
         jnp.zeros((1, d), out_e.dtype)], axis=0,
    )

    # combine back to token order with gate weights, per choice column
    # (dropped/over-capacity pairs hit the zero overflow row)
    out = jnp.zeros((t, d), jnp.float32)
    for j in range(k):
        g = jnp.where(keep[:, j], gates[:, j], 0.0)
        out = out + flat_out[slot[:, j]].astype(jnp.float32) * g[:, None]
    return out.astype(tokens.dtype)


def moe_apply(
    params: Dict,
    x: jax.Array,                  # (B, S, D)
    cfg: ModelConfig,
    mesh_axis: str = "model",
) -> jax.Array:
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    tokens = x.reshape(b * s, d)

    logits = jnp.einsum(
        "td,de->te", tokens.astype(jnp.float32), params["router"]
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)               # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    cf = cfg.moe_capacity_factor

    axes = ()
    mesh = None
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and not mesh.empty:
            axes = tuple(mesh.axis_names)
    except Exception:
        pass

    if mesh_axis in axes and e % mesh.shape[mesh_axis] == 0:
        n_shards = mesh.shape[mesh_axis]
        e_local = e // n_shards
        batch_axes = tuple(a for a in BATCH_AXES if a in axes)

        def shard_fn(tok, g, i, wg, wu, wd):
            rank = jax.lax.axis_index(mesh_axis)
            out = _expert_compute(
                tok, g, i, wg, wu, wd, cf, e, rank * e_local, e_local
            )
            return jax.lax.psum(out, mesh_axis)

        out = jax.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(
                P(batch_axes, None),           # tokens batch-sharded,
                P(batch_axes, None),           # replicated over 'model'
                P(batch_axes, None),
                P(mesh_axis, None, None),      # experts sharded (EP)
                P(mesh_axis, None, None),
                P(mesh_axis, None, None),
            ),
            out_specs=P(batch_axes, None),
            check_vma=False,
        )(tokens, gates, ids, params["wg"], params["wu"], params["wd"])
    else:
        out = _expert_compute(
            tokens, gates, ids, params["wg"], params["wu"], params["wd"],
            cf, e, 0, e,
        )

    out = out.reshape(b, s, d)
    if "shared" in params:
        sh = params["shared"]
        h = jnp.einsum("bsd,df->bsf", x, sh["wg"])
        u = jnp.einsum("bsd,df->bsf", x, sh["wu"])
        out = out + jnp.einsum("bsf,fd->bsd", jax.nn.silu(h) * u, sh["wd"])
    return ashard(out, BATCH_AXES, None, None)
