"""Shared NN substrate: norms, RoPE, chunked (flash-style) attention,
gated MLP, chunked cross-entropy, sharding helpers.

Everything is functional JAX over nested-dict parameter pytrees.
Activation sharding uses bare ``PartitionSpec`` constraints that are
no-ops outside a mesh context, so the same model code runs on a single
CPU device (tests) and on the 512-device production mesh (dry-run).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "ashard",
    "BATCH_AXES",
    "dense_init",
    "rms_norm",
    "rope",
    "chunked_attention",
    "gated_mlp_init",
    "gated_mlp",
    "chunked_xent",
    "NEG_INF",
]

NEG_INF = -1e30
BATCH_AXES = ("pod", "data")


def _mesh_axes() -> Tuple[str, ...]:
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return ()
        return tuple(mesh.axis_names)
    except Exception:  # pragma: no cover - very old jax
        return ()


def ashard(x: jax.Array, *spec) -> jax.Array:
    """Constrain activation sharding; silently drops axes the current
    mesh does not have (single-device tests see a no-op)."""
    axes = _mesh_axes()
    if not axes:
        return x
    cleaned = []
    for entry in spec:
        if entry is None:
            cleaned.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in axes)
            cleaned.append(kept if kept else None)
        else:
            cleaned.append(entry if entry in axes else None)
    return jax.lax.with_sharding_constraint(x, P(*cleaned))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def dense_init(key, shape: Sequence[int], dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) > 1 else 1
    s = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, tuple(shape), jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# norms & rope
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta) -> jax.Array:
    """Rotary embedding.  x: (..., L, D) with D even; positions: (L,).
    ``theta`` may be a traced scalar (gemma3 mixes rope bases per layer
    inside one scan)."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(
        -jnp.log(jnp.asarray(theta, jnp.float32))
        * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions.astype(jnp.float32)[:, None] * freq    # (L, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)                  # broadcast over lead dims
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def _apply_softcap(s: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return cap * jnp.tanh(s / cap)
    return s


def chunked_attention(
    q: jax.Array,            # (B, Hq, Lq, D)
    k: jax.Array,            # (B, Hkv, Lk, D)
    v: jax.Array,            # (B, Hkv, Lk, D)
    *,
    causal: bool = True,
    window,                  # int or traced scalar; <=0 means global
    softcap: float = 0.0,
    q_offset=0,              # absolute position of q[..., 0, :]
    kv_offset=0,             # absolute position of k[..., 0, :]
    kv_valid_len=None,       # #valid kv entries (decode caches are padded)
    kv_positions=None,       # (Lk,) absolute positions (ring caches)
    block: int = 1024,       # §Perf iteration 5: fewer kv iterations halve
                             # the scan-carry (q/acc) HBM re-reads
    scale: Optional[float] = None,
) -> jax.Array:
    """Online-softmax attention over KV blocks (the flash-attention
    algorithm in pure jnp): O(Lq * D) live memory instead of O(Lq * Lk)
    logits, with a custom VJP that *recomputes* blockwise in the
    backward pass (a plain ``lax.scan`` saves its carries — the f32
    accumulator per kv block — which blows past HBM at 32k context).

    Supports GQA (Hq a multiple of Hkv), causal masking, sliding windows
    (``window`` may be a traced per-layer scalar so local/global
    alternation rides through one ``lax.scan``), logit soft-capping
    (gemma-2/3), padded decode caches and ring-buffer position maps.
    """
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    g = hq // hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(d)

    block = min(block, lk)
    nb = -(-lk // block)
    pad = nb * block - lk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))

    imax = jnp.iinfo(jnp.int32).max
    qpos = q_offset + jnp.arange(lq, dtype=jnp.int32)
    if kv_positions is not None:
        kvpos = jnp.asarray(kv_positions, jnp.int32)
        kvpos = jnp.where(kvpos < 0, imax, kvpos)
    else:
        valid = lk if kv_valid_len is None else kv_valid_len
        idx = jnp.arange(lk, dtype=jnp.int32)
        kvpos = jnp.where(idx < valid, kv_offset + idx, imax)
    if pad:
        kvpos = jnp.pad(kvpos, (0, pad), constant_values=imax)

    qg = q.reshape(b, hkv, g, lq, d)
    out = _flash_core(
        causal, float(softcap), float(sc), block,
        qg, k, v, qpos, kvpos, jnp.asarray(window, jnp.int32),
    )
    return out.reshape(b, hq, lq, d).astype(q.dtype)


def _mask_for(causal: bool, qpos, kpos, window):
    mask = kpos[None, :] != jnp.iinfo(jnp.int32).max
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    mask = mask & jnp.where(
        window > 0, kpos[None, :] > qpos[:, None] - window, True
    )
    return mask  # (Lq, BK)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _flash_core(causal, softcap, scale, block, qg, k, v, qpos, kvpos, window):
    out, _ = _flash_fwd_impl(causal, softcap, scale, block, qg, k, v, qpos, kvpos, window)
    return out


def _flash_fwd_impl(causal, softcap, scale, block, qg, k, v, qpos, kvpos, window):
    b, hkv, g, lq, d = qg.shape
    lkp = k.shape[2]
    nb = lkp // block
    pb = kvpos.reshape(nb, block)

    def step(carry, bi):
        # dynamic_slice instead of a pre-transposed block stack: the
        # (B, Hkv, Lk, D) cache is read in place, never copied
        # (§Perf iteration 4 — halves decode bytes accessed)
        m, l, acc = carry
        kblk = jax.lax.dynamic_slice_in_dim(k, bi * block, block, axis=2)
        vblk = jax.lax.dynamic_slice_in_dim(v, bi * block, block, axis=2)
        kpos = jax.lax.dynamic_index_in_dim(pb, bi, keepdims=False)
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qg, kblk,
            preferred_element_type=jnp.float32,
        ) * scale
        s = _apply_softcap(s, softcap)
        mask = _mask_for(causal, qpos, kpos, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, lq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, lq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, lq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nb))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out, lse


def _flash_fwd(causal, softcap, scale, block, qg, k, v, qpos, kvpos, window):
    out, lse = _flash_fwd_impl(
        causal, softcap, scale, block, qg, k, v, qpos, kvpos, window
    )
    return out, (qg, k, v, qpos, kvpos, window, out, lse)


def _flash_bwd(causal, softcap, scale, block, res, dout):
    qg, k, v, qpos, kvpos, window, out, lse = res
    b, hkv, g, lq, d = qg.shape
    lkp = k.shape[2]
    nb = lkp // block
    pb = kvpos.reshape(nb, block)
    dout = dout.astype(jnp.float32)
    delta = jnp.sum(dout * out, axis=-1)                  # (B,Hkv,G,Lq)

    def step(dq, bi):
        kblk = jax.lax.dynamic_slice_in_dim(k, bi * block, block, axis=2)
        vblk = jax.lax.dynamic_slice_in_dim(v, bi * block, block, axis=2)
        kpos = jax.lax.dynamic_index_in_dim(pb, bi, keepdims=False)
        raw = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qg, kblk,
            preferred_element_type=jnp.float32,
        ) * scale
        if softcap > 0:
            t = jnp.tanh(raw / softcap)
            s = softcap * t
            dcap = 1.0 - t * t
        else:
            s = raw
            dcap = None
        mask = _mask_for(causal, qpos, kpos, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                   # (B,Hkv,G,Lq,BK)
        dv_j = jnp.einsum("bhgqk,bhgqd->bhkd", p, dout)
        dp = jnp.einsum(
            "bhgqd,bhkd->bhgqk", dout, vblk.astype(jnp.float32)
        )
        ds = p * (dp - delta[..., None])
        if dcap is not None:
            ds = ds * dcap
        ds = ds * scale
        dq = dq + jnp.einsum(
            "bhgqk,bhkd->bhgqd", ds, kblk.astype(jnp.float32)
        )
        dk_j = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qg.astype(jnp.float32))
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((b, hkv, g, lq, d), jnp.float32)
    dq, (dkb, dvb) = jax.lax.scan(step, dq0, jnp.arange(nb))
    dk = jnp.moveaxis(dkb, 0, 2).reshape(b, hkv, lkp, d)
    dv = jnp.moveaxis(dvb, 0, 2).reshape(b, hkv, lkp, d)

    import numpy as np

    f0 = jax.dtypes.float0
    return (
        dq.astype(qg.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
        np.zeros(qpos.shape, f0), np.zeros(kvpos.shape, f0),
        np.zeros(window.shape, f0),
    )


_flash_core.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def gated_mlp_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": dense_init(k1, (d_model, d_ff), dtype),
        "wu": dense_init(k2, (d_model, d_ff), dtype),
        "wd": dense_init(k3, (d_ff, d_model), dtype),
    }


def gated_mlp(params, x: jax.Array, act: str = "silu") -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, params["wg"])
    u = jnp.einsum("...d,df->...f", x, params["wu"])
    h = ashard(h, BATCH_AXES, None, "model")
    a = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    out = jnp.einsum("...f,fd->...d", a * u, params["wd"])
    return ashard(out, BATCH_AXES, None, None)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def chunked_xent(
    x: jax.Array,              # (B, S, D) final hidden states
    emb: jax.Array,            # (V, D) output embedding
    labels: jax.Array,         # (B, S) int32
    *,
    softcap: float = 0.0,
    chunk: int = 512,
) -> jax.Array:
    """Cross-entropy computed over sequence chunks so the (B, S, V)
    logits tensor never materialises (V up to 262k here)."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    nb = -(-s // chunk)
    pad = nb * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xb = jnp.moveaxis(x.reshape(b, nb, chunk, d), 1, 0)
    lb = jnp.moveaxis(labels.reshape(b, nb, chunk), 1, 0)

    # checkpointed: the (B, chunk, V) logits block is recomputed in the
    # backward pass instead of being saved once per chunk (V is 262k
    # for gemma3 — saving them is tens of GB per device)
    @jax.checkpoint
    def step(carry, xs):
        tot, cnt = carry
        xc, lc = xs
        logits = jnp.einsum(
            "bsd,vd->bsv", xc, emb, preferred_element_type=jnp.float32
        )
        # keep the vocab dim sharded: a (B, chunk, 262k) f32 block is
        # 8.6 GB/device unsharded
        logits = ashard(logits, BATCH_AXES, None, "model")
        logits = _apply_softcap(logits, softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction, NOT take_along_axis: a gather over the
        # sharded vocab axis makes GSPMD all-gather the full logits
        v = logits.shape[-1]
        onehot = jax.nn.one_hot(jnp.maximum(lc, 0), v, dtype=logits.dtype)
        gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
        valid = (lc >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - gold) * valid)
        cnt = cnt + jnp.sum(valid)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(step, (0.0, 0.0), (xb, lb))
    return tot / jnp.maximum(cnt, 1.0)
