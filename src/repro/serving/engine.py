"""Continuous-batching serving engine for one model.

Fixed-slot batching (vLLM-style static slots): a (B, max_len) KV cache
is allocated once; requests claim slots, prefill writes their prompt
into the slot's cache rows, and one fused decode step advances every
active slot per iteration.  Slot-level bookkeeping is host-side; the
device work is two jit'd callables (prefill one request into a slot,
decode the whole batch).

Per-slot cache positions: the decode step takes a (B,) position vector
and a (B,) active mask so ragged requests coexist in one batch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..models import LM
from ..models.config import ModelConfig
from .request import Request, RequestState

__all__ = ["EngineConfig", "ServingEngine"]


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_len: int = 512
    greedy: bool = True


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        self.cfg = cfg
        self.ecfg = ecfg
        self.model = LM(cfg)
        self.params = params
        self.cache = self.model.init_cache(ecfg.max_batch, ecfg.max_len)
        self.free_slots = list(range(ecfg.max_batch))
        self.active: Dict[int, Request] = {}
        self.queue: List[Request] = []
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        model, B = self.model, self.ecfg.max_batch

        def prefill_slot(params, cache, tokens, slot):
            """Prefill one request (batch-1) and scatter its KV rows into
            batch slot ``slot``."""
            small = model.init_cache(1, self.ecfg.max_len)
            logits, small = model.prefill(params, {"tokens": tokens}, small)
            def put(big, new):
                if big.ndim == new.ndim and big.shape[1] == B:
                    return jax.lax.dynamic_update_slice_in_dim(
                        big, new.astype(big.dtype), slot, axis=1
                    )
                return big
            cache = jax.tree.map(put, cache, small)
            return logits, cache

        def decode(params, cache, tokens, positions, active):
            """One token for every active slot.  The decode step is
            position-uniform, so it runs at the max active position;
            ragged slots stay correct because each slot's earlier cache
            rows were written at its own positions and causal masking
            ignores the (zero) rows beyond a slot's own length."""
            pos = jnp.max(jnp.where(active, positions, 0))
            logits, cache = model.decode_step(params, {"tokens": tokens}, cache, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, cache

        self._prefill = jax.jit(prefill_slot)
        self._decode = jax.jit(decode)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and self.free_slots:
            req = self.queue.pop(0)
            slot = self.free_slots.pop(0)
            req.slot = slot
            req.state = RequestState.PREFILLING
            tokens = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, self.cache = self._prefill(
                self.params, self.cache, tokens, slot
            )
            tok = int(jnp.argmax(logits[0, -1] if logits.ndim == 3 else logits[0]))
            req.generated.append(tok)
            req.pos = len(req.prompt)
            req.first_token_s = time.time()
            req.state = RequestState.DECODING
            self.active[slot] = req

    def step(self) -> int:
        """One engine iteration; returns #completed requests."""
        self._admit()
        if not self.active:
            return 0
        B = self.ecfg.max_batch
        toks = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        act = np.zeros((B,), bool)
        for slot, req in self.active.items():
            toks[slot, 0] = req.generated[-1]
            pos[slot] = req.pos
            act[slot] = True
        nxt, self.cache = self._decode(
            self.params, self.cache,
            jnp.asarray(toks), jnp.asarray(pos), jnp.asarray(act),
        )
        nxt = np.asarray(nxt)
        done = 0
        for slot, req in list(self.active.items()):
            req.generated.append(int(nxt[slot]))
            req.pos += 1
            if req.done or req.pos >= self.ecfg.max_len - 1:
                req.state = RequestState.DONE
                req.finish_s = time.time()
                del self.active[slot]
                self.free_slots.append(slot)
                done += 1
        return done

    def run_until_drained(self, max_iters: int = 10000) -> None:
        it = 0
        while (self.queue or self.active) and it < max_iters:
            self.step()
            it += 1
