"""Inference request model."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np

__all__ = ["Request", "RequestState"]


class RequestState(enum.Enum):
    QUEUED = 0
    PREFILLING = 1
    DECODING = 2
    DONE = 3
    DROPPED = 4


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32 token ids
    max_new_tokens: int = 32
    arrival_s: float = 0.0
    deadline_s: Optional[float] = None  # absolute; None = best effort
    state: RequestState = RequestState.QUEUED
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1                      # batch slot while active
    pos: int = 0                        # next cache position
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens
