"""ADS-Tile colocation layer for serving (the TPU adaptation of §IV).

Several models ("tasks") share one accelerator pool.  Jobs (inference
requests, possibly chained model->model like the ADS DAG) are admitted
and prioritised by the same mechanisms as the Tile-stream runtime:

* **elastic reservation** — per-model ERT/sub-deadline from a GHA-style
  offline pass over measured latency profiles; quota control picks the
  cheapest *compiled variant* (the serving analogue of a DoP candidate:
  each model is AOT-compiled at several batch/parallelism variants,
  §IV-D2's ``c_v^compiled``) that meets the job's target;
* **configurable isolation** — models are grouped into partitions; a
  job only ever executes on its partition's executor, so one model's
  burst cannot stall the whole pool;
* **DAG slack sharing** — job targets extend to
  ``e2e_deadline - downstream_budget`` when upstream ran late.

On this CPU container the pool is a single device, so "variants" differ
in batch size rather than chip count — the scheduler logic is identical
and is exactly what ``examples/serve_colocated.py`` demonstrates.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["ServedModel", "ColocatedServer", "ServeJob"]


@dataclasses.dataclass
class ServedModel:
    name: str
    #: variant name -> (callable(batch_of_prompts) -> outputs, est_latency_s)
    variants: Dict[str, Tuple[Callable, float]]
    partition: int = 0
    budget_s: float = 0.1             # l_v from the offline pass
    ert_offset_s: float = 0.0         # t_v
    downstream_budget_s: float = 0.0  # for slack sharing

    def cheapest_variant_meeting(self, slack_s: float) -> str:
        """FitQuota over compiled variants: slowest (cheapest) variant
        whose estimated latency fits the slack; fastest otherwise."""
        ordered = sorted(self.variants.items(), key=lambda kv: -kv[1][1])
        for name, (_, lat) in ordered:
            if lat <= slack_s:
                return name
        return ordered[-1][0]


@dataclasses.dataclass(order=True)
class ServeJob:
    sub_deadline_s: float
    seq: int = dataclasses.field(compare=True)
    model: str = dataclasses.field(compare=False, default="")
    payload: object = dataclasses.field(compare=False, default=None)
    arrival_s: float = dataclasses.field(compare=False, default=0.0)
    e2e_deadline_s: float = dataclasses.field(compare=False, default=np.inf)
    ert_s: float = dataclasses.field(compare=False, default=0.0)
    done_cb: Optional[Callable] = dataclasses.field(compare=False, default=None)


class ColocatedServer:
    """Partitioned EDF executor with ERT admission and variant quotas."""

    def __init__(self, models: Dict[str, ServedModel], num_partitions: int = 1):
        self.models = models
        self.parts: Dict[int, List[ServeJob]] = {}
        for m in models.values():
            self.parts.setdefault(m.partition, [])
        self._seq = 0
        self.log: List[Dict] = []

    # ------------------------------------------------------------------
    def submit(self, model: str, payload, deadline_s: Optional[float] = None,
               done_cb: Optional[Callable] = None) -> None:
        m = self.models[model]
        now = time.time()
        self._seq += 1
        e2e = now + deadline_s if deadline_s is not None else np.inf
        job = ServeJob(
            sub_deadline_s=now + m.ert_offset_s + m.budget_s,
            seq=self._seq,
            model=model,
            payload=payload,
            arrival_s=now,
            e2e_deadline_s=e2e,
            ert_s=now + m.ert_offset_s,
            done_cb=done_cb,
        )
        heapq.heappush(self.parts[m.partition], job)

    # ------------------------------------------------------------------
    def _target(self, job: ServeJob) -> float:
        m = self.models[job.model]
        # soft sub-deadline with slack sharing (§IV-C ③)
        return max(job.sub_deadline_s,
                   job.e2e_deadline_s - m.downstream_budget_s)

    def step_partition(self, part: int) -> Optional[Dict]:
        """Run the most urgent admitted job of one partition."""
        q = self.parts.get(part, [])
        now = time.time()
        admitted = [j for j in q if j.ert_s <= now]
        if not admitted:
            return None
        job = min(admitted, key=lambda j: (j.sub_deadline_s, j.seq))
        q.remove(job)
        heapq.heapify(q)

        m = self.models[job.model]
        if now > job.e2e_deadline_s:   # Getddl dequeue (§IV-C)
            rec = {"model": job.model, "dropped": True, "latency_s": None}
            self.log.append(rec)
            return rec
        slack = self._target(job) - now
        variant = m.cheapest_variant_meeting(slack)
        fn, est = m.variants[variant]
        t0 = time.time()
        out = fn(job.payload)
        dt = time.time() - t0
        rec = {
            "model": job.model,
            "variant": variant,
            "est_s": est,
            "actual_s": dt,
            "latency_s": time.time() - job.arrival_s,
            "missed": time.time() > job.e2e_deadline_s,
            "dropped": False,
        }
        self.log.append(rec)
        if job.done_cb:
            job.done_cb(out)
        return rec

    def run(self, duration_s: float) -> List[Dict]:
        end = time.time() + duration_s
        while time.time() < end:
            ran = False
            for part in self.parts:
                if self.step_partition(part) is not None:
                    ran = True
            if not ran:
                if all(not q for q in self.parts.values()):
                    break
                time.sleep(0.001)
        return self.log
