"""Serving substrate: continuous-batching engine per model plus the
ADS-Tile colocation layer that schedules several models on one
accelerator pool under E2E deadlines."""
from .request import Request, RequestState
from .engine import ServingEngine, EngineConfig
from .colocated import ColocatedServer, ServedModel

__all__ = [
    "Request",
    "RequestState",
    "ServingEngine",
    "EngineConfig",
    "ColocatedServer",
    "ServedModel",
]
