"""HLO-text collective accounting.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled (or lowered) HLO text: build a symbol table of instruction
result shapes per computation, then sum *operand* sizes of every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` op.

Loop weighting: collectives inside a ``while`` body execute once per
trip, so each computation carries a multiplier derived from its
enclosing while's trip count (scan over L layers -> x L).  Without this
the collective roofline term is underestimated by the layer count.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional

__all__ = ["parse_hlo_collectives", "collective_bytes"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_COMP_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*(?:->[^{]*)?\{\s*$"
)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s/#*]+?)\s+([\w\-]+)\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_ATTR_COMP_RE = re.compile(r"(to_apply|body|condition|calls)=%?([\w.\-]+)")
_CONST_INT_RE = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


class _Module:
    def __init__(self, text: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        cur = None
        for line in text.splitlines():
            m = _COMP_RE.match(line)
            # a header is NOT an instruction ("%x = type op(...)"); the
            # param list may contain '=' inside /*index=N*/ comments
            if m and not _INSTR_RE.match(line):
                cur = m.group(1)
                self.comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is not None:
                self.comps[cur].append(line)
        if self.entry is None and self.comps:
            self.entry = next(iter(self.comps))

        # result-shape symbol table per computation (names are unique
        # module-wide in practice; keep a global table)
        self.shapes: Dict[str, str] = {}
        for lines in self.comps.values():
            for line in lines:
                im = _INSTR_RE.match(line)
                if im:
                    self.shapes[im.group(1)] = im.group(2)

    def trip_count(self, cond_comp: str) -> int:
        """Heuristic: the largest integer constant in the while condition
        computation (scan bounds lower to `compare(i, L)`)."""
        best = 1
        for line in self.comps.get(cond_comp, []):
            for c in _CONST_INT_RE.finditer(line):
                best = max(best, int(c.group(1)))
        return best

    def multipliers(self) -> Dict[str, float]:
        """Effective execution multiplier per computation."""
        mult: Dict[str, float] = {c: 0.0 for c in self.comps}

        def visit(comp: str, factor: float) -> None:
            if comp not in self.comps:
                return
            if mult[comp] >= factor:  # already visited at >= weight
                return
            mult[comp] = factor
            for line in self.comps[comp]:
                im = _INSTR_RE.match(line)
                if not im:
                    continue
                op = im.group(3)
                refs = dict(
                    (k, v) for k, v in _ATTR_COMP_RE.findall(line)
                )
                if op == "while" and "body" in refs:
                    trips = self.trip_count(refs.get("condition", ""))
                    visit(refs["body"], factor * trips)
                    if "condition" in refs:
                        visit(refs["condition"], factor * trips)
                else:
                    for k, v in refs.items():
                        visit(v, factor)
                # conditional branches
                bm = re.search(r"branch_computations=\{([^}]*)\}", line)
                if bm:
                    for b in bm.group(1).split(","):
                        visit(b.strip().lstrip("%"), factor)

        if self.entry:
            visit(self.entry, 1.0)
        return mult


def parse_hlo_collectives(hlo_text: str) -> List[Dict]:
    """Per-collective records: op kind, operand bytes, result bytes,
    instruction name, loop-weighted execution count."""
    mod = _Module(hlo_text)
    mult = mod.multipliers()

    out: List[Dict] = []
    for comp, lines in mod.comps.items():
        weight = mult.get(comp, 1.0) or 1.0
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, result_shape, op = m.group(1), m.group(2), m.group(3)
            kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            if kind is None or op.endswith("-done"):
                continue  # -start/-done pairs: count the -start only
            try:
                args = line[line.index("(") + 1:]
            except ValueError:
                continue
            depth = 1
            body = []
            for ch in args:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                body.append(ch)
            body = "".join(body)
            op_bytes = 0
            for om in _OPERAND_RE.finditer(body):
                ref = om.group(1)
                if ref in mod.shapes:
                    op_bytes += _shape_bytes(mod.shapes[ref])
            out.append(
                {
                    "name": name,
                    "kind": kind,
                    "operand_bytes": op_bytes * weight,
                    "result_bytes": _shape_bytes(result_shape) * weight,
                    "static_operand_bytes": op_bytes,
                    "weight": weight,
                }
            )
    return out


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Aggregate loop-weighted operand bytes per collective kind."""
    recs = parse_hlo_collectives(hlo_text)
    agg: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    for r in recs:
        agg[r["kind"]] += r["operand_bytes"]
    agg["total"] = sum(agg[c] for c in _COLLECTIVES)
    agg["count"] = float(len(recs))
    return agg
