"""Compiled-artifact analysis: HLO collective-byte accounting and the
three-term roofline model."""
from .hlo import collective_bytes, parse_hlo_collectives
from .roofline import RooflineTerms, roofline_from_compiled, HW

__all__ = [
    "collective_bytes",
    "parse_hlo_collectives",
    "RooflineTerms",
    "roofline_from_compiled",
    "HW",
]
