"""Three-term roofline model from a compiled dry-run artifact.

TPU v5e target constants (per chip): 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI.

    compute    = HLO_FLOPs        / (chips * PEAK_FLOPS)
    memory     = HLO_bytes        / (chips * HBM_BW)
    collective = collective_bytes / (chips * ICI_BW)

``cost_analysis()`` on CPU reports *per-device* flops/bytes, so the
global figures are ``per_device * chips``; the two chip factors cancel
and the terms are per-device time estimates directly.  MODEL_FLOPS uses
the 6*N*D (train) / 2*N*D (inference forward) convention with N_active
for MoE.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from .hlo import collective_bytes

__all__ = ["HW", "RooflineTerms", "roofline_from_compiled"]


@dataclasses.dataclass(frozen=True)
class HWConstants:
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link


HW = HWConstants()


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw per-device quantities from the compiled module
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, float]
    # the three terms (seconds)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    # usefulness
    model_flops_global: float = 0.0
    tokens: int = 0
    raw_cost_analysis: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.compute_s = self.flops_per_device / HW.peak_flops
        self.memory_s = self.bytes_per_device / HW.hbm_bw
        self.collective_s = self.collective_bytes_per_device / HW.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global) — remat/redundancy waste."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chips' peak the *useful* model FLOPs achieve
        if execution takes exactly the dominant term."""
        if self.bound_s <= 0:
            return 0.0
        ideal = self.model_flops_global / (self.chips * HW.peak_flops)
        return ideal / self.bound_s

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_breakdown": self.collective_breakdown,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_global": self.model_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "tokens": self.tokens,
            "raw_cost_analysis": self.raw_cost_analysis,
            "bound_s": self.bound_s,
        }


def model_flops(cfg, shape_kind: str, tokens: int) -> float:
    """6*N*D for train (fwd+bwd), 2*N*D per inference forward; N_active
    for MoE."""
    n = cfg.active_param_count() if cfg.num_experts else cfg.param_count()
    per_tok = 6.0 if shape_kind == "train" else 2.0
    return per_tok * n * tokens


def roofline_from_compiled(
    arch: str,
    shape,
    mesh_name: str,
    chips: int,
    compiled,
    cfg,
) -> RooflineTerms:
    from .costs import weighted_costs

    cost = compiled.cost_analysis()
    text = compiled.as_text()
    # cost_analysis counts while bodies ONCE (verified: a 10-trip scanned
    # matmul reports 1 matmul) — use loop-weighted accounting, keep the
    # raw numbers for reference
    wc = weighted_costs(text)
    flops = float(wc["flops"])
    byts = float(wc["hbm_bytes"])
    coll = collective_bytes(text)
    # HLO text is the per-device SPMD module: operand sizes are already
    # per-device shards
    tokens = shape.tokens if shape.kind != "decode" else shape.global_batch
    terms = RooflineTerms(
        arch=arch,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=coll["total"],
        collective_breakdown={
            k: v for k, v in coll.items() if k not in ("total", "count")
        },
        model_flops_global=model_flops(cfg, shape.kind, tokens),
        tokens=tokens,
    )
    terms.raw_cost_analysis = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }
    return terms
