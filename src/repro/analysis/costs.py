"""Loop-weighted HLO cost accounting.

``compiled.cost_analysis()`` counts a ``while`` body **once**, so for
scan-over-layers models it under-reports FLOPs/bytes by ~num_layers
(verified experimentally — a 10-trip scanned matmul reports 1 matmul of
FLOPs).  This module re-derives costs from the optimized HLO text with
per-computation execution multipliers:

* **flops** — 2 * prod(result_dims) * prod(contracting_dims) for every
  ``dot`` (elementwise flops ignored: dots dominate every cell here);
* **hbm_bytes** — operand + result bytes of *fusion-boundary*
  instructions (post-fusion top-level ops are the kernels; their inputs
  and outputs are the HBM traffic), excluding no-data ops
  (tuple/gte/parameter/bitcast/constant).

Both are weighted by while-loop trip counts (see ``hlo._Module``).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Set

from .hlo import (
    _INSTR_RE,
    _Module,
    _OPERAND_RE,
    _shape_bytes,
)

__all__ = ["weighted_costs"]

_NO_DATA = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_SHAPE_ONE_RE = re.compile(r"^\s*(\w+)\[([\d,]*)\]")


def _dims(shape_str: str) -> List[int]:
    m = _SHAPE_ONE_RE.match(shape_str.strip())
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _result_elems(shape_str: str) -> int:
    n = 1
    for d in _dims(shape_str):
        n *= d
    return n


def weighted_costs(hlo_text: str) -> Dict[str, float]:
    mod = _Module(hlo_text)
    mult = mod.multipliers()

    # identify fusion-body computations (internal ops: no HBM traffic)
    fusion_bodies: Set[str] = set()
    for comp, lines in mod.comps.items():
        for line in lines:
            im = _INSTR_RE.match(line)
            if im and im.group(3) == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", line)
                if cm:
                    fusion_bodies.add(cm.group(1))

    flops = 0.0
    hbm = 0.0
    for comp, lines in mod.comps.items():
        w = mult.get(comp, 0.0)
        if w <= 0:
            continue
        internal = comp in fusion_bodies
        for line in lines:
            im = _INSTR_RE.match(line)
            if not im:
                continue
            name, rshape, op = im.group(1), im.group(2), im.group(3)

            if op == "dot":
                # contracting sizes from the lhs operand's shape
                ops = _OPERAND_RE.findall(line[line.index("("):])
                cdim = 1
                dm = _DOT_DIMS_RE.search(line)
                if dm and ops:
                    lhs_shape = _dims(mod.shapes.get(ops[0], ""))
                    for ax in dm.group(1).split(","):
                        if ax and int(ax) < len(lhs_shape):
                            cdim *= lhs_shape[int(ax)]
                flops += 2.0 * _result_elems(rshape) * cdim * w
            elif op == "convolution":
                # rough: 2 * out_elems * (kernel elems) — rare here
                flops += 2.0 * _result_elems(rshape) * w

            if internal or op in _NO_DATA:
                continue
            # fusion-boundary HBM traffic: result + operands, but charge
            # slice-consuming fusion inputs at slice granularity (a fused
            # dynamic-slice reads one block per trip, not the whole
            # array) and DUS-producing fusions at update granularity
            if op == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", line)
                b = _fusion_traffic(mod, cm.group(1) if cm else None, line, rshape)
            else:
                b = _shape_bytes(rshape)
                for ref in _operand_refs(line):
                    if ref in mod.shapes:
                        b += _shape_bytes(mod.shapes[ref])
            hbm += b * w

    return {"flops": flops, "hbm_bytes": hbm}


def _operand_refs(line: str) -> List[str]:
    args = line[line.index("(") + 1:] if "(" in line else ""
    depth = 1
    body = []
    for ch in args:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        body.append(ch)
    return [m.group(1) for m in _OPERAND_RE.finditer("".join(body))]


def _fusion_traffic(mod: _Module, body_comp: Optional[str], line: str,
                    rshape: str) -> float:
    """Input bytes with slice-awareness + output bytes with DUS-awareness."""
    operands = [r for r in _operand_refs(line) if r in mod.shapes]
    if body_comp is None or body_comp not in mod.comps:
        b = _shape_bytes(rshape)
        return b + sum(_shape_bytes(mod.shapes[r]) for r in operands)

    lines = mod.comps[body_comp]
    # map parameter index -> internal name, find slice-only params
    param_names: Dict[int, str] = {}
    slice_size: Dict[str, int] = {}
    root_line = None
    for l in lines:
        im = _INSTR_RE.match(l)
        if not im:
            continue
        if im.group(3) == "parameter":
            pm = re.search(r"parameter\((\d+)\)", l)
            if pm:
                param_names[int(pm.group(1))] = im.group(1)
        if l.lstrip().startswith("ROOT"):
            root_line = l
    # consumers of each param
    for l in lines:
        im = _INSTR_RE.match(l)
        if not im or im.group(3) == "parameter":
            continue
        refs = set(_operand_refs(l))
        for name in param_names.values():
            if name in refs:
                if im.group(3) in ("dynamic-slice", "slice"):
                    slice_size[name] = max(
                        slice_size.get(name, 0), _shape_bytes(im.group(2))
                    )
                else:
                    slice_size[name] = -1  # consumed whole somewhere

    total = 0.0
    for idx, ref in enumerate(operands):
        pname = param_names.get(idx)
        full = _shape_bytes(mod.shapes[ref])
        sz = slice_size.get(pname, -1) if pname else -1
        total += sz if sz and sz > 0 else full

    # output: DUS root writes only the update slice (+ reads nothing new
    # when aliased); otherwise the full result
    if root_line is not None:
        rm = _INSTR_RE.match(root_line)
        if rm and rm.group(3) == "dynamic-update-slice":
            refs = _operand_refs(root_line)
            upd = 0
            if len(refs) >= 2:
                # update operand is the 2nd arg; internal name shape
                shp = None
                for l in lines:
                    im2 = _INSTR_RE.match(l)
                    if im2 and im2.group(1) == refs[1]:
                        shp = im2.group(2)
                        break
                if shp:
                    upd = _shape_bytes(shp)
            total += upd if upd else _shape_bytes(rshape)
            return total
    total += _shape_bytes(rshape)
    return total
