"""Largest-buffer diagnosis from compiled HLO text — the dry-run
'profiler': since there is no wall-clock trace on this container, the
§Perf loop reasons from the lowered IR (see the Pallas-specific hints
in the brief): find the biggest live values, duplicate collectives and
layout-change copies.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

__all__ = ["top_buffers", "collective_census"]

_DB = {
    "pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|[\w\[\],{}\s/#*]+?)\s+([\w\-]+)\("
)
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")


def _nbytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DB:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DB[dt]
    return total


def top_buffers(hlo_text: str, k: int = 20, min_bytes: float = 1e8) -> List[Tuple[float, str, str]]:
    """(bytes, instr_name, op) of the k largest instruction results."""
    out = []
    for line in hlo_text.splitlines():
        m = _INSTR.match(line)
        if not m:
            continue
        b = _nbytes(m.group(2))
        if b >= min_bytes:
            out.append((float(b), m.group(1), m.group(3)))
    out.sort(key=lambda t: -t[0])
    return out[:k]


def collective_census(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: count + total result bytes (spotting
    redundant all-gathers of the same tensor)."""
    census: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _INSTR.match(line)
        if not m:
            continue
        op = m.group(3)
        for kind in ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute"):
            if op.startswith(kind) and not op.endswith("-done"):
                c = census.setdefault(kind, {"count": 0, "bytes": 0.0})
                c["count"] += 1
                c["bytes"] += _nbytes(m.group(2))
    return census
