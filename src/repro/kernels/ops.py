"""Jit'd dispatch wrappers for the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode — the
kernel body executes in Python for correctness validation; on TPU the
same calls compile to Mosaic.  ``use_pallas()`` gates dispatch so the
model zoo can flip between the pure-jnp path (default — it is what the
dry-run lowers) and the kernel path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash_attention
from .moe_gmm import moe_gmm as _moe_gmm
from .rglru import rglru_scan as _rglru_scan
from .ssd import ssd_intra_chunk as _ssd_intra_chunk

__all__ = [
    "on_tpu",
    "flash_attention",
    "ssd_chunked",
    "rglru_scan",
    "moe_gmm",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_k", "interpret"
))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128, interpret=None):
    interp = (not on_tpu()) if interpret is None else interpret
    return _flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interp,
    )


@partial(jax.jit, static_argnames=("chunk", "head_block", "interpret"))
def ssd_chunked(x, dt, A, Bm, Cm, *, chunk=128, head_block=8, interpret=None):
    """Full SSD via the intra-chunk kernel + jnp inter-chunk scan.

    x (B, L, H, P), dt (B, L, H), A (H,), Bm/Cm (B, L, G=1, N).
    Returns (y (B, L, H, P), final_state (B, H, P, N)).
    """
    interp = (not on_tpu()) if interpret is None else interpret
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, l)
    nb = -(-l // chunk)
    pad = nb * chunk - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    xc = x.reshape(b, nb, chunk, h, p)
    dtc = dt.reshape(b, nb, chunk, h)
    Bc = Bm.reshape(b, nb, chunk, -1, n)[:, :, :, 0]     # single group
    Cc = Cm.reshape(b, nb, chunk, -1, n)[:, :, :, 0]

    hb = head_block
    while h % hb:
        hb -= 1
    y_intra, contrib, chunk_decay = _ssd_intra_chunk(
        xc, dtc, A, Bc, Cc, head_block=hb, interpret=interp
    )

    # inter-chunk scan (jnp): carry the state, emit y_inter per chunk
    ack = jnp.cumsum(dtc.astype(jnp.float32) * A, axis=2)     # (B,nb,C,H)

    def step(state, xs):
        dec, con, Ck, ak = xs
        y_inter = jnp.einsum(
            "bcn,bhpn,bch->bchp", Ck.astype(jnp.float32), state, jnp.exp(ak)
        )
        new = state * dec[:, :, None, None] + con
        return new, y_inter

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    final, y_inter = jax.lax.scan(
        step, s0,
        (
            jnp.moveaxis(chunk_decay, 1, 0),
            jnp.moveaxis(contrib, 1, 0),
            jnp.moveaxis(Cc, 1, 0),
            jnp.moveaxis(ack, 1, 0),
        ),
    )
    y = (y_intra + jnp.moveaxis(y_inter, 0, 1)).reshape(b, nb * chunk, h, p)
    if pad:
        y = y[:, :l]
    return y.astype(x.dtype), final.astype(x.dtype)


@partial(jax.jit, static_argnames=("width_block", "interpret"))
def rglru_scan(x, r, i, lam, h0, *, width_block=128, interpret=None):
    interp = (not on_tpu()) if interpret is None else interpret
    wb = min(width_block, x.shape[-1])
    while x.shape[-1] % wb:
        wb -= 1
    return _rglru_scan(x, r, i, lam, h0, width_block=wb, interpret=interp)


@partial(jax.jit, static_argnames=("block_c", "interpret"))
def moe_gmm(x, wg, wu, wd, *, block_c=128, interpret=None):
    interp = (not on_tpu()) if interpret is None else interpret
    return _moe_gmm(x, wg, wu, wd, block_c=block_c, interpret=interp)
