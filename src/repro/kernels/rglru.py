"""Fused RG-LRU Pallas kernel (TPU target).

Fuses the gate nonlinearities and the linear recurrence

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(Lambda) * r_t)

over a (batch, width-block) grid; the sequential L loop runs inside the
kernel (``fori_loop``), so gate tensors never round-trip to HBM between
the elementwise stages — the recurrence is memory-bound and this is
exactly the fusion the VPU wants.  Width blocks are lane-aligned (128).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rglru_scan"]

_C = 8.0


def _kernel(x_ref, r_ref, i_ref, lam_ref, h0_ref, out_ref, hT_ref):
    # blocks: x/r/i (1, L, WB); lam (WB,); h0 (1, WB)
    x = x_ref[0]                            # (L, WB)
    r = r_ref[0]
    gi = i_ref[0]
    lam = jax.nn.softplus(lam_ref[...])     # (WB,)
    length = x.shape[0]

    log_a = -_C * lam[None, :] * jax.nn.sigmoid(r.astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * jax.nn.sigmoid(gi.astype(jnp.float32)) * x.astype(jnp.float32)

    def body(t, h):
        h_new = a[t] * h + b[t]
        out_ref[0, t] = h_new.astype(out_ref.dtype)
        return h_new

    h_fin = jax.lax.fori_loop(0, length, body, h0_ref[0].astype(jnp.float32))
    hT_ref[0] = h_fin.astype(hT_ref.dtype)


def rglru_scan(
    x: jax.Array,        # (B, L, W)  conv'd inputs
    r: jax.Array,        # (B, L, W)  recurrence-gate pre-activations
    i: jax.Array,        # (B, L, W)  input-gate pre-activations
    lam: jax.Array,      # (W,)       Lambda parameters
    h0: jax.Array,       # (B, W)     initial state
    width_block: int = 128,
    interpret: bool = False,
):
    """Returns (h (B, L, W), h_final (B, W))."""
    b, l, w = x.shape
    wb = min(width_block, w)
    assert w % wb == 0
    nw = w // wb

    out, h_fin = pl.pallas_call(
        _kernel,
        grid=(b, nw),
        in_specs=[
            pl.BlockSpec((1, l, wb), lambda i_, j: (i_, 0, j)),
            pl.BlockSpec((1, l, wb), lambda i_, j: (i_, 0, j)),
            pl.BlockSpec((1, l, wb), lambda i_, j: (i_, 0, j)),
            pl.BlockSpec((wb,), lambda i_, j: (j,)),
            pl.BlockSpec((1, wb), lambda i_, j: (i_, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, l, wb), lambda i_, j: (i_, 0, j)),
            pl.BlockSpec((1, wb), lambda i_, j: (i_, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, w), jnp.float32),
            jax.ShapeDtypeStruct((b, w), jnp.float32),
        ],
        interpret=interpret,
    )(x, r, i, lam, h0)
    return out, h_fin
