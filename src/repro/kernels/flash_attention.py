"""GQA flash attention Pallas kernel (TPU target).

Grid = (batch * kv_heads, q_blocks, kv_blocks); the kv dimension is the
innermost ("arbitrary") axis so VMEM scratch (running max / denominator
/ accumulator) carries across kv iterations — the canonical TPU online-
softmax structure.  BlockSpecs tile Q/K/V into VMEM: one (group, BQ, D)
query block and one (BK, D) key/value block live on-chip at a time.

Supports causal masking, sliding windows (gemma-2/3 local layers) and
attention-logit soft-capping.  Block shapes are MXU-aligned
(multiples of 128 on the matmul dims when the problem allows).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref,          # VMEM blocks
    o_ref,                        # output block
    m_scr, l_scr, acc_scr,        # VMEM scratch carried over kv dim
    *,
    scale: float,
    softcap: float,
    causal: bool,
    window: int,
    bq: int,
    bk: int,
    n_kv: int,
    kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                # (G, BQ, D)
    k = k_ref[0]                                # (BK, D)
    v = v_ref[0]

    s = jax.lax.dot_general(
        q, k, (((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                    # (G, BQ, BK)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < kv_len
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None], s, NEG_INF)

    m_prev = m_scr[...]                          # (G, BQ)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                 # (B, Hq, Lq, D)
    k: jax.Array,                 # (B, Hkv, Lk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(d)

    bq = min(block_q, lq)
    bk = min(block_k, lk)
    nq = -(-lq // bq)
    nk = -(-lk // bk)
    if lq % bq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, nq * bq - lq), (0, 0)))
    if lk % bk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, nk * bk - lk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, nk * bk - lk), (0, 0)))

    qg = q.reshape(b * hkv, g, nq * bq, d)
    kg = k.reshape(b * hkv, nk * bk, d)
    vg = v.reshape(b * hkv, nk * bk, d)

    kernel = functools.partial(
        _kernel,
        scale=sc, softcap=softcap, causal=causal, window=window,
        bq=bq, bk=bk, n_kv=nk, kv_len=lk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, g, bq, d), lambda h, i, j: (h, 0, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, bq, d), lambda h, i, j: (h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, nq * bq, d), q.dtype),
        scratch_shapes=[
            _vmem((g, bq), jnp.float32),
            _vmem((g, bq), jnp.float32),
            _vmem((g, bq, d), jnp.float32),
        ],
        compiler_params=_compiler_params(interpret),
        interpret=interpret,
    )(qg, kg, vg)

    out = out.reshape(b, hq, nq * bq, d)
    return out[:, :, :lq]


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _compiler_params(interpret: bool):
    if interpret:
        return None
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary"),
    )
