"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "ssd_intra_chunk_ref", "rglru_scan_ref", "moe_gmm_ref"]

_C = 8.0


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None):
    """Naive full-materialisation GQA attention."""
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    g = hq // hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(d)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q, kk, preferred_element_type=jnp.float32
    ) * sc
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(lq)[:, None]
    kpos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window and window > 0:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv)


def ssd_intra_chunk_ref(x, dt, A, Bm, Cm):
    """x (B,nb,C,H,P), dt (B,nb,C,H), A (H,), Bm/Cm (B,nb,C,N) — single
    group.  Returns (y_intra, contrib, chunk_decay) as f32."""
    b, nb, c, h, p = x.shape
    ack = jnp.cumsum(dt.astype(jnp.float32) * A, axis=2)          # (B,nb,C,H)
    seg = ack[:, :, :, None, :] - ack[:, :, None, :, :]           # (B,nb,C,C,H)
    causal = jnp.tril(jnp.ones((c, c), bool))
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)
    cb = jnp.einsum(
        "bncn2,bnsn2->bncs", Cm.astype(jnp.float32), Bm.astype(jnp.float32)
    )
    w = cb[..., None] * decay * dt[:, :, None, :, :]
    y = jnp.einsum("bncsh,bnshp->bnchp", w, x.astype(jnp.float32))
    d2e = jnp.exp(ack[:, :, -1:, :] - ack)
    contrib = jnp.einsum(
        "bnch,bncn2,bnchp->bnhpn2",
        dt * d2e, Bm.astype(jnp.float32), x.astype(jnp.float32),
    )
    chunk_decay = jnp.exp(ack[:, :, -1, :])
    return y, contrib, chunk_decay


def rglru_scan_ref(x, r, i, lam, h0):
    """Sequential-reference RG-LRU: x/r/i (B,L,W), lam (W,), h0 (B,W)."""
    log_a = -_C * jax.nn.softplus(lam)[None, None, :] * jax.nn.sigmoid(
        r.astype(jnp.float32)
    )
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bterm = beta * jax.nn.sigmoid(i.astype(jnp.float32)) * x.astype(jnp.float32)

    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h

    hT, hs = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(a, 1, 0), jnp.moveaxis(bterm, 1, 0)),
    )
    return jnp.moveaxis(hs, 0, 1), hT


def moe_gmm_ref(x, wg, wu, wd):
    h = jnp.einsum("ecd,edf->ecf", x, wg, preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", x, wu, preferred_element_type=jnp.float32)
    a = jax.nn.silu(h) * u
    return jnp.einsum(
        "ecf,efd->ecd", a.astype(wd.dtype), wd,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
