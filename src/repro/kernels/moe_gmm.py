"""MoE grouped matmul (GMM) Pallas kernel (TPU target).

Batched expert FFN over capacity buckets::

    out[e] = (silu(x[e] @ wg[e]) * (x[e] @ wu[e])) @ wd[e]

Grid = (experts, capacity-blocks); per grid cell one (BC, D) token block
and the expert's (D, F)/(F, D) weight tiles stream through VMEM, and
the whole gate-up-down chain is fused so the (BC, F) hidden block never
leaves the chip.  MXU alignment: BC and F blocks are multiples of 128
where the problem allows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["moe_gmm"]


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    x = x_ref[0]          # (BC, D)
    wg = wg_ref[0]        # (D, F)
    wu = wu_ref[0]
    wd = wd_ref[0]        # (F, D)
    h = jax.lax.dot(x, wg, preferred_element_type=jnp.float32)
    u = jax.lax.dot(x, wu, preferred_element_type=jnp.float32)
    a = jax.nn.silu(h) * u
    o_ref[0] = jax.lax.dot(
        a.astype(wd.dtype), wd, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def moe_gmm(
    x: jax.Array,       # (E, C, D) bucketed tokens
    wg: jax.Array,      # (E, D, F)
    wu: jax.Array,      # (E, D, F)
    wd: jax.Array,      # (E, F, D)
    block_c: int = 128,
    interpret: bool = False,
) -> jax.Array:
    e, c, d = x.shape
    f = wg.shape[-1]
    bc = min(block_c, c)
    nc = -(-c // bc)
    pad = nc * bc - c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))

    out = pl.pallas_call(
        _kernel,
        grid=(e, nc),
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, d, f), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, d, f), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, f, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((e, nc * bc, d), x.dtype),
        interpret=interpret,
    )(x, wg, wu, wd)
    return out[:, :c]
