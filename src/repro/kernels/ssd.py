"""Mamba-2 SSD intra-chunk Pallas kernel (TPU target).

The SSD chunked algorithm splits into (a) an embarrassingly-parallel
intra-chunk quadratic part + per-chunk state summaries, and (b) a cheap
O(L/chunk) inter-chunk scan.  This kernel computes (a): for one
(batch, chunk, head-block) grid cell it produces

    y_intra[c]   = sum_{s<=c} C_c.B_s exp(acum_c - acum_s) dt_s x_s
    contrib      = sum_s exp(acum_C - acum_s) dt_s B_s x_s^T   (state summary)
    chunk_decay  = exp(acum_C)

VMEM tiling: one (CHUNK, P) x-block, (CHUNK, N) B/C blocks and the
(CHUNK, CHUNK) decay matrix per head live on-chip; matmul dims are
MXU-aligned for chunk sizes that are multiples of 128.  The wrapper in
``ops.py`` runs the inter-chunk scan in jnp.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_intra_chunk"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
            y_ref, contrib_ref, decay_ref):
    # blocks: x (1,1,C,HB,P) dt (1,1,C,HB) a (HB,) b/c (1,1,C,N)
    x = x_ref[0, 0]                          # (C, HB, P)
    dt = dt_ref[0, 0].astype(jnp.float32)    # (C, HB)
    A = a_ref[...]                           # (HB,)
    Bm = b_ref[0, 0].astype(jnp.float32)     # (C, N)
    Cm = c_ref[0, 0].astype(jnp.float32)     # (C, N)
    chunk = x.shape[0]

    ack = jnp.cumsum(dt * A[None, :], axis=0)           # (C, HB)
    seg = ack[:, None, :] - ack[None, :, :]             # (C, C, HB)
    t = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_ = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where((s_ <= t)[..., None], seg, -jnp.inf)
    decay = jnp.exp(seg)                                # (C, C, HB)

    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                   # (C, C)
    w = cb[..., None] * decay * dt[None, :, :]          # (C, C, HB)
    # y[c, h, p] = sum_s w[c, s, h] * x[s, h, p]
    y = jnp.einsum(
        "csh,shp->chp", w, x.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    y_ref[0, 0] = y.astype(y_ref.dtype)

    d2e = jnp.exp(ack[-1:, :] - ack)                    # (C, HB)
    contrib = jnp.einsum(
        "ch,cn,chp->hpn", dt * d2e, Bm, x.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    contrib_ref[0, 0] = contrib.astype(contrib_ref.dtype)
    decay_ref[0, 0] = jnp.exp(ack[-1, :]).astype(decay_ref.dtype)


def ssd_intra_chunk(
    x: jax.Array,     # (B, nb, C, H, P)
    dt: jax.Array,    # (B, nb, C, H)  softplus'd
    A: jax.Array,     # (H,)
    Bm: jax.Array,    # (B, nb, C, N)  (single B/C group)
    Cm: jax.Array,    # (B, nb, C, N)
    head_block: int = 8,
    interpret: bool = False,
):
    """Returns (y_intra (B,nb,C,H,P), contrib (B,nb,H,P,N),
    chunk_decay (B,nb,H))."""
    b, nb, c, h, p = x.shape
    n = Bm.shape[-1]
    hb = min(head_block, h)
    assert h % hb == 0
    nh = h // hb

    grid = (b, nb, nh)
    y, contrib, decay = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, c, hb, p), lambda i, j, k: (i, j, 0, k, 0)),
            pl.BlockSpec((1, 1, c, hb), lambda i, j, k: (i, j, 0, k)),
            pl.BlockSpec((hb,), lambda i, j, k: (k,)),
            pl.BlockSpec((1, 1, c, n), lambda i, j, k: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, c, n), lambda i, j, k: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, hb, p), lambda i, j, k: (i, j, 0, k, 0)),
            pl.BlockSpec((1, 1, hb, p, n), lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, hb), lambda i, j, k: (i, j, k)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nb, c, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nb, h, p, n), jnp.float32),
            jax.ShapeDtypeStruct((b, nb, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, contrib, decay
