"""Pallas TPU kernels for the zoo's compute hot spots.

Each kernel module provides ``pl.pallas_call`` + explicit BlockSpec VMEM
tiling; ``ops.py`` holds the jit'd dispatch wrappers (interpret mode on
CPU, compiled on TPU) and ``ref.py`` the pure-jnp oracles used by the
allclose sweeps in ``tests/test_kernels.py``.

Kernels:
* ``flash_attention`` — GQA flash attention with causal + sliding-window
  masking and logit softcap (gemma-2/3), online softmax, (heads, q-block)
  parallel grid with an arbitrary kv-block dim carrying VMEM scratch.
* ``ssd`` — Mamba-2 SSD intra-chunk kernel (decay-weighted quadratic +
  chunk state summaries); the O(L) inter-chunk scan stays in jnp.
* ``rglru`` — fused RG-LRU gates + linear recurrence.
* ``moe_gmm`` — grouped expert matmul (E, C, D) x (E, D, F).
"""
from . import ops, ref

__all__ = ["ops", "ref"]
