"""Elastic scaling & straggler mitigation.

At 1000+ node scale, device sets change (preemptions, failures) and
stragglers appear.  This module provides the control-plane pieces:

* :class:`ElasticMesh` — rebuilds a mesh from the currently-healthy
  device set (largest (data, model) grid that preserves the model-
  parallel width), and re-lowers the step function for it.  Combined
  with :class:`~repro.training.checkpoint.CheckpointManager` this gives
  shrink-and-continue semantics: on failure, restore the last
  checkpoint host-side and re-shard onto the surviving mesh — exactly
  the paper's stop-migrate-restart reallocation, at pod scale, with the
  cost model of ``HardwareModel.realloc_latency``.
* :class:`StragglerMonitor` — per-step wall-time EWMA + deviation
  tracking; flags steps (and, with per-host timings, hosts) that exceed
  ``k`` deviations, the trigger real deployments use to evict or
  re-mesh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import jax

__all__ = ["ElasticMesh", "StragglerMonitor"]


class ElasticMesh:
    def __init__(self, model_parallel: int = 1):
        self.model_parallel = model_parallel

    def mesh_for(self, devices: Optional[Sequence] = None):
        devs = list(devices if devices is not None else jax.devices())
        mp = self.model_parallel
        usable = (len(devs) // mp) * mp
        if usable == 0:
            raise RuntimeError(
                f"not enough devices ({len(devs)}) for model_parallel={mp}"
            )
        import numpy as np

        grid = np.asarray(devs[:usable]).reshape(usable // mp, mp)
        return jax.sharding.Mesh(grid, ("data", "model"))

    def shrink(self, mesh, failed: Sequence) -> "jax.sharding.Mesh":
        """New mesh excluding failed devices (whole data-rows drop so the
        model-parallel groups stay intact)."""
        failed_ids = {d.id for d in failed}
        rows = [
            row for row in mesh.devices.reshape(mesh.devices.shape[0], -1)
            if not any(d.id in failed_ids for d in row)
        ]
        if not rows:
            raise RuntimeError("no healthy data-parallel rows remain")
        import numpy as np

        return jax.sharding.Mesh(
            np.stack(rows), mesh.axis_names
        )


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 3.0         # deviations
    alpha: float = 0.1             # EWMA factor
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt_s: float) -> bool:
        """Returns True if this step is a straggler."""
        if self.n < 5:  # warmup
            self.mean = (self.mean * self.n + dt_s) / (self.n + 1)
            self.n += 1
            return False
        dev = dt_s - self.mean
        std = math.sqrt(self.var) if self.var > 0 else self.mean * 0.1
        is_straggler = dev > self.threshold * max(std, 1e-9)
        self.mean += self.alpha * dev
        self.var = (1 - self.alpha) * (self.var + self.alpha * dev * dev)
        self.n += 1
        if is_straggler:
            self.flagged.append(step)
        return is_straggler
