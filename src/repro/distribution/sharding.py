"""Sharding rules over the ``(pod, data, model)`` production mesh.

Parameters: tensor-parallel over ``model`` (attention heads / FFN width
/ experts / vocab), optionally FSDP over ``data`` (big archs — required
to fit deepseek-v2's 472 GB of bf16 weights in 16 GB/chip), replicated
over ``pod`` (gradients cross pods once per step).

Rules are path-name based: every model module names its leaves with the
conventions below, and a structural test pins the mapping.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import PartitionSpec as P

from ..models.config import ModelConfig

__all__ = ["param_specs", "batch_specs", "cache_specs", "BATCH"]

BATCH = ("pod", "data")

# leaf name -> role
_COL = {  # output dim is 'model' (column parallel)
    "wq", "wk", "wv", "wg", "wu", "in_proj", "in_x", "in_gate",
    "q_up", "k_up", "v_up", "w_r", "w_i", "q_down", "kv_down", "k_rope",
}
_ROW = {  # input dim is 'model' (row parallel)
    "wo", "wd", "out_proj", "out",
}
_REPL = {
    "router", "conv", "A_log", "D", "dt_bias", "lam", "norm",
    "ln1", "ln2", "final_norm", "qn", "kn", "q_norm", "kv_norm",
}


def _is_expert(path: Tuple[str, ...]) -> bool:
    return "moe" in path and "shared" not in path


def param_specs(cfg: ModelConfig, params: Any, fsdp: bool = True):
    """PartitionSpec tree matching ``params``.

    Handles the scanned-layer leading axis automatically: rules are
    written for the *unstacked* leaf shape; an extra leading dim maps to
    ``None``.
    """

    def spec_for(path, leaf) -> P:
        names = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        ndim = leaf.ndim
        name = names[-1]
        fs = "data" if fsdp else None
        in_moe = _is_expert(names)
        in_shared = "shared" in names

        if name == "embed":
            if ndim == 3:
                return P(None, "model", fs)
            return P("model", fs)
        if name in _REPL:
            return P(*([None] * ndim))

        # base (unstacked) rule
        if name in _COL:
            if in_moe and not in_shared:
                base = ("model", fs, None)          # (E, d, f)
            else:
                base = (fs, "model")                # (d, f)
        elif name in _ROW:
            if in_moe and not in_shared:
                base = ("model", None, fs)          # (E, f, d)
            else:
                base = ("model", fs)                # (f, d)
        else:
            return P(*([None] * ndim))

        extra = ndim - len(base)
        if extra < 0:  # e.g. 1-D conv kernels caught by name sets above
            return P(*([None] * ndim))
        return P(*([None] * extra + list(base)))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def batch_specs(cfg: ModelConfig, batch: Dict[str, Any]):
    out = {}
    for k, v in batch.items():
        nd = v.ndim if hasattr(v, "ndim") else 0
        if nd == 0:
            out[k] = P()
        else:
            out[k] = P(*([BATCH] + [None] * (nd - 1)))
    return out


def cache_specs(
    cfg: ModelConfig,
    cache: Dict[str, Any],
    batch_shardable: bool,
    model_size: int = 16,
):
    """Decode/prefill cache sharding.

    A 32k-context decode cache is 300-800 GB globally, so batch sharding
    alone is not enough: KV heads shard over 'model' when the head count
    divides the axis, else the *sequence* dim does (GQA archs with 4-8 KV
    heads).  With ``batch_shardable=False`` (long_500k, batch=1) state
    width/heads carry all the sharding.
    """
    out = {}
    b = BATCH if batch_shardable else None
    for k, v in cache.items():
        nd = v.ndim
        if k in ("k", "v") and nd == 5:          # (L, B, Hkv, M, hd)
            hkv, m = v.shape[2], v.shape[3]
            if hkv % model_size == 0:
                out[k] = P(None, b, "model", None, None)
            elif m % model_size == 0:
                out[k] = P(None, b, None, "model", None)
            else:
                out[k] = P(None, b, None, None, None)
        elif k in ("c_kv", "k_rope", "k0", "v0") and nd == 4:  # (L,B,M,r)
            if v.shape[3] % model_size == 0:
                out[k] = P(None, b, None, "model")
            else:
                out[k] = P(None, b, "model", None)
        elif k == "ssm":                         # (L, B, H, P, N)
            out[k] = P(None, b, "model", None, None)
        elif k == "h":                           # (L, B, W)
            out[k] = P(None, b, "model")
        elif k == "conv":                        # (L, B, cw-1, C)
            out[k] = P(None, b, None, "model")
        else:
            out[k] = P(*([None] * nd))
    return out
