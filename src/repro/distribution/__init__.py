"""Distribution layer: sharding rules, gradient compression, collective
overlap helpers and elastic re-meshing."""
from .sharding import batch_specs, cache_specs, param_specs

__all__ = ["param_specs", "batch_specs", "cache_specs"]
