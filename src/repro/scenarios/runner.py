"""Scenario experiment runner: one dispatching entry point + sweeps.

:func:`run` is the single entry point for scenario simulation.  It owns
backend selection (the scalar reference engine, the bit-identical
batched lockstep engine, the distributional SoA jax backend) and the
per-spec fallback policy.  It replaced the four historical entry
points — ``run_scenario`` / ``run_scenario_batch`` /
``run_scenario_soa`` / ``run_scenario_group`` — whose deprecated shims
have completed their one-release grace period and are gone; see
docs/scenarios.md for the call-site translations.

``sweep`` is the fleet-scale view: ``N`` Markov-sampled scenarios x
policies, fanned out over a process pool with deterministic
per-scenario seeds, aggregated into per-policy and per-mode tables
(streaming form: :class:`repro.sweeps.SweepReducer`).  Passing
``cache_dir=`` routes the sweep through the campaign service
(:mod:`repro.sweeps.service`): rows become content-addressed cache
entries and repeated sweeps only execute new cells.

The pool utility :func:`parallel_map` is generic (the benchmark harness
reuses it for ``--jobs``) and is now a thin wrapper over
:class:`repro.sweeps.LocalPoolExecutor`.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from collections import abc as _abc
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple, Union

from ..core.experiment import ExperimentSpec, build_stack, make_policy
from ..core.runtime import (
    OnlineReplanner,
    PredictiveReplanner,
    SchedulePortfolio,
)
from ..core.sim import SimConfig, Simulator, SimReport
from ..core.sim.batch import LaneSimulator, run_batch, sample_trace_batch
from ..core.sim.trace import Trace, build_skeleton, sample_trace
from ..obs import TraceRecorder, attribution_report
from ..sweeps.executor import ItemFailure, LocalPoolExecutor
from ..sweeps.reduce import SweepReducer
from ..sweeps.rows import SweepRow
from .modes import get_mode, register_mode
from .script import MarkovScenarioGenerator, ScenarioScript, default_generator

__all__ = [
    "ScenarioSpec",
    "SweepBackend",
    "BackendRegistry",
    "SWEEP_BACKENDS",
    "compile_portfolio",
    "build_trace",
    "run",
    "soa_usable",
    "parallel_map",
    "ItemFailure",
    "summarize",
    "SweepRow",
    "SweepReducer",
    "sweep",
    "aggregate_sweep",
]


@dataclasses.dataclass
class ScenarioSpec(ExperimentSpec):
    """One scenario run (picklable, so sweeps can ship it to workers).

    Extends :class:`~repro.core.experiment.ExperimentSpec` — the
    workload fields (tiles, replicas, deadlines, ...) live there — with
    the scenario script, the replanning switch, and a scenario-length
    default horizon.
    """

    scenario: Optional[ScenarioScript] = None   # required (kw-only in use)
    replan: bool = True
    #: how the replanner reacts to context shifts:
    #:   "reactive"   — hot-swap at the seam (the PR-1 behaviour);
    #:   "predictive" — forecast-driven: pre-swap the full target table
    #:                  ahead of high-confidence seams, blend below;
    #:   "blend"      — hedge-only variant: every staged transition uses
    #:                  the blended table (ablation of the pre-swap).
    replan_mode: str = "reactive"
    #: predictive only: stage this many seconds before the forecast seam
    forecast_lead_s: float = 0.08
    #: reactive context-shift confirmation window (seconds): a runtime
    #: without a forecast detects a mode switch from observed
    #: statistics, swapping this long after the seam.  0 keeps the
    #: oracle-reactive behaviour.  A predictive replanner pays it only
    #: on wrong forecasts (correct forecasts turn detection into
    #: confirmation).
    detection_delay_s: float = 0.0
    #: predictive only: pin switch times from the script itself (the
    #: route-informed case); False falls back to pure Markov+dwell
    #: estimation, which can be early, late, or plain wrong
    route_forecast: bool = True
    #: predicted E2E miss-probability target for the tile-budget
    #: autotuner: each mode installs the cheapest frontier point
    #: meeting it (see ``docs/autotuner.md``).  None keeps the most
    #: conservative feasible table per mode (the legacy q-ladder
    #: choice).  Ignored when a precompiled ``portfolio`` is supplied.
    target_miss: Optional[float] = None
    duration_s: Optional[float] = None          # None = the scenario's length
    #: precompiled per-mode schedules; None compiles one per run.
    #: sweep() fills this so N scenarios share one portfolio per policy
    #: instead of recompiling identical GHA tables in every worker.
    portfolio: Optional[SchedulePortfolio] = None
    #: mode definitions to (re-)register before running.  Spawned pool
    #: workers re-import the bundled registry only, so custom modes
    #: added via register_mode must travel with the spec; sweep() fills
    #: this automatically from the generator's mode set.
    mode_defs: Optional[Dict[str, object]] = None
    #: attach a flight recorder (:mod:`repro.obs`) to the run: the
    #: report gains a ``attribution`` section (deadline-miss
    #: decomposition) and the recorder itself is reachable through
    #: ``run``'s ``recorders=`` argument for trace export.
    #: Off by default — recording a sweep costs memory per run.
    record: bool = False
    #: autotuned portfolios only (``target_miss`` set): pin every mode
    #: to one common partition count (the legacy pre-morphing
    #: behaviour).  False lets each mode keep its own best spatial
    #: layout — hot-swaps then split/merge partitions online.
    harmonize_partitions: bool = True

    def __post_init__(self) -> None:
        if self.scenario is None:
            raise ValueError("ScenarioSpec requires a scenario script")
        if self.replan_mode not in ("reactive", "predictive", "blend"):
            raise ValueError(
                f"unknown replan_mode {self.replan_mode!r} "
                "(choose from reactive/predictive/blend)"
            )


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------
def soa_usable(spec: "ScenarioSpec") -> Tuple[bool, str]:
    """Whether the SoA jax backend can run ``spec`` (and why not).

    The single place availability + per-spec support are decided: the
    :func:`run` dispatcher, ``sweep``'s group runner, and the campaign
    service all consult this instead of re-deriving the check.
    """
    from ..core.sim import soa

    if not soa.soa_available():
        return False, "jax is not available"
    if not soa.soa_supported(
        spec.policy, spec.replan_mode, spec.detection_delay_s,
        spec.drop_policy, spec.record,
    ):
        return (
            False,
            f"spec (policy={spec.policy!r}, replan_mode={spec.replan_mode!r}, "
            f"record={spec.record}) is outside the SoA support set",
        )
    if getattr(spec.scenario, "has_degradations", False):
        return (
            False,
            "scenario injects platform degradations (engine seams the "
            "SoA kernels do not model)",
        )
    return True, ""


def _soa_available() -> bool:
    from ..core.sim import soa

    return soa.soa_available()


def _always_available() -> bool:
    return True


def _always_supported(_spec) -> Tuple[bool, str]:
    return True, ""


@dataclasses.dataclass(frozen=True)
class SweepBackend:
    """Capability metadata for one simulation engine.

    ``kind`` is the equivalence contract: ``"exact"`` backends produce
    bit-identical reports to each other (the batch-equivalence CI gate
    pins this), ``"distributional"`` ones agree statistically (KS /
    CI-overlap gates).  The sweep cache keys cells by this contract,
    not by backend name — see ``repro.sweeps.cellkey``.
    """

    name: str
    #: "exact" | "distributional"
    kind: str
    #: runs many lanes in one call (seed fans / scenario groups)
    batched: bool
    description: str
    #: process-wide availability (e.g. optional jax dependency)
    is_available: Callable[[], bool] = _always_available
    #: per-spec support: ``(ok, reason_if_not)``
    supports: Callable[[object], Tuple[bool, str]] = _always_supported


class BackendRegistry(_abc.Mapping):
    """Name -> :class:`SweepBackend` mapping.

    Iterates over *names* (and ``repr``\\ s as the name tuple), so code
    and error messages written against the old ``SWEEP_BACKENDS``
    string tuple keep working; lookups return the full capability
    record.
    """

    def __init__(self, *backends: SweepBackend) -> None:
        self._by_name: Dict[str, SweepBackend] = {}
        for b in backends:
            self.register(b)

    def register(self, backend: SweepBackend, overwrite: bool = False) -> SweepBackend:
        if backend.name in self._by_name and not overwrite:
            raise ValueError(f"backend {backend.name!r} already registered")
        if backend.kind not in ("exact", "distributional"):
            raise ValueError(f"unknown backend kind {backend.kind!r}")
        self._by_name[backend.name] = backend
        return backend

    def __getitem__(self, name: str) -> SweepBackend:
        return self._by_name[name]

    def __iter__(self):
        return iter(self._by_name)

    def __len__(self) -> int:
        return len(self._by_name)

    def names(self) -> Tuple[str, ...]:
        return tuple(self._by_name)

    def __repr__(self) -> str:
        return repr(self.names())


#: engines :func:`run`/``sweep`` can route work through, with their
#: capability metadata.  "scalar" and "lockstep" are bit-identical to
#: each other; "soa" is distributionally equivalent (see
#: docs/performance.md).  Iterating yields names, so the old
#: string-tuple idioms (``backend in SWEEP_BACKENDS``) still hold.
SWEEP_BACKENDS = BackendRegistry(
    SweepBackend(
        name="scalar", kind="exact", batched=False,
        description="per-event reference engine, one run at a time",
    ),
    SweepBackend(
        name="lockstep", kind="exact", batched=True,
        description=(
            "batched lockstep engine; per-lane reports bit-identical "
            "to scalar (CI-gated)"
        ),
    ),
    SweepBackend(
        name="soa", kind="distributional", batched=True,
        description=(
            "structure-of-arrays jax backend; distributionally "
            "equivalent, profitable for many seeds of one cell"
        ),
        is_available=_soa_available,
        supports=soa_usable,
    ),
)


def _check_backend(backend: str, *, allow_auto: bool = False) -> None:
    if backend in SWEEP_BACKENDS or (allow_auto and backend == "auto"):
        return
    choices = (("auto",) if allow_auto else ()) + SWEEP_BACKENDS.names()
    raise ValueError(f"unknown backend {backend!r} (choose from {choices})")


# ---------------------------------------------------------------------------
# compilation / trace helpers
# ---------------------------------------------------------------------------
def compile_portfolio(
    spec: ScenarioSpec, modes: Optional[Sequence[str]] = None, **autotune_kw
) -> SchedulePortfolio:
    """Compile the per-mode schedule portfolio for ``spec``'s workload
    (``modes`` defaults to the scenario's own mode set).

    ``spec.target_miss`` (or any explicit ``autotune_kw``) engages the
    tile-budget autotuner's joint search; the default compiles each
    mode's most conservative feasible table.
    """
    wf, _hw, model, compiler = build_stack(spec)
    wanted = tuple(modes) if modes is not None else spec.scenario.modes()
    autotune_kw.setdefault("target_miss", spec.target_miss)
    autotune_kw.setdefault("harmonize_partitions", spec.harmonize_partitions)
    return SchedulePortfolio.compile(
        model, wf, {m: get_mode(m) for m in wanted}, compiler, **autotune_kw,
    )


def build_trace(spec: ScenarioSpec) -> Trace:
    """Sample the full randomness of one scenario run up front.

    The result can be passed to :func:`run` for every policy / replan
    variant of the same ``(scenario, seed, workload)`` — the draws are
    policy-independent under the engine's counter-based stream
    contract, so sharing a trace changes nothing about the results and
    only removes the redundant sampling work.
    """
    wf, _hw, model, _compiler = build_stack(spec)
    scen = spec.scenario
    duration = scen.duration_s if spec.duration_s is None else spec.duration_s
    skel = build_skeleton(wf, scen, duration)
    return sample_trace(skel, model, scen, spec.seed)


def _prepare_run(spec: ScenarioSpec):
    """The per-run setup shared by every backend: mode registration,
    workload stack, and the offline schedule portfolio — so a batched
    lane is constructed exactly like a scalar run."""
    if spec.mode_defs:
        # idempotent in the parent; in a spawn worker this restores
        # custom modes the fresh registry does not have
        for mode in spec.mode_defs.values():
            register_mode(mode, overwrite=True)
    scen = spec.scenario
    wf, _hw, model, compiler = build_stack(spec)

    # the offline table is compiled for the scenario's *initial* mode
    # (via the portfolio's q-relaxation ladder, so pinned and replanned
    # runs start from the identical table) — a pinned run then keeps it
    # for the whole drive
    initial_mode = scen.segments[0].mode
    portfolio = spec.portfolio
    if portfolio is None:
        wanted = scen.modes() if spec.replan else (initial_mode,)
        portfolio = SchedulePortfolio.compile(
            model, wf, {m: get_mode(m) for m in wanted}, compiler,
            target_miss=spec.target_miss,
            harmonize_partitions=spec.harmonize_partitions,
        )
    return wf, model, portfolio.schedules[initial_mode], portfolio


def _make_run_policy(spec: ScenarioSpec, portfolio: SchedulePortfolio):
    """Fresh policy (+ replanner) instance for one run/lane — replanner
    state (swap counters, forecast bookkeeping) is per-run, so batched
    lanes never share it; the compiled portfolio itself is read-only
    and shared."""
    scen = spec.scenario
    policy = make_policy(spec.policy)
    if spec.replan:
        if spec.replan_mode == "reactive":
            policy.replanner = OnlineReplanner(
                portfolio, detection_delay_s=spec.detection_delay_s
            )
        else:
            kw = dict(
                forecaster=scen.forecaster(route_informed=spec.route_forecast),
                lead_s=spec.forecast_lead_s,
                detection_delay_s=spec.detection_delay_s,
            )
            if spec.replan_mode == "blend":
                # hedge-only ablation: no forecast is confident enough
                # for a full pre-swap, every stage blends
                kw["confidence_hi"] = 2.0
            policy.replanner = PredictiveReplanner(portfolio, **kw)
    return policy


def _sim_config(
    spec: ScenarioSpec, trace: Optional[Trace], rec: Optional[TraceRecorder],
) -> SimConfig:
    scen = spec.scenario
    return SimConfig(
        duration_s=(
            scen.duration_s if spec.duration_s is None else spec.duration_s
        ),
        seed=spec.seed,
        drop_policy=spec.drop_policy,
        scenario=scen,
        trace=trace,
        recorder=rec,
    )


# ---------------------------------------------------------------------------
# backend implementations (private; dispatch through run())
# ---------------------------------------------------------------------------
def _run_single(
    spec: ScenarioSpec,
    trace: Optional[Trace] = None,
    recorder: Optional[TraceRecorder] = None,
) -> SimReport:
    """Scalar reference engine: one scenario end-to-end."""
    wf, model, sched, portfolio = _prepare_run(spec)
    policy = _make_run_policy(spec, portfolio)
    rec = recorder
    if rec is None and spec.record:
        rec = TraceRecorder()
    sim = Simulator(
        wf, model, sched, policy, _sim_config(spec, trace, rec),
    )
    report = sim.run()
    if rec is not None:
        report.attribution = attribution_report(sim, rec)
    return report


def _run_lockstep_seeds(
    spec: ScenarioSpec,
    seeds: Sequence[int],
    recorders: Optional[Mapping[int, TraceRecorder]] = None,
) -> List[SimReport]:
    """Lockstep engine, seed fan: ``len(seeds)`` Monte-Carlo drives of
    one spec as lanes of one batch.

    Each lane's report is bit-identical to the scalar engine run with
    that seed — the stack/portfolio setup is shared, the
    stream-contract trace is batch-materialized once
    (:func:`~repro.core.sim.batch.sample_trace_batch`) and the lanes
    advance in lockstep (:func:`~repro.core.sim.batch.run_batch`).

    ``recorders`` attaches flight recorders to individual lanes by seed
    *index* — a recorded lane de-batches to the scalar per-lane driver
    (recorder hooks live on the engine paths the fused loop elides) but
    stays inside the lockstep loop; ``spec.record`` attaches one to
    every lane.
    """
    wf, model, sched, portfolio = _prepare_run(spec)
    scen = spec.scenario
    duration = scen.duration_s if spec.duration_s is None else spec.duration_s
    skel = build_skeleton(wf, scen, duration)
    btrace = sample_trace_batch(skel, model, scen, seeds)

    sims: List[LaneSimulator] = []
    recs: List[Optional[TraceRecorder]] = []
    for k, s in enumerate(seeds):
        rec = recorders.get(k) if recorders is not None else None
        if rec is None and spec.record:
            rec = TraceRecorder()
        lane_spec = dataclasses.replace(spec, seed=int(s))
        sims.append(LaneSimulator(
            wf, model, sched, _make_run_policy(lane_spec, portfolio),
            _sim_config(lane_spec, btrace.lane(k), rec),
        ))
        recs.append(rec)
    reports = run_batch(sims)
    for sim, rec, report in zip(sims, recs, reports):
        if rec is not None:
            report.attribution = attribution_report(sim, rec)
    return reports


def _run_lockstep_group(
    specs: Sequence[ScenarioSpec],
    trace: Optional[Trace] = None,
    recorders: Optional[Mapping[int, TraceRecorder]] = None,
) -> List[SimReport]:
    """Lockstep engine, policy group: several specs sharing (scenario,
    seed, workload), differing in policy/replan, as lanes of one batch
    sharing ``trace``.

    Reports are bit-identical to the scalar engine per spec; this is
    the batched path under :func:`sweep`.
    """
    sims: List[LaneSimulator] = []
    recs: List[Optional[TraceRecorder]] = []
    for i, spec in enumerate(specs):
        wf, model, sched, portfolio = _prepare_run(spec)
        rec = recorders.get(i) if recorders is not None else None
        if rec is None and spec.record:
            rec = TraceRecorder()
        sims.append(LaneSimulator(
            wf, model, sched, _make_run_policy(spec, portfolio),
            _sim_config(spec, trace, rec),
        ))
        recs.append(rec)
    reports = run_batch(sims)
    for sim, rec, report in zip(sims, recs, reports):
        if rec is not None:
            report.attribution = attribution_report(sim, rec)
    return reports


#: per-process memo of SoA window pads that proved necessary, keyed by
#: (skeleton key, policy, drop policy, duration) — see _run_soa
_SOA_LIFE_PAD_HINT: Dict[tuple, float] = {}


def _run_soa(
    spec: ScenarioSpec,
    seeds: Sequence[int],
    options=None,
) -> List[SimReport]:
    """Structure-of-arrays jax backend, seed fan.

    Unlike the lockstep engine (bit-identical lanes), the SoA backend
    advances all lanes as jnp arrays through discrete scheduling
    rounds: reports agree with the scalar engine *distributionally*
    (KS on chain latencies, CI overlap on summary rates) and *exactly*
    on structural invariants, but individual event timestamps differ
    at the round granularity — see ``docs/performance.md#soa-backend``
    for the contract and for when this backend is profitable (many
    seeds of one scenario cell, e.g. tail estimation; the jit compile
    is amortized across lanes but repaid on every new scenario shape).

    Raises :class:`repro.core.sim.soa.SoaUnsupported` when jax is
    missing or the spec needs features outside the kernel's support
    set; :func:`run` consults :func:`soa_usable` first and owns the
    fallback decision.
    """
    from ..core.sim import soa

    ok, why = soa_usable(spec)
    if not ok:
        raise soa.SoaUnsupported(why)
    wf, model, sched, portfolio = _prepare_run(spec)
    scen = spec.scenario
    duration = scen.duration_s if spec.duration_s is None else spec.duration_s
    skel = build_skeleton(wf, scen, duration)
    btrace = sample_trace_batch(skel, model, scen, seeds, device=True)
    # overloaded cells under drop_policy="soft" can queue jobs past the
    # default job-window lifetime bound; the backend refuses to return
    # truncated results (SoaWindowOverflow), so retry wider: first a
    # doubled window (mild overruns), then one capped at the horizon —
    # full job coverage, structurally incapable of overflowing.  A pad
    # that worked is remembered per cell so repeat calls (seed batches
    # of one cell, the backend's throughput shape) skip the discarded
    # detection run; the hint only ever *widens* the default, and only
    # applies when the caller did not pass explicit options.
    hint_key = (skel.key, spec.policy, spec.drop_policy, float(duration))
    opt0 = options if options is not None else soa.SoaOptions(
        life_pad_s=_SOA_LIFE_PAD_HINT.get(hint_key, 0.0)
    )
    opt = opt0
    for attempt in range(3):
        problem = soa.build_problem(
            wf, model, sched, portfolio,
            _make_run_policy(spec, portfolio), scen, duration,
            replan=spec.replan, n_lanes=len(seeds),
            drop_policy=spec.drop_policy, options=opt,
        )
        try:
            reports = soa.run_problem(problem, btrace, seeds)
        except soa.SoaWindowOverflow:
            if problem.life >= duration or attempt == 2:
                raise
            warnings.warn(
                f"SoA job window ({problem.life:.3f}s) overflowed under "
                "overload; retrying with a "
                + ("doubled" if attempt == 0 else "full-horizon")
                + " window (recompiles the round loop)",
                RuntimeWarning,
                stacklevel=2,
            )
            pad = problem.life if attempt == 0 else duration
            opt = dataclasses.replace(
                opt0, life_pad_s=opt0.life_pad_s + pad
            )
        else:
            if options is None and opt.life_pad_s > _SOA_LIFE_PAD_HINT.get(
                hint_key, 0.0
            ):
                _SOA_LIFE_PAD_HINT[hint_key] = opt.life_pad_s
            return reports


# ---------------------------------------------------------------------------
# the entry point
# ---------------------------------------------------------------------------
def run(
    specs: Union[ScenarioSpec, Sequence[ScenarioSpec]],
    *,
    seeds: Optional[Sequence[int]] = None,
    backend: str = "auto",
    trace: Optional[Trace] = None,
    recorders: Optional[Mapping[int, TraceRecorder]] = None,
    options=None,
    fallback: bool = True,
) -> List[SimReport]:
    """Run scenario simulations; always returns one report per run.

    The one entry point over every engine.  Three call shapes:

    * ``run(spec)`` — a single drive (``run(spec)[0]`` is the report);
    * ``run(spec, seeds=[...])`` — a Monte-Carlo *seed fan* of one
      spec, one report per seed;
    * ``run([spec_a, spec_b, ...])`` — a *group* of specs (typically
      one scenario+seed across policies), one report per spec, in
      order.

    ``backend`` selects the engine (see :data:`SWEEP_BACKENDS`):

    * ``"auto"`` (default) — deterministic best choice: the scalar
      reference engine for a single run, the bit-identical lockstep
      engine for seed fans, and for groups the lockstep engine over
      maximal sub-groups that can share a trace (same scenario, seed
      and workload), sampling each shared trace once.  Never picks the
      SoA backend — its rows are only distributionally equivalent, so
      it must be asked for by name.
    * ``"scalar"`` / ``"lockstep"`` — force that exact-family engine.
    * ``"soa"`` — the distributional jax backend.  Specs it cannot run
      (unavailable jax, unsupported feature, attached recorder) fall
      back to an exact engine when ``fallback=True`` (the sweep
      default) or raise ``SoaUnsupported`` when ``fallback=False``.

    ``trace`` injects presampled randomness (:func:`build_trace`) into
    exact-engine runs; a group sharing one trace must share (scenario,
    seed, workload).  Incompatible with ``seeds=`` (a trace carries
    one seed's draws) and with the SoA backend (it materializes its
    own device-resident trace batch).

    ``recorders`` maps run index (seed index for fans, spec index for
    groups, ``0`` for a single spec) to a caller-owned
    :class:`~repro.obs.TraceRecorder`; ``spec.record`` instead attaches
    an internal one to every run.  Either way recorded reports carry an
    ``attribution`` section.

    ``options`` passes :class:`~repro.core.sim.soa.SoaOptions` through
    to the SoA backend (SoA-only).
    """
    single = isinstance(specs, ScenarioSpec)
    spec_list: List[ScenarioSpec] = [specs] if single else list(specs)
    _check_backend(backend, allow_auto=True)
    if not spec_list:
        return []
    if options is not None and backend != "soa":
        raise ValueError("options= configures the SoA backend; pass backend='soa'")
    if seeds is not None:
        if not single:
            raise ValueError(
                "seeds= fans one spec over Monte-Carlo seeds; pass a "
                "single spec (a list of specs is a group, one run each)"
            )
        if trace is not None:
            raise ValueError(
                "trace= carries one seed's presampled draws; it cannot "
                "be combined with seeds= (the engine batch-materializes "
                "the fan's traces itself)"
            )
        return _dispatch_seed_fan(
            spec_list[0], list(seeds), backend, recorders, options, fallback,
        )
    return _dispatch_group(spec_list, backend, trace, recorders, options, fallback)


def _dispatch_seed_fan(
    spec: ScenarioSpec,
    seeds: List[int],
    backend: str,
    recorders: Optional[Mapping[int, TraceRecorder]],
    options,
    fallback: bool,
) -> List[SimReport]:
    if backend == "soa":
        ok, why = soa_usable(spec)
        if ok and recorders:
            ok, why = False, "recorders need engine hooks the SoA kernel elides"
        if ok:
            return _run_soa(spec, seeds, options)
        if not fallback:
            from ..core.sim import soa

            raise soa.SoaUnsupported(why)
        return _run_lockstep_seeds(spec, seeds, recorders)
    if backend == "scalar":
        out: List[SimReport] = []
        for k, s in enumerate(seeds):
            rec = recorders.get(k) if recorders is not None else None
            out.append(
                _run_single(dataclasses.replace(spec, seed=int(s)), None, rec)
            )
        return out
    # auto / lockstep: the batched exact engine is the right default
    return _run_lockstep_seeds(spec, seeds, recorders)


def _dispatch_group(
    spec_list: List[ScenarioSpec],
    backend: str,
    trace: Optional[Trace],
    recorders: Optional[Mapping[int, TraceRecorder]],
    options,
    fallback: bool,
) -> List[SimReport]:
    recorders = recorders or {}
    if backend == "soa":
        if trace is not None:
            raise ValueError(
                "the SoA backend materializes its own device trace; "
                "trace= is only valid for exact backends"
            )
        out: List[SimReport] = []
        for i, spec in enumerate(spec_list):
            rec = recorders.get(i)
            ok, why = soa_usable(spec)
            if ok and rec is not None:
                ok, why = False, "recorders need engine hooks the SoA kernel elides"
            if ok:
                out.append(_run_soa(spec, [spec.seed], options)[0])
            elif fallback:
                out.append(_run_single(spec, None, rec))
            else:
                from ..core.sim import soa

                raise soa.SoaUnsupported(why)
        return out
    if backend == "lockstep":
        return _run_lockstep_group(spec_list, trace, recorders or None)
    if backend == "scalar":
        return [
            _run_single(s, trace, recorders.get(i))
            for i, s in enumerate(spec_list)
        ]
    # auto
    if len(spec_list) == 1:
        return [_run_single(spec_list[0], trace, recorders.get(0))]
    if trace is not None:
        # the caller vouches the group shares the trace's (scenario,
        # seed, workload) — the batch engine's skeleton guard backstops
        return _run_lockstep_group(spec_list, trace, recorders or None)
    out2: List[Optional[SimReport]] = [None] * len(spec_list)
    for idxs in _auto_groups(spec_list):
        if len(idxs) == 1:
            i = idxs[0]
            out2[i] = _run_single(spec_list[i], None, recorders.get(i))
        else:
            sub = [spec_list[i] for i in idxs]
            shared = build_trace(sub[0])
            sub_recs = {
                j: recorders[i]
                for j, i in enumerate(idxs) if i in recorders
            }
            reports = _run_lockstep_group(sub, shared, sub_recs or None)
            for j, i in enumerate(idxs):
                out2[i] = reports[j]
    return out2  # type: ignore[return-value]


#: ExperimentSpec/ScenarioSpec fields that shape the sampled trace and
#: skeleton; specs agreeing on all of them (plus scenario and seed) can
#: share one trace as lockstep lanes.  Policy/replan fields are absent
#: on purpose — draws are policy-independent (counter-based streams).
_TRACE_FIELDS = (
    "seed", "duration_s", "tiles", "cockpit_replicas", "load_factor",
    "deadline_s", "q", "num_partitions", "p99_ratio", "dram_utilization",
    "drop_policy",
)


def _auto_groups(spec_list: Sequence[ScenarioSpec]) -> List[List[int]]:
    """Partition specs into trace-sharing groups (order-stable)."""
    groups: Dict[tuple, List[int]] = {}
    for i, spec in enumerate(spec_list):
        key = (
            spec.scenario.cache_token(),
            spec.scenario.profile_token(),
            tuple(getattr(spec, f) for f in _TRACE_FIELDS),
        )
        groups.setdefault(key, []).append(i)
    return list(groups.values())


# ---------------------------------------------------------------------------
# process-pool utility (reused by benchmarks/run.py --jobs)
# ---------------------------------------------------------------------------
def parallel_map(
    fn: Callable,
    items: Sequence,
    jobs: Optional[int] = None,
    *,
    return_errors: bool = False,
) -> List:
    """``[fn(x) for x in items]``, fanned out over ``jobs`` processes.

    Thin wrapper over :class:`repro.sweeps.LocalPoolExecutor` (which
    keeps the historical semantics: order preserved, ``spawn`` start
    method — fork after JAX initialisation is unsafe — ``jobs=None``
    uses the CPU count capped at the number of items, ``jobs`` <= 1 or
    a single item degrades to a plain in-process loop, so ``fn`` and
    every item must be picklable).

    Error handling is per-item: a failing item no longer aborts the
    pool mid-pass and discards its siblings' completed results.  With
    ``return_errors=True`` failures come back in place as
    :class:`~repro.sweeps.ItemFailure` entries; otherwise the first
    failure's original exception re-raises after the full pass.
    """
    return LocalPoolExecutor(jobs).map(fn, items, return_errors=return_errors)


# ---------------------------------------------------------------------------
# Monte-Carlo sweeps
# ---------------------------------------------------------------------------
def summarize(spec: ScenarioSpec, report: SimReport) -> Dict[str, object]:
    """Flatten one run into a picklable summary row — the dict form of
    :class:`repro.sweeps.SweepRow` (``SweepRow.from_report`` is the
    typed equivalent; this wrapper keeps the historical dict shape that
    committed benchmark JSON and the result cache store)."""
    return SweepRow.from_report(spec, report).to_dict()


def _run_one(spec: ScenarioSpec) -> Dict[str, object]:
    return summarize(spec, _run_single(spec))


def _run_group(
    specs: Sequence[ScenarioSpec], backend: str = "lockstep"
) -> List[Dict[str, object]]:
    """Run every spec of one scenario seed, sampling its trace once.

    All specs in a group share (scenario, seed, workload) and differ
    only in policy/replan, so one trace serves them all: the paired
    policy comparison stays exact at the job level while the sampling
    cost is paid once instead of once per policy.

    ``backend`` selects the engine (see :data:`SWEEP_BACKENDS`):

    * ``"lockstep"`` (default) — the batched lockstep engine; per-lane
      reports are bit-identical to the scalar path (the
      ``batch-equivalence`` CI gate pins this), so sweep rows are
      unchanged.
    * ``"scalar"`` — the per-event reference engine, one spec at a
      time.
    * ``"soa"`` — the structure-of-arrays jax backend.  Rows are
      distributionally (not bitwise) equivalent to the other two.  A
      sweep group holds *one* seed per scenario, which is the SoA
      backend's worst shape (the jit compile cache only pays off
      across many seeds of one skeleton), so this selector exists for
      apples-to-apples validation sweeps; throughput work should call
      ``run(spec, seeds=..., backend="soa")`` with many seeds per cell
      instead.  Specs outside the SoA support set fall back to the
      scalar engine, mirroring the lockstep engine's per-lane fallback.
    """
    _check_backend(backend)
    if backend == "soa":
        reports = run(list(specs), backend="soa", fallback=True)
        return [summarize(s, r) for s, r in zip(specs, reports)]
    if len(specs) <= 1 or backend == "scalar":
        return [summarize(s, _run_single(s)) for s in specs]
    trace = build_trace(specs[0])
    reports = _run_lockstep_group(specs, trace)
    return [summarize(s, r) for s, r in zip(specs, reports)]


def sweep(
    n_scenarios: int,
    policies: Sequence[str] = ("ads_tile", "tp_driven"),
    duration_s: float = 2.0,
    seed: int = 0,
    jobs: Optional[int] = None,
    generator: Optional[MarkovScenarioGenerator] = None,
    replan: bool = True,
    backend: str = "lockstep",
    cache_dir=None,
    manifest_path=None,
    **spec_kw,
) -> List[Dict[str, object]]:
    """Monte-Carlo sweep: ``n_scenarios`` Markov drives x ``policies``.

    Scenario ``i`` is sampled with the deterministic seed
    ``seed * 100003 + i`` and simulated with the same seed for every
    policy, so policy comparisons are paired and the whole sweep is
    reproducible from ``seed`` alone.  The unit of parallel work is one
    *scenario* (all its policies run in the same worker, sharing one
    sampled trace and one cached structural skeleton).

    ``backend`` selects the per-group engine (see :func:`_run_group`):
    ``"lockstep"`` (default, bit-identical rows), ``"scalar"``
    (reference engine), or ``"soa"`` (distributionally-equivalent jax
    backend; per-scenario jit compiles make it the validation shape
    here, not the throughput shape — use ``run(spec, seeds=...,
    backend="soa")`` directly for many-seed cells).

    ``cache_dir`` routes the sweep through the campaign service
    (:func:`repro.sweeps.run_campaign`): rows are stored
    content-addressed on disk, so an identical repeat sweep executes
    zero cells and an extended one executes only the new cells.
    ``manifest_path`` additionally writes the resumable campaign
    manifest there (requires ``cache_dir``).  Rows are identical to the
    direct path either way.
    """
    if cache_dir is not None:
        from ..sweeps.service import CampaignSpec, run_campaign

        campaign = CampaignSpec(
            name="sweep",
            n_scenarios=n_scenarios,
            policies=tuple(policies),
            scenario_duration_s=duration_s,
            seed=seed,
            replan=replan,
            backend=backend,
            generator=generator,
            spec_kw=dict(spec_kw),
        )
        return run_campaign(
            campaign, cache_dir=cache_dir, manifest_path=manifest_path,
            jobs=jobs,
        ).rows
    if manifest_path is not None:
        raise ValueError("manifest_path= requires cache_dir= (campaign mode)")
    gen = generator or default_generator()
    all_modes = sorted(gen.transitions)
    mode_defs = {m: get_mode(m) for m in all_modes}
    groups: List[List[ScenarioSpec]] = []
    portfolios: Dict[str, SchedulePortfolio] = {}
    for i in range(n_scenarios):
        s_i = seed * 100003 + i
        script = gen.sample(duration_s, seed=s_i)
        group: List[ScenarioSpec] = []
        for pol in policies:
            spec = ScenarioSpec(
                scenario=script, policy=pol, replan=replan, seed=s_i,
                mode_defs=mode_defs,
                **spec_kw,
            )
            # one portfolio per policy, covering every mode the
            # generator can emit — compiled here once instead of per
            # worker run
            if pol not in portfolios:
                portfolios[pol] = compile_portfolio(spec, all_modes)
            group.append(dataclasses.replace(spec, portfolio=portfolios[pol]))
        groups.append(group)
    rows_per_group = parallel_map(
        functools.partial(_run_group, backend=backend), groups, jobs
    )
    return [row for rows in rows_per_group for row in rows]


def aggregate_sweep(
    rows: Sequence[Mapping[str, object]],
) -> Dict[str, Dict[str, object]]:
    """Aggregate sweep rows into per-policy means (and per-mode means).

    Returns ``{policy: {n, violation_rate, task_miss_rate,
    realloc_frac, per_mode: {mode: {...}}}}``.  Rows from recorded runs
    (``ScenarioSpec(record=True)``) additionally aggregate online into
    an ``attribution`` entry: summed lateness decomposed into
    queueing / realloc-stall / re-stagger / duration-tail seconds, so a
    sweep can print *why* a policy misses, not just how often.

    Thin batch wrapper over the streaming
    :class:`repro.sweeps.SweepReducer` — the two are equal by
    construction; use the reducer directly when rows arrive
    incrementally (campaigns, shard workers).
    """
    return SweepReducer().update_many(rows).result()
