"""Scenario experiment runner + multiprocessing Monte-Carlo sweeps.

``run_scenario`` is the one-call entry point for a single drive: build
the benchmark workflow, compile the GHA schedule for the scenario's
*initial* mode, optionally precompile a per-mode schedule portfolio for
online replanning, and run Tile-stream with the scenario attached.

``sweep`` is the fleet-scale view: ``N`` Markov-sampled scenarios x
policies, fanned out over a process pool with deterministic
per-scenario seeds, aggregated into per-policy and per-mode tables.
The pool utility :func:`parallel_map` is generic (the benchmark harness
reuses it for ``--jobs``).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import multiprocessing
import os
import warnings
from typing import Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from ..core.experiment import ExperimentSpec, build_stack, make_policy
from ..core.runtime import (
    OnlineReplanner,
    PredictiveReplanner,
    SchedulePortfolio,
)
from ..core.sim import SimConfig, Simulator, SimReport
from ..core.sim.batch import LaneSimulator, run_batch, sample_trace_batch
from ..core.sim.trace import Trace, build_skeleton, sample_trace
from ..obs import TraceRecorder, attribution_report
from .modes import get_mode, register_mode
from .script import MarkovScenarioGenerator, ScenarioScript, default_generator

__all__ = [
    "ScenarioSpec",
    "compile_portfolio",
    "build_trace",
    "run_scenario",
    "run_scenario_batch",
    "run_scenario_group",
    "run_scenario_soa",
    "parallel_map",
    "sweep",
    "aggregate_sweep",
    "SWEEP_BACKENDS",
]

#: engines ``sweep()``/``_run_group`` can route a scenario group
#: through.  "scalar" and "lockstep" are bit-identical to each other;
#: "soa" is distributionally equivalent (see docs/performance.md).
SWEEP_BACKENDS = ("scalar", "lockstep", "soa")


@dataclasses.dataclass
class ScenarioSpec(ExperimentSpec):
    """One scenario run (picklable, so sweeps can ship it to workers).

    Extends :class:`~repro.core.experiment.ExperimentSpec` — the
    workload fields (tiles, replicas, deadlines, ...) live there — with
    the scenario script, the replanning switch, and a scenario-length
    default horizon.
    """

    scenario: Optional[ScenarioScript] = None   # required (kw-only in use)
    replan: bool = True
    #: how the replanner reacts to context shifts:
    #:   "reactive"   — hot-swap at the seam (the PR-1 behaviour);
    #:   "predictive" — forecast-driven: pre-swap the full target table
    #:                  ahead of high-confidence seams, blend below;
    #:   "blend"      — hedge-only variant: every staged transition uses
    #:                  the blended table (ablation of the pre-swap).
    replan_mode: str = "reactive"
    #: predictive only: stage this many seconds before the forecast seam
    forecast_lead_s: float = 0.08
    #: reactive context-shift confirmation window (seconds): a runtime
    #: without a forecast detects a mode switch from observed
    #: statistics, swapping this long after the seam.  0 keeps the
    #: oracle-reactive behaviour.  A predictive replanner pays it only
    #: on wrong forecasts (correct forecasts turn detection into
    #: confirmation).
    detection_delay_s: float = 0.0
    #: predictive only: pin switch times from the script itself (the
    #: route-informed case); False falls back to pure Markov+dwell
    #: estimation, which can be early, late, or plain wrong
    route_forecast: bool = True
    #: predicted E2E miss-probability target for the tile-budget
    #: autotuner: each mode installs the cheapest frontier point
    #: meeting it (see ``docs/autotuner.md``).  None keeps the most
    #: conservative feasible table per mode (the legacy q-ladder
    #: choice).  Ignored when a precompiled ``portfolio`` is supplied.
    target_miss: Optional[float] = None
    duration_s: Optional[float] = None          # None = the scenario's length
    #: precompiled per-mode schedules; None compiles one per run.
    #: sweep() fills this so N scenarios share one portfolio per policy
    #: instead of recompiling identical GHA tables in every worker.
    portfolio: Optional[SchedulePortfolio] = None
    #: mode definitions to (re-)register before running.  Spawned pool
    #: workers re-import the bundled registry only, so custom modes
    #: added via register_mode must travel with the spec; sweep() fills
    #: this automatically from the generator's mode set.
    mode_defs: Optional[Dict[str, object]] = None
    #: attach a flight recorder (:mod:`repro.obs`) to the run: the
    #: report gains a ``attribution`` section (deadline-miss
    #: decomposition) and the recorder itself is reachable through
    #: ``run_scenario``'s ``recorder=`` argument for trace export.
    #: Off by default — recording a sweep costs memory per run.
    record: bool = False

    def __post_init__(self) -> None:
        if self.scenario is None:
            raise ValueError("ScenarioSpec requires a scenario script")
        if self.replan_mode not in ("reactive", "predictive", "blend"):
            raise ValueError(
                f"unknown replan_mode {self.replan_mode!r} "
                "(choose from reactive/predictive/blend)"
            )


def compile_portfolio(
    spec: ScenarioSpec, modes: Optional[Sequence[str]] = None, **autotune_kw
) -> SchedulePortfolio:
    """Compile the per-mode schedule portfolio for ``spec``'s workload
    (``modes`` defaults to the scenario's own mode set).

    ``spec.target_miss`` (or any explicit ``autotune_kw``) engages the
    tile-budget autotuner's joint search; the default compiles each
    mode's most conservative feasible table.
    """
    wf, _hw, model, compiler = build_stack(spec)
    wanted = tuple(modes) if modes is not None else spec.scenario.modes()
    autotune_kw.setdefault("target_miss", spec.target_miss)
    return SchedulePortfolio.compile(
        model, wf, {m: get_mode(m) for m in wanted}, compiler, **autotune_kw,
    )


def build_trace(spec: ScenarioSpec) -> Trace:
    """Sample the full randomness of one scenario run up front.

    The result can be passed to :func:`run_scenario` for every policy /
    replan variant of the same ``(scenario, seed, workload)`` — the
    draws are policy-independent under the engine's counter-based
    stream contract, so sharing a trace changes nothing about the
    results and only removes the redundant sampling work.
    """
    wf, _hw, model, _compiler = build_stack(spec)
    scen = spec.scenario
    duration = scen.duration_s if spec.duration_s is None else spec.duration_s
    skel = build_skeleton(wf, scen, duration)
    return sample_trace(skel, model, scen, spec.seed)


def run_scenario(
    spec: ScenarioSpec,
    trace: Optional[Trace] = None,
    recorder: Optional[TraceRecorder] = None,
) -> SimReport:
    """Run one scenario end-to-end and return its :class:`SimReport`.

    ``trace`` optionally injects presampled randomness (see
    :func:`build_trace`); ``None`` samples inside the engine.

    ``recorder`` attaches a caller-owned flight recorder (so the caller
    can export the trace afterwards); ``spec.record`` makes the runner
    create an internal one.  Either way the report's ``attribution``
    field is filled with the run's deadline-miss decomposition.
    """
    wf, model, sched, portfolio = _prepare_run(spec)
    policy = _make_run_policy(spec, portfolio)
    rec = recorder
    if rec is None and spec.record:
        rec = TraceRecorder()
    sim = Simulator(
        wf, model, sched, policy, _sim_config(spec, trace, rec),
    )
    report = sim.run()
    if rec is not None:
        report.attribution = attribution_report(sim, rec)
    return report


def _prepare_run(spec: ScenarioSpec):
    """The per-run setup of :func:`run_scenario`: mode registration,
    workload stack, and the offline schedule portfolio.  Shared with
    the batched entry points so a batched lane is constructed exactly
    like a scalar run."""
    if spec.mode_defs:
        # idempotent in the parent; in a spawn worker this restores
        # custom modes the fresh registry does not have
        for mode in spec.mode_defs.values():
            register_mode(mode, overwrite=True)
    scen = spec.scenario
    wf, _hw, model, compiler = build_stack(spec)

    # the offline table is compiled for the scenario's *initial* mode
    # (via the portfolio's q-relaxation ladder, so pinned and replanned
    # runs start from the identical table) — a pinned run then keeps it
    # for the whole drive
    initial_mode = scen.segments[0].mode
    portfolio = spec.portfolio
    if portfolio is None:
        wanted = scen.modes() if spec.replan else (initial_mode,)
        portfolio = SchedulePortfolio.compile(
            model, wf, {m: get_mode(m) for m in wanted}, compiler,
            target_miss=spec.target_miss,
        )
    return wf, model, portfolio.schedules[initial_mode], portfolio


def _make_run_policy(spec: ScenarioSpec, portfolio: SchedulePortfolio):
    """Fresh policy (+ replanner) instance for one run/lane — replanner
    state (swap counters, forecast bookkeeping) is per-run, so batched
    lanes never share it; the compiled portfolio itself is read-only
    and shared."""
    scen = spec.scenario
    policy = make_policy(spec.policy)
    if spec.replan:
        if spec.replan_mode == "reactive":
            policy.replanner = OnlineReplanner(
                portfolio, detection_delay_s=spec.detection_delay_s
            )
        else:
            kw = dict(
                forecaster=scen.forecaster(route_informed=spec.route_forecast),
                lead_s=spec.forecast_lead_s,
                detection_delay_s=spec.detection_delay_s,
            )
            if spec.replan_mode == "blend":
                # hedge-only ablation: no forecast is confident enough
                # for a full pre-swap, every stage blends
                kw["confidence_hi"] = 2.0
            policy.replanner = PredictiveReplanner(portfolio, **kw)
    return policy


def _sim_config(
    spec: ScenarioSpec, trace: Optional[Trace], rec: Optional[TraceRecorder],
) -> SimConfig:
    scen = spec.scenario
    return SimConfig(
        duration_s=(
            scen.duration_s if spec.duration_s is None else spec.duration_s
        ),
        seed=spec.seed,
        drop_policy=spec.drop_policy,
        scenario=scen,
        trace=trace,
        recorder=rec,
    )


def run_scenario_batch(
    spec: ScenarioSpec,
    seeds: Sequence[int],
    recorders: Optional[Mapping[int, TraceRecorder]] = None,
) -> List[SimReport]:
    """Run ``len(seeds)`` Monte-Carlo drives of one spec through the
    batched lockstep engine and return one report per seed.

    Each lane's report is bit-identical to
    ``run_scenario(replace(spec, seed=s))`` — the stack/portfolio setup
    is shared, the stream-contract trace is batch-materialized once
    (:func:`~repro.core.sim.batch.sample_trace_batch`) and the lanes
    advance in lockstep (:func:`~repro.core.sim.batch.run_batch`).

    ``recorders`` optionally attaches a flight recorder to individual
    lanes by seed *index* — a recorded lane de-batches to the scalar
    per-lane driver (recorder hooks live on the engine paths the fused
    loop elides) but stays inside the lockstep loop, and its report
    gains the usual ``attribution`` section.  ``spec.record`` attaches
    one to every lane.
    """
    wf, model, sched, portfolio = _prepare_run(spec)
    scen = spec.scenario
    duration = scen.duration_s if spec.duration_s is None else spec.duration_s
    skel = build_skeleton(wf, scen, duration)
    btrace = sample_trace_batch(skel, model, scen, seeds)

    sims: List[LaneSimulator] = []
    recs: List[Optional[TraceRecorder]] = []
    for k, s in enumerate(seeds):
        rec = recorders.get(k) if recorders is not None else None
        if rec is None and spec.record:
            rec = TraceRecorder()
        lane_spec = dataclasses.replace(spec, seed=int(s))
        sims.append(LaneSimulator(
            wf, model, sched, _make_run_policy(lane_spec, portfolio),
            _sim_config(lane_spec, btrace.lane(k), rec),
        ))
        recs.append(rec)
    reports = run_batch(sims)
    for sim, rec, report in zip(sims, recs, reports):
        if rec is not None:
            report.attribution = attribution_report(sim, rec)
    return reports


#: per-process memo of SoA window pads that proved necessary, keyed by
#: (skeleton key, policy, drop policy, duration) — see run_scenario_soa
_SOA_LIFE_PAD_HINT: Dict[tuple, float] = {}


def run_scenario_soa(
    spec: ScenarioSpec,
    seeds: Sequence[int],
    options=None,
) -> List[SimReport]:
    """Run ``len(seeds)`` Monte-Carlo drives of one spec through the
    structure-of-arrays jax backend and return one report per seed.

    Unlike :func:`run_scenario_batch` (bit-identical lockstep lanes),
    the SoA backend advances all lanes as jnp arrays through discrete
    scheduling rounds: reports agree with the scalar engine
    *distributionally* (KS on chain latencies, CI overlap on summary
    rates) and *exactly* on structural invariants, but individual
    event timestamps differ at the round granularity — see
    ``docs/performance.md#soa-backend`` for the contract and for when
    this backend is profitable (many seeds of one scenario cell, e.g.
    tail estimation; the jit compile is amortized across lanes but
    repaid on every new scenario shape).

    Raises :class:`repro.core.sim.soa.SoaUnsupported` when jax is
    missing or the spec needs features outside the kernel's support
    set (predictive replanning, recorders, non-paper policies);
    callers wanting a silent fallback should check
    ``soa.soa_available()`` / ``soa.soa_supported(...)`` first.
    """
    from ..core.sim import soa

    if not soa.soa_available():
        raise soa.SoaUnsupported("jax is not available; use run_scenario_batch")
    if not soa.soa_supported(
        spec.policy,
        spec.replan_mode,
        spec.detection_delay_s,
        spec.drop_policy,
        spec.record,
    ):
        raise soa.SoaUnsupported(
            f"spec (policy={spec.policy!r}, replan_mode={spec.replan_mode!r}, "
            f"record={spec.record}) is outside the SoA support set"
        )
    wf, model, sched, portfolio = _prepare_run(spec)
    scen = spec.scenario
    duration = scen.duration_s if spec.duration_s is None else spec.duration_s
    skel = build_skeleton(wf, scen, duration)
    btrace = sample_trace_batch(skel, model, scen, seeds, device=True)
    # overloaded cells under drop_policy="soft" can queue jobs past the
    # default job-window lifetime bound; the backend refuses to return
    # truncated results (SoaWindowOverflow), so retry wider: first a
    # doubled window (mild overruns), then one capped at the horizon —
    # full job coverage, structurally incapable of overflowing.  A pad
    # that worked is remembered per cell so repeat calls (seed batches
    # of one cell, the backend's throughput shape) skip the discarded
    # detection run; the hint only ever *widens* the default, and only
    # applies when the caller did not pass explicit options.
    hint_key = (skel.key, spec.policy, spec.drop_policy, float(duration))
    opt0 = options if options is not None else soa.SoaOptions(
        life_pad_s=_SOA_LIFE_PAD_HINT.get(hint_key, 0.0)
    )
    opt = opt0
    for attempt in range(3):
        problem = soa.build_problem(
            wf, model, sched, portfolio,
            _make_run_policy(spec, portfolio), scen, duration,
            replan=spec.replan, n_lanes=len(seeds),
            drop_policy=spec.drop_policy, options=opt,
        )
        try:
            reports = soa.run_problem(problem, btrace, seeds)
        except soa.SoaWindowOverflow:
            if problem.life >= duration or attempt == 2:
                raise
            warnings.warn(
                f"SoA job window ({problem.life:.3f}s) overflowed under "
                "overload; retrying with a "
                + ("doubled" if attempt == 0 else "full-horizon")
                + " window (recompiles the round loop)",
                RuntimeWarning,
                stacklevel=2,
            )
            pad = problem.life if attempt == 0 else duration
            opt = dataclasses.replace(
                opt0, life_pad_s=opt0.life_pad_s + pad
            )
        else:
            if options is None and opt.life_pad_s > _SOA_LIFE_PAD_HINT.get(
                hint_key, 0.0
            ):
                _SOA_LIFE_PAD_HINT[hint_key] = opt.life_pad_s
            return reports


def run_scenario_group(
    specs: Sequence[ScenarioSpec], trace: Optional[Trace] = None,
) -> List[SimReport]:
    """Run one *group* — several specs sharing (scenario, seed,
    workload), differing in policy/replan — as lanes of one lockstep
    batch, sharing ``trace`` exactly like the scalar group runner.

    Reports are bit-identical to ``run_scenario(spec, trace=trace)``
    per spec; this is the batched path under :func:`sweep`.
    """
    sims: List[LaneSimulator] = []
    recs: List[Optional[TraceRecorder]] = []
    for spec in specs:
        wf, model, sched, portfolio = _prepare_run(spec)
        rec = TraceRecorder() if spec.record else None
        sims.append(LaneSimulator(
            wf, model, sched, _make_run_policy(spec, portfolio),
            _sim_config(spec, trace, rec),
        ))
        recs.append(rec)
    reports = run_batch(sims)
    for sim, rec, report in zip(sims, recs, reports):
        if rec is not None:
            report.attribution = attribution_report(sim, rec)
    return reports


# ---------------------------------------------------------------------------
# process-pool utility (reused by benchmarks/run.py --jobs)
# ---------------------------------------------------------------------------
def parallel_map(
    fn: Callable, items: Sequence, jobs: Optional[int] = None
) -> List:
    """``[fn(x) for x in items]``, fanned out over ``jobs`` processes.

    Order is preserved.  ``jobs`` <= 1 (or a single item) degrades to a
    plain in-process loop; ``jobs=None`` uses the CPU count capped at
    the number of items.  Uses the ``spawn`` start method — fork after
    JAX initialisation is unsafe — so ``fn`` and every item must be
    picklable (module-level functions and frozen dataclasses are).
    """
    if jobs is None:
        jobs = os.cpu_count() or 1
    jobs = min(jobs, len(items))
    if multiprocessing.current_process().daemon:
        # already inside a pool worker (e.g. a sweep launched by
        # ``benchmarks.run --jobs``): daemonic processes cannot spawn
        # children, so degrade to the in-process loop
        jobs = 1
    if jobs <= 1 or len(items) <= 1:
        return [fn(x) for x in items]
    ctx = multiprocessing.get_context("spawn")
    with ctx.Pool(processes=jobs) as pool:
        return pool.map(fn, items)


# ---------------------------------------------------------------------------
# Monte-Carlo sweeps
# ---------------------------------------------------------------------------
def summarize(spec: ScenarioSpec, report: SimReport) -> Dict[str, object]:
    """Flatten one run into a picklable summary row."""
    fc = report.forecast
    return {
        "scenario": spec.scenario.name,
        "script": spec.scenario.to_string(),
        "policy": spec.policy,
        "replan": spec.replan,
        "replan_mode": spec.replan_mode,
        "seed": spec.seed,
        "forecast": None if fc is None else {
            "n_forecasts": fc.n_forecasts,
            "n_preswaps": fc.n_preswaps,
            "n_blends": fc.n_blends,
            "n_hits": fc.n_hits,
            "n_misses": fc.n_misses,
            "n_reverts": fc.n_reverts,
            "hit_rate": fc.hit_rate,
            "prestage_stall_s": fc.prestage_stall_s,
        },
        "violation_rate": report.violation_rate,
        "task_miss_rate": report.task_miss_rate,
        "effective_frac": report.effective_frac,
        "realloc_frac": report.realloc_frac,
        "n_realloc": report.n_realloc,
        "n_mode_switches": report.n_mode_switches,
        "tiles_used": report.tiles_used,
        "tiles_reserved_mean": report.tiles_reserved_mean,
        "target_miss": spec.target_miss,
        # deadline-miss decomposition (recorded runs only, else None)
        "attribution": report.attribution,
        "per_mode": {
            m: {
                "span_s": s.span_s,
                "n_completed": s.n_completed,
                "n_violations": s.n_violations,
                "violation_rate": s.violation_rate,
                # None rather than NaN: NaN breaks row equality and JSON
                "p99_s": None if math.isnan(s.p99_s) else s.p99_s,
                "effective_frac": s.effective_frac,
                "realloc_frac": s.realloc_frac,
            }
            for m, s in report.mode_stats.items()
        },
    }


def _run_one(spec: ScenarioSpec) -> Dict[str, object]:
    return summarize(spec, run_scenario(spec))


def _run_group(
    specs: Sequence[ScenarioSpec], backend: str = "lockstep"
) -> List[Dict[str, object]]:
    """Run every spec of one scenario seed, sampling its trace once.

    All specs in a group share (scenario, seed, workload) and differ
    only in policy/replan, so one trace serves them all: the paired
    policy comparison stays exact at the job level while the sampling
    cost is paid once instead of once per policy.

    ``backend`` selects the engine (see :data:`SWEEP_BACKENDS`):

    * ``"lockstep"`` (default) — several specs route through the
      batched lockstep engine (:func:`run_scenario_group`); per-lane
      reports are bit-identical to the scalar path (the
      ``batch-equivalence`` CI gate pins this), so sweep rows are
      unchanged.
    * ``"scalar"`` — the per-event reference engine, one spec at a
      time (still sharing the group's sampled trace).
    * ``"soa"`` — the structure-of-arrays jax backend.  Rows are
      distributionally (not bitwise) equivalent to the other two.  A
      sweep group holds *one* seed per scenario, which is the SoA
      backend's worst shape (the jit compile cache only pays off
      across many seeds of one skeleton), so this selector exists for
      apples-to-apples validation sweeps; throughput work should call
      :func:`run_scenario_soa` with many seeds per cell instead.
      Specs outside the SoA support set fall back to the scalar
      engine, mirroring the lockstep engine's per-lane fallback.
    """
    if backend not in SWEEP_BACKENDS:
        raise ValueError(f"unknown backend {backend!r} (choose from {SWEEP_BACKENDS})")
    if backend == "soa":
        from ..core.sim import soa

        rows = []
        for s in specs:
            if soa.soa_available() and soa.soa_supported(
                s.policy, s.replan_mode, s.detection_delay_s,
                s.drop_policy, s.record,
            ):
                rows.append(summarize(s, run_scenario_soa(s, [s.seed])[0]))
            else:
                rows.append(summarize(s, run_scenario(s)))
        return rows
    if len(specs) <= 1 or backend == "scalar":
        return [summarize(s, run_scenario(s)) for s in specs]
    trace = build_trace(specs[0])
    reports = run_scenario_group(specs, trace=trace)
    return [summarize(s, r) for s, r in zip(specs, reports)]


def sweep(
    n_scenarios: int,
    policies: Sequence[str] = ("ads_tile", "tp_driven"),
    duration_s: float = 2.0,
    seed: int = 0,
    jobs: Optional[int] = None,
    generator: Optional[MarkovScenarioGenerator] = None,
    replan: bool = True,
    backend: str = "lockstep",
    **spec_kw,
) -> List[Dict[str, object]]:
    """Monte-Carlo sweep: ``n_scenarios`` Markov drives x ``policies``.

    Scenario ``i`` is sampled with the deterministic seed
    ``seed * 100003 + i`` and simulated with the same seed for every
    policy, so policy comparisons are paired and the whole sweep is
    reproducible from ``seed`` alone.  The unit of parallel work is one
    *scenario* (all its policies run in the same worker, sharing one
    sampled trace and one cached structural skeleton).

    ``backend`` selects the per-group engine (see :func:`_run_group`):
    ``"lockstep"`` (default, bit-identical rows), ``"scalar"``
    (reference engine), or ``"soa"`` (distributionally-equivalent jax
    backend; per-scenario jit compiles make it the validation shape
    here, not the throughput shape — use :func:`run_scenario_soa`
    directly for many-seed cells).
    """
    gen = generator or default_generator()
    all_modes = sorted(gen.transitions)
    mode_defs = {m: get_mode(m) for m in all_modes}
    groups: List[List[ScenarioSpec]] = []
    portfolios: Dict[str, SchedulePortfolio] = {}
    for i in range(n_scenarios):
        s_i = seed * 100003 + i
        script = gen.sample(duration_s, seed=s_i)
        group: List[ScenarioSpec] = []
        for pol in policies:
            spec = ScenarioSpec(
                scenario=script, policy=pol, replan=replan, seed=s_i,
                mode_defs=mode_defs,
                **spec_kw,
            )
            # one portfolio per policy, covering every mode the
            # generator can emit — compiled here once instead of per
            # worker run
            if pol not in portfolios:
                portfolios[pol] = compile_portfolio(spec, all_modes)
            group.append(dataclasses.replace(spec, portfolio=portfolios[pol]))
        groups.append(group)
    rows_per_group = parallel_map(
        functools.partial(_run_group, backend=backend), groups, jobs
    )
    return [row for rows in rows_per_group for row in rows]


def aggregate_sweep(
    rows: Sequence[Mapping[str, object]],
) -> Dict[str, Dict[str, object]]:
    """Aggregate sweep rows into per-policy means (and per-mode means).

    Returns ``{policy: {n, violation_rate, task_miss_rate,
    realloc_frac, per_mode: {mode: {...}}}}``.  Rows from recorded runs
    (``ScenarioSpec(record=True)``) additionally aggregate online into
    an ``attribution`` entry: summed lateness decomposed into
    queueing / realloc-stall / re-stagger / duration-tail seconds, so a
    sweep can print *why* a policy misses, not just how often.
    """
    out: Dict[str, Dict[str, object]] = {}
    by_pol: Dict[str, List[Mapping[str, object]]] = {}
    for r in rows:
        by_pol.setdefault(str(r["policy"]), []).append(r)
    for pol, rs in sorted(by_pol.items()):
        per_mode: Dict[str, Dict[str, List[float]]] = {}
        for r in rs:
            for m, st in r["per_mode"].items():  # type: ignore[union-attr]
                bucket = per_mode.setdefault(
                    m, {"violation_rate": [], "p99_s": [], "realloc_frac": []}
                )
                bucket["violation_rate"].append(st["violation_rate"])
                if st["p99_s"] is not None:
                    bucket["p99_s"].append(st["p99_s"])
                bucket["realloc_frac"].append(st["realloc_frac"])
        out[pol] = {
            "n": len(rs),
            "violation_rate": float(np.mean([r["violation_rate"] for r in rs])),
            "task_miss_rate": float(np.mean([r["task_miss_rate"] for r in rs])),
            "realloc_frac": float(np.mean([r["realloc_frac"] for r in rs])),
            "tiles_used": int(max(int(r.get("tiles_used", 0)) for r in rs)),
            "per_mode": {
                m: {k: float(np.mean(v)) if v else float("nan")
                    for k, v in b.items()}
                for m, b in sorted(per_mode.items())
            },
        }
        # online miss-attribution aggregation over recorded rows
        att_rows = [a for r in rs if (a := r.get("attribution")) is not None]
        if att_rows:
            comp = {"queueing": 0.0, "realloc_stall": 0.0,
                    "restagger": 0.0, "duration_tail": 0.0}
            for a in att_rows:
                for k in comp:
                    comp[k] += float(a["components_s"][k])
            out[pol]["attribution"] = {
                "n_recorded": len(att_rows),
                "n_late": sum(int(a["n_late"]) for a in att_rows),
                "n_dropped": sum(int(a["n_dropped"]) for a in att_rows),
                "n_degraded": sum(int(a["n_degraded"]) for a in att_rows),
                "lateness_s": sum(float(a["lateness_s"]) for a in att_rows),
                "components_s": comp,
            }
    return out
