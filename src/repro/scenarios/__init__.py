"""Driving-scenario subsystem: non-stationary, scriptable workloads.

The paper's premise — DNN execution time varies up to 3.3x with the
driving context — only matters if the workload actually *changes*
during a run.  This package makes it change:

* :mod:`~repro.scenarios.modes` — a registry of driving modes (urban,
  highway, parking, adverse weather, night), each a transform over the
  per-task latency profiles;
* :mod:`~repro.scenarios.script` — a scenario timeline DSL (ordered
  mode segments, transient bursts, sensor dropouts) plus a
  Markov-chain scenario generator;
* :mod:`~repro.scenarios.runner` — the :func:`run` entry point (one
  spec, a seed fan, or a spec group, over a selectable backend) and
  multiprocessing Monte-Carlo sweeps; :mod:`repro.sweeps` layers
  content-addressed caching and resumable campaigns on top.

The engine reacts through ``mode_change`` events and, when a policy
carries an :class:`~repro.core.runtime.OnlineReplanner`, hot-swaps
per-mode GHA schedules through the bounded-reallocation path.
"""
from .modes import MODES, DrivingMode, get_mode, mode_names, register_mode
from .script import (
    BUNDLED_SCENARIOS,
    DEGRADATION_TYPES,
    BandwidthLoss,
    Burst,
    MarkovScenarioGenerator,
    ModeSegment,
    ScenarioScript,
    SensorDropout,
    SensorDropoutStorm,
    ThermalThrottle,
    TileFault,
    default_generator,
    get_scenario,
)
from .runner import (
    SWEEP_BACKENDS,
    BackendRegistry,
    ItemFailure,
    ScenarioSpec,
    SweepBackend,
    SweepReducer,
    SweepRow,
    aggregate_sweep,
    build_trace,
    compile_portfolio,
    parallel_map,
    run,
    soa_usable,
    summarize,
    sweep,
)

__all__ = [
    "MODES",
    "DrivingMode",
    "get_mode",
    "mode_names",
    "register_mode",
    "BUNDLED_SCENARIOS",
    "DEGRADATION_TYPES",
    "BandwidthLoss",
    "Burst",
    "MarkovScenarioGenerator",
    "ModeSegment",
    "ScenarioScript",
    "SensorDropout",
    "SensorDropoutStorm",
    "ThermalThrottle",
    "TileFault",
    "default_generator",
    "get_scenario",
    "SWEEP_BACKENDS",
    "BackendRegistry",
    "ItemFailure",
    "ScenarioSpec",
    "SweepBackend",
    "SweepReducer",
    "SweepRow",
    "aggregate_sweep",
    "build_trace",
    "compile_portfolio",
    "parallel_map",
    "run",
    "soa_usable",
    "summarize",
    "sweep",
]
