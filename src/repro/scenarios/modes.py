"""Driving-mode registry: context-dependent latency-profile transforms.

The paper's premise is that DNN inference time in an ADS varies with the
driving context — up to 3.3x between the mean and the p99 [4] — and the
variation is *mode-structured*: urban vs. highway vs. parking, weather,
illumination and traffic density each shift whole groups of tasks at
once (Liu et al., "Understanding Time Variations of DNN Inference in
Autonomous Driving").  A :class:`DrivingMode` captures one such context
as a transform over :class:`~repro.core.latency_model.TaskLatencyProfile`s:

* ``work_scale`` — multiplier on every DNN task's mean FLOPs (scene
  complexity: number of agents, proposals, occupied voxels);
* ``p99_ratio_scale`` — widens/narrows the execution-variation tail F1;
* ``io_base_scale`` / ``io_rate_scale`` — shift the I/O contention model
  F2 (``rate`` is the M/M/1 service rate, so a scale < 1 makes queuing
  tails *heavier*);
* ``sensor_latency_scale`` — sensor preprocessing cost (e.g. denoising
  in rain, longer exposure at night);
* ``task_work_scale`` — per-task extra multipliers keyed by the *base*
  task name (cockpit replicas ``foo#r2`` inherit ``foo``'s entry);
* ``sensor_rate_scale`` / ``sensor_rate_hz`` — per-sensor *rate*
  modulation (ADS sensors run 10-240 Hz and adapt to context: cameras
  downclock at night for exposure, radar/LiDAR upclocks in rain).
  Rate changes alter the workflow's hyper-period, so the simulator
  re-unrolls the DAG piecewise at every regime boundary.

Modes are registered in a module-level registry so scenario scripts can
reference them by name; :func:`register_mode` adds custom ones.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Tuple

from ..core.latency_model import (
    LatencyModel,
    LogNormal,
    ShiftedExponential,
    TaskLatencyProfile,
)
from ..core.workload import Workflow

__all__ = [
    "DrivingMode",
    "MODES",
    "register_mode",
    "get_mode",
    "mode_names",
]

#: lognormal p99/mean ratios beyond this are unrepresentable (sigma
#: saturates in LogNormal); cap to keep widened tails well-defined
_MAX_P99_RATIO = 8.0


@dataclasses.dataclass(frozen=True)
class DrivingMode:
    """One driving context as a transform over task latency profiles."""

    name: str
    work_scale: float = 1.0
    p99_ratio_scale: float = 1.0
    io_base_scale: float = 1.0
    io_rate_scale: float = 1.0
    sensor_latency_scale: float = 1.0
    task_work_scale: Mapping[str, float] = dataclasses.field(default_factory=dict)
    #: per-sensor rate multipliers (2.0 doubles the rate, halving the
    #: period), keyed by base sensor name
    sensor_rate_scale: Mapping[str, float] = dataclasses.field(default_factory=dict)
    #: absolute per-sensor rate overrides in Hz; take precedence over
    #: ``sensor_rate_scale``
    sensor_rate_hz: Mapping[str, float] = dataclasses.field(default_factory=dict)
    description: str = ""

    def __post_init__(self) -> None:
        for k, v in {**self.sensor_rate_scale, **self.sensor_rate_hz}.items():
            if v <= 0:
                raise ValueError(f"mode {self.name}: non-positive rate for {k}")

    def _task_scale(self, task: str) -> float:
        base = task.split("#")[0]  # cockpit replicas inherit the base task
        return self.work_scale * float(self.task_work_scale.get(base, 1.0))

    def transform_profile(self, prof: TaskLatencyProfile) -> TaskLatencyProfile:
        """Return ``prof`` re-parameterised for this mode."""
        if prof.is_sensor:
            sl = prof.sensor_latency
            return dataclasses.replace(
                prof,
                sensor_latency=LogNormal(
                    sl.mean * self.sensor_latency_scale, sl.p99_ratio
                ),
            )
        ratio = min(
            max(1.0, prof.work.p99_ratio * self.p99_ratio_scale), _MAX_P99_RATIO
        )
        return dataclasses.replace(
            prof,
            work=LogNormal(prof.work.mean * self._task_scale(prof.name), ratio),
            io=ShiftedExponential(
                prof.io.base * self.io_base_scale,
                prof.io.rate * self.io_rate_scale,
            ),
        )

    def transform_model(self, model: LatencyModel) -> LatencyModel:
        """A new :class:`LatencyModel` with every profile transformed
        (the offline view used to compile this mode's GHA schedule)."""
        return LatencyModel(
            {n: self.transform_profile(p) for n, p in model.profiles.items()},
            model.hw,
        )

    # -- sensor-rate modulation -------------------------------------------
    @property
    def modulates_rates(self) -> bool:
        return bool(self.sensor_rate_scale or self.sensor_rate_hz)

    def sensor_period(self, sensor: str, base_period_s: float) -> float:
        """The period of ``sensor`` under this mode (absolute ``_hz``
        override first, else the base period over ``_scale``)."""
        base = sensor.split("#")[0]
        hz = self.sensor_rate_hz.get(base)
        if hz is not None:
            return 1.0 / hz
        return base_period_s / float(self.sensor_rate_scale.get(base, 1.0))

    def transform_workflow(self, wf: Workflow) -> Workflow:
        """``wf`` re-derived with this mode's sensor rates (returns
        ``wf`` itself when the mode modulates no rate).  The per-mode
        GHA compile consumes this so each mode's reservation table is
        built against its *own* hyper-period.

        Rate keys naming no sensor of ``wf`` raise: a typo'd key would
        otherwise silently modulate nothing.
        """
        if not self.modulates_rates:
            return wf
        known = {s.name.split("#")[0] for s in wf.sensor_tasks}
        unknown = sorted(
            k for k in {**self.sensor_rate_scale, **self.sensor_rate_hz}
            if k not in known
        )
        if unknown:
            raise ValueError(
                f"mode {self.name}: rate modulation for unknown sensor(s) "
                f"{unknown} (workflow sensors: {sorted(known)})"
            )
        return wf.with_sensor_rates({
            s.name: self.sensor_period(s.name, s.period_s)
            for s in wf.sensor_tasks
        })


#: the bundled mode registry (name -> DrivingMode)
MODES: Dict[str, DrivingMode] = {}


def register_mode(mode: DrivingMode, overwrite: bool = False) -> DrivingMode:
    if mode.name in MODES and not overwrite:
        raise ValueError(f"mode {mode.name!r} already registered")
    MODES[mode.name] = mode
    return mode


def get_mode(name: str) -> DrivingMode:
    try:
        return MODES[name]
    except KeyError:
        raise KeyError(
            f"unknown driving mode {name!r} (registered: {sorted(MODES)})"
        ) from None


def mode_names() -> Tuple[str, ...]:
    return tuple(sorted(MODES))


# ---------------------------------------------------------------------------
# bundled modes — scales chosen so the spread across modes reproduces the
# up-to-3.3x context variation the paper cites; per-task overrides follow
# the mode structure of Liu et al. (detection/prediction scale with agent
# density, sensors with weather/illumination).  Rate modulation follows
# the same source: cameras halve their rate at night (exposure), the
# LiDAR/radar group doubles in rain (denser returns needed), rush-hour
# perception upclocks the cameras.
# ---------------------------------------------------------------------------
register_mode(DrivingMode(
    name="urban",
    work_scale=1.30,
    p99_ratio_scale=1.15,
    io_rate_scale=0.80,
    task_work_scale={
        "vis_det": 1.30,      # dense scenes: more proposals
        "traj_pred": 1.50,    # many agents to predict
        "path_plan": 1.50,    # crowded solution space
        "traffic_light": 1.25,
    },
    description="dense traffic, many agents, frequent signals",
))
register_mode(DrivingMode(
    name="highway",
    work_scale=0.85,
    io_rate_scale=1.10,
    task_work_scale={"traffic_light": 0.50, "traj_pred": 0.80},
    description="sparse scenes at speed; light detection, long horizon",
))
register_mode(DrivingMode(
    name="parking",
    work_scale=0.55,
    p99_ratio_scale=0.90,
    io_rate_scale=1.20,
    task_work_scale={"traffic_light": 0.40, "traj_pred": 0.60},
    description="low speed, near-field perception only",
))
register_mode(DrivingMode(
    name="adverse_weather",
    work_scale=1.45,
    p99_ratio_scale=1.30,
    io_base_scale=1.30,
    io_rate_scale=0.60,
    sensor_latency_scale=1.50,
    task_work_scale={"lidar_det": 1.20, "depth_est": 1.20},
    sensor_rate_scale={"lidar": 2.0},       # 10 -> 20 Hz: denser returns
    description="rain/fog: denoising, degraded returns, heavy tails",
))
register_mode(DrivingMode(
    name="night",
    work_scale=1.10,
    p99_ratio_scale=1.15,
    sensor_latency_scale=1.30,
    task_work_scale={"traffic_light": 1.30, "optical_flow": 1.20},
    sensor_rate_scale={"cam_multi": 0.5},   # 30 -> 15 Hz: longer exposure
    description="low light: longer exposure, noisier imagery",
))
register_mode(DrivingMode(
    name="rush_hour",
    work_scale=1.35,
    p99_ratio_scale=1.20,
    io_rate_scale=0.75,
    task_work_scale={
        "vis_det": 1.35,
        "traj_pred": 1.60,
        "path_plan": 1.55,
        "traffic_light": 1.25,
    },
    sensor_rate_scale={"cam_multi": 2.0},   # 30 -> 60 Hz: dense traffic
    description="peak urban density: cameras upclocked, heavy prediction",
))
