"""Scenario timeline DSL + Markov-chain scenario generator.

A :class:`ScenarioScript` is a deterministic timeline of driving-mode
segments plus two kinds of transients:

* :class:`Burst` — a time window during which sampled workloads are
  scaled on top of the active mode (a traffic wave, a construction
  zone);
* :class:`SensorDropout` — a window during which one sensor produces no
  frames (occlusion, glare, a transport hiccup); downstream jobs run
  degraded exactly as the engine already models dropped predecessors.

Scripts are pure data (hashable, picklable) so a Monte-Carlo sweep can
ship them to worker processes, and the compact text form
``"urban:0.5 highway:1.0 urban:0.5"`` round-trips via :meth:`parse`.

:class:`MarkovScenarioGenerator` samples random scripts from a
mode-transition matrix with per-mode dwell times — the fleet-scale view
where each scenario is one drive.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..core.latency_model import LatencyModel, TaskLatencyProfile
from ..core.workload import Workflow
from .modes import get_mode

__all__ = [
    "ModeSegment",
    "Burst",
    "SensorDropout",
    "TileFault",
    "ThermalThrottle",
    "SensorDropoutStorm",
    "BandwidthLoss",
    "DEGRADATION_TYPES",
    "ScenarioScript",
    "MarkovScenarioGenerator",
    "default_generator",
    "BUNDLED_SCENARIOS",
    "get_scenario",
]


@dataclasses.dataclass(frozen=True)
class ModeSegment:
    mode: str
    duration_s: float

    def __post_init__(self) -> None:
        if self.duration_s <= 0:
            raise ValueError(f"segment {self.mode}: non-positive duration")


@dataclasses.dataclass(frozen=True)
class Burst:
    """Transient workload spike on top of the active mode."""

    start_s: float
    duration_s: float
    work_scale: float = 1.5
    tasks: Tuple[str, ...] = ()   # empty = every DNN task

    def active(self, task: str, t: float) -> bool:
        if not (self.start_s <= t < self.start_s + self.duration_s):
            return False
        return not self.tasks or task.split("#")[0] in self.tasks


@dataclasses.dataclass(frozen=True)
class SensorDropout:
    """Window during which one sensor produces no frames."""

    sensor: str
    start_s: float
    duration_s: float

    def active(self, sensor: str, t: float) -> bool:
        return (
            sensor == self.sensor
            and self.start_s <= t < self.start_s + self.duration_s
        )


# ---------------------------------------------------------------------------
# platform-degradation events (ROADMAP item 4)
# ---------------------------------------------------------------------------
# Unlike bursts/dropouts (which perturb the *workload*), these degrade
# the *platform* under it.  All four are pure frozen data with a common
# shape — ``kind`` tag, ``start_s``, and an ``end_s(horizon)`` giving
# the instant the platform effect lifts — so the engine can thread them
# through one event seam and account time-to-recover per event
# (docs/degradation.md).


@dataclasses.dataclass(frozen=True)
class TileFault:
    """A partition loses ``k_tiles`` tiles at ``start_s``.

    ``duration_s=None`` models a hard fault (the tiles never come
    back); a float models a recoverable fault (e.g. a tile island
    power-cycled back online).
    """

    start_s: float
    partition: int
    k_tiles: int
    duration_s: Optional[float] = None

    kind = "tile_fault"

    def __post_init__(self) -> None:
        if self.start_s < 0 or self.k_tiles <= 0 or self.partition < 0:
            raise ValueError(f"bad tile fault {self!r}")
        if self.duration_s is not None and self.duration_s <= 0:
            raise ValueError(f"bad tile fault duration {self.duration_s}")

    def end_s(self, horizon: float) -> float:
        if self.duration_s is None:
            return horizon
        return min(self.start_s + self.duration_s, horizon)


@dataclasses.dataclass(frozen=True)
class ThermalThrottle:
    """Thermal throttling: task durations stretch by up to ``scale``.

    The stretch ramps linearly over ``ramp_s`` on the way in and out
    (silicon heats and cools; a step is the ``ramp_s=0`` special case).
    The factor is a deterministic function of release time, applied in
    the trace skeleton exactly like a :class:`Burst` work multiplier —
    so throttled draws stay on the counter-based stream contract.
    """

    start_s: float
    duration_s: float
    scale: float = 1.3
    ramp_s: float = 0.0

    kind = "thermal_throttle"

    def __post_init__(self) -> None:
        if self.start_s < 0 or self.duration_s <= 0 or self.scale < 1.0:
            raise ValueError(f"bad thermal throttle {self!r}")
        if self.ramp_s < 0 or self.ramp_s > self.duration_s / 2:
            raise ValueError(
                f"throttle ramp {self.ramp_s} must fit twice in "
                f"duration {self.duration_s}"
            )

    def end_s(self, horizon: float) -> float:
        return min(self.start_s + self.duration_s, horizon)

    def factor(self, t: float) -> float:
        """Duration multiplier at time ``t`` (trapezoidal profile)."""
        t0, t1 = self.start_s, self.start_s + self.duration_s
        if not (t0 <= t < t1):
            return 1.0
        if self.ramp_s > 0.0:
            rise = min(1.0, (t - t0) / self.ramp_s)
            fall = min(1.0, (t1 - t) / self.ramp_s)
            return 1.0 + (self.scale - 1.0) * min(rise, fall)
        return self.scale


@dataclasses.dataclass(frozen=True)
class SensorDropoutStorm:
    """Random per-frame sensor losses over a window.

    Each release of a matching sensor inside the window is dropped with
    probability ``drop_frac`` — drawn on the dedicated degradation
    stream of the counter contract, so the storm changes no other draw
    of the run.  Contrast :class:`SensorDropout`, which silences one
    sensor completely.
    """

    start_s: float
    duration_s: float
    drop_frac: float = 0.3
    sensors: Tuple[str, ...] = ()   # empty = every sensor

    kind = "sensor_dropout_storm"

    def __post_init__(self) -> None:
        if self.start_s < 0 or self.duration_s <= 0:
            raise ValueError(f"bad dropout storm {self!r}")
        if not (0.0 <= self.drop_frac <= 1.0):
            raise ValueError(f"storm drop_frac {self.drop_frac} not in [0,1]")

    def end_s(self, horizon: float) -> float:
        return min(self.start_s + self.duration_s, horizon)

    def active(self, sensor: str, t: float) -> bool:
        if not (self.start_s <= t < self.start_s + self.duration_s):
            return False
        return not self.sensors or sensor.split("#")[0] in self.sensors


@dataclasses.dataclass(frozen=True)
class BandwidthLoss:
    """Transient loss of a fraction of the migration bandwidth.

    During the window every stop-migrate-restart stall's byte-transfer
    term is charged against ``(1 - frac)`` of the nominal NoC/DRAM
    bandwidth (the fixed decision/hop terms are unaffected).
    """

    start_s: float
    duration_s: float
    frac: float = 0.5

    kind = "bandwidth_loss"

    def __post_init__(self) -> None:
        if self.start_s < 0 or self.duration_s <= 0:
            raise ValueError(f"bad bandwidth loss {self!r}")
        if not (0.0 <= self.frac < 1.0):
            raise ValueError(f"bandwidth loss frac {self.frac} not in [0,1)")

    def end_s(self, horizon: float) -> float:
        return min(self.start_s + self.duration_s, horizon)


#: the degradation event union (kept in one place for isinstance checks)
DEGRADATION_TYPES = (TileFault, ThermalThrottle, SensorDropoutStorm,
                     BandwidthLoss)


@dataclasses.dataclass(frozen=True)
class ScenarioScript:
    """An ordered timeline of mode segments with optional transients."""

    name: str
    segments: Tuple[ModeSegment, ...]
    bursts: Tuple[Burst, ...] = ()
    dropouts: Tuple[SensorDropout, ...] = ()
    #: platform-degradation events (tile faults, thermal throttling,
    #: dropout storms, bandwidth loss) — see docs/degradation.md
    degradations: Tuple[object, ...] = ()

    def __post_init__(self) -> None:
        if not self.segments:
            raise ValueError("scenario needs at least one mode segment")
        for seg in self.segments:
            get_mode(seg.mode)  # fail fast on unknown modes
        for d in self.degradations:
            if not isinstance(d, DEGRADATION_TYPES):
                raise ValueError(
                    f"unknown degradation event {d!r} (want one of "
                    f"{[t.__name__ for t in DEGRADATION_TYPES]})"
                )

    # -- timeline queries -------------------------------------------------
    @property
    def duration_s(self) -> float:
        return sum(s.duration_s for s in self.segments)

    def modes(self) -> Tuple[str, ...]:
        """Distinct modes in order of first appearance."""
        seen: List[str] = []
        for s in self.segments:
            if s.mode not in seen:
                seen.append(s.mode)
        return tuple(seen)

    def boundaries(self) -> List[Tuple[float, str]]:
        """``(start_time, mode)`` per segment; first entry is at t=0."""
        out, t = [], 0.0
        for s in self.segments:
            out.append((t, s.mode))
            t += s.duration_s
        return out

    def mode_at(self, t: float) -> str:
        """Active mode at time ``t`` (clamped to the last segment)."""
        acc = 0.0
        for s in self.segments:
            acc += s.duration_s
            if t < acc:
                return s.mode
        return self.segments[-1].mode

    # -- forecast hooks ---------------------------------------------------
    def next_switch(self, t: float) -> Optional[Tuple[float, str]]:
        """``(switch_time, next_mode)`` for the first mode *change*
        strictly after ``t``, or ``None`` past the last seam.

        This is the route-informed forecast source: a scenario script
        *is* the planned route, so feeding it to a
        :class:`~repro.core.runtime.ModeForecaster` as ``timeline``
        models a navigation stack that knows the on-ramp is coming
        (switch times exact, confidence still bounded by the Markov
        structure — routes get re-planned).
        """
        acc = 0.0
        for i, s in enumerate(self.segments[:-1]):
            acc += s.duration_s
            nxt = self.segments[i + 1].mode
            if acc > t + 1e-12 and nxt != s.mode:
                return acc, nxt
        return None

    def empirical_transitions(
        self,
    ) -> Tuple[Dict[str, Dict[str, float]], Dict[str, float]]:
        """``(transitions, mean_dwell_s)`` estimated from the script's
        own segment bigrams — the Markov structure a fleet would learn
        from logged drives of this route.  Modes with no outgoing
        segment get an empty row (absorbing)."""
        trans: Dict[str, Dict[str, float]] = {m: {} for m in self.modes()}
        dwell_sum: Dict[str, float] = {}
        dwell_n: Dict[str, int] = {}
        for i, s in enumerate(self.segments):
            dwell_sum[s.mode] = dwell_sum.get(s.mode, 0.0) + s.duration_s
            dwell_n[s.mode] = dwell_n.get(s.mode, 0) + 1
            if i + 1 < len(self.segments):
                nxt = self.segments[i + 1].mode
                row = trans[s.mode]
                row[nxt] = row.get(nxt, 0.0) + 1.0
        mean_dwell = {m: dwell_sum[m] / dwell_n[m] for m in dwell_sum}
        return trans, mean_dwell

    def forecaster(self, route_informed: bool = True, **kw):
        """A :class:`~repro.core.runtime.ModeForecaster` primed with
        this script's empirical Markov structure; ``route_informed``
        additionally pins exact switch times from the timeline."""
        from ..core.runtime.forecast import ModeForecaster

        return ModeForecaster.from_script(
            self, use_timeline=route_informed, **kw
        )

    def burst_scale(self, task: str, t: float) -> float:
        scale = 1.0
        for b in self.bursts:
            if b.active(task, t):
                scale *= b.work_scale
        return scale

    def dropped(self, sensor: str, t: float) -> bool:
        return any(d.active(sensor, t) for d in self.dropouts)

    # -- degradation queries ----------------------------------------------
    @property
    def has_degradations(self) -> bool:
        return bool(self.degradations)

    def throttle_factor(self, t: float) -> float:
        """Deterministic duration multiplier from active throttles."""
        f = 1.0
        for d in self.degradations:
            if isinstance(d, ThermalThrottle):
                f *= d.factor(t)
        return f

    def storm_drop_frac(self, sensor: str, t: float) -> float:
        """Per-frame drop probability at ``(sensor, t)`` — overlapping
        storms compose as independent loss processes."""
        keep = 1.0
        for d in self.degradations:
            if isinstance(d, SensorDropoutStorm) and d.active(sensor, t):
                keep *= 1.0 - d.drop_frac
        return 1.0 - keep

    def bandwidth_scale(self, t: float) -> float:
        """Fraction of nominal migration bandwidth available at ``t``."""
        avail = 1.0
        for d in self.degradations:
            if isinstance(d, BandwidthLoss):
                if d.start_s <= t < d.start_s + d.duration_s:
                    avail *= 1.0 - d.frac
        return avail

    def throttles(self) -> Tuple[ThermalThrottle, ...]:
        """The thermal-throttle events (trace skeleton consumer — the
        core layer duck-types the script, so this accessor keeps it
        from importing the event classes)."""
        return tuple(
            d for d in self.degradations if isinstance(d, ThermalThrottle)
        )

    def storms(self) -> Tuple[SensorDropoutStorm, ...]:
        """The sensor-dropout-storm events (trace sampler consumer)."""
        return tuple(
            d for d in self.degradations if isinstance(d, SensorDropoutStorm)
        )

    def rate_regimes(
        self, wf: Workflow, end_s: float
    ) -> List[Tuple[float, float, Workflow]]:
        """Piecewise-constant sensor-rate timeline: ``(t0, t1, wf_r)``
        spans covering ``[0, max(end_s, script length))``.

        Adjacent segments whose modes agree on every sensor period are
        merged into one regime — a mode switch that touches no rate
        must not re-anchor the sensor timers (and a script with no
        rate-modulating mode collapses to a single regime, reproducing
        the stationary unrolling exactly).  At a regime boundary the
        hardware timers restart: the engine re-unrolls the DAG for
        ``wf_r`` with phase 0 at ``t0``.
        """
        bounds = self.boundaries()
        end = max(end_s, self.duration_s)
        out: List[List[object]] = []   # [t0, t1, wf_r]
        for i, (t0, mode) in enumerate(bounds):
            if t0 >= end - 1e-12:
                break
            t1 = bounds[i + 1][0] if i + 1 < len(bounds) else end
            wf_m = get_mode(mode).transform_workflow(wf)
            if out and out[-1][2].sensor_periods == wf_m.sensor_periods:
                out[-1][1] = t1        # same rates: extend, don't re-anchor
            else:
                out.append([t0, t1, wf_m])
        out[-1][1] = max(out[-1][1], end)
        return [(t0, t1, wf_r) for t0, t1, wf_r in out]

    def modulates_rates(self, wf: Workflow) -> bool:
        """True when any mode switch in the script changes a sensor
        period (i.e. the run needs piecewise re-unrolling)."""
        return len(self.rate_regimes(wf, self.duration_s)) > 1

    def cache_token(self) -> tuple:
        """Hashable identity of everything *structural* this script
        contributes to a simulation: the script itself (segments,
        bursts, dropouts are frozen tuples) plus the sensor-rate
        modulation of each referenced mode as currently registered.
        The trace-skeleton cache keys on this, so re-registering a mode
        with different rates invalidates stale skeletons while profile
        -only changes (which never alter structure) do not."""
        return (
            self,
            tuple(
                (
                    m,
                    tuple(sorted(get_mode(m).sensor_rate_scale.items())),
                    tuple(sorted(get_mode(m).sensor_rate_hz.items())),
                )
                for m in self.modes()
            ),
        )

    def profile_token(self) -> tuple:
        """The mode objects this script samples from, as currently
        registered.  ``DrivingMode`` is a frozen value-compared
        dataclass, so the trace sampler uses this (by equality) to
        notice a mode re-registered with different *profile* transforms
        — which must invalidate cached sampling parameters even though
        the structural :meth:`cache_token` rightly ignores it."""
        return tuple(get_mode(m) for m in self.modes())

    def profiles_for(
        self, model: LatencyModel
    ) -> Dict[str, Dict[str, TaskLatencyProfile]]:
        """Per-mode transformed profile tables (consumed by the engine's
        job builder)."""
        return {
            m: {
                n: get_mode(m).transform_profile(p)
                for n, p in model.profiles.items()
            }
            for m in self.modes()
        }

    # -- compact text form ------------------------------------------------
    def to_string(self) -> str:
        return " ".join(f"{s.mode}:{s.duration_s:g}" for s in self.segments)

    @classmethod
    def parse(cls, text: str, name: str = "parsed") -> "ScenarioScript":
        """Parse ``"urban:0.5 highway:1.0"`` (commas also accepted)."""
        segs = []
        for tok in text.replace(",", " ").split():
            mode, _, dur = tok.partition(":")
            if not dur:
                raise ValueError(f"bad segment {tok!r}: want mode:seconds")
            segs.append(ModeSegment(mode, float(dur)))
        return cls(name=name, segments=tuple(segs))


# ---------------------------------------------------------------------------
# Markov-chain scenario generation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MarkovScenarioGenerator:
    """Samples random :class:`ScenarioScript`s from a mode-transition
    matrix.

    Dwell time in mode ``m`` is ``mean_dwell_s[m] * U(0.5, 1.5)``
    (bounded, so every sampled scenario exercises several switches);
    with probability ``burst_prob`` a segment carries a workload burst,
    and with ``dropout_prob`` a sensor dropout.  Sampling is fully
    determined by ``seed``.
    """

    transitions: Mapping[str, Mapping[str, float]]
    mean_dwell_s: Mapping[str, float]
    initial: Optional[str] = None          # None = uniform over states
    burst_prob: float = 0.15
    dropout_prob: float = 0.05
    dropout_sensors: Tuple[str, ...] = ("cam_multi", "lidar")

    def sample(self, duration_s: float, seed: int) -> ScenarioScript:
        rng = np.random.RandomState(seed)
        states = sorted(self.transitions)
        mode = self.initial or states[rng.randint(len(states))]
        segs: List[ModeSegment] = []
        bursts: List[Burst] = []
        drops: List[SensorDropout] = []
        t = 0.0
        while t < duration_s - 1e-9:
            dwell = float(self.mean_dwell_s[mode]) * float(rng.uniform(0.5, 1.5))
            dwell = min(dwell, duration_s - t)
            segs.append(ModeSegment(mode, dwell))
            if rng.uniform() < self.burst_prob and dwell > 0.1:
                start = t + float(rng.uniform(0.0, dwell * 0.5))
                bursts.append(Burst(
                    start_s=start,
                    duration_s=float(rng.uniform(0.05, dwell * 0.5)),
                    work_scale=float(rng.uniform(1.3, 2.0)),
                ))
            if rng.uniform() < self.dropout_prob and dwell > 0.1:
                sensor = self.dropout_sensors[
                    rng.randint(len(self.dropout_sensors))
                ]
                start = t + float(rng.uniform(0.0, dwell * 0.5))
                drops.append(SensorDropout(
                    sensor=sensor,
                    start_s=start,
                    duration_s=float(rng.uniform(0.05, 0.2)),
                ))
            t += dwell
            nxt = self.transitions[mode]
            names = sorted(nxt)
            probs = np.asarray([nxt[n] for n in names], dtype=float)
            probs /= probs.sum()
            mode = names[int(rng.choice(len(names), p=probs))]
        # self-transitions extend the dwell rather than splitting the
        # timeline into equal-mode segments
        merged: List[ModeSegment] = []
        for seg in segs:
            if merged and merged[-1].mode == seg.mode:
                merged[-1] = ModeSegment(
                    seg.mode, merged[-1].duration_s + seg.duration_s
                )
            else:
                merged.append(seg)
        return ScenarioScript(
            name=f"markov-{seed}",
            segments=tuple(merged),
            bursts=tuple(bursts),
            dropouts=tuple(drops),
        )


#: plausible drive structure: urban is the hub; weather strikes from
#: urban/highway and clears back; parking only borders urban; rush
#: hour builds out of (and decays back into) ordinary urban traffic.
#: rush_hour upclocks the cameras (30 -> 60 Hz), so random Monte-Carlo
#: drives now exercise sensor-rate churn — piecewise re-unrolling and
#: rate-seam hot-swaps — not just the scripted rate benchmarks.
DEFAULT_TRANSITIONS: Dict[str, Dict[str, float]] = {
    "urban": {"highway": 0.30, "parking": 0.13, "adverse_weather": 0.14,
              "night": 0.09, "rush_hour": 0.12, "urban": 0.22},
    "highway": {"urban": 0.40, "adverse_weather": 0.15, "night": 0.10,
                "rush_hour": 0.05, "highway": 0.30},
    "parking": {"urban": 0.90, "parking": 0.10},
    "adverse_weather": {"urban": 0.50, "highway": 0.30,
                        "adverse_weather": 0.20},
    "night": {"urban": 0.40, "highway": 0.40, "night": 0.20},
    "rush_hour": {"urban": 0.55, "highway": 0.20, "rush_hour": 0.25},
}

DEFAULT_DWELL_S: Dict[str, float] = {
    "urban": 0.8, "highway": 1.0, "parking": 0.5,
    "adverse_weather": 0.7, "night": 0.9, "rush_hour": 0.6,
}


def default_generator(**overrides) -> MarkovScenarioGenerator:
    kw = dict(transitions=DEFAULT_TRANSITIONS, mean_dwell_s=DEFAULT_DWELL_S)
    kw.update(overrides)
    return MarkovScenarioGenerator(**kw)


# ---------------------------------------------------------------------------
# bundled named scenarios (used by tests, benchmarks and the demo)
# ---------------------------------------------------------------------------
BUNDLED_SCENARIOS: Dict[str, ScenarioScript] = {
    # leave the garage into rush-hour traffic, then a downpour: the
    # parking-mode schedule is badly undersized for what follows, which
    # is exactly the case online replanning exists for
    "calm_to_rush": ScenarioScript(
        name="calm_to_rush",
        segments=(
            ModeSegment("parking", 0.4),
            ModeSegment("urban", 0.8),
            ModeSegment("adverse_weather", 0.8),
        ),
    ),
    # a commute: city -> highway -> city with a mid-drive traffic wave
    "commute": ScenarioScript(
        name="commute",
        segments=(
            ModeSegment("urban", 0.6),
            ModeSegment("highway", 0.8),
            ModeSegment("urban", 0.6),
        ),
        bursts=(Burst(start_s=1.6, duration_s=0.2, work_scale=1.6),),
    ),
    # night highway run hitting a storm with a brief camera dropout
    "night_storm": ScenarioScript(
        name="night_storm",
        segments=(
            ModeSegment("night", 0.6),
            ModeSegment("adverse_weather", 0.8),
            ModeSegment("highway", 0.6),
        ),
        dropouts=(SensorDropout("cam_multi", 0.8, 0.15),),
    ),
    # pure rate churn: cameras at 15 Hz before dawn, 30 Hz through the
    # morning, 60 Hz in rush hour — every seam changes the hyper-period,
    # so the engine re-unrolls piecewise and the runtime must swap to a
    # table compiled for the new rates (the figS_rates benchmark)
    "rate_churn": ScenarioScript(
        name="rate_churn",
        segments=(
            ModeSegment("night", 0.6),
            ModeSegment("urban", 0.6),
            ModeSegment("rush_hour", 0.8),
        ),
    ),
    # the platform degrades mid-drive (ROADMAP item 4): a camera glare
    # storm on the on-ramp, then a tile island faults out of the
    # perception partition right as rush-hour load arrives — with the
    # migration bandwidth halved while the island power-cycles — and
    # the silicon throttles thermally on the way out.  figS_degrade
    # compares how the policies ride through it on paired traces.
    "degraded_commute": ScenarioScript(
        name="degraded_commute",
        segments=(
            ModeSegment("urban", 0.6),
            ModeSegment("rush_hour", 0.8),
            ModeSegment("urban", 0.6),
        ),
        degradations=(
            SensorDropoutStorm(start_s=0.3, duration_s=0.2,
                               drop_frac=0.3, sensors=("cam_multi",)),
            TileFault(start_s=0.7, partition=1, k_tiles=8, duration_s=0.5),
            BandwidthLoss(start_s=0.7, duration_s=0.5, frac=0.5),
            ThermalThrottle(start_s=1.3, duration_s=0.4,
                            scale=1.25, ramp_s=0.1),
        ),
    ),
}


def get_scenario(name: str) -> ScenarioScript:
    try:
        return BUNDLED_SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r} (bundled: {sorted(BUNDLED_SCENARIOS)})"
        ) from None
