"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1
[arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; pattern:
(recurrent, recurrent, attention) repeating; window 2048; lru_width
4096.  head_dim 256 so 16 heads span d_model... (Griffin uses
head_dim=256 MQA).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    window=2048,
    lru_blocks_per_attn=2,
    lru_width=4096,
    conv_width=4,
    rope_theta=10000.0,
    tie_embeddings=True,
    dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG,
        num_layers=5,   # 1 full (r,r,a) unit + 2 trailing lru blocks
        d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=128, window=8, lru_width=64, dtype="float32",
    )
