"""The four assigned input-shape cells (LM-family transformers).

``train_4k`` lowers ``train_step``; ``prefill_32k`` lowers the prefill
``serve_step``; ``decode_32k`` / ``long_500k`` lower the one-token
decode ``serve_step`` with a KV/state cache of the given length.
``long_500k`` requires sub-quadratic attention and only runs for the
SSM/hybrid families (skips recorded per DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

__all__ = ["ShapeSpec", "SHAPES", "runnable_cells"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def runnable_cells(cfg) -> List[Tuple[str, str]]:
    """All (arch, shape) cells this config runs; long_500k only for
    sub-quadratic families."""
    cells = []
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        cells.append((cfg.name, s))
    if cfg.sub_quadratic:
        cells.append((cfg.name, "long_500k"))
    return cells
