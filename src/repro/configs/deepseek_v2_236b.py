"""deepseek-v2-236b [moe] — MLA + 2 shared + 160 routed top-6
[arXiv:2405.04434].

60L d_model=5120 128H, MLA kv_lora=512 q_lora=1536 nope/rope 128/64
v=128; expert d_ff=1536; first layer dense (d_ff 12288);
vocab=102400.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=192,                 # qk_nope + qk_rope
    d_ff=12288,                   # the leading dense layer
    vocab_size=102400,
    num_experts=160,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1536,
    first_dense_layers=1,
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    rope_theta=10000.0,
    tie_embeddings=False,
    dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG,
        num_layers=3, d_model=64, num_heads=4, head_dim=24, d_ff=128,
        vocab_size=128, num_experts=4, experts_per_token=2,
        num_shared_experts=1, moe_d_ff=32,
        kv_lora_rank=32, q_lora_rank=48, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, dtype="float32",
    )
