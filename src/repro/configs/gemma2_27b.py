"""gemma2-27b [dense] — local+global alternating, logit softcap
[arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000; head_dim=128;
1:1 local(4096):global alternation; attn softcap 50, final softcap 30.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    window=4096,
    pattern_period=2,
    global_layer_ids=(1,),        # local, global, local, global, ...
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    rope_theta=10000.0,
    tie_embeddings=True,
    dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG,
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, window=16, dtype="float32",
    )
