"""mamba2-2.7b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

64L d_model=2560 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
expand=2 => d_inner=5120; headdim=64 => 80 SSD heads; 1 B/C group.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    conv_width=4,
    ssd_chunk=256,
    tie_embeddings=True,
    dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG,
        num_layers=2, d_model=64, vocab_size=128,
        ssm_state=16, ssm_head_dim=16, ssd_chunk=16,
        dtype="float32",
    )
