"""Architecture configs (one module per assigned arch) + shape cells."""
from __future__ import annotations

import importlib
from typing import Dict

from ..models.config import ModelConfig
from .shapes import SHAPES, ShapeSpec, runnable_cells

ARCHS = (
    "mamba2_2p7b",
    "gemma2_27b",
    "gemma3_4b",
    "phi4_mini_3p8b",
    "stablelm_12b",
    "recurrentgemma_9b",
    "granite_moe_1b",
    "deepseek_v2_236b",
    "phi3_vision_4p2b",
    "musicgen_large",
)

_ALIAS = {
    "mamba2-2.7b": "mamba2_2p7b",
    "gemma2-27b": "gemma2_27b",
    "gemma3-4b": "gemma3_4b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "stablelm-12b": "stablelm_12b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "musicgen-large": "musicgen_large",
}


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    mod_name = _ALIAS.get(name, name.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced() if reduced else mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}


__all__ = [
    "ARCHS",
    "SHAPES",
    "ShapeSpec",
    "get_config",
    "all_configs",
    "runnable_cells",
]
