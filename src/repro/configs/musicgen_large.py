"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].

48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048; 4 EnCodec
codebooks (embeddings summed, 4 output heads; the delay-pattern
interleaving and the EnCodec encoder are data-pipeline stubs per the
assignment).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    num_codebooks=4,
    rope_theta=10000.0,
    tie_embeddings=True,
    dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG,
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=64, num_codebooks=2, dtype="float32",
    )
