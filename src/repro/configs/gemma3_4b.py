"""gemma3-4b [dense] — 5:1 local:global, 128k context
[hf:google/gemma-3-*-pt].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144; head_dim=256;
window 1024 on local layers; rope base 1M global / 10k local.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    window=1024,
    pattern_period=6,
    global_layer_ids=(5,),        # 5 local then 1 global
    qk_norm=True,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    attn_logit_softcap=0.0,
    final_logit_softcap=0.0,
    tie_embeddings=True,
    dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG,
        num_layers=6, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, window=8, dtype="float32",
    )
