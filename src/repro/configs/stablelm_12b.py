"""stablelm-12b [dense] [hf:stabilityai/stablelm-2-12b].

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352; head_dim=160;
per-head QK-norm.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    qk_norm=True,
    rope_theta=10000.0,
    tie_embeddings=False,
    dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG,
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128, dtype="float32",
    )
