"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend STUB
[hf:microsoft/Phi-3-vision-128k-instruct].

32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064.  The CLIP
vision tower is a stub per the assignment: ``input_specs()`` provides
precomputed patch embeddings (576 patches) prepended to the token
embeddings.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    num_patches=576,
    rope_theta=10000.0,
    tie_embeddings=True,
    dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG,
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, num_patches=16, dtype="float32",
    )
