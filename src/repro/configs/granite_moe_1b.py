"""granite-moe-1b-a400m [moe] — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=0,
    vocab_size=49155,
    num_experts=32,
    experts_per_token=8,
    moe_d_ff=512,
    rope_theta=10000.0,
    tie_embeddings=True,
    dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG,
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        vocab_size=128, num_experts=4, experts_per_token=2, moe_d_ff=32,
        dtype="float32",
    )
