"""Observability layer: flight recorder, trace export, miss
attribution, and a self-profiling metrics registry.

This package observes the rest of the reproduction without being
imported by it: the engine holds the recorder behind a duck-typed
``SimConfig.recorder`` slot, and core modules reach only
:mod:`repro.obs.metrics` (which imports nothing from core), so there
are no import cycles and no overhead when nothing is recording.

Entry points:

* :class:`TraceRecorder` — pass as ``SimConfig(recorder=...)`` or use
  ``ScenarioSpec(record=True)``;
* :func:`export_chrome_trace` — Perfetto / ``chrome://tracing`` JSON;
* :func:`attribute_misses` / :func:`attribution_report` — decompose
  each missed chain's lateness (queueing / realloc stall / re-stagger /
  duration tail);
* :mod:`~repro.obs.metrics` — counters + phase timers, exported as the
  benchmark JSON's ``profile`` section.

See ``docs/observability.md`` for the event taxonomy and a Perfetto
walkthrough.
"""
from . import metrics
from .attribution import (
    ChainMiss,
    attribute_misses,
    attribution_report,
    summarize_attribution,
)
from .events import EVENT_KINDS, TraceEvent, TraceRecorder
from .export import chrome_trace, export_chrome_trace
from .schema import SchemaError, validate_trace

__all__ = [
    "EVENT_KINDS",
    "ChainMiss",
    "SchemaError",
    "TraceEvent",
    "TraceRecorder",
    "attribute_misses",
    "attribution_report",
    "chrome_trace",
    "export_chrome_trace",
    "metrics",
    "summarize_attribution",
    "validate_trace",
]
