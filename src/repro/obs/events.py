"""Flight-recorder event rows + the zero-overhead-when-off recorder.

The engine's accounting (``SimReport``) is all *aggregates*; this
module records the *timeline*: typed, append-only event rows for every
job lifecycle transition, every partition stall, every table swap and
every forecast, so a run can be replayed, visualized
(:mod:`~repro.obs.export`) and decomposed
(:mod:`~repro.obs.attribution`) after the fact.

Design constraints, in order:

1. **Zero overhead when off.**  The engine holds ``self._rec``
   (``SimConfig.recorder``, default ``None``) and every hook site is a
   single ``if rec is not None`` guard — a recorder-less run executes
   the exact same arithmetic as before the hooks existed, and
   pinned-seed reports stay bit-identical (pinned by
   ``tests/test_obs.py``).
2. **Append-only typed rows.**  One frozen :class:`TraceEvent` per
   occurrence; the recorder never mutates or reorders past rows.  Rows
   carry simulation time in seconds, a kind from :data:`EVENT_KINDS`,
   and whichever of jid/task/partition/chain apply (sentinels
   otherwise), so downstream passes need no engine internals.
3. **Cheap enabled path.**  ``emit`` is one dataclass construction and
   a list append; per-partition stall windows are additionally indexed
   on the fly (they are the one thing the attribution pass needs in
   interval rather than event form).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

__all__ = ["EVENT_KINDS", "TraceEvent", "TraceRecorder"]


#: the event taxonomy (docs/observability.md documents each kind)
EVENT_KINDS = frozenset({
    # job lifecycle
    "job_release",      # sensor frame released by its hardware timer
    "job_ready",        # DNN job's inputs arrived (deps drained)
    "job_start",        # tiles granted; value = DoP
    "job_chunk",        # chunk-boundary scheduling point
    "job_resize",       # DoP changed mid-run; value = new DoP
    "job_preempt",      # running job pushed back to READY; value = freed DoP
    "job_finish",       # completion; value = DoP held at finish
    "job_drop",         # terminated (deadline dequeue / sensor dropout)
    # chain accounting
    "chain_complete",   # sink finished; value = E2E latency (s)
    "deadline_miss",    # completed late; value = lateness (s)
    "chain_drop",       # sink dropped: a violation with no completion
    # partition / reallocation
    "stall_begin",      # stop-migrate-restart stall opens; value = stall (s)
    "stall_end",        # partition resumes
    "realloc",          # DoP reallocation applied; value = bytes moved
    "hotswap",          # schedule table installed; value = summed stall (s)
    "prestage",         # background staging window; value = bytes staged
    # degraded operation (docs/degradation.md)
    "degrade_begin",    # injected platform event applies; info = kind
    "degrade_end",      # its effect lifts; info = kind
    "morph",            # online partition split/merge; value = new count
    # control plane
    "mode_change",      # driving-context switch; info = new mode
    "rate_seam",        # sensor-rate regime boundary; value = hyper-period
    "forecast_arm",     # forecast scheduling point armed; value = fire time
    "forecast_fire",    # armed forecast delivered to the policy
    "drain_arm",        # drain watch armed
    "drain_clear",      # drain watch cleared
    "schedule",         # initial table metadata; value = peak tiles
})


@dataclasses.dataclass(frozen=True, slots=True)
class TraceEvent:
    """One recorded occurrence.  ``t`` is simulation seconds; unused
    reference fields hold sentinels (``jid=-1``, ``partition=-1``,
    empty strings, ``data=None``)."""

    t: float
    kind: str
    jid: int = -1
    task: str = ""
    partition: int = -1
    chain: str = ""
    value: float = 0.0
    info: str = ""
    data: Optional[dict] = None


class TraceRecorder:
    """Append-only flight recorder for one simulation run.

    Pass one as ``SimConfig(recorder=...)`` (or
    ``ScenarioSpec(record=True)`` to have the runner create it).  A
    recorder is single-run: reusing one across Simulators interleaves
    their timelines.
    """

    __slots__ = ("events", "meta", "stall_windows", "_open_stalls", "end_s")

    def __init__(self) -> None:
        self.events: List[TraceEvent] = []
        #: run metadata filled by the engine at ``run()`` start
        #: (tiles, partition capacities, policy, seed, horizon)
        self.meta: Dict[str, object] = {}
        #: partition -> closed [begin, end] stall intervals, in order
        self.stall_windows: Dict[int, List[Tuple[float, float]]] = {}
        self._open_stalls: Dict[int, float] = {}
        #: horizon the run drained to; set by :meth:`finalize`
        self.end_s: Optional[float] = None

    # -- recording (engine-facing; the hot path) -----------------------
    def emit(
        self,
        t: float,
        kind: str,
        jid: int = -1,
        task: str = "",
        partition: int = -1,
        chain: str = "",
        value: float = 0.0,
        info: str = "",
        data: Optional[dict] = None,
    ) -> None:
        self.events.append(
            TraceEvent(t, kind, jid, task, partition, chain, value, info, data)
        )

    def stall_begin(self, partition: int, t: float) -> None:
        """Open (or extend) the stall window of ``partition``.  The
        engine may re-stall an already stalled partition (a hot-swap on
        top of a resize extends ``stall_end``); the window keeps the
        earliest begin and closes at the real resume."""
        if partition not in self._open_stalls:
            self._open_stalls[partition] = t

    def stall_end(self, partition: int, t: float) -> None:
        t0 = self._open_stalls.pop(partition, None)
        if t0 is not None:
            self.stall_windows.setdefault(partition, []).append((t0, t))

    def finalize(self, end_s: float) -> None:
        """Close the recording at the horizon: open stall windows are
        clipped to ``end_s`` (a run can end mid-stall)."""
        for p in list(self._open_stalls):
            self.stall_end(p, end_s)
        self.end_s = end_s

    # -- reading (exporter/attribution-facing) -------------------------
    def by_kind(self, kind: str) -> Iterator[TraceEvent]:
        return (e for e in self.events if e.kind == kind)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def __len__(self) -> int:
        return len(self.events)
