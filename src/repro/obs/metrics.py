"""Self-profiling registry: named counters + phase timers.

The reproduction's own machinery (skeleton build, trace sampling,
portfolio compiles, autotune search, the engine event loop) is what the
performance docs reason about, so it should be measurable without an
external profiler.  This module is a process-global registry of

* **counters** — monotonically increasing named integers/floats
  (``count("skeleton_cache_hit")``), and
* **phase timers** — wall-clock accumulators around named phases
  (``with phase("engine_run"): ...``), recording call count and total
  seconds.

Everything is **disabled by default**: instrumented call sites pay one
module-level boolean check and nothing else, so the hot paths the
registry observes are not perturbed by it (the same
zero-overhead-when-off contract as the engine's
:class:`~repro.obs.events.TraceRecorder`).  ``benchmarks/run.py``
enables it for ``--out``/``--trace-out`` runs and exports
:func:`snapshot` as the benchmark JSON's ``profile`` section.

The registry is deliberately not thread-safe and not shared across
``spawn`` pool workers — each process profiles itself; parent-side
snapshots cover the parent's own work (compiles, single runs, the
non-parallel sweep path).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict, Iterator, List

__all__ = [
    "count",
    "enable",
    "enabled",
    "phase",
    "reset",
    "snapshot",
]

_enabled: bool = False
_counters: Dict[str, float] = {}
#: name -> [n_calls, total_seconds]
_phases: Dict[str, List[float]] = {}


def enable(on: bool = True) -> None:
    """Turn the registry on (or off).  Off is the default; call sites
    compiled into hot paths only ever pay the boolean check."""
    global _enabled
    _enabled = on


def enabled() -> bool:
    return _enabled


def reset() -> None:
    """Clear all counters and timers (the enable flag is untouched)."""
    _counters.clear()
    _phases.clear()


def count(name: str, value: float = 1) -> None:
    """Add ``value`` to counter ``name`` (no-op while disabled)."""
    if _enabled:
        _counters[name] = _counters.get(name, 0) + value


@contextmanager
def phase(name: str) -> Iterator[None]:
    """Time a named phase (no-op while disabled).

    Re-entrant in the trivial sense: nested/repeated phases of the same
    name accumulate into one bucket."""
    if not _enabled:
        yield
        return
    t0 = time.perf_counter()
    try:
        yield
    finally:
        dt = time.perf_counter() - t0
        slot = _phases.get(name)
        if slot is None:
            _phases[name] = [1, dt]
        else:
            slot[0] += 1
            slot[1] += dt


def snapshot(reset_after: bool = False) -> Dict[str, object]:
    """A picklable/JSON-able view of everything recorded so far:
    ``{"counters": {name: value}, "phases": {name: {"n", "total_s",
    "mean_s"}}}``."""
    out: Dict[str, object] = {
        "counters": dict(sorted(_counters.items())),
        "phases": {
            name: {
                "n": int(n),
                "total_s": total,
                "mean_s": total / n if n else 0.0,
            }
            for name, (n, total) in sorted(_phases.items())
        },
    }
    if reset_after:
        reset()
    return out
