"""Chrome-trace / Perfetto JSON exporter for recorded runs.

Turns a :class:`~repro.obs.events.TraceRecorder` into the Trace Event
Format consumed by ``chrome://tracing`` and https://ui.perfetto.dev —
the run becomes a scrollable timeline instead of a scalar report:

* **one track group per partition** — jobs are laid out on tile
  *lanes* (greedy interval coloring, so concurrent jobs of one
  partition stack instead of overlap), with a dedicated ``stalls``
  lane rendering every stop-migrate-restart window as a slice;
* **sensor tracks** — one per sensor, slices from release to frame
  delivery;
* **counter tracks** — per-partition allocated tiles, cumulative
  reallocation bytes, and the active table's reserved peak tiles;
* **flow events** — each E2E chain completion links its source sensor
  slice to its sink slice, so deadline chains render as arrows
  threading across the swap stalls (violated chains are flagged in
  ``args``);
* **instant markers** — mode changes, rate seams, hot-swaps,
  pre-stage windows, forecast arm/fire, drain watch.

Timestamps are microseconds (the format's unit); simulation second 0
maps to ts 0.  The export validates against the checked-in
``trace_schema.json`` (see :mod:`~repro.obs.schema`).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

from .events import TraceRecorder

__all__ = ["chrome_trace", "export_chrome_trace"]

_US = 1e6
_PID = 1
#: tid layout: small fixed tids for marker tracks, one block of 10 for
#: sensor tracks, one block of 100 per partition (stall lane + job lanes)
_TID_CONTEXT = 1
_TID_RUNTIME = 2
_TID_SENSOR0 = 10
_PART_BLOCK = 100


def _part_base(p: int) -> int:
    return _PART_BLOCK * (p + 1)


def _assign_lanes(
    slices: List[dict], base_tid: int, max_lanes: int = 64
) -> None:
    """Greedy interval coloring: place each slice (sorted by start) on
    the first lane whose previous slice has ended.  Mutates ``tid`` in
    place."""
    lanes: List[float] = []
    for s in sorted(slices, key=lambda s: (s["_t0"], s["_t1"])):
        lane = None
        for i, end in enumerate(lanes):
            if end <= s["_t0"] + 1e-12:
                lane = i
                break
        if lane is None:
            if len(lanes) < max_lanes:
                lanes.append(0.0)
                lane = len(lanes) - 1
            else:  # saturated: stack on the last lane rather than drop
                lane = len(lanes) - 1
        lanes[lane] = s["_t1"]
        s["tid"] = base_tid + 1 + lane


def chrome_trace(recorder: TraceRecorder) -> dict:
    """Build the Trace Event Format object for one recorded run."""
    events = recorder.events
    end_s = recorder.end_s
    if end_s is None:
        end_s = max((e.t for e in events), default=0.0)

    out: List[dict] = []
    meta_rows: List[dict] = []

    def thread_meta(tid: int, name: str, sort: int) -> None:
        meta_rows.append({
            "ph": "M", "name": "thread_name", "pid": _PID, "tid": tid,
            "args": {"name": name},
        })
        meta_rows.append({
            "ph": "M", "name": "thread_sort_index", "pid": _PID, "tid": tid,
            "args": {"sort_index": sort},
        })

    meta_rows.append({
        "ph": "M", "name": "process_name", "pid": _PID,
        "args": {"name": "tile-stream run"},
    })
    thread_meta(_TID_CONTEXT, "context (modes / rate seams)", 0)
    thread_meta(_TID_RUNTIME, "runtime (swaps / forecasts)", 1)

    # ------------------------------------------------------------------
    # job slices (open on start, close on finish/drop, clip at horizon)
    # ------------------------------------------------------------------
    open_jobs: Dict[int, dict] = {}
    slices: List[dict] = []
    sensor_tasks: List[str] = []

    def close(jid: int, t1: float, dropped: bool) -> None:
        s = open_jobs.pop(jid, None)
        if s is None:
            return
        s["_t1"] = max(t1, s["_t0"])
        if dropped:
            s["args"]["dropped"] = True
        slices.append(s)

    # per-partition allocation / cumulative realloc-byte counters,
    # emitted while walking the event stream once
    alloc: Dict[int, int] = {}
    rbytes: Dict[int, float] = {}
    reserved = 0.0

    def counter(t: float, name: str, value: float) -> None:
        out.append({
            "ph": "C", "name": name, "pid": _PID, "tid": 0,
            "ts": t * _US, "args": {"value": value},
        })

    def bump_alloc(t: float, p: int, delta: float) -> None:
        if p < 0 or not delta:
            return
        alloc[p] = alloc.get(p, 0) + int(delta)
        counter(t, f"tiles alloc p{p}", alloc[p])

    def bump_bytes(t: float, p: int, nbytes: float) -> None:
        if p < 0 or nbytes <= 0:
            return
        rbytes[p] = rbytes.get(p, 0.0) + nbytes
        counter(t, f"realloc bytes p{p}", rbytes[p])

    def instant(t: float, tid: int, name: str, args: Optional[dict] = None,
                scope: str = "t") -> None:
        row = {
            "ph": "i", "name": name, "pid": _PID, "tid": tid,
            "ts": t * _US, "s": scope,
        }
        if args:
            row["args"] = args
        out.append(row)

    chain_completes: List = []
    for e in events:
        k = e.kind
        if k == "job_start" or k == "job_release":
            open_jobs[e.jid] = {
                "ph": "X", "name": e.task, "pid": _PID, "cat": "job",
                "_t0": e.t, "_t1": e.t, "_part": e.partition,
                "args": {"jid": e.jid, "dop": int(e.value)},
            }
            if k == "job_release" and e.task not in sensor_tasks:
                sensor_tasks.append(e.task)
            bump_alloc(e.t, e.partition, e.value)
        elif k == "job_finish":
            close(e.jid, e.t, dropped=False)
            bump_alloc(e.t, e.partition, -e.value)
        elif k == "job_drop":
            close(e.jid, e.t, dropped=True)
            bump_alloc(e.t, e.partition, -e.value)
        elif k == "job_preempt":
            close(e.jid, e.t, dropped=False)
            bump_alloc(e.t, e.partition, -e.value)
        elif k == "job_resize":
            s = open_jobs.get(e.jid)
            old = float((e.data or {}).get("old", 0))
            if s is not None:
                s["args"]["dop"] = int(e.value)
                s["args"]["resizes"] = s["args"].get("resizes", 0) + 1
                if e.value == 0:  # preempted back to READY by a resize
                    close(e.jid, e.t, dropped=False)
            bump_alloc(e.t, e.partition, e.value - old)
        elif k == "stall_begin":
            bump_bytes(e.t, e.partition, float((e.data or {}).get("bytes", 0)))
        elif k == "prestage":
            for p, nb in ((e.data or {}).get("per_partition") or {}).items():
                bump_bytes(e.t, int(p), float(nb))
            instant(e.t, _TID_RUNTIME, f"prestage {e.value:.0f}B",
                    {"bytes": e.value, **(e.data or {})})
        elif k == "hotswap":
            reserved = float((e.data or {}).get("peak_tiles", reserved))
            counter(e.t, "tiles reserved", reserved)
            instant(e.t, _TID_RUNTIME, f"hotswap:{e.info or 'table'}",
                    {"stall_s": e.value, **(e.data or {})})
        elif k == "schedule":
            reserved = e.value
            counter(e.t, "tiles reserved", reserved)
        elif k == "mode_change":
            instant(e.t, _TID_CONTEXT, f"mode:{e.info}", scope="g")
        elif k == "rate_seam":
            instant(e.t, _TID_CONTEXT, "rate seam",
                    {"hyper_period_s": e.value}, scope="g")
        elif k == "forecast_arm":
            instant(e.t, _TID_RUNTIME, "forecast armed", {"fire_t": e.value})
        elif k == "forecast_fire":
            instant(e.t, _TID_RUNTIME, "forecast fired")
        elif k == "drain_arm":
            instant(e.t, _TID_RUNTIME, "drain watch armed")
        elif k == "drain_clear":
            instant(e.t, _TID_RUNTIME, "drain watch cleared")
        elif k == "chain_complete":
            chain_completes.append(e)
    for jid in list(open_jobs):
        close(jid, end_s, dropped=False)

    # ------------------------------------------------------------------
    # lane layout: sensors by task, partitions by block
    # ------------------------------------------------------------------
    sensor_tid = {t: _TID_SENSOR0 + i for i, t in enumerate(sorted(sensor_tasks))}
    for t, tid in sorted(sensor_tid.items()):
        thread_meta(tid, f"sensor {t}", tid)
    by_part: Dict[int, List[dict]] = {}
    for s in slices:
        p = s.pop("_part")
        if p < 0:
            s["tid"] = sensor_tid.get(s["name"], _TID_SENSOR0)
        else:
            by_part.setdefault(p, []).append(s)
    for p, group in sorted(by_part.items()):
        base = _part_base(p)
        _assign_lanes(group, base)
        n_lanes = max(s["tid"] - base for s in group)
        thread_meta(base, f"partition {p} stalls", base)
        for k in range(1, n_lanes + 1):
            thread_meta(base + k, f"partition {p} lane {k - 1}", base + k)

    slice_of: Dict[int, dict] = {}
    for s in slices:
        t0, t1 = s.pop("_t0"), s.pop("_t1")
        s["ts"] = t0 * _US
        s["dur"] = max(t1 - t0, 0.0) * _US
        slice_of[s["args"]["jid"]] = s
        out.append(s)

    # stall windows as slices on each partition's stall lane
    for p, windows in sorted(recorder.stall_windows.items()):
        base = _part_base(p)
        if p not in by_part:
            thread_meta(base, f"partition {p} stalls", base)
        for (a, b) in windows:
            out.append({
                "ph": "X", "name": "stall", "pid": _PID, "tid": base,
                "cat": "stall", "ts": a * _US, "dur": (b - a) * _US,
            })

    # ------------------------------------------------------------------
    # flow events: source sensor slice -> sink slice per E2E completion
    # ------------------------------------------------------------------
    flow_id = 0
    for e in chain_completes:
        data = e.data or {}
        sink = slice_of.get(e.jid)
        if sink is None:
            continue
        src_task = data.get("src_task", "")
        t0 = float(data.get("t0", e.t - e.value))
        flow_id += 1
        violated = bool(data.get("violated"))
        out.append({
            "ph": "s", "id": flow_id, "name": e.chain, "cat": "chain",
            "pid": _PID, "tid": sensor_tid.get(src_task, _TID_SENSOR0),
            "ts": t0 * _US, "args": {"violated": violated},
        })
        out.append({
            "ph": "f", "bp": "e", "id": flow_id, "name": e.chain,
            "cat": "chain", "pid": _PID, "tid": sink["tid"],
            "ts": sink["ts"] + sink["dur"],
            "args": {"violated": violated, "latency_s": e.value},
        })

    other = {str(k): str(v) for k, v in sorted(recorder.meta.items())}
    other["end_s"] = str(end_s)
    return {
        "traceEvents": meta_rows + out,
        "displayTimeUnit": "ms",
        "otherData": other,
    }


def export_chrome_trace(
    recorder: TraceRecorder, path: Optional[str] = None, validate: bool = True
) -> dict:
    """Export ``recorder`` to the Trace Event Format; optionally write
    the JSON to ``path`` (loadable in Perfetto / ``chrome://tracing``).

    ``validate`` checks the object against the checked-in schema first
    (cheap; a malformed export fails loudly here instead of silently
    rendering empty in the viewer)."""
    obj = chrome_trace(recorder)
    if validate:
        from .schema import validate_trace

        validate_trace(obj)
    if path is not None:
        with open(path, "w") as fh:
            json.dump(obj, fh)
    return obj
