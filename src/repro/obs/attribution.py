"""Deadline-miss attribution: decompose each late chain's lateness.

A violation count says *that* a chain missed; this pass says *why*.
For every chain completion recorded late (``chain_complete`` events
with positive lateness), it walks the **realized critical path**
backward from the sink — at each job, the predecessor whose finish
determined the job's ``ready_t`` — and classifies every instant of the
interval ``[source sample, sink finish]`` into four components:

``realloc_stall``
    the job's partition was inside a stop-migrate-restart stall window
    (recorded by the :class:`~repro.obs.events.TraceRecorder`), whether
    the job was waiting or frozen mid-run;
``restagger``
    admission gating: the job was READY but not yet admitted
    (``now < ert`` — the ERT grid, including hot-swap re-staggering
    onto a new rate regime's release grid), plus the release-alignment
    prefix between the chain's source sample and the critical path's
    first event (a sink gated by its *slowest* input waits there);
``queueing``
    READY and admitted, but the policy had not granted tiles
    (contention inside the partition);
``exec`` (reported as ``duration_tail``)
    the job was actually progressing.  ``duration_tail = exec -
    deadline``: how much of the lateness is pure duration overrun
    (often negative — execution fits the deadline and the wait
    components alone explain the miss).

By construction the components **sum exactly** to the observed
lateness::

    queueing + realloc_stall + restagger + duration_tail == latency - deadline

(up to float addition order; the test pins a 1e-9 tolerance), because
the critical path covers ``[t0, finish]`` gaplessly: a job's
``ready_t`` *is* its critical predecessor's ``finish_t``.

Attribution needs the recorder (for the stall windows) and the
simulator's job list (for the realized timing) — it runs on completed
:class:`~repro.core.sim.engine.Simulator` instances, not on reports.
Chains that *dropped* or starved have no completion to decompose; they
are counted separately (``n_dropped`` from ``chain_drop`` events,
``n_unfinished`` from the report-side starvation accounting).
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

from .events import TraceRecorder

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..core.sim.engine import Simulator

__all__ = [
    "ChainMiss",
    "attribute_misses",
    "attribution_report",
    "summarize_attribution",
]

#: matching the engine's violation comparison (lat > deadline + 1e-12)
_LATE_TOL = 1e-12


@dataclasses.dataclass(frozen=True)
class ChainMiss:
    """One late chain completion, decomposed."""

    chain: str
    sink_jid: int
    t0: float                  # source sample time
    deadline_s: float
    latency_s: float
    lateness_s: float          # latency - deadline (> 0)
    queueing_s: float
    realloc_stall_s: float
    restagger_s: float
    duration_tail_s: float     # exec - deadline (may be negative)
    path: Tuple[int, ...]      # critical-path jids, source first

    @property
    def components(self) -> Dict[str, float]:
        return {
            "queueing": self.queueing_s,
            "realloc_stall": self.realloc_stall_s,
            "restagger": self.restagger_s,
            "duration_tail": self.duration_tail_s,
        }


def _overlap(
    lo: float, hi: float, windows: Sequence[Tuple[float, float]]
) -> float:
    """Length of ``[lo, hi]`` covered by the (ordered, disjoint) stall
    windows."""
    if hi <= lo:
        return 0.0
    total = 0.0
    for a, b in windows:
        if b <= lo:
            continue
        if a >= hi:
            break
        total += min(hi, b) - max(lo, a)
    return total


def _critical_path(sim: "Simulator", sink_jid: int) -> List[int]:
    """Walk the realized critical path from the sink back to a sensor.

    A job enters READY exactly when its last predecessor finishes, so
    the critical predecessor is the one with the maximal ``finish_t``
    (dropped predecessors carry their drop time there).  Every
    predecessor of a completed sink finished or dropped, so the walk is
    total."""
    preds = getattr(sim, "_obs_preds", None)
    if preds is None:
        preds = {}
        for j in sim.jobs:
            for sid in j.succs:
                preds.setdefault(sid, []).append(j.jid)
        sim._obs_preds = preds  # memo: one inversion serves every chain
    jobs = sim.jobs
    path = [sink_jid]
    cur = sink_jid
    while True:
        ps = preds.get(cur)
        if not ps:
            break
        cur = max(
            ps,
            key=lambda p: (
                jobs[p].finish_t if not math.isnan(jobs[p].finish_t)
                else -math.inf
            ),
        )
        path.append(cur)
    path.reverse()
    return path


def _classify(
    sim: "Simulator",
    rec: TraceRecorder,
    path: Sequence[int],
    t0: float,
) -> Tuple[float, float, float, float]:
    """(queueing, realloc_stall, restagger, exec) over ``[t0, finish]``.

    Each component is computed as a difference of interval lengths, so
    the four telescope exactly to ``finish - t0``."""
    jobs = sim.jobs
    queue = stall = stagger = exec_ = 0.0
    head = jobs[path[0]]
    # release-alignment prefix: the chain's source sampled at t0, but
    # the realized critical path may start at a later-released input;
    # a path head released *before* t0 (a slower sibling sensor) is
    # clipped at t0 so coverage is exactly [t0, finish]
    arrival = head.release if not math.isnan(head.release) else t0
    stagger += max(0.0, arrival - t0)
    prev_finish = max(arrival, t0)
    for jid in path:
        job = jobs[jid]
        a = prev_finish
        fin = job.finish_t
        if math.isnan(fin):
            break  # defensive: cannot happen for a completed sink
        if fin <= a:
            continue  # fully covered by the clip (pre-t0 work)
        if job.is_sensor:
            exec_ += fin - a
            prev_finish = fin
            continue
        windows = rec.stall_windows.get(job.partition, ())
        start = job.start_t
        wait_hi = fin if math.isnan(start) else min(start, fin)
        if wait_hi > a:
            # split the wait at the admission time (ERT gating)
            ert = min(max(job.ert, a), wait_hi)
            pre_stall = _overlap(a, ert, windows)
            post_stall = _overlap(ert, wait_hi, windows)
            stall += pre_stall + post_stall
            stagger += (ert - a) - pre_stall
            queue += (wait_hi - ert) - post_stall
        if not math.isnan(start) and fin > start:
            run_lo = max(start, a)
            run_stall = _overlap(run_lo, fin, windows)
            stall += run_stall
            exec_ += (fin - run_lo) - run_stall
        prev_finish = fin
    return queue, stall, stagger, exec_


def attribute_misses(
    sim: "Simulator", recorder: Optional[TraceRecorder] = None
) -> List[ChainMiss]:
    """Decompose every late chain completion of a finished run.

    ``recorder`` defaults to the run's own ``SimConfig.recorder``;
    raises if neither is available (the stall windows only exist on a
    recording)."""
    rec = recorder if recorder is not None else sim.cfg.recorder
    if rec is None:
        raise ValueError(
            "attribution needs the run's TraceRecorder "
            "(run with SimConfig(recorder=...) / ScenarioSpec(record=True))"
        )
    out: List[ChainMiss] = []
    for e in rec.events:
        if e.kind != "chain_complete":
            continue
        data = e.data or {}
        deadline = float(data.get("deadline_s", math.inf))
        lat = e.value
        lateness = lat - deadline
        if lateness <= _LATE_TOL:
            continue
        t0 = float(data.get("t0", e.t - lat))
        path = _critical_path(sim, e.jid)
        queue, stall, stagger, exec_ = _classify(sim, rec, path, t0)
        out.append(ChainMiss(
            chain=e.chain,
            sink_jid=e.jid,
            t0=t0,
            deadline_s=deadline,
            latency_s=lat,
            lateness_s=lateness,
            queueing_s=queue,
            realloc_stall_s=stall,
            restagger_s=stagger,
            duration_tail_s=exec_ - deadline,
            path=tuple(path),
        ))
    return out


def summarize_attribution(
    misses: Sequence[ChainMiss],
    n_dropped: int = 0,
    n_degraded: int = 0,
) -> Dict[str, object]:
    """Aggregate a run's :class:`ChainMiss` rows into the picklable
    dict surfaced as ``SimReport.attribution`` / ``summarize()`` rows
    (and summed across rows by ``aggregate_sweep``)."""
    comp = {"queueing": 0.0, "realloc_stall": 0.0, "restagger": 0.0,
            "duration_tail": 0.0}
    by_chain: Dict[str, Dict[str, float]] = {}
    total = 0.0
    for m in misses:
        total += m.lateness_s
        ch = by_chain.setdefault(
            m.chain, {"n_late": 0, "lateness_s": 0.0, **{k: 0.0 for k in comp}}
        )
        ch["n_late"] += 1
        ch["lateness_s"] += m.lateness_s
        for k, v in m.components.items():
            comp[k] += v
            ch[k] += v
    worst = max(by_chain, key=lambda c: by_chain[c]["lateness_s"]) \
        if by_chain else None
    return {
        "n_late": len(misses),
        "n_dropped": n_dropped,
        "n_degraded": n_degraded,
        "lateness_s": total,
        "components_s": comp,
        "worst_chain": worst,
        "by_chain": by_chain,
    }


def attribution_report(
    sim: "Simulator", recorder: Optional[TraceRecorder] = None
) -> Dict[str, object]:
    """One-call per-run attribution summary (see
    :func:`summarize_attribution`): late completions decomposed,
    violations without a completion counted alongside."""
    rec = recorder if recorder is not None else sim.cfg.recorder
    misses = attribute_misses(sim, rec)
    n_dropped = sum(1 for e in rec.events if e.kind == "chain_drop")
    n_degraded = sum(
        1 for e in rec.events
        if e.kind == "chain_complete"
        and (e.data or {}).get("violated")
        and e.value <= float((e.data or {}).get("deadline_s", math.inf))
    )
    return summarize_attribution(misses, n_dropped, n_degraded)
