"""Minimal JSON-schema validation for exported traces.

The container has no ``jsonschema`` package, so this implements the
small subset the checked-in ``trace_schema.json`` uses — ``type``,
``required``, ``properties``, ``additionalProperties`` (schema form),
``items``, ``enum``, ``minItems`` — enough to pin the exporter's output
shape in tests and fail loudly on a malformed export.  It is not a
general validator and does not resolve ``$ref``.
"""
from __future__ import annotations

import json
import os
from typing import Any, List

__all__ = ["SchemaError", "load_schema", "validate", "validate_trace"]

_SCHEMA_PATH = os.path.join(os.path.dirname(__file__), "trace_schema.json")

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "number": (int, float),
    "integer": int,
    "boolean": bool,
    "null": type(None),
}


class SchemaError(ValueError):
    """Raised when an instance does not match the schema."""


def load_schema() -> dict:
    with open(_SCHEMA_PATH) as fh:
        return json.load(fh)


def _check(obj: Any, schema: dict, path: str, errors: List[str]) -> None:
    typ = schema.get("type")
    if typ is not None:
        types = typ if isinstance(typ, list) else [typ]
        pytypes = tuple(t for name in types for t in (
            _TYPES[name] if isinstance(_TYPES[name], tuple)
            else (_TYPES[name],)
        ))
        ok = isinstance(obj, pytypes)
        # bool is an int subclass in Python; keep them distinct
        if ok and isinstance(obj, bool) and "boolean" not in types:
            ok = False
        if not ok:
            errors.append(f"{path}: expected {typ}, got {type(obj).__name__}")
            return
    if "enum" in schema and obj not in schema["enum"]:
        errors.append(f"{path}: {obj!r} not in enum {schema['enum']}")
    if isinstance(obj, dict):
        for key in schema.get("required", ()):
            if key not in obj:
                errors.append(f"{path}: missing required key {key!r}")
        props = schema.get("properties", {})
        for key, sub in props.items():
            if key in obj:
                _check(obj[key], sub, f"{path}.{key}", errors)
        extra = schema.get("additionalProperties")
        if isinstance(extra, dict):
            for key, val in obj.items():
                if key not in props:
                    _check(val, extra, f"{path}.{key}", errors)
        elif extra is False:
            for key in obj:
                if key not in props:
                    errors.append(f"{path}: unexpected key {key!r}")
    if isinstance(obj, list):
        if "minItems" in schema and len(obj) < schema["minItems"]:
            errors.append(
                f"{path}: {len(obj)} items < minItems {schema['minItems']}"
            )
        items = schema.get("items")
        if isinstance(items, dict):
            for i, val in enumerate(obj):
                _check(val, items, f"{path}[{i}]", errors)


def validate(obj: Any, schema: dict) -> None:
    """Raise :class:`SchemaError` (listing every mismatch) if ``obj``
    does not conform to ``schema``."""
    errors: List[str] = []
    _check(obj, schema, "$", errors)
    if errors:
        raise SchemaError(
            f"{len(errors)} schema violation(s):\n  " + "\n  ".join(errors[:20])
        )


def validate_trace(obj: Any) -> None:
    """Validate a Chrome-trace export against ``trace_schema.json``."""
    validate(obj, load_schema())
