"""GHA Phase II — Spatial Partitioning (paper §III-B3, Eq. 6-7).

Clusters tasks into S partitions ("bins"), trading off three criteria:

    min  w1 * sum_s |B_s|  -  w2 * Score_affinity  +  w3 * Score_balance

subject to one-bin-per-task (Eq. 6a) and per-window capacity (Eq. 6b,
which *defines* |B_s| = the bin's peak concurrent tile demand).

Implementation: chain-grouped initial assignment (mirroring Phase I's
chain-per-partition view), greedy bin merging down to the target S
(Fig. 5a: merge for affinity and for balance), then single-task local
search until a fixed point.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ...obs import metrics
from ..workload import Workflow, unroll_hyperperiod
from .phase1 import Phase1Result, chain_priority

__all__ = ["Phase2Result", "TimeWindows", "build_windows", "run_phase2"]


@dataclasses.dataclass
class TimeWindows:
    """Disjoint windows T splitting all task-interval boundaries; for each
    window, the per-task number of simultaneously active instances."""

    bounds: List[float]                      # len W+1
    active: List[Dict[str, int]]             # len W: task -> #active instances
    hyper_period_s: float

    @property
    def durations(self) -> List[float]:
        return [b - a for a, b in zip(self.bounds, self.bounds[1:])]


def build_windows(
    wf: Workflow,
    p1: Phase1Result,
    starts: Optional[Dict[str, float]] = None,
) -> TimeWindows:
    """Fold every task instance's planned interval into [0, T_hp) and cut
    the timeline at all interval boundaries."""
    thp = wf.hyper_period_s
    starts = starts if starts is not None else p1.start_offsets
    segments: List[Tuple[float, float, str]] = []
    for inst in unroll_hyperperiod(wf):
        task = inst.task
        if wf.tasks[task].is_sensor:
            continue  # sensors run on SPEs, not tiles
        s = inst.release_s + starts[task]
        e = s + p1.budget(task)
        s, e = s % thp, None
        dur = p1.budget(task)
        e = s + dur
        if e <= thp + 1e-12:
            segments.append((s, min(e, thp), task))
        else:  # wraps around
            segments.append((s, thp, task))
            segments.append((0.0, e - thp, task))

    cuts = sorted({0.0, thp, *(s for s, _, _ in segments), *(e for _, e, _ in segments)})
    bounds = [c for c in cuts if 0.0 <= c <= thp]
    active: List[Dict[str, int]] = []
    for a, b in zip(bounds, bounds[1:]):
        mid = 0.5 * (a + b)
        act: Dict[str, int] = {}
        for s, e, task in segments:
            if s - 1e-12 <= mid < e + 1e-12 and s < e:
                act[task] = act.get(task, 0) + 1
        active.append(act)
    return TimeWindows(bounds=bounds, active=active, hyper_period_s=thp)


@dataclasses.dataclass
class Phase2Result:
    assignment: Dict[str, int]          # task -> bin index (x_vs)
    capacities: List[int]               # |B_s|
    windows: TimeWindows
    score: float

    @property
    def num_partitions(self) -> int:
        return len(self.capacities)


class _Scorer:
    """Vectorised Eq. 7 evaluator.

    Precomputes the (task x window) tile-demand matrix once; a candidate
    partitioning is then scored with a handful of numpy reductions.  The
    three terms are normalised to comparable scales (capacity by M-like
    magnitude, affinity by |E|, balance in [0,1]) so the weights express
    actual trade-offs rather than unit mismatches.
    """

    def __init__(self, wf: Workflow, dops: Dict[str, int], windows: TimeWindows):
        import numpy as np

        self.np = np
        self.tasks = sorted(dops)
        self.index = {t: i for i, t in enumerate(self.tasks)}
        n, w = len(self.tasks), len(windows.active)
        demand = np.zeros((n, w))
        for j, act in enumerate(windows.active):
            for t, cnt in act.items():
                demand[self.index[t], j] = dops[t] * cnt
        self.demand = demand
        self.dur = np.asarray(windows.durations)
        self.thp = windows.hyper_period_s
        self.dop_vec = np.asarray([dops[t] for t in self.tasks])
        self.edges = [
            (self.index[u], self.index[v])
            for u, v in wf.edges
            if u in self.index and v in self.index
        ]
        self.norm_cap = max(1.0, float(self.dop_vec.sum()))
        #: bin -> (capacity, busy) memo: the greedy merge + local
        #: search re-evaluate mostly-unchanged partitionings, so the
        #: same bins recur thousands of times per compile
        self._stats_cache: Dict[tuple, Tuple[int, float]] = {}

    #: safety margin on sustained demand (runtime jitter headroom)
    SUSTAIN_MARGIN = 1.15

    def _bin_stats(self, b: List[str]) -> Tuple[int, float]:
        """(capacity, busy tile-seconds) of one bin — the expensive
        per-window demand aggregation, memoized on the bin's member set
        and shared by :meth:`capacities` and :meth:`score`."""
        key = tuple(sorted(b))
        hit = self._stats_cache.get(key)
        if hit is not None:
            return hit
        idx = sorted(self.index[t] for t in b)
        if not idx:
            self._stats_cache[key] = (0, 0.0)
            return 0, 0.0
        col = self.demand[idx].sum(axis=0)
        peak = float(col.max()) if len(self.dur) else 0.0
        peak = max(peak, float(self.dop_vec[idx].max()))
        # sustained tile demand: the bin must carry its members' total
        # tile-seconds per hyper-period even when planned offsets
        # interleave perfectly on paper but jitter at runtime
        busy = float((col * self.dur).sum())
        sustained = self.SUSTAIN_MARGIN * busy / self.thp
        out = (int(round(max(peak, sustained))), busy)
        self._stats_cache[key] = out
        return out

    def capacities(self, bins: List[List[str]]):
        return [self._bin_stats(b)[0] for b in bins]

    def score(
        self, bins: List[List[str]], w: Tuple[float, float, float]
    ) -> Tuple[float, List[int]]:
        w1, w2, w3 = w
        caps: List[int] = []
        busys: List[float] = []
        for b in bins:
            cap, busy = self._bin_stats(b)
            caps.append(cap)
            busys.append(busy)

        where = {}
        for s, b in enumerate(bins):
            for t in b:
                where[self.index[t]] = s
        affinity = sum(1 for u, v in self.edges if where[u] == where[v])

        utils = [
            busy / (cap * self.thp) if cap else 0.0
            for cap, busy in zip(caps, busys)
        ]
        balance = (max(utils) - min(utils)) if utils else 0.0
        # capacity-spread component: merged bins of similar size are
        # preferred over one mega-bin plus singletons (isolation domains
        # only bound reallocation if load is actually spread, §IV-B1)
        if caps:
            balance += (max(caps) - min(caps)) / self.norm_cap

        score = (
            w1 * sum(caps) / self.norm_cap
            - w2 * affinity / max(1, len(self.edges))
            + w3 * balance
        )
        return score, caps


def _warm_bins(
    warm_start: Dict[str, int], dops: Dict[str, int], target: int
) -> Optional[List[List[str]]]:
    """Rebuild Phase-II bins from a neighbouring cell's final assignment.

    Valid only when the assignment covers exactly this cell's task set
    and its group count matches the target bin count — otherwise the
    caller falls back to the cold chain-grouped construction."""
    if set(warm_start) != set(dops):
        return None
    groups: Dict[int, List[str]] = {}
    for t in sorted(dops):
        groups.setdefault(warm_start[t], []).append(t)
    if len(groups) != target:
        return None
    return [groups[g] for g in sorted(groups)]


def run_phase2(
    wf: Workflow,
    p1: Phase1Result,
    num_partitions: int,
    weights: Tuple[float, float, float] = (2.0, 1.0, 3.0),
    local_search_rounds: int = 4,
    warm_start: Optional[Dict[str, int]] = None,
) -> Phase2Result:
    """Partition tasks into ``num_partitions`` bins.

    ``num_partitions=1`` reproduces the Tp-driven single-bin view; larger
    values give the configurable-isolation domains of §IV-B1.

    ``warm_start`` (task -> bin) seeds the search with a neighbouring
    compile cell's final assignment, skipping the chain-grouped
    construction and the O(S²) greedy merge; the single-task local
    search still runs, so a warm start converges to the same fixed
    points the cold path reaches from a nearby basin.
    """
    dops = {t: c for t, (c, _) in p1.shapes.items() if not wf.tasks[t].is_sensor}
    windows = build_windows(wf, p1)
    scorer = _Scorer(wf, dops, windows)

    bins: Optional[List[List[str]]] = None
    if warm_start is not None:
        bins = _warm_bins(warm_start, dops, max(num_partitions, 1))
    if bins is not None:
        metrics.count("phase2_warm_start")
    else:
        metrics.count("phase2_cold_start")
        # -- initial: one bin per chain (priority order; first chain wins
        #    a shared task) ------------------------------------------------
        bins = []
        seen: set = set()
        for chain in sorted(wf.chains, key=lambda c: chain_priority(wf, c)):
            members = [
                n for n in chain.nodes
                if not wf.tasks[n].is_sensor and n not in seen
            ]
            if members:
                bins.append(members)
                seen.update(members)
        leftovers = [t for t in dops if t not in seen]
        if leftovers:
            bins.append(leftovers)

        # -- greedy merging down to the target S (Fig. 5a) ----------------
        while len(bins) > max(num_partitions, 1):
            best = None
            for i in range(len(bins)):
                for j in range(i + 1, len(bins)):
                    trial = [b for k, b in enumerate(bins) if k not in (i, j)]
                    trial.append(bins[i] + bins[j])
                    sc, _ = scorer.score(trial, weights)
                    if best is None or sc < best[0]:
                        best = (sc, i, j)
            _, i, j = best
            merged = bins[i] + bins[j]
            bins = [b for k, b in enumerate(bins) if k not in (i, j)]
            bins.append(merged)

    # -- local search: single-task moves ----------------------------------
    score, caps = scorer.score(bins, weights)
    for _ in range(local_search_rounds):
        improved = False
        for t in list(dops):
            src = next(i for i, b in enumerate(bins) if t in b)
            if len(bins[src]) == 1:
                continue
            for dst in range(len(bins)):
                if dst == src:
                    continue
                trial = [list(b) for b in bins]
                trial[src].remove(t)
                trial[dst].append(t)
                sc, c2 = scorer.score(trial, weights)
                if sc < score - 1e-9:
                    bins, score, caps = trial, sc, c2
                    improved = True
                    break
        if not improved:
            break

    assignment = {t: i for i, b in enumerate(bins) for t in b}
    return Phase2Result(
        assignment=assignment, capacities=caps, windows=windows, score=score
    )
