"""GHA Phase I — Chain-by-Chain Slack Assignment (paper §III-B2, Alg. 1).

Each E2E chain is isolated into its own (logical) partition with tasks
executing sequentially; per chain we determine the shape ``(c_v, l_v)``
of every task by solving

    min   max_v c_v                                   (Eq. 3)
    s.t.  sum_v l_v <= D_rem                          (Eq. 4a, chain form)
          l_v >= L_v(q, c_v)                          (Eq. 5a)
          c_v in c_v^compiled                         (Eq. 5b)

Chains are processed in priority order; previously assigned nodes keep
their allocation and consume part of the remaining deadline on later
chains (Alg. 1).  Start offsets then follow from a topological pass
(Alg. 1 lines 10-14).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from ..latency_model import LatencyModel
from ..workload import Chain, Workflow

__all__ = ["Phase1Result", "solve_subchain", "run_phase1"]


@dataclasses.dataclass
class Phase1Result:
    # task -> (c_v, l_v); sensors get c_v = 0
    shapes: Dict[str, Tuple[int, float]]
    # task -> planned start offset s_v (relative to source activation)
    start_offsets: Dict[str, float]
    # chains whose deadline could not be met even at max DoP
    infeasible_chains: List[str]

    def dop(self, task: str) -> int:
        return self.shapes[task][0]

    def budget(self, task: str) -> float:
        return self.shapes[task][1]


def _best_latency_under_cap(
    model: LatencyModel, wf: Workflow, task: str, cap: int, q: float
) -> Tuple[Optional[int], float]:
    """(argmin-latency DoP <= cap, its latency); (None, inf) if no
    candidate fits the cap."""
    t = wf.tasks[task]
    best_c, best_l = None, float("inf")
    for c in t.dop_candidates():
        if c > cap:
            continue
        lat = model.bound(task, q, c)  # (task, q, c)-cached
        if lat < best_l:
            best_c, best_l = c, lat
    return best_c, best_l


def solve_subchain(
    model: LatencyModel,
    wf: Workflow,
    unassigned: Sequence[str],
    d_rem: float,
    q: float,
    tile_cap: int,
) -> Tuple[Dict[str, Tuple[int, float]], bool]:
    """SolveSubChain (Alg. 1 line 8): minimize peak tiles subject to
    ``sum l_v <= d_rem`` for the unassigned nodes of one chain.

    Returns (shapes, feasible).  Two-step solve:

    1. *Peak minimization* — binary-search style scan over candidate peak
       caps C (ascending): the smallest C whose per-task best latencies
       sum within ``d_rem``.
    2. *Tile compaction* under the fixed peak — greedily step tasks down
       to smaller DoP candidates, choosing at each step the task whose
       step-down costs the least extra latency per tile freed, while the
       chain still fits ``d_rem``.  (The peak stays optimal; total tile
       usage shrinks.)
    """
    dnn = [t for t in unassigned if not wf.tasks[t].is_sensor]
    sensors = [t for t in unassigned if wf.tasks[t].is_sensor]

    shapes: Dict[str, Tuple[int, float]] = {}
    budget = d_rem
    for s in sensors:
        l = model.bound(s, q, 0)
        shapes[s] = (0, l)
        budget -= l

    if not dnn:
        return shapes, budget >= 0

    # -- step 1: minimal feasible peak C --------------------------------
    caps = sorted({
        c for t in dnn for c in wf.tasks[t].dop_candidates() if c <= tile_cap
    })
    if not caps:
        caps = [tile_cap]
    chosen_cap = None
    for C in caps:
        total = 0.0
        ok = True
        for t in dnn:
            c, lat = _best_latency_under_cap(model, wf, t, C, q)
            if c is None:
                ok = False
                break
            total += lat
        if ok and total <= budget:
            chosen_cap = C
            break
    feasible = chosen_cap is not None
    if chosen_cap is None:
        chosen_cap = caps[-1]  # best effort: run at the largest cap

    # latency-minimal allocation under the chosen peak
    alloc: Dict[str, int] = {}
    lats: Dict[str, float] = {}
    for t in dnn:
        c, lat = _best_latency_under_cap(model, wf, t, chosen_cap, q)
        if c is None:  # smallest candidate exceeds even the largest cap
            c = min(wf.tasks[t].dop_candidates())
            lat = model.bound(t, q, c)
        alloc[t], lats[t] = c, lat

    # -- step 2: greedy tile compaction ----------------------------------
    if feasible:
        improved = True
        while improved:
            improved = False
            total = sum(lats.values())
            best: Optional[Tuple[float, str, int, float]] = None
            for t in dnn:
                cands = [c for c in wf.tasks[t].dop_candidates() if c < alloc[t]]
                if not cands:
                    continue
                c2 = max(cands)
                lat2 = model.bound(t, q, c2)
                if total - lats[t] + lat2 > budget:
                    continue
                cost = (lat2 - lats[t]) / max(alloc[t] - c2, 1)
                if best is None or cost < best[0]:
                    best = (cost, t, c2, lat2)
            if best is not None:
                _, t, c2, lat2 = best
                alloc[t], lats[t] = c2, lat2
                improved = True

    for t in dnn:
        shapes[t] = (alloc[t], lats[t])
    return shapes, feasible


def chain_priority(wf: Workflow, chain: Chain) -> Tuple:
    """Sort key: critical chains first, then total load descending, then
    tightest deadline (Alg. 1 line 2)."""
    load = sum(wf.tasks[n].mean_flops for n in chain.nodes)
    return (not chain.critical, chain.deadline_s, -load, chain.name)


def run_phase1(
    model: LatencyModel,
    wf: Workflow,
    q: float,
    tile_cap: Optional[int] = None,
) -> Phase1Result:
    """Algorithm 1 — Multi-Chain Slack Distribution."""
    cap = tile_cap if tile_cap is not None else model.hw.num_tiles
    shapes: Dict[str, Tuple[int, float]] = {}
    infeasible: List[str] = []

    for chain in sorted(wf.chains, key=lambda c: chain_priority(wf, c)):
        done = [n for n in chain.nodes if n in shapes]
        unassigned = [n for n in chain.nodes if n not in shapes]
        d_rem = chain.deadline_s - sum(shapes[n][1] for n in done)
        if not unassigned:
            if d_rem < 0:
                infeasible.append(chain.name)
            continue
        sub, feasible = solve_subchain(model, wf, unassigned, d_rem, q, cap)
        shapes.update(sub)
        if not feasible:
            infeasible.append(chain.name)

    # nodes not on any chain (none in the stock benchmark, but allowed):
    for name, task in wf.tasks.items():
        if name in shapes:
            continue
        if task.is_sensor:
            shapes[name] = (0, model.profiles[name].latency_bound(q, 0, 1.0))
        else:
            c = model.best_dop(task, q, cap)
            shapes[name] = (c, model.bound(name, q, c))

    # -- topological start offsets (Alg. 1 lines 10-14) ------------------
    start: Dict[str, float] = {}
    end: Dict[str, float] = {}
    for v in wf.topological_order():
        preds = wf.preds(v)
        start[v] = max((end[u] for u in preds), default=0.0)
        end[v] = start[v] + shapes[v][1]

    return Phase1Result(
        shapes=shapes, start_offsets=start, infeasible_chains=infeasible
    )
