"""Guided Hybrid Allocation (GHA) — the paper's offline compiler (§III-B).

GHA decomposes the joint spatio-temporal scheduling problem into three
phases plus a physical-binding step:

* :mod:`phase1` — chain-by-chain slack assignment (Algorithm 1):
  per-task shape ``(c_v, l_v)`` minimizing peak tile usage under the
  E2E deadline.
* :mod:`phase2` — spatial partitioning (Eq. 6-7): task-to-partition
  mapping ``x_vs`` and capacities ``|B_s|``.
* :mod:`phase3` — intra-partition temporal compaction (FFD repack,
  enforcing the total tile budget M).
* :mod:`guillotine` — physical partition binding (rectangular cuts +
  memory-controller affinity).
* :mod:`compiler` — the pipeline driver producing a :class:`Schedule`.
"""
from .schedule import PartitionPlan, Schedule, TaskPlan
from .compiler import GHACompiler, compile_schedule

__all__ = [
    "TaskPlan",
    "PartitionPlan",
    "Schedule",
    "GHACompiler",
    "compile_schedule",
]
