"""The GHA compiler driver (paper §III-B, Fig. 4-5, Fig. 7 'offline').

``compile_schedule`` runs Phases I-III + physical binding and returns the
:class:`Schedule` (the scheduling table consumed by every runtime policy:
Cyc., Tp-driven and ADS-Tile all take their baseline operating point from
here — GHA is the *common adaptation layer*, §III-A3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from ..latency_model import LatencyModel
from ..workload import Workflow
from .guillotine import bind_memory_controllers, guillotine_cut
from .phase1 import run_phase1
from .phase2 import run_phase2
from .phase3 import run_phase3
from .schedule import PartitionPlan, Schedule, TaskPlan

__all__ = ["GHACompiler", "compile_schedule"]


@dataclasses.dataclass
class GHACompiler:
    """Configuration of the offline compiler.

    ``num_partitions=1`` yields the Tp-driven view (single shared bin);
    ``num_partitions=None`` keeps one bin per chain (the Cyc. view);
    intermediate values give ADS-Tile's configurable isolation domains.

    ``tile_budget`` caps the tiles the schedule may *reserve* below the
    hardware's ``M`` (Phases I and III solve against the budget; the
    mesh and ``Schedule.total_tiles`` stay the hardware's).  The
    tile-budget autotuner sweeps this to trace how few tiles a
    workload actually needs at a given service level — ``None`` keeps
    the classic full-chip compile.
    """

    q: float = 0.95
    num_partitions: Optional[int] = 4
    phase2_weights: Tuple[float, float, float] = (1.0, 2.0, 8.0)
    bind_physical: bool = True
    tile_budget: Optional[int] = None

    def compile(
        self,
        model: LatencyModel,
        wf: Workflow,
        warm_start: Optional[Dict[str, int]] = None,
    ) -> Schedule:
        """Run Phases I-III and bind; ``warm_start`` (task -> bin) seeds
        Phase II from a neighbouring compile's final partitioning."""
        hw = model.hw
        m = hw.num_tiles
        if self.tile_budget is not None:
            m = max(1, min(int(self.tile_budget), m))

        p1 = run_phase1(model, wf, self.q, tile_cap=m)

        n_parts = self.num_partitions
        if n_parts is None:
            n_parts = len(wf.chains)
        n_parts = max(1, min(n_parts, len(wf.dnn_tasks)))
        p2 = run_phase2(wf, p1, n_parts, self.phase2_weights, warm_start=warm_start)

        p3 = run_phase3(model, wf, p1, p2, m, self.q)

        # physical binding ------------------------------------------------
        # integer guillotine cuts need slack: near-100% packings are often
        # unrealisable with rectangles, so trade up to ~3% of capacity
        # (largest bins first) for bindability
        rects = None
        mcs = None
        caps = list(p3.capacities)
        if self.bind_physical and sum(caps) <= m:
            budget = max(1, int(0.03 * sum(caps)))
            for _ in range(budget + 1):
                try:
                    rects = guillotine_cut(hw.mesh_shape, caps)
                    mcs = bind_memory_controllers(rects, hw)
                    p3.capacities = caps
                    break
                except ValueError:
                    big = max(range(len(caps)), key=lambda i: caps[i])
                    if caps[big] <= 2:
                        break
                    caps[big] -= 1
            else:
                rects = mcs = None  # logical-only binding

        partitions = []
        for s, cap in enumerate(p3.capacities):
            partitions.append(
                PartitionPlan(
                    index=s,
                    capacity=cap,
                    rect=rects[s] if rects else None,
                    memory_controller=mcs[s] if mcs else None,
                )
            )

        plans = {}
        cap_of = {s: c for s, c in enumerate(p3.capacities)}
        for t, (c, l) in p3.shapes.items():
            if wf.tasks[t].is_sensor:
                continue
            part = p2.assignment[t]
            if c > cap_of[part]:  # capacity shrank for bindability
                cands = [x for x in wf.tasks[t].dop_candidates()
                         if x <= cap_of[part]]
                c = max(cands) if cands else min(wf.tasks[t].dop_candidates())
                l = model.bound(t, self.q, c)
            plans[t] = TaskPlan(
                task=t,
                partition=part,
                dop=c,
                budget_s=l,
                ert_s=p3.start_offsets[t],
            )

        sched = Schedule(
            plans=plans,
            partitions=partitions,
            q=self.q,
            total_tiles=hw.num_tiles,
            meta={
                "phase1_infeasible": p1.infeasible_chains,
                "phase3_violations": p3.deadline_violations,
                "phase2_score": p2.score,
                "num_partitions": len(partitions),
                "tile_budget": m,
            },
        )
        sched.validate()
        return sched


def compile_schedule(
    model: LatencyModel,
    wf: Workflow,
    q: float = 0.95,
    num_partitions: Optional[int] = 4,
) -> Schedule:
    return GHACompiler(q=q, num_partitions=num_partitions).compile(model, wf)
