"""Schedule data structures produced by GHA and consumed by the runtime.

A :class:`Schedule` is the paper's "scheduling table": for every task its
partition ``x_vs``, offline DoP ``c_v``, latency budget ``l_v``, planned
start offset / Earliest-Ready-Time ``t_v`` and sub-deadline
``ddl_sub = t_v + l_v`` — all *relative to the activation of the chain's
source sensor* (instance-level absolute times are obtained by adding the
source sample timestamp; §II-C2, §IV-B).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

__all__ = ["TaskPlan", "PartitionPlan", "Schedule"]


@dataclasses.dataclass
class TaskPlan:
    task: str
    partition: int
    dop: int                    # c_v (offline tile allocation)
    budget_s: float             # l_v
    ert_s: float                # t_v (offset from source activation)
    # derived: sub-deadline offset
    @property
    def subdeadline_s(self) -> float:
        return self.ert_s + self.budget_s


@dataclasses.dataclass
class PartitionPlan:
    index: int
    capacity: int               # |B_s| in tiles
    rect: Optional[Tuple[int, int, int, int]] = None  # (row0, col0, h, w)
    memory_controller: Optional[int] = None

    @property
    def area(self) -> int:
        if self.rect is None:
            return self.capacity
        return self.rect[2] * self.rect[3]


@dataclasses.dataclass
class Schedule:
    plans: Dict[str, TaskPlan]
    partitions: List[PartitionPlan]
    q: float
    total_tiles: int
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def plan(self, task: str) -> TaskPlan:
        return self.plans[task]

    def partition_tasks(self, s: int) -> List[str]:
        return [t for t, p in self.plans.items() if p.partition == s]

    @property
    def peak_tiles(self) -> int:
        return sum(p.capacity for p in self.partitions)

    def validate(self) -> None:
        caps = {p.index: p.capacity for p in self.partitions}
        for name, plan in self.plans.items():
            if plan.partition not in caps:
                raise ValueError(f"{name}: unknown partition {plan.partition}")
            if plan.dop > caps[plan.partition]:
                raise ValueError(
                    f"{name}: dop {plan.dop} exceeds partition capacity "
                    f"{caps[plan.partition]}"
                )
            if plan.budget_s <= 0:
                raise ValueError(f"{name}: non-positive budget")
        if self.peak_tiles > self.total_tiles:
            raise ValueError(
                f"partition capacities {self.peak_tiles} exceed M={self.total_tiles}"
            )

    # -- (de)serialisation -------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "q": self.q,
                "total_tiles": self.total_tiles,
                "plans": {
                    t: dataclasses.asdict(p) for t, p in self.plans.items()
                },
                "partitions": [dataclasses.asdict(p) for p in self.partitions],
                "meta": self.meta,
            },
            indent=2,
        )

    @classmethod
    def from_json(cls, text: str) -> "Schedule":
        d = json.loads(text)
        return cls(
            plans={t: TaskPlan(**p) for t, p in d["plans"].items()},
            partitions=[
                PartitionPlan(
                    index=p["index"], capacity=p["capacity"],
                    rect=tuple(p["rect"]) if p.get("rect") else None,
                    memory_controller=p.get("memory_controller"),
                )
                for p in d["partitions"]
            ],
            q=d["q"],
            total_tiles=d["total_tiles"],
            meta=d.get("meta", {}),
        )
