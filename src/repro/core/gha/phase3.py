"""GHA Phase III — Intra-partition Temporal Compaction (paper §III-B4).

Enforces the total tile budget ``sum_s |B_s| <= M``:

1. scale bin capacities proportionally:
   ``|B_s| <- floor(|B_s| * M / sum |B_s'|)`` (Fig. 5b);
2. repack tasks inside each bin with a first-fit-decreasing heuristic —
   sort by tie-broken priority (criticality, sub-deadline, size), place
   each at the earliest offset respecting precedence and bin capacity,
   reshaping (smaller DoP candidate + recomputed budget) any item wider
   than its shrunken bin;
3. iterate to compact gaps.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

from ..latency_model import LatencyModel
from ..workload import Workflow
from .phase1 import Phase1Result
from .phase2 import Phase2Result

__all__ = ["Phase3Result", "run_phase3"]


@dataclasses.dataclass
class Phase3Result:
    shapes: Dict[str, Tuple[int, float]]      # possibly reshaped (c_v, l_v)
    start_offsets: Dict[str, float]           # refined t_v
    capacities: List[int]                     # scaled |B_s|
    deadline_violations: List[str]            # chains whose plan now overruns


def _chain_end(wf: Workflow, chain, ends: Dict[str, float]) -> float:
    return ends[chain.nodes[-1]]


def _surplus(caps: List[int], floors: List[int]) -> int:
    return sum(max(0, c - f) for c, f in zip(caps, floors))


def _bin_floors(
    model: LatencyModel,
    wf: Workflow,
    p1: Phase1Result,
    p2: Phase2Result,
    q: float,
) -> List[int]:
    """Per-bin minimum capacity: (a) each member task must retain a DoP
    whose budget keeps every chain through it within deadline (other
    tasks held at their Phase-I budgets); (b) the bin must carry its
    members' sustained tile-seconds per hyper-period (mean-rate floor —
    a bin below it falls behind no matter how the runtime schedules)."""
    nbins = len(p2.capacities)
    floors = [1] * nbins
    for t, b in p2.assignment.items():
        task = wf.tasks[t]
        # slack available to t on its tightest chain
        tightest = float("inf")
        for ch in wf.chain_for(t):
            others = sum(
                p1.budget(n) for n in ch.nodes if n != t
            )
            tightest = min(tightest, ch.deadline_s - others)
        if tightest == float("inf"):
            tightest = p1.budget(t)
        c_need = None
        for c in task.dop_candidates():
            if model.bound(t, q, c) <= tightest:
                c_need = c
                break
        if c_need is None:
            c_need = min(task.dop_candidates())
        floors[b] = max(floors[b], c_need)

    # sustained-demand floor from the Phase-II windows
    windows = p2.windows
    thp = windows.hyper_period_s
    busy = [0.0] * nbins
    dops = {t: c for t, (c, _) in p1.shapes.items() if not wf.tasks[t].is_sensor}
    for act, d in zip(windows.active, windows.durations):
        for t, n in act.items():
            busy[p2.assignment[t]] += dops[t] * n * d
    for s in range(nbins):
        floors[s] = max(floors[s], int(math.ceil(1.1 * busy[s] / thp)))
    return floors


def run_phase3(
    model: LatencyModel,
    wf: Workflow,
    p1: Phase1Result,
    p2: Phase2Result,
    total_tiles: int,
    q: float,
    compaction_rounds: int = 3,
) -> Phase3Result:
    shapes = dict(p1.shapes)
    caps = list(p2.capacities)

    # -- 1. proportional capacity scaling ---------------------------------
    total = sum(caps)
    if total > total_tiles:
        caps = [max(1, int(c * total_tiles / total)) for c in caps]

    # -- feasibility repair: a bin must at least fit, for each member, the
    # smallest DoP that keeps the member's chains within deadline assuming
    # every *other* budget stays at its Phase-I value.  Fund starved bins
    # from bins holding surplus above their own floor. --------------------
    floors = _bin_floors(model, wf, p1, p2, q)
    deficit = [max(0, floors[s] - caps[s]) for s in range(len(caps))]
    for s in range(len(caps)):
        while deficit[s] > 0:
            donors = [
                d for d in range(len(caps))
                if d != s and caps[d] > floors[d]
            ]
            if not donors:
                break
            d = max(donors, key=lambda d: caps[d] - floors[d])
            caps[d] -= 1
            caps[s] += 1
            deficit[s] -= 1
    # never shrink below the largest *minimum* DoP candidate in the bin
    for s, cap in enumerate(caps):
        members = [t for t, b in p2.assignment.items() if b == s]
        if members:
            need = max(min(wf.tasks[t].dop_candidates()) for t in members)
            caps[s] = max(cap, need)

    # -- reshape items wider than their bin (Fig. 5b, task B2) ------------
    for t, b in p2.assignment.items():
        c, _ = shapes[t]
        if c > caps[b]:
            cands = [x for x in wf.tasks[t].dop_candidates() if x <= caps[b]]
            c2 = max(cands) if cands else min(wf.tasks[t].dop_candidates())
            shapes[t] = (c2, model.bound(t, q, c2))

    # -- 2-3. FFD repack with precedence, iterated -------------------------
    starts = dict(p1.start_offsets)
    for _ in range(compaction_rounds):
        starts = _ffd_repack(model, wf, shapes, p2.assignment, caps, starts)

    # recompute ends & check chain deadlines
    ends: Dict[str, float] = {}
    for v in wf.topological_order():
        ends[v] = starts[v] + shapes[v][1]
    violations = [
        ch.name for ch in wf.chains
        if _chain_end(wf, ch, ends) > ch.deadline_s + 1e-9
    ]

    return Phase3Result(
        shapes=shapes,
        start_offsets=starts,
        capacities=caps,
        deadline_violations=violations,
    )


def _ffd_repack(
    model: LatencyModel,
    wf: Workflow,
    shapes: Dict[str, Tuple[int, float]],
    assignment: Dict[str, int],
    caps: List[int],
    prev_starts: Dict[str, float],
) -> Dict[str, float]:
    """One FFD pass over all bins, respecting cross-bin precedence.

    Items are placed in topological order (so predecessor end times are
    known), tie-broken by (criticality, previous sub-deadline, -size) —
    the paper's 'deadline/criticality, then index' priority.
    """
    crit = {
        t: any(c.critical for c in wf.chain_for(t)) for t in wf.tasks
    }
    # topological placement order keeps predecessor ends known; among
    # topological peers, critical/tight-deadline items are visited first
    # (the paper's 'deadline/criticality, then index' tie-break).
    topo_rank = {t: i for i, t in enumerate(wf.topological_order())}
    order = sorted(
        (t for t in wf.tasks if not wf.tasks[t].is_sensor),
        key=lambda t: (
            topo_rank[t],
            not crit[t],
            prev_starts.get(t, 0.0) + shapes[t][1],
        ),
    )
    starts: Dict[str, float] = {}
    ends: Dict[str, float] = {}
    for s in wf.tasks:
        if wf.tasks[s].is_sensor:
            starts[s] = 0.0
            ends[s] = shapes[s][1]

    # per-bin placed intervals: list of (start, end, width)
    placed: Dict[int, List[Tuple[float, float, int]]] = {
        b: [] for b in range(len(caps))
    }

    def fits(b: int, t0: float, t1: float, width: int) -> bool:
        cap = caps[b]
        pts = sorted({t0, *(
            max(a, t0) for a, e, _ in placed[b] if t0 < e and a < t1
        )})
        for p in pts:
            used = sum(w for a, e, w in placed[b] if a <= p < e)
            if used + width > cap:
                return False
        return True

    for t in order:
        b = assignment[t]
        c, l = shapes[t]
        ready = max((ends[u] for u in wf.preds(t)), default=0.0)
        t0 = ready
        # earliest feasible offset: scan candidate starts (ready time and
        # ends of already-placed items)
        candidates = sorted(
            {t0, *(e for _, e, _ in placed[b] if e >= t0 - 1e-12)}
        )
        pos = None
        for cand in candidates:
            if fits(b, cand, cand + l, c):
                pos = cand
                break
        if pos is None:  # place after everything in the bin
            pos = max((e for _, e, _ in placed[b]), default=t0)
            pos = max(pos, t0)
        starts[t] = pos
        ends[t] = pos + l
        placed[b].append((pos, pos + l, c))

    return starts
