"""Physical partition binding via Guillotine cutting (paper §III-B5).

Maps logical bin capacities to rectangular tile regions of the physical
2D mesh through a series of bisecting end-to-end cuts [Beasley 1985],
then binds each rectangle to its nearest boundary memory controller —
minimizing cross-partition NoC traffic and fixing data paths.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from ..hardware import HardwareModel

__all__ = ["guillotine_cut", "bind_memory_controllers", "mc_positions"]

Rect = Tuple[int, int, int, int]  # (row0, col0, height, width)


def guillotine_cut(
    mesh_shape: Tuple[int, int], areas: Sequence[int]
) -> List[Rect]:
    """Cut the (rows x cols) mesh into len(areas) rectangles whose sizes
    are proportional to ``areas`` (each >= its requested area when the
    mesh has spare tiles; total area == rows*cols).

    Recursive bisection: split the bin set into two groups of nearly
    equal total area, cut the rectangle along its longer edge at the
    proportional integer boundary, recurse.
    """
    rows, cols = mesh_shape
    total_tiles = rows * cols
    need = sum(areas)
    if need > total_tiles:
        raise ValueError(f"areas sum {need} exceeds mesh {total_tiles}")
    if not areas:
        return []

    result: List[Rect] = [None] * len(areas)  # type: ignore[list-item]

    def split_ok(span: int, other: int, need1: int, need2: int):
        """Integer cut position along ``span`` such that both sides hold
        their needs; None if impossible on this axis."""
        lo = -(-need1 // other)                 # ceil(need1 / other)
        hi = span - (-(-need2 // other))
        if lo == 0:
            lo = 1
        if lo <= hi and 0 < lo < span:
            # bias toward proportional position within the feasible band
            prop = round(span * need1 / max(need1 + need2, 1))
            return min(max(prop, lo), hi)
        return None

    def cut(rect: Rect, idxs: List[int]) -> bool:
        r0, c0, h, w = rect
        if len(idxs) == 1:
            if h * w < areas[idxs[0]]:
                return False
            result[idxs[0]] = rect
            return True
        # balanced two-way split of the bin set by area (greedy LPT)
        idxs_sorted = sorted(idxs, key=lambda i: -areas[i])
        groupings = []
        g1: List[int] = []
        g2: List[int] = []
        a1 = a2 = 0
        for i in idxs_sorted:
            if a1 <= a2:
                g1.append(i)
                a1 += areas[i]
            else:
                g2.append(i)
                a2 += areas[i]
        groupings.append((g1, g2, a1, a2))
        # alternatives: every prefix split of the size-sorted list
        # (covers e.g. [9,2] | [1,1,1] where LPT pairs 9 with the ones)
        for i in range(1, len(idxs_sorted)):
            ga = idxs_sorted[:i]
            gb = idxs_sorted[i:]
            groupings.append((
                ga, gb,
                sum(areas[j] for j in ga), sum(areas[j] for j in gb),
            ))

        for ga, gb, na, nb in groupings:
            # try the longer axis first, then the other
            axes = ("w", "h") if w >= h else ("h", "w")
            for ax in axes:
                if ax == "w":
                    pos = split_ok(w, h, na, nb)
                    if pos is None:
                        continue
                    if cut((r0, c0, h, pos), ga) and cut(
                        (r0, c0 + pos, h, w - pos), gb
                    ):
                        return True
                else:
                    pos = split_ok(h, w, na, nb)
                    if pos is None:
                        continue
                    if cut((r0, c0, pos, w), ga) and cut(
                        (r0 + pos, c0, h - pos, w), gb
                    ):
                        return True
        return False

    if not cut((0, 0, rows, cols), list(range(len(areas)))):
        raise ValueError(
            f"guillotine cutting failed for areas {list(areas)} on "
            f"{mesh_shape} (fragmentation)"
        )
    return result


def mc_positions(hw: HardwareModel) -> List[Tuple[float, float]]:
    """Memory controllers sit at the mesh boundary (paper §II-C1): spread
    evenly along the perimeter midpoints."""
    rows, cols = hw.mesh_shape
    n = hw.num_memory_controllers
    anchors = [
        (0.0, cols / 2),          # top edge
        (rows - 1.0, cols / 2),   # bottom edge
        (rows / 2, 0.0),          # left edge
        (rows / 2, cols - 1.0),   # right edge
    ]
    return [anchors[i % 4] for i in range(n)]


def bind_memory_controllers(
    rects: Sequence[Rect], hw: HardwareModel
) -> List[int]:
    """Nearest-MC binding by Manhattan distance from the rect centre."""
    mcs = mc_positions(hw)
    out: List[int] = []
    for r0, c0, h, w in rects:
        cy, cx = r0 + h / 2, c0 + w / 2
        best = min(
            range(len(mcs)),
            key=lambda i: abs(mcs[i][0] - cy) + abs(mcs[i][1] - cx),
        )
        out.append(best)
    return out
