"""Tp-driven — non-isolated, colocation-aware work-conserving scheduler
(paper §III-A2; Planaria [14] as the representative).

Maintains a deadline-driven task queue; *every* queue change (arrival or
completion) triggers on-the-fly rescheduling that redistributes all
available tiles among ready tasks to keep every tile saturated.  Jobs
are treated as independent, each with its (GHA-derived) sub-deadline.
Reallocation is assumed cheap — the engine charges the real
stop-migrate-restart stall, which is exactly the mismatch the paper
measures (§III-C2).

With the partitioned variant (``pglb``, ablation §V-B2) the same policy
runs independently inside each of the N partitions.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from ..sim.engine import Job, JobState, Simulator
from ..sim.policy import Policy

__all__ = ["TpDrivenPolicy"]


class TpDrivenPolicy(Policy):
    name = "tp_driven"

    #: on_point ignores "chunk"; let the engine skip those events
    uses_chunk_points = False

    def __init__(self, drop_on_subddl: bool = False):
        #: Fig. 12 'hard' variant: drop a job once its sub-deadline passed
        self.drop_on_subddl = drop_on_subddl
        self._cands: dict = {}

    def setup(self, sim: Simulator) -> None:
        # per-task DoP candidate cache (hot: every reallocation pass
        # walks the candidate ladder for every queued job)
        self._cands = {
            name: t.dop_candidates()
            for name, t in sim.wf.tasks.items() if not t.is_sensor
        }

    # ------------------------------------------------------------------
    def _reallocate(self, sim: Simulator, partition: int, now: float) -> None:
        part = sim.parts[partition]
        if part.stalled:
            return  # decisions resume when the migration completes
        cap = part.capacity
        tf = sim.hw.tile_flops

        running = [sim.jobs[jid] for jid in part.running]
        ready = sim.eligible_jobs(partition, admitted_only=False)
        queue: List[Job] = sorted(
            running + ready, key=lambda j: (j.sub_ddl, j.jid)
        )

        # EDF quota pass: give each job the smallest DoP meeting its
        # deadline; urgent jobs first.
        alloc: Dict[int, int] = {}
        left = cap
        cands_of = self._cands
        for job in queue:
            cands = cands_of[job.task]
            slack = job.sub_ddl - now
            rem = 1.0 - job.progress
            durs = job.duration_ladder(cands, tf)
            pick = 0
            for c, d in zip(cands, durs):
                if c > left:
                    break
                pick = c
                if rem * d <= slack:
                    break
            alloc[job.jid] = pick
            left -= pick

        # work-conserving pass: saturate every tile (§III-A2) by bumping
        # jobs (EDF order) to their next DoP candidates.
        bumped = True
        while left > 0 and bumped:
            bumped = False
            for job in queue:
                cands = cands_of[job.task]
                cur = alloc.get(job.jid, 0)
                for c in cands:  # next candidate above cur (inline: hot)
                    if c > cur:
                        if c - cur <= left:
                            alloc[job.jid] = c
                            left -= c - cur
                            bumped = True
                        break

        resize: Dict[int, int] = {}
        starts: Dict[int, int] = {}
        for job in queue:
            a = alloc.get(job.jid, 0)
            if job.state == JobState.RUNNING:
                if a != job.dop:
                    resize[job.jid] = a  # 0 preempts
            elif a > 0:
                starts[job.jid] = a
        if resize or starts:
            sim.resize(partition, resize, starts)

    # ------------------------------------------------------------------
    def on_point(
        self, sim: Simulator, partition: int, now: float, reason: str,
        job: Optional[Job] = None,
    ) -> None:
        if partition < 0:
            return
        if reason == "timer" and job is not None:
            if job.state not in (JobState.DONE, JobState.DROPPED):
                if self.drop_on_subddl and now >= job.sub_ddl - 1e-12:
                    sim.terminate(job, "subddl_drop")
                elif sim.cfg.drop_policy == "hard" and now >= job.e2e_ddl - 1e-12:
                    sim.terminate(job, "e2e_deadline")
            return
        if reason == "ready" and job is not None:
            if self.drop_on_subddl:
                sim.arm_timer(partition, job.sub_ddl, job)
            elif sim.cfg.drop_policy == "hard":
                sim.arm_timer(partition, job.e2e_ddl, job)
        if reason in ("ready", "finish", "drop", "resume"):
            # every queue change triggers rescheduling (Fig. 3a)
            self._reallocate(sim, partition, now)
