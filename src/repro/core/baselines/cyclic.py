"""Cyc. — fully-isolated, time-multiplexing scheduler (paper §III-A1).

Static reservation: every task has a fixed tile count (its GHA DoP) and
a reserved slot ``[t_v, t_v + l_v]``.  A job may start only at its slot
(ERT) and is **terminated when it overruns its budget** (hard
sub-deadline), so an overrun never delays other tasks.  Resource
bindings are fully static; rescheduling overhead is zero by
construction.

Cyc.(S) — the elastic variant of the ablation (§V-B1): identical
partitions, tile budgets and DoPs, but ERT/DDL act as *elastic*
references: a job starts as soon as its data (and tiles) are available
and is only abandoned at the E2E deadline — this releases slack along
the chain ("E2E slack sharing") at near-zero rescheduling overhead.
"""
from __future__ import annotations

from typing import Optional

from ..sim.engine import Job, JobState, Simulator
from ..sim.policy import Policy

__all__ = ["CyclicPolicy", "ElasticCyclicPolicy"]


class CyclicPolicy(Policy):
    name = "cyc"

    #: hard per-task budget enforcement
    elastic = False
    #: on_point ignores "chunk"; let the engine skip those events
    uses_chunk_points = False

    def setup(self, sim: Simulator) -> None:
        pass

    # -- helpers -----------------------------------------------------------
    def _try_start(self, sim: Simulator, partition: int) -> None:
        part = sim.parts[partition]
        jobs = sim.eligible_jobs(partition, admitted_only=not self.elastic)
        # reservation-table order: earliest slot first
        for job in sorted(jobs, key=lambda j: (j.ert, j.sub_ddl)):
            if job.plan_dop <= part.free():
                sim.start_job(job, job.plan_dop)
                if not self.elastic:
                    # budget enforcement timer at the sub-deadline
                    sim.arm_timer(partition, job.sub_ddl, job)
                elif sim.cfg.drop_policy == "hard":
                    sim.arm_timer(partition, job.e2e_ddl, job)

    def on_point(
        self, sim: Simulator, partition: int, now: float, reason: str,
        job: Optional[Job] = None,
    ) -> None:
        if partition < 0:
            return
        if reason == "timer" and job is not None:
            if job.state in (JobState.DONE, JobState.DROPPED):
                return
            if not self.elastic:
                # hard budget: overrun -> terminate (paper Fig. 3b)
                if now >= job.sub_ddl - 1e-12:
                    sim.terminate(job, "budget_overrun")
            else:
                if sim.cfg.drop_policy == "hard" and now >= job.e2e_ddl - 1e-12:
                    sim.terminate(job, "e2e_deadline")
            self._try_start(sim, partition)
            return
        if reason in ("ready", "ert", "finish", "drop", "resume"):
            if not self.elastic and reason == "ready" and job is not None:
                # a job whose slot cannot be honoured is dropped at its
                # sub-deadline even if it never starts
                if job.state == JobState.READY:
                    sim.arm_timer(partition, job.sub_ddl, job)
            self._try_start(sim, partition)


class ElasticCyclicPolicy(CyclicPolicy):
    name = "cyc_s"
    elastic = True
