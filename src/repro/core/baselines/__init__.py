"""Baseline scheduling paradigms adapted to tile-based ADS via GHA
(paper §III-A): the fully-isolated time-multiplexing scheduler (Cyc.)
with its elastic variant Cyc.(S), and the non-isolated colocation-aware
work-conserving scheduler (Tp-driven, Planaria-style)."""
from .cyclic import CyclicPolicy, ElasticCyclicPolicy
from .tpdriven import TpDrivenPolicy

__all__ = ["CyclicPolicy", "ElasticCyclicPolicy", "TpDrivenPolicy"]
