"""Hardware model for tile-based accelerators (paper §II-C1, §V-A).

Two instantiations ship with the framework:

* :func:`simba_chip` — the paper's evaluation platform (Simba-derived,
  128 tiles @ 2 GHz, 16 PE x 16 MAC per tile, 1.25 MB SRAM/tile, 64 B NoC
  links, LPDDR5 @ 102 GB/s).  Used by the faithful reproduction
  (Tile-stream simulator + GHA compiler + benchmarks).
* :func:`tpu_pod` — the TPU adaptation where a "tile" is one TPU v5e chip
  and the NoC is the ICI torus.  Used by the serving engine and the
  multi-pod launch path (see DESIGN.md §3).

The scheduler stack is hardware-agnostic: everything consumes a
:class:`HardwareModel`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

__all__ = [
    "HardwareModel",
    "simba_chip",
    "tpu_pod",
    "ReallocCostModel",
]


@dataclasses.dataclass(frozen=True)
class ReallocCostModel:
    """Cost of a stop-migrate-restart DoP reallocation (paper §IV-D1).

    The paper decomposes reallocation overhead into three parts (§V-A):
      1. scheduler decision  (<10 us on the RISC-V controller)
      2. context switch      (state checkpoint to DRAM)
      3. data migration      (dominant; proportional to checkpoint bytes,
                              moved over the NoC / DRAM path)

    ``latency(bytes, hops)`` returns seconds.
    """

    decision_s: float = 8e-6          # scheduler decision latency
    per_hop_s: float = 50e-9          # NoC per-hop latency
    migration_bw: float = 102e9       # bytes/s sustained for migration traffic
    fixed_s: float = 20e-6            # stop/restart control-plane constant

    def latency(self, checkpoint_bytes: float, hops: float = 4.0) -> float:
        move = checkpoint_bytes / self.migration_bw
        return self.fixed_s + self.decision_s + hops * self.per_hop_s + move


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """A tile-based accelerator (one scheduling domain).

    ``tile_flops`` is the per-tile peak (MAC counted as 2 FLOPs) so that
    per-task compute latency is ``work_flops / (c_v * tile_flops)`` —
    the ``W_v / (c_v * P)`` term of Eq. (1).
    """

    name: str
    num_tiles: int                    # M
    mesh_shape: Tuple[int, int]       # physical 2D mesh (rows, cols)
    tile_flops: float                 # peak FLOP/s per tile (P)
    tile_sram_bytes: float            # private SRAM per tile
    noc_link_bytes_per_s: float       # one NoC link
    dram_bw_bytes_per_s: float        # aggregate DRAM bandwidth
    num_memory_controllers: int
    freq_hz: float
    realloc: ReallocCostModel = dataclasses.field(default_factory=ReallocCostModel)

    def __post_init__(self) -> None:
        r, c = self.mesh_shape
        if r * c != self.num_tiles:
            raise ValueError(
                f"mesh_shape {self.mesh_shape} does not cover num_tiles={self.num_tiles}"
            )

    # -- derived ---------------------------------------------------------
    @property
    def chip_flops(self) -> float:
        return self.num_tiles * self.tile_flops

    def avg_hops_to_mc(self, partition_tiles: int) -> float:
        """Average hop count from a rectangular partition to its bound MC.

        With fixed partition->MC paths (paper §II-C1) the hop count is
        bounded by a constant ~ the partition diameter.
        """
        side = max(1.0, math.sqrt(max(partition_tiles, 1)))
        return (side - 1.0) + 1.0  # cross the partition + enter the MC node

    def realloc_latency(self, checkpoint_bytes: float, partition_tiles: int) -> float:
        return self.realloc.latency(
            checkpoint_bytes, hops=self.avg_hops_to_mc(partition_tiles)
        )

    def scaled(self, num_tiles: int) -> "HardwareModel":
        """Return a copy with a different tile count (capacities scale
        linearly with tiles, as in the paper's scaling study §V-C1)."""
        rows = int(math.sqrt(num_tiles))
        while num_tiles % rows:
            rows -= 1
        cols = num_tiles // rows
        scale = num_tiles / self.num_tiles
        return dataclasses.replace(
            self,
            num_tiles=num_tiles,
            mesh_shape=(rows, cols),
            dram_bw_bytes_per_s=self.dram_bw_bytes_per_s * scale,
            num_memory_controllers=max(1, int(round(self.num_memory_controllers * scale))),
        )


def simba_chip(num_tiles: int = 128) -> HardwareModel:
    """The paper's hardware configuration (§V-A).

    128 tiles @ 2 GHz; each tile has 16 PEs x 16 16-bit MACs
    (weight-stationary NVDLA dataflow): 16*16*2 GHz = 512 GMAC/s
    = 1.024 TFLOP/s per tile.  1.25 MB SRAM per tile; 64 B NoC links
    (@2 GHz -> 128 GB/s per link); LPDDR5 @ 102 GB/s.

    Multi-chip setups (the benchmark needs 3-5 chips = 384-640 tiles) are
    modelled as one larger mesh, as the paper does when sweeping
    tile counts {200..500}; cross-chip PCIe is folded into the I/O
    variation term F2.
    """
    freq = 2.0e9
    base = HardwareModel(
        name=f"simba-{num_tiles}t",
        num_tiles=128,
        mesh_shape=(8, 16),
        tile_flops=16 * 16 * 2 * freq,          # 1.024 TFLOP/s fp16
        tile_sram_bytes=1.25e6,
        noc_link_bytes_per_s=64 * freq,          # 128 GB/s
        dram_bw_bytes_per_s=102e9,
        num_memory_controllers=4,
        freq_hz=freq,
        realloc=ReallocCostModel(migration_bw=102e9),
    )
    if num_tiles == 128:
        return base
    return base.scaled(num_tiles)


def tpu_pod(num_chips: int = 256) -> HardwareModel:
    """TPU adaptation: one 'tile' = one v5e chip (DESIGN.md §3).

    197 bf16 TFLOP/s and 819 GB/s HBM per chip; ICI links ~50 GB/s.
    Reallocation = resharding params/KV over ICI.
    """
    rows = int(math.sqrt(num_chips))
    while num_chips % rows:
        rows -= 1
    return HardwareModel(
        name=f"tpu-v5e-{num_chips}c",
        num_tiles=num_chips,
        mesh_shape=(rows, num_chips // rows),
        tile_flops=197e12,
        tile_sram_bytes=16e9,                    # HBM plays the SRAM role
        noc_link_bytes_per_s=50e9,
        dram_bw_bytes_per_s=819e9 * num_chips,
        num_memory_controllers=num_chips,
        freq_hz=0.94e9,
        realloc=ReallocCostModel(
            decision_s=5e-6, per_hop_s=1e-6, migration_bw=50e9, fixed_s=100e-6
        ),
    )
