"""One-call experiment runner: workload -> GHA -> policy -> Tile-stream.

This is the entry point used by the benchmark harness (one function per
paper figure) and by the examples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from .baselines import CyclicPolicy, ElasticCyclicPolicy, TpDrivenPolicy
from .benchmark import make_ads_benchmark
from .gha import GHACompiler
from .hardware import simba_chip
from .latency_model import LatencyModel
from .runtime import AdsTilePolicy
from .sim import SimConfig, Simulator, SimReport
from .sim.policy import Policy
from .workload import Workflow

__all__ = [
    "ExperimentSpec", "run_experiment", "make_policy", "POLICIES",
    "build_stack",
]

POLICIES = (
    "cyc",            # static reservation, hard budgets (§III-A1)
    "cyc_s",          # elastic variant (ablation §V-B1)
    "tp_driven",      # work-conserving, single bin (§III-A2)
    "tp_driven_hard", # + sub-deadline dropping (Fig. 12 'hard')
    "pglb",           # work-conserving within N partitions (§V-B2)
    "reserv",         # partitions + elastic reservation, no slack share
    "ads_tile",       # the full system (§IV)
)


def make_policy(name: str) -> Policy:
    if name == "cyc":
        return CyclicPolicy()
    if name == "cyc_s":
        return ElasticCyclicPolicy()
    if name == "tp_driven":
        return TpDrivenPolicy()
    if name == "tp_driven_hard":
        return TpDrivenPolicy(drop_on_subddl=True)
    if name == "pglb":
        return TpDrivenPolicy()
    if name == "reserv":
        return AdsTilePolicy(slack_sharing=False)
    if name == "ads_tile":
        return AdsTilePolicy()
    raise ValueError(f"unknown policy {name!r} (choose from {POLICIES})")


@dataclasses.dataclass
class ExperimentSpec:
    policy: str = "ads_tile"
    tiles: int = 400
    cockpit_replicas: int = 1
    load_factor: float = 1.0
    deadline_s: float = 0.100
    q: float = 0.95
    num_partitions: Optional[int] = 4
    duration_s: float = 2.0
    seed: int = 0
    drop_policy: str = "soft"
    p99_ratio: float = 3.3
    dram_utilization: float = 0.5

    def resolved_partitions(self) -> Optional[int]:
        """Policy-implied partitioning: Tp-driven is single-bin by
        definition; Cyc. uses per-chain bins (S=None)."""
        if self.policy in ("tp_driven", "tp_driven_hard"):
            return 1
        if self.policy in ("cyc", "cyc_s"):
            return None
        return self.num_partitions


def build_stack(spec):
    """Workflow / hardware / latency model / GHA compiler construction
    shared by the stationary runner and the scenario runner.  ``spec``
    is any object with :class:`ExperimentSpec`'s workload fields (the
    scenario runner's spec qualifies)."""
    wf = make_ads_benchmark(
        cockpit_replicas=spec.cockpit_replicas,
        load_factor=spec.load_factor,
        critical_deadline_s=spec.deadline_s,
        cockpit_deadline_s=max(spec.deadline_s, 0.100),
    )
    hw = simba_chip(spec.tiles)
    model = LatencyModel.from_workflow(
        wf, hw, p99_ratio=spec.p99_ratio,
        dram_utilization=spec.dram_utilization,
    )
    compiler = GHACompiler(q=spec.q, num_partitions=spec.resolved_partitions())
    return wf, hw, model, compiler


def run_experiment(spec: ExperimentSpec) -> SimReport:
    wf, _hw, model, compiler = build_stack(spec)
    sched = compiler.compile(model, wf)
    policy = make_policy(spec.policy)
    sim = Simulator(
        wf, model, sched, policy,
        SimConfig(
            duration_s=spec.duration_s, seed=spec.seed,
            drop_policy=spec.drop_policy,
        ),
    )
    return sim.run()


def critical_map(wf: Workflow) -> Dict[str, bool]:
    return {c.name: c.critical for c in wf.chains}
