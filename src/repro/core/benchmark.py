"""The L4 ADS benchmark of the paper (Fig. 1 / Fig. 10).

14 DNN tasks derived from industry & academia workloads, fed by four
sensor groups (multi-view cameras 30 Hz, stereo cameras 20 Hz, LiDAR
10 Hz, IMU 240 Hz).  Driving functions (perception -> localization ->
prediction -> planning -> control) target the actuator; four cockpit
monitoring modules (road semantics, depth, dynamic targets, optical
flow) target the display and are replicated x1/x6/x9 to scale load.

Per-task mean compute (GMACs/job) is estimated from the public profiles
of the cited models (ResNet18, YoloX, BEVFormer, Deformable-DETR, LAV,
ERFNet, PointPillars/CenterNet, PWC-Net, SemAttNet), scaled so that the
aggregate demand lands in the paper's stated 180-300 TMAC/s regime at
x6..x9 cockpit replication.  Bandwidth columns come straight from
Fig. 10.  ``checkpoint_bytes`` is the *per-tile* live state migrated on
a DoP switch (bounded by the 1.25 MB tile SRAM); the reallocation model
multiplies by the current DoP.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from .workload import Chain, DnnTask, SensorTask, Workflow

__all__ = ["make_ads_benchmark", "COCKPIT_CHAINS", "ADS_TASK_TABLE"]

_GMAC = 2e9  # 1 GMAC = 2e9 FLOPs

# id, name, model, GMACs/job, avg BW frac, peak GB/s, per-tile ckpt MB, DoPs
# DoP candidate sets reflect §III-B2: upstream perception encoders are
# inherently larger and support high DoP; tail planning/control models
# parallelise poorly.
ADS_TASK_TABLE: List[Tuple[int, str, str, float, float, float, float, Tuple[int, ...]]] = [
    (1,  "traffic_light", "ResNet18(E)+brake",       12.0, 0.084, 14.4, 0.6, (1, 2, 4, 8)),
    (2,  "img_backbone",  "YoloX(E)",               480.0, 0.507, 17.1, 1.0, (16, 32, 64, 96, 128)),
    (3,  "cam_fusion",    "BevFormer(E)",           600.0, 0.190, 280.2, 1.1, (16, 32, 64, 96, 128, 192)),
    (4,  "vis_det",       "DeformableDETR(H)",      100.0, 0.017, 31.9, 0.8, (4, 8, 16, 32, 64)),
    (5,  "traj_pred",     "LAV",                     40.0, 0.013, 10.3, 0.7, (2, 4, 8, 16, 32)),
    (6,  "path_plan",     "LAV-plan",                10.0, 0.013, 1.0, 0.5, (1, 2, 4, 8, 16)),
    (7,  "control",       "LAV-ctrl",                 1.5, 0.001, 2.0, 0.3, (1, 2, 4)),
    (8,  "stereo_lidar",  "ERFNet(E)+PointPainting", 400.0, 0.054, 21.0, 1.0, (8, 16, 32, 64, 96)),
    (9,  "lane_seg",      "ERFNet(H)",               70.0, 0.049, 27.2, 0.8, (2, 4, 8, 16, 32)),
    (10, "lidar_det",     "PointPillars+CenterNet", 120.0, 0.012, 78.2, 0.9, (4, 8, 16, 32, 64)),
    (11, "drivable_seg",  "ERFNet(H)",               70.0, 0.037, 26.8, 0.8, (2, 4, 8, 16, 32)),
    (12, "semantic_seg",  "ERFNet(H)",               70.0, 0.025, 27.0, 0.8, (2, 4, 8, 16, 32)),
    (13, "optical_flow",  "PWC-NET(H)",              90.0, 0.010, 4.8, 0.8, (2, 4, 8, 16, 32)),
    (14, "depth_est",     "SemAttNet(H)",           150.0, 0.025, 15.3, 0.9, (4, 8, 16, 32, 64)),
]

# chains whose replication scales the cockpit load (nodes 11-14 and their
# private heads; upstream backbones/sensors stay shared)
COCKPIT_CHAINS = ("ck_drivable", "ck_semantic", "ck_flow", "ck_depth")


def make_ads_benchmark(
    cockpit_replicas: int = 1,
    load_factor: float = 1.0,
    critical_deadline_s: float = 0.100,
    cockpit_deadline_s: float = 0.100,
) -> Workflow:
    """Build the benchmark workflow.

    ``cockpit_replicas`` in {1, 4, 6, 9} reproduces the paper's workload
    scaling; ``load_factor`` scales every DNN's mean compute (the paper's
    {0.5, 1.0} sweep); deadlines follow §V-A (80/90/100 ms critical).
    """
    tasks: Dict[str, DnnTask] = {}
    for _id, name, model, gmacs, bw, peak, ckpt_mb, dops in ADS_TASK_TABLE:
        tasks[name] = DnnTask(
            name=name,
            mean_flops=gmacs * _GMAC * load_factor,
            checkpoint_bytes=ckpt_mb * 1e6,
            avg_bw_frac=bw,
            peak_bw=peak * 1e9,
            compiled_dops=dops,
            model=model,
        )

    sensors = {
        "cam_multi": SensorTask(
            name="cam_multi", period_s=1.0 / 30.0, mean_latency_s=2.0e-3
        ),
        "cam_stereo": SensorTask(
            name="cam_stereo", period_s=1.0 / 20.0, mean_latency_s=2.5e-3
        ),
        "lidar": SensorTask(name="lidar", period_s=1.0 / 10.0, mean_latency_s=4.0e-3),
        "imu": SensorTask(name="imu", period_s=1.0 / 240.0, mean_latency_s=0.1e-3),
    }

    all_tasks: Dict[str, DnnTask] = {**sensors, **tasks}

    edges = [
        # sensing -> perception
        ("cam_multi", "traffic_light"),
        ("cam_multi", "img_backbone"),
        ("cam_multi", "optical_flow"),
        ("cam_stereo", "stereo_lidar"),
        ("cam_stereo", "depth_est"),
        ("lidar", "stereo_lidar"),
        ("lidar", "lidar_det"),
        ("lidar", "depth_est"),
        # perception internal
        ("img_backbone", "cam_fusion"),
        ("cam_fusion", "vis_det"),
        # backbone heads (cockpit)
        ("img_backbone", "lane_seg"),
        ("img_backbone", "drivable_seg"),
        ("img_backbone", "semantic_seg"),
        # localization/prediction
        ("imu", "traj_pred"),
        ("vis_det", "traj_pred"),
        ("stereo_lidar", "traj_pred"),
        ("lidar_det", "traj_pred"),
        # planning/control
        ("traj_pred", "path_plan"),
        ("traffic_light", "path_plan"),
        ("path_plan", "control"),
    ]

    chains = [
        Chain(
            "drv_vision",
            ("cam_multi", "img_backbone", "cam_fusion", "vis_det",
             "traj_pred", "path_plan", "control"),
            critical_deadline_s, critical=True,
        ),
        Chain(
            "drv_lidar",
            ("lidar", "lidar_det", "traj_pred", "path_plan", "control"),
            critical_deadline_s, critical=True,
        ),
        Chain(
            "drv_fusion",
            ("cam_stereo", "stereo_lidar", "traj_pred", "path_plan", "control"),
            critical_deadline_s, critical=True,
        ),
        Chain(
            "drv_light",
            ("cam_multi", "traffic_light", "path_plan", "control"),
            critical_deadline_s, critical=True,
        ),
        Chain(
            "ck_lane",
            ("cam_multi", "img_backbone", "lane_seg"),
            cockpit_deadline_s, critical=False,
        ),
        Chain(
            "ck_drivable",
            ("cam_multi", "img_backbone", "drivable_seg"),
            cockpit_deadline_s, critical=False,
        ),
        Chain(
            "ck_semantic",
            ("cam_multi", "img_backbone", "semantic_seg"),
            cockpit_deadline_s, critical=False,
        ),
        Chain(
            "ck_flow",
            ("cam_multi", "optical_flow"),
            cockpit_deadline_s, critical=False,
        ),
        Chain(
            "ck_depth",
            ("cam_stereo", "depth_est"),
            cockpit_deadline_s, critical=False,
        ),
    ]

    wf = Workflow(tasks=all_tasks, edges=edges, chains=chains)
    if cockpit_replicas > 1:
        wf = wf.replicate_cockpit(cockpit_replicas, COCKPIT_CHAINS)
    return wf
