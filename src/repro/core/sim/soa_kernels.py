"""JAX round kernels for the structure-of-arrays Monte-Carlo backend.

This module holds the device side of :mod:`repro.core.sim.soa`: a
``jax.jit``-compiled loop that advances **R runs of one scenario
skeleton simultaneously** through discrete scheduling rounds.  The host
(:func:`repro.core.sim.soa.build_problem`) precomputes everything that
is lane-independent — the round grid (seam-aligned), per-round job
windows over the release-sorted job axis, EDF permutations, per-segment
schedule bindings, hot-swap capacities/staging volumes — and the kernel
only does the lane-dependent part as fused array ops over ``(R, W)``
windows:

* readiness via *finish codes*: every job resolves to one float in a
  ``(R, n_jobs + n_sensors + 1)`` code array (``+inf`` unresolved,
  ``t`` clean finish at ``t``, ``-t - 1`` degraded/dropped at ``t``),
  so dependency propagation is a single gather;
* *backdated exact event times*: rounds only decide **that** something
  happens, the times themselves (ready/start/finish/drop) are computed
  exactly from the inputs, so chain latencies carry round-quantization
  noise only through changed *decisions*, not through time rounding;
* policy decisions (cyc / cyc_s / tp_driven / ads_tile) re-expressed as
  masked ladder/EDF array ops (see ``_alloc_ladder``), with the
  engine's quota semantics: ``grant = largest candidate <=
  min(want, tiles_left)`` where ``want`` is the smallest candidate
  meeting the deadline (``fit_quota`` equivalence);
* schedule hot-swaps as a ``lax.cond`` seam step (capacity switch,
  vectorized largest-first preemption, staging bytes precomputed on the
  host).

Everything is float32; the absolute times in a <=2 s horizon keep
~1e-7 s resolution, far below the multi-ms effects under study.  The
contract with the scalar engine is **distributional** (KS + CI overlap
+ exact structural invariants), enforced by
``benchmarks.check_equivalence --mode distributional`` — see
``docs/performance.md#soa-backend`` for what is and is not guaranteed.

jax is an optional dependency of the sim package: importing this module
without jax leaves ``HAS_JAX`` False and every entry point raising, so
the scalar/lockstep engines (and their tests) never notice.
"""
from __future__ import annotations

import dataclasses
import hashlib
from functools import partial
from typing import Dict, Tuple

import numpy as np

try:  # pragma: no cover - exercised via HAS_JAX gates in tests
    import jax
    import jax.numpy as jnp
    from jax import lax

    HAS_JAX = True
except Exception:  # pragma: no cover
    jax = None
    jnp = None
    lax = None
    HAS_JAX = False

try:  # pragma: no cover
    from jax.experimental import pallas as pl

    HAS_PALLAS = HAS_JAX
except Exception:  # pragma: no cover
    pl = None
    HAS_PALLAS = False

__all__ = [
    "HAS_JAX",
    "HAS_PALLAS",
    "KernelConfig",
    "NFIELDS",
    "F_STATE",
    "F_READY",
    "F_DEG",
    "F_START",
    "F_FIN",
    "F_DOP",
    "F_PART",
    "F_REM",
    "F_SUB",
    "F_TGT",
    "PEND",
    "READY",
    "RUN",
    "DONE",
    "DROP",
    "POLICY_IDS",
    "simulate",
    "ladder_grant_reference",
    "clear_kernel_cache",
]

# mutable per-job state: one (R, N, NFIELDS) float32 array so each round
# slides a single (R, W, NFIELDS) window in and out
(
    F_STATE,   # job state code (PEND..DROP)
    F_READY,   # exact ready time (resolve of release + preds)
    F_DEG,     # degraded flag (dropped/degraded predecessor upstream)
    F_START,   # exact (backdated) start time
    F_FIN,     # finish projection while RUNNING; final time once DONE/DROP
    F_DOP,     # currently held tiles
    F_PART,    # partition bound at start
    F_REM,     # remaining work fraction (1 until started; set on preempt)
    F_SUB,     # sub-deadline bound at start (retargets stop at start)
    F_TGT,     # ads slack-shared target bound at start
    F_ADV,     # last progress-sync time (start / freeze / stall end): the
               # scalar engine only advances ``job.progress`` at realloc
               # freezes, so its at-risk and quota projections run on
               # progress *stale since this time* — reproduced here
) = range(11)
NFIELDS = 11

PEND, READY, RUN, DONE, DROP = 0.0, 1.0, 2.0, 3.0, 4.0

POLICY_IDS = {"cyc": 0, "cyc_s": 1, "tp_driven": 2, "ads_tile": 3}
_CYC, _CYC_S, _TP, _ADS = 0, 1, 2, 3


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Hashable static configuration of one compiled round loop.

    Everything here participates in the jit cache key; array shapes do
    too (via the traced arguments), so one scenario x policy x (R, dt)
    cell compiles once and is then reused across seed batches.
    """

    policy: int                # POLICY_IDS value
    R: int                     # lanes (runs)
    W: int                     # window width over the job axis
    C: int                     # DoP-candidate ladder width
    PM: int                    # max predecessor in-degree
    P: int                     # partitions
    tile_flops: float
    fixed_s: float
    decision_s: float
    per_hop_s: float
    inv_bw: float              # 1 / migration bandwidth
    realloc_gate: float = 1.0
    admission: bool = True     # ads ablation / cyc ERT gate
    quota_control: bool = True
    #: deadline-drop regime: 0 = none (the runner's default
    #: ``drop_policy="soft"`` arms no e2e timers for tp/ads), 1 =
    #: sub-deadline termination (cyc's unconditional budget
    #: enforcement), 2 = e2e-deadline dequeue (``drop_policy="hard"``)
    drop_mode: int = 0
    #: chunk boundaries per job (SimConfig.n_chunks): the scalar engine
    #: syncs a running job's progress only at its chunk events, so the
    #: ads at-risk projection runs on progress stale by up to one chunk
    #: interval — the kernel reproduces that bounded staleness
    n_chunks: int = 6
    alloc_iters: int = 8       # monotone EDF-allocation refinement steps
    bump_passes: int = 8       # tp work-conserving bump refinement steps
    use_pallas: bool = False   # route _alloc_ladder through Pallas
    pallas_interpret: bool = True


# ---------------------------------------------------------------------------
# allocation primitives
# ---------------------------------------------------------------------------
def _ladder_grant(limit, cand):
    """Largest candidate DoP <= ``limit`` (0 when none fits).

    ``limit``: (R, W) float tile budget per job; ``cand``: (W, C) or
    (R, W, C) candidate values (padded by repeating the last rung).
    This is the vectorized form of the engine's quota walk: with
    ``limit = min(want, tiles_left)`` it reproduces ``fit_quota``'s
    "smallest candidate meeting the deadline, else the largest that
    fits" exactly.
    """
    ok = cand <= limit[..., None] + 0.5
    return jnp.max(jnp.where(ok, cand, 0.0), axis=-1)


def _ladder_grant_pallas(limit, cand, interpret=True):
    """Pallas version of :func:`_ladder_grant` (one lane-block per grid
    step).  Same math, kept for platforms where a fused scalar loop
    beats XLA's reduce; on CPU it only runs in interpret mode (tests),
    the jnp path stays the performance default."""
    R, W = limit.shape
    cand3 = jnp.broadcast_to(cand, (R,) + cand.shape[-2:])

    def kernel(limit_ref, cand_ref, out_ref):
        lim = limit_ref[...]
        cd = cand_ref[...]
        ok = cd <= lim[..., None] + 0.5
        out_ref[...] = jnp.max(jnp.where(ok, cd, 0.0), axis=-1)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((R, W), limit.dtype),
        grid=(1,),
        interpret=interpret,
    )(limit, cand3)


def ladder_grant_reference(limit: np.ndarray, cand: np.ndarray) -> np.ndarray:
    """NumPy oracle for the grant select (test hook for jnp vs pallas)."""
    ok = cand <= limit[..., None] + 0.5
    return np.max(np.where(ok, cand, 0.0), axis=-1)


def _class_prefix(cfg, part_s, cap_p, dtype):
    """Per-partition queue-prefix operators for one sorted queue.

    Returns ``(excl, total, capg)``: ``excl(d)`` is each entry's
    exclusive prefix sum of ``d`` over earlier same-partition entries,
    ``total(d)`` the inclusive whole-partition sum seen by each entry,
    and ``capg`` the entry's own partition budget.  With one partition
    these are a plain cumsum / broadcast sum; multi-partition uses a
    same-partition strict-lower mask as a batched matvec."""
    if cfg.P == 1:
        capg = jnp.broadcast_to(cap_p[:, :1], part_s.shape)

        def excl(d):
            return jnp.cumsum(d, axis=1) - d

        def total(d):
            return jnp.broadcast_to(
                jnp.sum(d, axis=1, keepdims=True), d.shape
            )

        return excl, total, capg

    part_i = jnp.clip(part_s.astype(jnp.int32), 0, cfg.P - 1)
    same = (part_i[:, :, None] == part_i[:, None, :]).astype(dtype)
    W = part_i.shape[1]
    tril = jnp.tril(jnp.ones((W, W), dtype=dtype), k=-1)
    Mpre = same * tril[None]
    capg = jnp.take_along_axis(cap_p, part_i, axis=1)

    def excl(d):
        return jnp.einsum("rjk,rk->rj", Mpre, d)

    def total(d):
        return jnp.einsum("rjk,rk->rj", same, d)

    return excl, total, capg


def _alloc_ladder(cfg, want, entry, part_s, cand_s, cap_p):
    """Feasible EDF ladder allocation over one round's sorted queue.

    ``want``: (R, W) desired DoP per queue entry (EDF order);
    ``entry``: (R, W) bool participation mask; ``part_s``: (R, W)
    partition id per entry; ``cand_s``: (W, C) candidate rows;
    ``cap_p``: (R, P) tile budget per partition.

    The scalar engine walks the queue sequentially, each entry seeing
    the tiles left by its predecessors.  Here a monotone fixed-point
    iteration replaces the walk: start from ``want``, compute each
    entry's exclusive prefix load per partition, re-grant against
    ``min(want, left)``, repeat.  Grants only ever shrink, so the
    result is always feasible; ``alloc_iters`` bounds how much
    freed-by-predecessor capacity later entries can recover (the
    documented approximation vs the exact walk).
    """
    want = jnp.where(entry, want, 0.0)
    cur = want
    sel = (
        partial(_ladder_grant_pallas, interpret=cfg.pallas_interpret)
        if (cfg.use_pallas and HAS_PALLAS)
        else _ladder_grant
    )
    # the per-partition exclusive prefix ("tiles my EDF predecessors in
    # my partition already took") is one fused op per iteration instead
    # of P masked cumsums
    excl, _, capg = _class_prefix(cfg, part_s, cap_p, want.dtype)

    def step(cur):
        cume = excl(cur)
        return jnp.where(
            entry, sel(jnp.minimum(want, capg - cume), cand_s), 0.0
        )

    # the refinement map is a pure function of ``cur``: once an
    # application leaves it unchanged every further one would too, so a
    # convergence-gated while_loop is exactly the unrolled loop (the
    # fixed point is usually reached in 2-3 steps; ``alloc_iters``
    # stays the worst-case bound)
    def cond(c):
        i, cur, prev = c
        return (i < cfg.alloc_iters) & jnp.any(cur != prev)

    def it(c):
        i, cur, _ = c
        return i + 1, step(cur), cur

    _, cur, _ = lax.while_loop(cond, it, (0, step(want), want + 1.0))
    return cur


def _bump_work_conserving(cfg, grant, entry, part_s, cand_s, cap_p):
    """tp_driven's saturation pass: spend leftover tiles by bumping
    queue entries (EDF order) to their next candidate rung.  Two
    conservative passes approximate the scalar ``while bumped`` loop
    (each pass assumes every earlier eligible entry takes its bump, so
    it never over-commits)."""
    excl, total, capg = _class_prefix(cfg, part_s, cap_p, grant.dtype)

    def one_pass(grant):
        above = cand_s > grant[..., None] + 0.5
        nxt = jnp.min(jnp.where(above, cand_s, jnp.inf), axis=-1)
        delta = jnp.where(entry & jnp.isfinite(nxt), nxt - grant, 0.0)
        leftg = capg - total(grant)
        # the scalar walk skips an entry whose bump no longer fits and
        # still offers the tiles to later entries; a plain prefix gate
        # would block them, so relax the take-set to that fixed point
        take = delta > 0
        for _ in range(3):
            cume = excl(jnp.where(take, delta, 0.0))
            take = (delta > 0) & (cume + delta <= leftg + 0.5)
        # enforce feasibility of the final set (prefix over taken only)
        cume = excl(jnp.where(take, delta, 0.0))
        ok = take & (cume + delta <= leftg + 0.5)
        return jnp.where(ok, grant + delta, grant)

    # same convergence argument as the ladder: a pass that changes
    # nothing makes every further pass a no-op
    def cond(c):
        i, grant, prev = c
        return (i < cfg.bump_passes) & jnp.any(grant != prev)

    def it(c):
        i, grant, _ = c
        return i + 1, one_pass(grant), grant

    _, grant, _ = lax.while_loop(cond, it, (0, one_pass(grant), grant - 1.0))
    return grant


# ---------------------------------------------------------------------------
# the round loop
# ---------------------------------------------------------------------------
def _build_loop(cfg: KernelConfig, const: Dict[str, "jnp.ndarray"]):
    R, W, P, C, PM = cfg.R, cfg.W, cfg.P, cfg.C, cfg.PM
    tf = cfg.tile_flops
    pol = cfg.policy
    n_rounds = int(const["t0"].shape[0])
    S_ = int(const["caps"].shape[0])

    def dur(work, io, sync, c):
        cc = jnp.maximum(c, 1.0)
        return work / (cc * tf) + io + sync * (cc - 1.0)

    def seam_step(op):
        """Schedule hot-swap at a segment-entry round (time = t0):
        capacity switch, largest-first preemption down to the new caps,
        one stop-migrate-restart stall per partition charged with the
        host-precomputed staging volume plus preempted checkpoints."""
        (state, fin, dop, rem, adv, pborn, stall_end, nre, rbytes,
         t0, workw, iow, syncw, ckptw, capsg, hopsg, stagedg) = op
        run = state == RUN
        d_cur = dur(workw, iow, syncw, dop)
        pos = jnp.arange(W, dtype=jnp.float32)
        moved = jnp.zeros((R, P), dtype=jnp.float32)
        vict = jnp.zeros((R, W), dtype=bool)
        for p in range(P):
            mp = run & (pborn == p)
            dv = jnp.where(mp, dop, 0.0)
            over = jnp.sum(dv, axis=1) - capsg[p]
            # removal order: largest dop first, later jid first on ties
            key = -(dv * (W + 1.0) + pos[None, :])
            order = jnp.argsort(key, axis=1)
            inv = jnp.argsort(order, axis=1)
            dsort = jnp.take_along_axis(dv, order, axis=1)
            cume = jnp.cumsum(dsort, axis=1) - dsort
            v_sorted = (dsort > 0) & (cume < over[:, None] - 1e-6)
            vp = jnp.take_along_axis(v_sorted, inv, axis=1)
            vict = vict | vp
            moved = moved.at[:, p].add(
                stagedg[p] + jnp.sum(jnp.where(vp, ckptw * dop, 0.0), axis=1)
            )
        stall = (
            cfg.fixed_s + cfg.decision_s + hopsg[None, :] * cfg.per_hop_s
            + moved * cfg.inv_bw
        )
        stall_end = jnp.maximum(stall_end, t0 + stall)
        # preempted: back to READY with exact residual fraction
        rem = jnp.where(
            vict, jnp.clip((fin - t0) / jnp.maximum(d_cur, 1e-12), 0.0, 1.0), rem
        )
        state = jnp.where(vict, READY, state)
        dop = jnp.where(vict, 0.0, dop)
        fin = jnp.where(vict, jnp.inf, fin)
        # freeze survivors for their partition's stall
        stall_own = jnp.sum(
            jnp.stack([
                jnp.where(pborn == p, stall[:, p][:, None], 0.0)
                for p in range(P)
            ]),
            axis=0,
        )
        still = (state == RUN)
        fin = jnp.where(still, fin + stall_own, fin)
        adv = jnp.where(still, t0 + stall_own, adv)
        nre = nre + jnp.float32(P)
        rbytes = rbytes + jnp.sum(moved, axis=1)
        return state, fin, dop, rem, adv, stall_end, nre, rbytes

    def body(r, carry):
        st, codes, stall_end, busy, rel, nre, rbytes, dwork = carry
        t0 = const["t0"][r]
        t1 = const["t1"][r]
        sg = const["seg"][r]
        lo = const["lo"][r]

        # ``st`` is a tuple of NFIELDS separate (R, N) planes: updating
        # a (R, W) window of each is in-place under the fori_loop,
        # whereas a packed (R, N, NFIELDS) array made XLA:CPU copy the
        # whole state every round (~7x the slice cost)
        (state, ready_t, deg, start, fin, dop, pborn, rem, subb, tgtb,
         adv) = (
            lax.dynamic_slice(a, (0, lo), (R, W)) for a in st
        )

        relw = lax.dynamic_slice(const["release"], (lo,), (W,))
        e2ew = lax.dynamic_slice(const["e2e"], (lo,), (W,))
        syncw = lax.dynamic_slice(const["sync"], (lo,), (W,))
        ckptw = lax.dynamic_slice(const["ckpt"], (lo,), (W,))
        predw = lax.dynamic_slice(const["preds"], (lo, 0), (W, PM))
        workw = lax.dynamic_slice(const["work"], (0, lo), (R, W))
        iow = lax.dynamic_slice(const["io"], (0, lo), (R, W))
        ertw = lax.dynamic_slice(const["ert"], (sg, lo), (1, W))[0]
        subw = lax.dynamic_slice(const["sub"], (sg, lo), (1, W))[0]
        tgtw = lax.dynamic_slice(const["tgt"], (sg, lo), (1, W))[0]
        pdw = lax.dynamic_slice(const["pdop"], (sg, lo), (1, W))[0]
        parw = lax.dynamic_slice(const["part"], (sg, lo), (1, W))[0]
        candw = lax.dynamic_slice(const["cands"], (sg, lo, 0), (1, W, C))[0]
        capsg = lax.dynamic_slice(const["caps"], (sg, 0), (1, P))[0]
        hopsg = lax.dynamic_slice(const["hops"], (sg, 0), (1, P))[0]
        stagedg = lax.dynamic_slice(const["staged"], (sg, 0), (1, P))[0]
        permr = const["perm"][r]
        ipermr = const["iperm"][r]

        d_cur = dur(workw, iow, syncw, dop)

        # ---- seam hot-swap (rare; only at segment-entry rounds) ------
        do_swap = const["entry"][r] & const["swap"][sg]
        state, fin, dop, rem, adv, stall_end, nre, rbytes = lax.cond(
            do_swap,
            seam_step,
            lambda op: (op[0], op[1], op[2], op[3], op[4], op[6], op[7], op[8]),
            (state, fin, dop, rem, adv, pborn, stall_end, nre, rbytes,
             t0, workw, iow, syncw, ckptw, capsg, hopsg, stagedg),
        )
        d_cur = dur(workw, iow, syncw, dop)

        # ---- finishes ------------------------------------------------
        # drop_mode 1: cyc's unconditional budget enforcement at the
        # bound sub-deadline; drop_mode 2: hard e2e-deadline dequeue;
        # drop_mode 0 (the runner's soft default): late jobs finish late
        run = state == RUN
        if cfg.drop_mode == 1:
            lim_run = subb
        elif cfg.drop_mode == 2:
            lim_run = jnp.broadcast_to(e2ew[None, :], (R, W))
        else:
            lim_run = jnp.full((R, W), jnp.inf, dtype=jnp.float32)
        drop_run = run & (lim_run <= t1) & (fin > lim_run + 1e-9)
        done_now = run & (fin <= t1) & ~drop_run
        state = jnp.where(done_now, DONE, state)

        # ---- readiness (release passed + all predecessors resolved) --
        pend = state == PEND
        pcodes = codes[:, predw.reshape(-1)].reshape(R, W, PM)
        unresolved = jnp.any(jnp.isinf(pcodes), axis=-1)
        rtimes = jnp.where(pcodes < 0, -pcodes - 1.0, pcodes)
        res_t = jnp.maximum(relw[None, :], jnp.max(rtimes, axis=-1))
        newready = pend & (relw[None, :] <= t1) & ~unresolved
        state = jnp.where(newready, READY, state)
        ready_t = jnp.where(newready, res_t, ready_t)
        deg = jnp.where(newready, jnp.any(pcodes < -0.5, axis=-1), deg)

        # ---- deadline drops (exact drop times, backdated) ------------
        if cfg.drop_mode == 1:
            lim_rdy = jnp.broadcast_to(subw[None, :], (R, W))
        elif cfg.drop_mode == 2:
            lim_rdy = jnp.broadcast_to(e2ew[None, :], (R, W))
        else:
            lim_rdy = jnp.full((R, W), jnp.inf, dtype=jnp.float32)
        rdy = state == READY
        drop_rdy = rdy & (lim_rdy <= t1)
        droptime = jnp.where(
            drop_run, lim_run, jnp.maximum(lim_rdy, ready_t)
        )
        dropping = drop_run | drop_rdy
        rem_d = jnp.where(
            drop_run,
            jnp.clip((fin - droptime) / jnp.maximum(d_cur, 1e-12), 0.0, 1.0),
            rem,
        )
        d_plan = dur(workw, iow, syncw, pdw[None, :])
        dwork = dwork + jnp.sum(
            jnp.where(dropping, rem_d * d_plan * pdw[None, :], 0.0), axis=1
        )
        state = jnp.where(dropping, DROP, state)
        fin = jnp.where(dropping, droptime, fin)
        deg = jnp.where(dropping, 1.0, deg)

        # in-round capacity-release times per partition: a job that sat
        # queued through earlier rounds can only start at the event that
        # made room (a completion or drop), never back at its admission
        # time — the scalar starts it from that event's callback
        fpart = jnp.where(drop_rdy, parw[None, :], pborn).astype(jnp.int32)
        freeing = done_now | dropping
        ar_p = jnp.arange(P, dtype=jnp.int32)
        freed_t_p = jnp.max(
            jnp.where(
                freeing[..., None] & (fpart[..., None] == ar_p),
                fin[..., None], t0,
            ),
            axis=1,
        )

        # ---- finish codes (idempotent re-derivation for the window) --
        terminal = state >= DONE
        code_w = jnp.where(
            terminal, jnp.where(deg > 0.5, -fin - 1.0, fin), jnp.inf
        )
        codes = lax.dynamic_update_slice(codes, code_w, (0, lo))

        # ---- accounting: tile presence of the pre-policy state -------
        run = state == RUN
        alloc_p = jnp.sum(
            jnp.where(
                run[..., None] & (pborn.astype(jnp.int32)[..., None] == ar_p),
                dop[..., None], 0.0,
            ),
            axis=1,
        )
        presence = jnp.where(
            state >= RUN,
            dop * jnp.clip(jnp.minimum(fin, t1) - jnp.maximum(start, t0), 0.0, None),
            0.0,
        ).sum(axis=1)
        ov_p = jnp.clip(jnp.minimum(stall_end, t1) - t0, 0.0, None)
        realloc_r = jnp.sum(alloc_p * ov_p, axis=1)

        # ---- policy pass ---------------------------------------------
        parw_i = parw.astype(jnp.int32)
        stall_rdy = stall_end[:, jnp.clip(parw_i, 0, P - 1)]
        adm = jnp.maximum(ready_t, stall_rdy)
        if pol == _CYC or (pol == _ADS and cfg.admission):
            adm = jnp.maximum(adm, ertw[None, :])
        can = (state == READY) & (adm <= t1 + 1e-12)
        own_freed = freed_t_p[:, jnp.clip(parw_i, 0, P - 1)]

        free_p = capsg[None, :] - alloc_p
        stalled_p = stall_end > t1

        d_lad = (
            workw[..., None] / (jnp.maximum(candw, 1.0)[None, :, :] * tf)
            + iow[..., None]
            + syncw[None, :, None] * jnp.maximum(candw - 1.0, 0.0)[None, :, :]
        )

        def want_of(rem_f, slack):
            """fit_quota's ladder target with no tile cap (cap folds in
            at grant time): smallest candidate meeting the deadline,
            else the largest rung."""
            if not cfg.quota_control:
                return jnp.broadcast_to(candw[None, :, -1], (R, W))
            meet = rem_f[..., None] * d_lad <= slack[..., None] + 1e-12
            first = jnp.argmax(meet, axis=-1)
            anym = jnp.any(meet, axis=-1)
            cw = jnp.broadcast_to(candw[None, :, :], (R, W, C))
            picked = jnp.take_along_axis(cw, first[..., None], axis=-1)[..., 0]
            return jnp.where(anym, picked, candw[None, :, -1])

        def edf_alloc(want_m, entry_m, part_m, cand_rows, pool, bump=False):
            """EDF-permute, ladder-allocate, inverse-permute."""
            want_s = jnp.take(want_m, permr, axis=1)
            entry_s = jnp.take(entry_m, permr, axis=1)
            part_s = jnp.take(part_m, permr, axis=1)
            cand_s = (
                jnp.take(cand_rows, permr, axis=0)
                if cand_rows.ndim == 2
                else cand_rows
            )
            grant_s = _alloc_ladder(cfg, want_s, entry_s, part_s, cand_s, pool)
            if bump:
                grant_s = _bump_work_conserving(
                    cfg, grant_s, entry_s, part_s, cand_s, pool
                )
            return jnp.take(grant_s, ipermr, axis=1)

        def per_part(mask, val=None):
            """(R, P) per-partition sum (or any) keyed by an id array."""
            m, ids = mask
            oh = jnp.broadcast_to(ids, (R, W))[..., None] == ar_p
            if val is None:
                return jnp.any(m[..., None] & oh, axis=1)
            v = jnp.broadcast_to(val, (R, W))
            return jnp.sum(
                jnp.where(m[..., None] & oh, v[..., None], 0.0), axis=1
            )

        def own_of(arr_p, idx_i, padval):
            pad = jnp.full((R, 1), padval, dtype=arr_p.dtype)
            return jnp.take_along_axis(
                jnp.concatenate([arr_p, pad], axis=1),
                jnp.clip(idx_i, 0, P), axis=1,
            )

        cap_pool = jnp.broadcast_to(capsg, (R, P))
        if pol in (_CYC, _CYC_S):
            # runners keep their tiles until they finish: ready jobs bid
            # on *free* capacity only (under overload the planned slots
            # collide and instances queue exactly like the scalar)
            want = jnp.where(can, pdw[None, :], 0.0)
            grant = edf_alloc(
                want, can, jnp.broadcast_to(parw[None, :], (R, W)),
                pdw[:, None], free_p,
            )
            started = can & (grant > 0.5)
        elif pol == _TP:
            # tp re-walks ready+running EDF against the *full* capacity
            # on every queue change; between rounds the fixed point of
            # quota+bump is stationary, so recomputing it each round
            # reproduces the event-driven walk as long as the allocator
            # reaches the same fixed point (alloc_iters / bump_passes)
            slack_rdy = jnp.broadcast_to(subw[None, :], (R, W)) - jnp.maximum(adm, t0)
            want_rdy = jnp.where(can, want_of(rem, slack_rdy), 0.0)
            rem_run = jnp.clip(
                (fin - t1) / jnp.maximum(d_cur, 1e-12), 0.0, 1.0
            )
            want_run_q = want_of(rem_run, subb - t1)
            own_stalled = own_of(
                stalled_p, pborn.astype(jnp.int32), True
            )
            want_run = jnp.where(own_stalled, dop, want_run_q)
            want = jnp.where(run, want_run, want_rdy)
            grant = edf_alloc(
                want, can | run, jnp.where(run, pborn, parw[None, :]),
                candw, cap_pool, bump=True,
            )
            started = can & (grant > 0.5)
        else:
            # ---- ads Algorithm 2, mirrored in two phases --------------
            # Phase A (fast path): ready jobs start on *free* tiles at
            # their quota while running jobs hold their allocation —
            # under pressure this yields the scalar engine's best-effort
            # small starts (fit_quota degrades to the largest rung that
            # fits free), which is what later makes them at-risk and
            # drives the grow cascade.
            pborn_i = pborn.astype(jnp.int32)
            cmaxw = candw[:, -1]
            slack_rdy = jnp.broadcast_to(tgtw[None, :], (R, W)) - jnp.maximum(adm, t0)
            want_rdy = jnp.where(can, want_of(rem, slack_rdy), 0.0)
            partA = jnp.broadcast_to(parw[None, :], (R, W))
            grantA = edf_alloc(want_rdy, can, partA, candw, free_p)
            started1 = can & (grantA > 0.5)

            # ChkTrigger on the post-fast-path state; the running set is
            # the pre-start snapshot, as in the scalar policy.
            alloc2 = alloc_p + per_part((started1, parw_i[None, :]), grantA)
            free2 = cap_pool - alloc2
            still = can & ~started1
            own_free2 = free2[:, jnp.clip(parw_i, 0, P - 1)]
            blocked = still & (want_rdy > own_free2 + 0.5)
            # The scalar engine syncs ``job.progress`` only at the job's
            # chunk boundaries (n_chunks per duration) and at realloc
            # freezes, so its projection ``now + remaining`` runs on
            # progress stale by up to one chunk interval — a job started
            # with a thin margin drifts into at-risk between chunk
            # syncs even though it is on track.  ``adv`` anchors the
            # chunk grid (start / freeze end); the staleness at t1 is
            # the time since the last chunk boundary before t1.
            chunk_iv = jnp.maximum(d_cur, 1e-12) / jnp.float32(cfg.n_chunks)
            stale_amt = jnp.where(
                run,
                jnp.mod(jnp.clip(t1 - adv, 0.0, None), chunk_iv),
                0.0,
            )
            rem_stale = jnp.clip(
                ((fin - t1) + stale_amt) / jnp.maximum(d_cur, 1e-12),
                0.0, 1.0,
            )
            at_risk = run & (cmaxw[None, :] > dop + 0.5) & (
                t1 + rem_stale * d_cur > tgtb
            )
            blocked_p = per_part((blocked, parw_i[None, :]))
            risk_p = per_part((at_risk, pborn_i))
            trig_p = (blocked_p | risk_p) & ~stalled_p
            own_trig_run = own_of(trig_p, pborn_i, False)
            own_trig_rdy = trig_p[:, jnp.clip(parw_i, 0, P - 1)]

            # Phase B (quota control): triggered partitions re-bid
            # running + still-ready jobs EDF against the full capacity,
            # using the same stale-progress projection as the trigger.
            want_run_q = want_of(rem_stale, tgtb - t1)
            entryB = (run & own_trig_run) | (still & own_trig_rdy)
            wantB = jnp.where(run, jnp.maximum(want_run_q, 1.0), want_rdy)
            grantB = edf_alloc(
                wantB, entryB, jnp.where(run, pborn, partA), candw, cap_pool
            )

            # benefit/cost gates: grow only when the saved time beats the
            # whole-partition stall it causes; shrink only to admit a
            # blocked job; never preempt a runner to zero.
            d_new = dur(workw, iow, syncw, grantB)
            n_run_p = per_part((run, pborn_i), 1.0)
            own_nrun = own_of(n_run_p, pborn_i, 1.0)
            own_hops = hopsg[jnp.clip(pborn_i, 0, P - 1)]
            stall_c = (
                cfg.fixed_s + cfg.decision_s + own_hops * cfg.per_hop_s
                + ckptw[None, :] * jnp.abs(grantB - dop) * cfg.inv_bw
            )
            benefit = rem_stale * (d_cur - d_new)
            grow_ok = benefit > stall_c * jnp.maximum(own_nrun, 1.0) * cfg.realloc_gate
            blocked_own = own_of(blocked_p, pborn_i, False)
            g = grantB
            g = jnp.where(g > dop, jnp.where(grow_ok, g, dop), g)
            g = jnp.where((g < dop) & ~blocked_own, dop, g)
            g = jnp.where(g < 0.5, dop, g)
            g = jnp.where(run & own_trig_run, g, dop)

            # Phase B starts: validate against free + net freed tiles,
            # EDF order, dropping what no longer fits (scalar lines
            # 209-219).
            freed_p = per_part((run & own_trig_run, pborn_i),
                               jnp.maximum(dop - g, 0.0))
            grown_p = per_part((run & own_trig_run, pborn_i),
                               jnp.maximum(g - dop, 0.0))
            availB = free2 + freed_p - grown_p
            dB = jnp.where(still & own_trig_rdy, grantB, 0.0)
            dB_s = jnp.take(dB, permr, axis=1)
            exclB, _, availg = _class_prefix(
                cfg, jnp.take(partA, permr, axis=1), availB, dB_s.dtype
            )
            keep_s = (dB_s > 0) & (exclB(dB_s) + dB_s <= availg + 0.5)
            started2 = jnp.take(keep_s, ipermr, axis=1)
            started = started1 | started2
            grant = jnp.where(
                run, g, jnp.where(started1, grantA, jnp.where(started2, grantB, 0.0))
            )

        # ---- apply: starts -------------------------------------------
        # a job admitted before this round opened was blocked on
        # capacity; it starts at the in-round release event, not at adm
        d_start = dur(workw, iow, syncw, grant)
        start_t = jnp.where(
            adm >= t0 - 1e-9,
            adm,
            jnp.minimum(jnp.maximum(own_freed, t0), t1),
        )
        state = jnp.where(started, RUN, state)
        start = jnp.where(started, start_t, start)
        fin = jnp.where(started, start_t + rem * d_start, fin)
        pborn = jnp.where(started, parw[None, :], pborn)
        subb = jnp.where(started, subw[None, :], subb)
        tgtb = jnp.where(started, tgtw[None, :], tgtb)

        # ---- apply: resizes / preempts (tp, ads) ---------------------
        if pol in (_TP, _ADS):
            resized = run & (jnp.abs(grant - dop) > 0.5)
            if pol == _TP:
                preempt = resized & (grant < 0.5)
            else:
                preempt = jnp.zeros_like(resized)
            moved_j = jnp.where(
                resized,
                ckptw[None, :] * jnp.where(preempt, dop, jnp.abs(grant - dop)),
                0.0,
            )
            ohres = pborn.astype(jnp.int32)[..., None] == ar_p
            moved_p = jnp.sum(
                jnp.where(ohres, moved_j[..., None], 0.0), axis=1
            )
            changed_p = jnp.any(resized[..., None] & ohres, axis=1)
            stall_p = jnp.where(
                changed_p,
                cfg.fixed_s + cfg.decision_s + hopsg[None, :] * cfg.per_hop_s
                + moved_p * cfg.inv_bw,
                0.0,
            )
            stall_end = jnp.maximum(stall_end, t1 + stall_p)
            rem_now = jnp.clip((fin - t1) / jnp.maximum(d_cur, 1e-12), 0.0, 1.0)
            d_res = dur(workw, iow, syncw, grant)
            fin = jnp.where(resized & ~preempt, t1 + rem_now * d_res, fin)
            dop = jnp.where(resized & ~preempt, grant, dop)
            rem = jnp.where(preempt, rem_now, rem)
            state = jnp.where(preempt, READY, state)
            dop = jnp.where(preempt, 0.0, dop)
            fin = jnp.where(preempt, jnp.inf, fin)
            # whole-partition freeze: survivors wait out the stall
            stall_own = jnp.take_along_axis(
                jnp.concatenate([stall_p, jnp.zeros((R, 1))], axis=1),
                jnp.clip(pborn.astype(jnp.int32), 0, P), axis=1,
            )
            frozen = (state == RUN) & ~started & (stall_own > 0)
            fin = jnp.where(frozen, fin + stall_own, fin)
            # the freeze is where the scalar engine syncs progress: the
            # staleness clock restarts at the stall's end
            adv = jnp.where(
                frozen | (resized & ~preempt), t1 + stall_own, adv
            )
            nre = nre + jnp.sum(changed_p.astype(jnp.float32), axis=1)
            rbytes = rbytes + jnp.sum(moved_p, axis=1)

        dop = jnp.where(started, grant, dop)
        adv = jnp.where(started, start_t, adv)

        # ---- accumulate tile-seconds into the segment buckets --------
        start_corr = jnp.sum(
            jnp.where(started, grant * jnp.clip(t1 - start_t, 0.0, None), 0.0),
            axis=1,
        )
        busy_r = jnp.clip(presence + start_corr - realloc_r, 0.0, None)
        onehot = (jnp.arange(S_) == sg).astype(busy.dtype)
        busy = busy + onehot[None, :] * busy_r[:, None]
        rel = rel + onehot[None, :] * realloc_r[:, None]

        # ---- pack the window back ------------------------------------
        new_w = (state, ready_t, deg, start, fin, dop, pborn, rem, subb,
                 tgtb, adv)
        st = tuple(
            lax.dynamic_update_slice(a, w, (0, lo))
            for a, w in zip(st, new_w)
        )
        return st, codes, stall_end, busy, rel, nre, rbytes, dwork

    def loop(st, codes, stall_end, busy, rel, nre, rbytes, dwork):
        return lax.fori_loop(
            0, n_rounds, body,
            (st, codes, stall_end, busy, rel, nre, rbytes, dwork),
        )

    loop.body = body  # exposed for eager single-round debugging/tests
    return loop


# ---------------------------------------------------------------------------
# entry point + compile cache
# ---------------------------------------------------------------------------
_LOOP_CACHE: Dict[Tuple, object] = {}


def clear_kernel_cache() -> None:
    """Drop compiled round loops (test isolation hook)."""
    _LOOP_CACHE.clear()


def _const_digest(const_np: Dict[str, np.ndarray]) -> bytes:
    """Content identity of the host-precomputed statics.

    The compiled loop closes over the ``const`` arrays as baked-in
    compile-time constants, so the cache key must distinguish cells by
    *value*, not just shape: two portfolios (different caps / deadline
    bindings / staging volumes) over the same skeleton share every
    shape yet need different compiled loops.
    """
    h = hashlib.sha1()
    for k in sorted(const_np):
        v = np.ascontiguousarray(const_np[k])
        h.update(k.encode())
        h.update(str(v.dtype).encode())
        h.update(str(v.shape).encode())
        h.update(v.tobytes())
    return h.digest()


def simulate(
    cfg: KernelConfig,
    const_np: Dict[str, np.ndarray],
    lanes_np: Dict[str, np.ndarray],
) -> Dict[str, np.ndarray]:
    """Run the compiled round loop; returns final state as NumPy arrays.

    ``const_np`` holds the host-precomputed statics (see
    :func:`repro.core.sim.soa.build_problem`), ``lanes_np`` the per-lane
    trace data (``work``, ``io``, ``codes0``).  The compiled loop is
    cached on ``(cfg, const-content digest, lane shapes)`` — the const
    arrays are closed over as compile-time constants, so the key must
    carry their *values* (see :func:`_const_digest`); re-running the
    same scenario cell with new seeds skips compilation entirely.
    """
    if not HAS_JAX:  # pragma: no cover
        raise RuntimeError("repro.core.sim.soa requires jax")
    R, N = lanes_np["work"].shape
    key = (
        cfg,
        _const_digest(const_np),
        (R, N, lanes_np["codes0"].shape[1]),
    )
    cached = _LOOP_CACHE.get(key)
    if cached is None:
        const = {k: jnp.asarray(v) for k, v in const_np.items()}
        S_ = int(const["caps"].shape[0])
        P = cfg.P

        @jax.jit
        def run(work, io, codes0):
            cdev = dict(const)
            cdev["work"] = work
            cdev["io"] = io
            loop = _build_loop(cfg, cdev)
            zeros = jnp.zeros((R, N), dtype=jnp.float32)
            inf = jnp.full((R, N), jnp.inf, dtype=jnp.float32)
            fills = {
                F_FIN: inf, F_SUB: inf, F_TGT: inf,
                F_PART: jnp.full((R, N), -1.0, dtype=jnp.float32),
                F_REM: jnp.ones((R, N), dtype=jnp.float32),
            }
            st0 = tuple(fills.get(f, zeros) for f in range(NFIELDS))
            zf = partial(jnp.zeros, dtype=jnp.float32)
            return loop(
                st0, codes0, zf((R, P)), zf((R, S_)), zf((R, S_)),
                zf((R,)), zf((R,)), zf((R,)),
            )

        cached = run
        _LOOP_CACHE[key] = cached

    st, codes, stall_end, busy, rel, nre, rbytes, dwork = cached(
        jnp.asarray(lanes_np["work"]),
        jnp.asarray(lanes_np["io"]),
        jnp.asarray(lanes_np["codes0"]),
    )
    return {
        "state": np.asarray(st[F_STATE]),
        "ready_t": np.asarray(st[F_READY]),
        "deg": np.asarray(st[F_DEG]),
        "start": np.asarray(st[F_START]),
        "fin": np.asarray(st[F_FIN]),
        "dop": np.asarray(st[F_DOP]),
        "codes": np.asarray(codes),
        "busy": np.asarray(busy, dtype=np.float64),
        "realloc": np.asarray(rel, dtype=np.float64),
        "n_realloc": np.asarray(nre, dtype=np.float64),
        "realloc_bytes": np.asarray(rbytes, dtype=np.float64),
        "dropped_work": np.asarray(dwork, dtype=np.float64),
    }
