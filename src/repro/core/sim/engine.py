"""Tile-stream event-driven simulation engine (paper §V-A).

Execution model
---------------
Each DNN *job* (one activation of a task) samples its workload ``W`` (F1)
and I/O latency ``I`` (F2) from the task's latency profile.  Run
start-to-finish at DoP ``c`` the job would take::

    T(c) = W / (c * P) + I + (c - 1) * sync_s

Progress is tracked as a fraction in [0, 1]; running at DoP ``c``
advances progress at rate ``1/T(c)``.  DoP changes and preemptions are
initiated at scheduling points; chunk boundaries (``n_chunks`` per job,
§IV-D2 operator chunks) generate additional scheduling points for
long-running jobs.  A reallocation stalls *the whole partition*
(stop-migrate-restart, §IV-D1); migration volume follows the L2P
minimal-move model (§IV-D3): ``per-tile checkpoint bytes x |c_new -
c_old|`` per resized job.

Accounting
----------
Per partition the engine integrates allocated-tile-seconds, split into
*effective* (running) and *realloc waste* (allocated but stalled).
Idle is everything else.  E2E chain latencies are measured from source
sample time to sink completion using the unrolled instance dependency
structure (§II-C2).
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..gha.schedule import Schedule
from ..hardware import HardwareModel
from ..latency_model import LatencyModel
from ..workload import Workflow, unroll_hyperperiod
from .policy import Policy

__all__ = [
    "Job", "JobState", "ModeStats", "SimConfig", "Simulator", "SimReport",
]


class JobState(enum.Enum):
    PENDING = 0   # waiting for data
    READY = 1     # data available, not running
    RUNNING = 2
    DONE = 3
    DROPPED = 4


@dataclasses.dataclass(eq=False)  # identity hash: jobs live in ready sets
class Job:
    jid: int
    task: str
    cycle: int
    idx: int
    release: float                  # absolute source-sample time
    is_sensor: bool
    work_flops: float
    io_s: float
    sync_s: float
    partition: int                  # -1 for sensors
    ert: float                      # absolute earliest-ready-time (t_v)
    sub_ddl: float                  # absolute sub-deadline
    e2e_ddl: float                  # tightest E2E deadline through this task
    plan_dop: int                   # offline c_v
    deps_remaining: int = 0
    succs: List[int] = dataclasses.field(default_factory=list)

    state: JobState = JobState.PENDING
    progress: float = 0.0
    dop: int = 0
    rate: float = 0.0               # progress per second (0 while stalled)
    last_t: float = 0.0
    gen: int = 0
    ready_t: float = math.nan
    start_t: float = math.nan
    finish_t: float = math.nan
    degraded: bool = False          # an upstream job was dropped
    n_resizes: int = 0
    drop_at_release: bool = False   # scenario sensor dropout window

    def duration(self, c: int, tile_flops: float) -> float:
        if self.is_sensor:
            return self.io_s  # sensor latency pre-sampled into io_s
        c = max(int(c), 1)
        return (
            self.work_flops / (c * tile_flops)
            + self.io_s
            + self.sync_s * (c - 1)
        )

    def remaining(self, c: int, tile_flops: float) -> float:
        return (1.0 - self.progress) * self.duration(c, tile_flops)


@dataclasses.dataclass
class _Partition:
    idx: int
    capacity: int
    running: Dict[int, int] = dataclasses.field(default_factory=dict)  # jid -> dop
    stalled: bool = False
    stall_end: float = 0.0
    last_t: float = 0.0
    busy_ts: float = 0.0           # effective tile-seconds
    realloc_ts: float = 0.0        # stalled-but-allocated tile-seconds
    n_realloc: int = 0
    realloc_bytes: float = 0.0
    decision_ratios: List[float] = dataclasses.field(default_factory=list)

    @property
    def allocated(self) -> int:
        return sum(self.running.values())

    def free(self) -> int:
        return self.capacity - self.allocated


@dataclasses.dataclass
class SimConfig:
    duration_s: float = 2.0
    seed: int = 0
    n_chunks: int = 6
    drop_policy: str = "hard"       # "hard": drop at E2E ddl; "soft": never
    collect_latencies: bool = True
    #: §IV-D2 fidelity: chunks are unpreemptable, so a reallocation must
    #: wait for the longest in-flight chunk before migration starts.
    #: Off by default (continuous-progress approximation).
    chunk_boundary_realloc: bool = False
    #: optional ``repro.scenarios.ScenarioScript`` (duck-typed so the
    #: engine stays independent of the scenarios package): jobs sample
    #: from the mode active at their release time, segment boundaries
    #: become ``mode_change`` events, and the report gains per-mode
    #: accounting.  Modes that modulate sensor *rates* change the
    #: hyper-period mid-run: the engine unrolls the DAG piecewise per
    #: rate regime (``scenario.rate_regimes``), re-anchoring the sensor
    #: timers at each seam while in-flight jobs of the old regime drain
    #: normally.  None reproduces the stationary single-profile run
    #: bit-for-bit.
    scenario: Optional[object] = None


@dataclasses.dataclass
class ModeStats:
    """Per-driving-mode slice of a scenario run.

    Chain completions are attributed to the mode active at their
    *source sample time*; tile-second accounting is split exactly at
    ``mode_change`` boundaries (the engine touches every partition when
    the mode switches).
    """

    mode: str
    span_s: float                   # wall time spent in this mode
    n_completed: int                # chain sink completions
    n_violations: int
    p99_s: float                    # E2E p99 over chains in this mode
    effective_frac: float           # of tiles * span_s
    realloc_frac: float

    @property
    def violation_rate(self) -> float:
        return self.n_violations / self.n_completed if self.n_completed else 0.0


@dataclasses.dataclass
class SimReport:
    duration_s: float
    total_tiles: int
    # capacity decomposition (fractions of total processing power)
    effective_frac: float
    realloc_frac: float
    idle_frac: float
    dropped_work_frac: float
    # events
    n_realloc: int
    realloc_bytes: float
    n_jobs: int
    n_dropped: int
    task_miss_rate: float
    # per-chain
    chain_count: Dict[str, int]
    chain_violations: Dict[str, int]
    chain_p99_s: Dict[str, float]
    chain_latencies: Dict[str, List[float]]
    decision_ratios: List[float]
    # scenario runs only: per-mode accounting + switch count
    mode_stats: Dict[str, ModeStats] = dataclasses.field(default_factory=dict)
    n_mode_switches: int = 0

    @property
    def violation_rate(self) -> float:
        tot = sum(self.chain_count.values())
        return sum(self.chain_violations.values()) / tot if tot else 0.0

    def group_p99(self, critical: Dict[str, bool], want_critical: bool) -> float:
        lats: List[float] = []
        for ch, ls in self.chain_latencies.items():
            if critical.get(ch, False) == want_critical:
                lats.extend(ls)
        if not lats:
            return float("nan")
        return float(np.percentile(np.asarray(lats), 99))


class Simulator:
    """Event-driven Tile-stream simulator."""

    def __init__(
        self,
        wf: Workflow,
        model: LatencyModel,
        schedule: Schedule,
        policy: Policy,
        config: Optional[SimConfig] = None,
    ):
        self.wf = wf
        self.model = model
        self.schedule = schedule
        self.policy = policy
        self.cfg = config or SimConfig()
        if self.cfg.duration_s <= 0:
            raise ValueError("SimConfig.duration_s must be > 0")
        self.hw: HardwareModel = model.hw
        self.rng = np.random.RandomState(self.cfg.seed)

        self.now = 0.0
        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._seq = 0

        self.jobs: List[Job] = []
        self.parts: List[_Partition] = [
            _Partition(idx=p.index, capacity=p.capacity)
            for p in schedule.partitions
        ]
        # scenario state: active mode + per-mode accounting buckets
        self._mode_now: Optional[str] = None
        self._mode_busy: Dict[str, float] = {}
        self._mode_realloc: Dict[str, float] = {}
        self._mode_lats: Dict[str, List[float]] = {}
        # (chain, mode) -> [completions, violations]
        self._sink_by_mode: Dict[Tuple[str, str], List[int]] = {}
        self.n_mode_switches = 0
        self._build_jobs()
        self.chain_latencies: Dict[str, List[float]] = {
            c.name: [] for c in wf.chains
        }
        self.chain_violations: Dict[str, int] = {c.name: 0 for c in wf.chains}
        self.chain_count: Dict[str, int] = {c.name: 0 for c in wf.chains}
        self.dropped_work_ts = 0.0

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    def _chain_sources(self, insts) -> Dict[Tuple[str, int], float]:
        """(chain name, sink instance index) -> source sample time, by
        walking each sink's predecessor chain through the unrolled
        instance graph (same units as the instances' releases)."""
        inst_by_key = {(i.task, i.index): i for i in insts}
        release_of = {(i.task, i.index): i.release_s for i in insts}

        def trace(chain, sink_idx: int) -> Optional[int]:
            node_i = len(chain.nodes) - 1
            cur = inst_by_key.get((chain.nodes[node_i], sink_idx))
            while cur is not None and node_i > 0:
                prev = chain.nodes[node_i - 1]
                nxt = None
                for (pt, pj) in cur.preds:
                    if pt == prev:
                        nxt = inst_by_key.get((pt, pj))
                        break
                cur = nxt
                node_i -= 1
            return cur.index if cur is not None else None

        out: Dict[Tuple[str, int], float] = {}
        for chain in self.wf.chains:
            sink = chain.nodes[-1]
            n_sink = sum(1 for i in insts if i.task == sink)
            for k in range(n_sink):
                src_idx = trace(chain, k)
                if src_idx is None:
                    continue
                out[(chain.name, k)] = release_of[(chain.nodes[0], src_idx)]
        return out

    def _build_jobs(self) -> None:
        wf, cfg = self.wf, self.cfg
        scen = self.cfg.scenario
        # non-stationary workloads: jobs sample from the profile of the
        # driving mode active at their release time
        mode_profiles = scen.profiles_for(self.model) if scen is not None else None

        # piecewise hyper-period re-unrolling: scenario modes may
        # modulate sensor rates, which changes the hyper-period mid-run.
        # The timeline splits into regimes of constant sensor periods;
        # each regime re-anchors the hardware timers at its start and
        # unrolls its *own* workflow.  A script with no rate-modulating
        # mode (or no scenario at all) is a single regime and reproduces
        # the stationary cyclic unrolling bit-for-bit.  Regimes past the
        # simulation horizon build no jobs (a script may be far longer
        # than the run).
        if scen is not None and hasattr(scen, "rate_regimes"):
            regimes = [
                r for r in scen.rate_regimes(wf, cfg.duration_s)
                if r[0] < cfg.duration_s - 1e-12
            ]
        else:
            regimes = [(0.0, cfg.duration_s, wf)]
        self._regimes = regimes

        # tightest E2E deadline offset per task (chain structure and
        # deadlines are rate-independent)
        ddl_off: Dict[str, float] = {}
        for t in wf.tasks:
            chains = wf.chain_for(t)
            ddl_off[t] = min((c.deadline_s for c in chains), default=math.inf)

        # chain accounting: (chain name, sink jid) -> absolute source
        # sample time, valid across regime seams
        self._sink_src: Dict[Tuple[str, int], float] = {}

        sink_of = {c.name: c.nodes[-1] for c in wf.chains}
        for ri, (r0, r1, wf_r) in enumerate(regimes):
            thp = wf_r.hyper_period_s
            final = ri == len(regimes) - 1
            span = (cfg.duration_s - r0) if final else (r1 - r0)
            # the - 1e-9 absorbs float accumulation in segment bounds
            # (0.4 + 0.8 > 1.2), which would otherwise add an empty cycle
            n_cycles = max(1, int(math.ceil(span / thp - 1e-9)))
            # one segment unroll per regime: every full cycle repeats its
            # structure at a +cycle*thp offset; only a non-final regime's
            # last cycle (truncated at the seam, where the next regime
            # re-anchors and re-releases from r1) unrolls separately
            insts_full = unroll_hyperperiod(wf_r, t0=r0, t1=r0 + thp)
            src_full = self._chain_sources(insts_full)
            index_of: Dict[Tuple[str, int], int] = {}
            for cycle in range(n_cycles):
                off = cycle * thp
                base = r0 + off
                t1 = base + thp if final else min(base + thp, r1)
                if t1 - base <= 1e-12:
                    continue
                if t1 >= base + thp - 1e-12:   # full cycle
                    insts = insts_full
                    src_rel_of = {k: v + off for k, v in src_full.items()}
                else:                           # truncated seam cycle
                    insts = unroll_hyperperiod(wf_r, t0=base, t1=t1)
                    src_rel_of = self._chain_sources(insts)
                    off = 0.0                   # releases already absolute

                for inst in insts:
                    task = wf.tasks[inst.task]
                    rel_t = inst.release_s + off
                    if mode_profiles is not None:
                        prof = mode_profiles[scen.mode_at(rel_t)][inst.task]
                    else:
                        prof = self.model.profiles[inst.task]
                    jid = len(self.jobs)
                    index_of[(inst.task, inst.index)] = jid
                    if task.is_sensor:
                        lat = float(
                            prof.sensor_latency.quantile(
                                min(self.rng.uniform(0.001, 0.999), 0.999)
                            )
                        )
                        job = Job(
                            jid=jid, task=inst.task, cycle=cycle, idx=inst.index,
                            release=rel_t, is_sensor=True,
                            work_flops=0.0, io_s=lat, sync_s=0.0, partition=-1,
                            ert=rel_t,
                            sub_ddl=rel_t + lat * 2,
                            e2e_ddl=rel_t + ddl_off[inst.task],
                            plan_dop=0,
                            drop_at_release=(
                                scen is not None and scen.dropped(inst.task, rel_t)
                            ),
                        )
                    else:
                        w = float(
                            self.rng.lognormal(prof.work.mu, max(prof.work.sigma, 1e-12))
                        ) if prof.work.mean > 0 else 0.0
                        io = prof.io.base + (
                            float(self.rng.exponential(1.0 / prof.io.rate))
                            if prof.io.rate > 0 else 0.0
                        )
                        if scen is not None:
                            w *= scen.burst_scale(inst.task, rel_t)
                        plan = self.schedule.plans[inst.task]
                        job = Job(
                            jid=jid, task=inst.task, cycle=cycle, idx=inst.index,
                            release=rel_t, is_sensor=False,
                            work_flops=w, io_s=io, sync_s=prof.sync_per_tile_s,
                            partition=plan.partition,
                            ert=rel_t + plan.ert_s,
                            sub_ddl=rel_t + plan.subdeadline_s,
                            e2e_ddl=rel_t + ddl_off[inst.task],
                            plan_dop=plan.dop,
                        )
                    self.jobs.append(job)

                # wire dependencies (within the same cycle: a job's
                # predecessors release no later than it, so the segment
                # unroll never leaves one on the far side of a seam)
                for inst in insts:
                    jid = index_of[(inst.task, inst.index)]
                    job = self.jobs[jid]
                    job.deps_remaining = len(inst.preds)
                    for (pt, pj) in inst.preds:
                        self.jobs[index_of[(pt, pj)]].succs.append(jid)
                # register absolute chain-source sample times for the
                # sinks of this cycle
                for (cname, k), src_t0 in src_rel_of.items():
                    sink_jid = index_of.get((sink_of[cname], k))
                    if sink_jid is not None:
                        self._sink_src[(cname, sink_jid)] = src_t0
                index_of.clear()

    # ------------------------------------------------------------------
    # event queue
    # ------------------------------------------------------------------
    def _push(self, t: float, kind: str, payload: tuple) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, kind, payload))

    # ------------------------------------------------------------------
    # partition accounting
    # ------------------------------------------------------------------
    def _touch(self, part: _Partition) -> None:
        dt = self.now - part.last_t
        if dt > 0:
            alloc = part.allocated
            if part.stalled:
                part.realloc_ts += alloc * dt
                if self._mode_now is not None:
                    self._mode_realloc[self._mode_now] = (
                        self._mode_realloc.get(self._mode_now, 0.0) + alloc * dt
                    )
            else:
                part.busy_ts += alloc * dt
                if self._mode_now is not None:
                    self._mode_busy[self._mode_now] = (
                        self._mode_busy.get(self._mode_now, 0.0) + alloc * dt
                    )
        part.last_t = self.now

    def _advance_job(self, job: Job) -> None:
        dt = self.now - job.last_t
        if dt > 0 and job.rate > 0:
            job.progress = min(1.0, job.progress + dt * job.rate)
        job.last_t = self.now

    # ------------------------------------------------------------------
    # policy verbs
    # ------------------------------------------------------------------
    def free_tiles(self, partition: int) -> int:
        return self.parts[partition].free()

    def eligible_jobs(
        self, partition: int, admitted_only: bool = True
    ) -> List[Job]:
        """READY jobs of the partition, optionally filtered by ERT
        admission control (§IV-B2)."""
        out = []
        for job in self._ready_sets[partition]:
            if admitted_only and self.now + 1e-12 < job.ert:
                continue
            out.append(job)
        return out

    def start_job(self, job: Job, dop: int) -> None:
        part = self.parts[job.partition]
        assert job.state == JobState.READY, (job.task, job.state)
        assert dop <= part.free(), (
            f"{job.task}: dop {dop} > free {part.free()} in partition {part.idx}"
        )
        self._touch(part)
        self._ready_sets[job.partition].discard(job)
        job.state = JobState.RUNNING
        job.start_t = self.now
        job.dop = dop
        job.last_t = self.now
        part.running[job.jid] = dop
        if part.stalled:
            job.rate = 0.0  # will start when the stall ends
        else:
            self._set_rate(job)

    def _set_rate(self, job: Job) -> None:
        job.gen += 1
        t_total = job.duration(job.dop, self.hw.tile_flops)
        job.rate = 1.0 / max(t_total, 1e-9)
        rem = (1.0 - job.progress) / job.rate
        self._push(self.now + rem, "finish", (job.jid, job.gen))
        # next chunk boundary
        n = self.cfg.n_chunks
        nxt = math.floor(job.progress * n + 1e-9) + 1
        if nxt < n:
            dt = (nxt / n - job.progress) / job.rate
            self._push(self.now + dt, "chunk", (job.jid, job.gen))

    def resize(
        self,
        partition: int,
        new_dops: Dict[int, int],
        starts: Optional[Dict[int, int]] = None,
    ) -> float:
        """Apply a reallocation in one partition: resize running jobs per
        ``new_dops`` (jid -> dop) and start READY jobs per ``starts``.

        Returns the stall duration.  The whole partition stalls while
        checkpoints migrate (§IV-D1); migration volume uses the L2P
        minimal-move model.  If nothing actually changes for running
        jobs, new jobs start with zero stall.
        """
        part = self.parts[partition]
        starts = starts or {}
        changed = {
            jid: d for jid, d in new_dops.items()
            if jid in part.running and part.running[jid] != d
        }
        if not changed:
            for jid, d in starts.items():
                self.start_job(self.jobs[jid], d)
            return 0.0

        self._touch(part)
        moved = 0.0
        for jid, d in changed.items():
            job = self.jobs[jid]
            per_tile = self.wf.tasks[job.task].checkpoint_bytes
            old = part.running[jid]
            moved += per_tile * (old if d == 0 else abs(d - old))
            job.n_resizes += 1
        stall = self.hw.realloc_latency(moved, part.capacity)
        if self.cfg.chunk_boundary_realloc:
            # §IV-D2: chunks are unpreemptable — migration waits for the
            # in-flight chunks of the *resized* jobs to drain (checkpoint
            # positions exist only at chunk boundaries)
            n = self.cfg.n_chunks
            drain = 0.0
            for jid in changed:
                job = self.jobs[jid]
                if job.rate <= 0 or jid not in part.running:
                    continue
                self._advance_job(job)
                frac = (job.progress * n) % 1.0
                drain = max(drain, (1.0 - frac) / (n * job.rate))
            stall += drain
        # freeze all running jobs (whole-partition stall, §IV-D1)
        for jid in part.running:
            job = self.jobs[jid]
            self._advance_job(job)
            job.rate = 0.0
            job.gen += 1
        # apply new dops now (tiles occupied during the stall);
        # dop == 0 preempts back to the ready queue
        for jid, d in changed.items():
            job = self.jobs[jid]
            if d == 0:
                del part.running[jid]
                job.dop = 0
                job.state = JobState.READY
                self._ready_sets[partition].add(job)
            else:
                part.running[jid] = d
                job.dop = d
        self._begin_stall(part, moved, stall)
        for jid, d in starts.items():
            self.start_job(self.jobs[jid], d)
        return stall

    def _begin_stall(self, part: _Partition, moved: float, stall: float) -> None:
        """Charge one stop-migrate-restart stall on ``part`` — shared by
        DoP resizes and schedule hot-swaps so both reallocation paths
        account identically (events, bytes, decision/migration ratio,
        resume arming)."""
        part.n_realloc += 1
        part.realloc_bytes += moved
        # decision/migration split: clamp migration time to >= 0 and skip
        # degenerate samples (tiny migrations would otherwise produce
        # nonsense ratios)
        mig = max(stall - self.hw.realloc.decision_s, 0.0)
        if mig > 1e-12:
            part.decision_ratios.append(self.hw.realloc.decision_s / mig)
        part.stalled = True
        part.stall_end = max(part.stall_end, self.now + stall)
        self._push(part.stall_end, "resume", (part.idx,))

    def hotswap_schedule(self, new: Schedule) -> float:
        """Online replanning: swap the active scheduling table (the
        ``mode_change`` reaction of the runtime, §IV-C applied across
        contexts).

        Running jobs keep their tiles; if a partition's capacity shrank
        below its current allocation, running jobs are preempted back to
        the ready queue (largest allocation first) until it fits, and
        their checkpoints count as migration volume.  Every partition
        pays a stop-migrate-restart stall through the same bounded
        reallocation cost model as a DoP resize, so hot-swap cost lands
        in ``realloc_frac`` honestly.  PENDING/READY jobs are retargeted
        to the new plans (partition, ERT, sub-deadline, plan DoP).

        Returns the summed stall time across partitions.
        """
        if len(new.partitions) != len(self.parts):
            raise ValueError(
                "hot-swap requires a schedule with the same partition count"
            )
        total_stall = 0.0
        for part in self.parts:
            new_cap = new.partitions[part.idx].capacity
            self._touch(part)
            moved = 0.0
            if part.allocated > new_cap:
                victims = sorted(part.running, key=lambda j: (part.running[j], j))
                while part.allocated > new_cap and victims:
                    jid = victims.pop()  # largest allocation first
                    job = self.jobs[jid]
                    moved += (
                        self.wf.tasks[job.task].checkpoint_bytes
                        * part.running[jid]
                    )
                    self._advance_job(job)
                    del part.running[jid]
                    job.rate = 0.0
                    job.gen += 1
                    job.dop = 0
                    job.n_resizes += 1
                    job.state = JobState.READY
                    self._ready_sets[part.idx].add(job)
            part.capacity = new_cap
            stall = self.hw.realloc_latency(moved, max(new_cap, 1))
            # freeze whatever keeps running for the swap stall (§IV-D1)
            for jid in part.running:
                frozen = self.jobs[jid]
                self._advance_job(frozen)
                frozen.rate = 0.0
                frozen.gen += 1
            self._begin_stall(part, moved, stall)
            total_stall += stall

        # retarget future jobs to the new plans
        for job in self.jobs:
            if job.is_sensor or job.state not in (JobState.PENDING, JobState.READY):
                continue
            plan = new.plans.get(job.task)
            if plan is None:
                continue
            if job.state == JobState.READY and plan.partition != job.partition:
                self._ready_sets[job.partition].discard(job)
                self._ready_sets[plan.partition].add(job)
            job.partition = plan.partition
            job.ert = job.release + plan.ert_s
            job.sub_ddl = job.release + plan.subdeadline_s
            job.plan_dop = plan.dop
            if job.state == JobState.READY and job.ert > self.now:
                self._push(job.ert, "ert", (job.jid,))
        self.schedule = new
        return total_stall

    def preempt(self, job: Job) -> None:
        """Remove a running job from its tiles back to the ready queue
        (progress preserved; used by work-conserving baselines)."""
        part = self.parts[job.partition]
        assert job.state == JobState.RUNNING
        self._touch(part)
        self._advance_job(job)
        job.rate = 0.0
        job.gen += 1
        job.dop = 0
        del part.running[job.jid]
        job.state = JobState.READY
        self._ready_sets[job.partition].add(job)

    def terminate(self, job: Job, reason: str = "deadline") -> None:
        """Drop a job (Cyc. budget overrun / E2E-deadline dequeue)."""
        part = self.parts[job.partition] if job.partition >= 0 else None
        if job.state == JobState.RUNNING and part is not None:
            self._touch(part)
            self._advance_job(job)
            del part.running[job.jid]
        elif job.state == JobState.READY:
            self._ready_sets[job.partition].discard(job)
        job.state = JobState.DROPPED
        job.finish_t = self.now
        job.rate = 0.0
        job.gen += 1
        # account dropped processing power (remaining work at plan DoP);
        # sensors run on the SPE, not on tiles, so they carry none
        if not job.is_sensor:
            rem = job.remaining(max(job.plan_dop, 1), self.hw.tile_flops)
            self.dropped_work_ts += rem * max(job.plan_dop, 1)
        self._propagate(job)
        self._record_dropped_sink(job)
        self.policy.on_point(self, job.partition, self.now, "drop", job)

    def arm_timer(self, partition: int, t: float, job: Optional[Job] = None) -> None:
        self._push(t, "timer", (partition, job.jid if job else -1))

    # ------------------------------------------------------------------
    # dependency propagation
    # ------------------------------------------------------------------
    def _propagate(self, job: Job) -> None:
        for sid in job.succs:
            succ = self.jobs[sid]
            if job.state == JobState.DROPPED or job.degraded:
                succ.degraded = True
            succ.deps_remaining -= 1
            if succ.deps_remaining == 0 and succ.state == JobState.PENDING:
                succ.state = JobState.READY
                succ.ready_t = self.now
                if succ.is_sensor:
                    continue
                self._ready_sets[succ.partition].add(succ)
                self._push(self.now, "ready", (succ.jid,))
                if succ.ert > self.now:
                    self._push(succ.ert, "ert", (succ.jid,))

    def _finish_job(self, job: Job) -> None:
        part = self.parts[job.partition] if job.partition >= 0 else None
        if part is not None and job.jid in part.running:
            self._touch(part)
            del part.running[job.jid]
        job.state = JobState.DONE
        job.progress = 1.0
        job.finish_t = self.now
        job.rate = 0.0
        job.gen += 1
        self._propagate(job)
        # chain accounting at sinks
        for chain in self.wf.chain_for(job.task):
            if chain.nodes[-1] != job.task:
                continue
            t0 = self._sink_src.get((chain.name, job.jid))
            if t0 is None:
                continue
            lat = self.now - t0
            violated = lat > chain.deadline_s + 1e-12 or job.degraded
            self.chain_count[chain.name] += 1
            if self.cfg.collect_latencies:
                self.chain_latencies[chain.name].append(lat)
            if violated:
                self.chain_violations[chain.name] += 1
            if self.cfg.scenario is not None:
                # attribute to the mode active at the source sample time
                m = self.cfg.scenario.mode_at(t0)
                rec = self._sink_by_mode.setdefault((chain.name, m), [0, 0])
                rec[0] += 1
                rec[1] += int(violated)
                if self.cfg.collect_latencies:
                    self._mode_lats.setdefault(m, []).append(lat)

    def _record_dropped_sink(self, job: Job) -> None:
        for chain in self.wf.chain_for(job.task):
            if chain.nodes[-1] != job.task:
                continue
            self.chain_count[chain.name] += 1
            self.chain_violations[chain.name] += 1
            if self.cfg.scenario is not None:
                t0 = self._sink_src.get((chain.name, job.jid), job.release)
                m = self.cfg.scenario.mode_at(t0)
                rec = self._sink_by_mode.setdefault((chain.name, m), [0, 0])
                rec[0] += 1
                rec[1] += 1

    # ------------------------------------------------------------------
    # main loop
    # ------------------------------------------------------------------
    def run(self) -> SimReport:
        self._ready_sets: List[set] = [set() for _ in self.parts]
        self.policy.setup(self)

        # seed events: sensor jobs are released by hardware timers
        for job in self.jobs:
            if job.is_sensor:
                self._push(job.release, "sensor", (job.jid,))

        # seed mode-switch events from the scenario timeline (adjacent
        # equal-mode segments are one context: no event, no switch)
        scen = self.cfg.scenario
        if scen is not None:
            self._mode_now = scen.mode_at(0.0)
            prev = self._mode_now
            for t, mode in scen.boundaries()[1:]:
                if mode != prev and t < self.cfg.duration_s:
                    self._push(t, "mode_change", (mode,))
                prev = mode

        end_t = self.cfg.duration_s
        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if t > end_t:
                break
            self.now = t

            if kind == "sensor":
                job = self.jobs[payload[0]]
                if job.drop_at_release:
                    # scenario dropout: the frame never arrives;
                    # downstream jobs run degraded
                    self.terminate(job, "sensor_dropout")
                    continue
                job.state = JobState.RUNNING
                job.start_t = self.now
                self._push(self.now + job.io_s, "sensor_done", (job.jid,))
            elif kind == "sensor_done":
                self._finish_job(self.jobs[payload[0]])
            elif kind == "ready":
                job = self.jobs[payload[0]]
                if job.state == JobState.READY:
                    self.policy.on_point(self, job.partition, self.now, "ready", job)
            elif kind == "ert":
                job = self.jobs[payload[0]]
                if job.state == JobState.READY:
                    self.policy.on_point(self, job.partition, self.now, "ert", job)
            elif kind == "finish":
                jid, gen = payload
                job = self.jobs[jid]
                if job.gen != gen or job.state != JobState.RUNNING:
                    continue
                self._advance_job(job)
                self._finish_job(job)
                self.policy.on_point(self, job.partition, self.now, "finish", job)
            elif kind == "chunk":
                jid, gen = payload
                job = self.jobs[jid]
                if job.gen != gen or job.state != JobState.RUNNING:
                    continue
                self._advance_job(job)
                # re-arm next chunk boundary
                n = self.cfg.n_chunks
                nxt = math.floor(job.progress * n + 1e-9) + 1
                if nxt < n and job.rate > 0:
                    dt = (nxt / n - job.progress) / job.rate
                    self._push(self.now + dt, "chunk", (job.jid, job.gen))
                self.policy.on_point(self, job.partition, self.now, "chunk", job)
            elif kind == "resume":
                part = self.parts[payload[0]]
                if part.stall_end > t + 1e-12:
                    continue  # superseded by a longer stall (hot-swap)
                self._touch(part)
                part.stalled = False
                for jid in list(part.running):
                    job = self.jobs[jid]
                    self._advance_job(job)
                    self._set_rate(job)
                self.policy.on_point(self, part.idx, self.now, "resume", None)
            elif kind == "timer":
                pid, jid = payload
                job = self.jobs[jid] if jid >= 0 else None
                if job is not None and job.state in (JobState.DONE, JobState.DROPPED):
                    continue
                self.policy.on_point(self, pid, self.now, "timer", job)
            elif kind == "mode_change":
                mode = payload[0]
                # split tile-second accounting exactly at the boundary
                for part in self.parts:
                    self._touch(part)
                self._mode_now = mode
                self.n_mode_switches += 1
                self.policy.on_mode_change(self, mode, self.now)

        # drain accounting to end time
        self.now = end_t
        for part in self.parts:
            self._touch(part)
        return self._report()

    # ------------------------------------------------------------------
    def _report(self) -> SimReport:
        total = self.hw.num_tiles * self.cfg.duration_s
        busy = sum(p.busy_ts for p in self.parts)
        realloc = sum(p.realloc_ts for p in self.parts)
        dnn_jobs = [
            j for j in self.jobs
            if not j.is_sensor and j.release <= self.cfg.duration_s
        ]
        considered = [
            j for j in dnn_jobs
            if j.e2e_ddl <= self.cfg.duration_s  # had a chance to finish
        ]
        dropped = [j for j in considered if j.state == JobState.DROPPED]
        late = [
            j for j in considered
            if j.state == JobState.DONE and j.finish_t > j.e2e_ddl
        ]
        unfinished = [
            j for j in considered
            if j.state in (JobState.PENDING, JobState.READY, JobState.RUNNING)
        ]
        n_miss = len(dropped) + len(late) + len(unfinished)

        # chains whose sink never completed within the horizon count as
        # violations (starvation must not look like success)
        scen = self.cfg.scenario
        for chain in self.wf.chains:
            expected = 0
            exp_mode: Dict[str, int] = {}
            for (cname, _jid), t0 in self._sink_src.items():
                if cname != chain.name:
                    continue
                if t0 + chain.deadline_s <= self.cfg.duration_s:
                    expected += 1
                    if scen is not None:
                        m = scen.mode_at(t0)
                        exp_mode[m] = exp_mode.get(m, 0) + 1
            have = self.chain_count[chain.name]
            deficit = max(0, expected - have)
            if deficit:
                self.chain_violations[chain.name] += deficit
                self.chain_count[chain.name] = expected
            # mirror per (chain, mode): attribute exactly the chain's
            # global deficit to modes with missing sinks (chronological
            # order), so per-mode totals always reconcile with the
            # global counters — a mode's shortfall can be offset by
            # bonus completions (deadline beyond the horizon) elsewhere
            if scen is not None and deficit:
                for m in scen.modes():
                    if m not in exp_mode:
                        continue
                    rec = self._sink_by_mode.setdefault((chain.name, m), [0, 0])
                    take = min(max(0, exp_mode[m] - rec[0]), deficit)
                    if take:
                        rec[0] += take
                        rec[1] += take
                        deficit -= take
                    if not deficit:
                        break

        p99 = {}
        for ch, lats in self.chain_latencies.items():
            p99[ch] = float(np.percentile(lats, 99)) if lats else float("nan")
        ratios = [r for p in self.parts for r in p.decision_ratios]

        # per-mode report slices
        mode_stats: Dict[str, ModeStats] = {}
        if scen is not None:
            bounds = scen.boundaries()
            ends = [t for t, _m in bounds[1:]]
            # a run longer than the script stays in the final mode, so
            # the last segment's end is the horizon itself
            ends.append(max(self.cfg.duration_s, bounds[-1][0]))
            spans: Dict[str, float] = {}
            for (t0, m), t1 in zip(bounds, ends):
                spans[m] = spans.get(m, 0.0) + max(
                    0.0,
                    min(t1, self.cfg.duration_s) - min(t0, self.cfg.duration_s),
                )
            for m, span in spans.items():
                done = sum(
                    rec[0] for (_c, mm), rec in self._sink_by_mode.items()
                    if mm == m
                )
                viol = sum(
                    rec[1] for (_c, mm), rec in self._sink_by_mode.items()
                    if mm == m
                )
                lats = self._mode_lats.get(m, [])
                denom = self.hw.num_tiles * span
                mode_stats[m] = ModeStats(
                    mode=m,
                    span_s=span,
                    n_completed=done,
                    n_violations=viol,
                    p99_s=(
                        float(np.percentile(np.asarray(lats), 99))
                        if lats else float("nan")
                    ),
                    effective_frac=(
                        self._mode_busy.get(m, 0.0) / denom if denom > 0 else 0.0
                    ),
                    realloc_frac=(
                        self._mode_realloc.get(m, 0.0) / denom if denom > 0 else 0.0
                    ),
                )

        return SimReport(
            duration_s=self.cfg.duration_s,
            total_tiles=self.hw.num_tiles,
            effective_frac=busy / total,
            realloc_frac=realloc / total,
            idle_frac=max(0.0, 1.0 - (busy + realloc) / total),
            dropped_work_frac=self.dropped_work_ts / total,
            n_realloc=sum(p.n_realloc for p in self.parts),
            realloc_bytes=sum(p.realloc_bytes for p in self.parts),
            n_jobs=len(considered),
            n_dropped=len(dropped),
            task_miss_rate=n_miss / max(len(considered), 1),
            chain_count=dict(self.chain_count),
            chain_violations=dict(self.chain_violations),
            chain_p99_s=p99,
            chain_latencies=dict(self.chain_latencies),
            decision_ratios=ratios,
            mode_stats=mode_stats,
            n_mode_switches=self.n_mode_switches,
        )
