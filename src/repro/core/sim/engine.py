"""Tile-stream event-driven simulation engine (paper §V-A).

Execution model
---------------
Each DNN *job* (one activation of a task) samples its workload ``W`` (F1)
and I/O latency ``I`` (F2) from the task's latency profile.  Run
start-to-finish at DoP ``c`` the job would take::

    T(c) = W / (c * P) + I + (c - 1) * sync_s

Progress is tracked as a fraction in [0, 1]; running at DoP ``c``
advances progress at rate ``1/T(c)``.  DoP changes and preemptions are
initiated at scheduling points; chunk boundaries (``n_chunks`` per job,
§IV-D2 operator chunks) generate additional scheduling points for
long-running jobs.  A reallocation stalls *the whole partition*
(stop-migrate-restart, §IV-D1); migration volume follows the L2P
minimal-move model (§IV-D3): ``per-tile checkpoint bytes x |c_new -
c_old|`` per resized job.

Accounting
----------
Per partition the engine integrates allocated-tile-seconds, split into
*effective* (running) and *realloc waste* (allocated but stalled).
Idle is everything else.  E2E chain latencies are measured from source
sample time to sink completion using the unrolled instance dependency
structure (§II-C2).
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ...obs import metrics
from ..gha.schedule import Schedule
from ..hardware import HardwareModel
from ..latency_model import LatencyModel
from ..workload import Workflow
from .policy import Policy
from .trace import Trace, build_skeleton, sample_trace

__all__ = [
    "DegradeStats", "ForecastStats", "Job", "JobState", "ModeStats",
    "SimConfig", "Simulator", "SimReport",
]


class JobState(enum.Enum):
    PENDING = 0   # waiting for data
    READY = 1     # data available, not running
    RUNNING = 2
    DONE = 3
    DROPPED = 4


#  - eq=False: identity hash, jobs live in ready sets
#  - slots=True: ~2x faster construction (the warm-build hot loop) and
#    faster field access everywhere in the event loop
@dataclasses.dataclass(eq=False, slots=True)
class Job:
    jid: int
    task: str
    cycle: int
    idx: int
    release: float                  # absolute source-sample time
    is_sensor: bool
    work_flops: float
    io_s: float
    sync_s: float
    partition: int                  # -1 for sensors
    ert: float                      # absolute earliest-ready-time (t_v)
    sub_ddl: float                  # absolute sub-deadline
    e2e_ddl: float                  # tightest E2E deadline through this task
    plan_dop: int                   # offline c_v
    deps_remaining: int = 0
    succs: Sequence[int] = ()       # skeleton-shared tuple; never mutated

    state: JobState = JobState.PENDING
    progress: float = 0.0
    dop: int = 0
    rate: float = 0.0               # progress per second (0 while stalled)
    last_t: float = 0.0
    gen: int = 0
    ready_t: float = math.nan
    start_t: float = math.nan
    finish_t: float = math.nan
    degraded: bool = False          # an upstream job was dropped
    n_resizes: int = 0
    drop_at_release: bool = False   # scenario sensor dropout window
    #: DoP -> total duration memo: policies re-evaluate the same few
    #: candidate durations at every scheduling point (event-loop fast
    #: path; work/io/sync are fixed once sampled).  Lazily created so
    #: job construction does not allocate a dict per job.
    _dur: Optional[Dict[int, float]] = dataclasses.field(
        default=None, repr=False
    )
    #: (candidate tuple, durations tuple) memo for the policies'
    #: candidate-ladder walks; see :meth:`duration_ladder`
    _ladder: Optional[tuple] = dataclasses.field(default=None, repr=False)
    #: ``(gen, target - projected_finish)`` memo for at-risk scans,
    #: with the finish projection anchored at ``last_t`` (``last_t +
    #: (1-progress)/rate``): for a job running steadily at one DoP the
    #: projection is constant, so the slack against its deadline target
    #: is too — one float per rate epoch (``gen`` changes whenever
    #: rate/DoP do).  Used by the batched fast lanes.
    _margin: Optional[tuple] = dataclasses.field(default=None, repr=False)

    def duration(self, c: int, tile_flops: float) -> float:
        if self.is_sensor:
            return self.io_s  # sensor latency pre-sampled into io_s
        c = max(int(c), 1)
        memo = self._dur
        if memo is None:
            memo = self._dur = {}
        d = memo.get(c)
        if d is None:
            d = (
                self.work_flops / (c * tile_flops)
                + self.io_s
                + self.sync_s * (c - 1)
            )
            memo[c] = d
        return d

    def remaining(self, c: int, tile_flops: float) -> float:
        # duration() inlined: this runs per candidate at every
        # scheduling point and the extra call frame is measurable
        if self.is_sensor:
            return (1.0 - self.progress) * self.io_s
        c = max(int(c), 1)
        memo = self._dur
        if memo is None:
            memo = self._dur = {}
        d = memo.get(c)
        if d is None:
            d = (
                self.work_flops / (c * tile_flops)
                + self.io_s
                + self.sync_s * (c - 1)
            )
            memo[c] = d
        return (1.0 - self.progress) * d

    def duration_ladder(self, cands: tuple, tile_flops: float) -> tuple:
        """Durations for a whole DoP-candidate tuple, memoized on the
        tuple's identity.  Policies walk this ladder at every
        scheduling point (FitQuota, the EDF quota pass); per-candidate
        ``remaining()`` calls were the hottest line of a Monte-Carlo
        sweep.  Callers must pass the *same* tuple object per task
        (the policies' per-task candidate caches do)."""
        lad = self._ladder
        if lad is None or lad[0] is not cands:
            lad = self._ladder = (
                cands,
                tuple(self.duration(c, tile_flops) for c in cands),
            )
        return lad[1]


@dataclasses.dataclass(slots=True)
class _Partition:
    idx: int
    capacity: int
    running: Dict[int, int] = dataclasses.field(default_factory=dict)  # jid -> dop
    #: running total of sum(running.values()); maintained incrementally
    #: at every mutation of ``running`` (event-loop fast path —
    #: ``free``/``allocated`` are called at every scheduling point)
    alloc: int = 0
    stalled: bool = False
    stall_end: float = 0.0
    last_t: float = 0.0
    busy_ts: float = 0.0           # effective tile-seconds
    realloc_ts: float = 0.0        # stalled-but-allocated tile-seconds
    n_realloc: int = 0
    realloc_bytes: float = 0.0
    decision_ratios: List[float] = dataclasses.field(default_factory=list)

    @property
    def allocated(self) -> int:
        return self.alloc

    def free(self) -> int:
        return self.capacity - self.alloc


@dataclasses.dataclass
class SimConfig:
    duration_s: float = 2.0
    seed: int = 0
    n_chunks: int = 6
    drop_policy: str = "hard"       # "hard": drop at E2E ddl; "soft": never
    collect_latencies: bool = True
    #: §IV-D2 fidelity: chunks are unpreemptable, so a reallocation must
    #: wait for the longest in-flight chunk before migration starts.
    #: Off by default (continuous-progress approximation).
    chunk_boundary_realloc: bool = False
    #: optional ``repro.scenarios.ScenarioScript`` (duck-typed so the
    #: engine stays independent of the scenarios package): jobs sample
    #: from the mode active at their release time, segment boundaries
    #: become ``mode_change`` events, and the report gains per-mode
    #: accounting.  Modes that modulate sensor *rates* change the
    #: hyper-period mid-run: the engine unrolls the DAG piecewise per
    #: rate regime (``scenario.rate_regimes``), re-anchoring the sensor
    #: timers at each seam while in-flight jobs of the old regime drain
    #: normally.  None reproduces the stationary single-profile run
    #: bit-for-bit.
    scenario: Optional[object] = None
    #: optional precomputed :class:`~repro.core.sim.trace.Trace`: the
    #: sampled randomness for this (workflow, scenario, horizon, seed).
    #: When several policies simulate the *same* drive (paired
    #: Monte-Carlo comparisons) the caller samples once and shares the
    #: trace; ``None`` samples one internally.  The engine rejects a
    #: trace whose skeleton key does not match this run; the caller
    #: must also sample it from an equal latency model.
    trace: Optional[Trace] = None
    #: optional flight recorder (duck-typed
    #: :class:`~repro.obs.events.TraceRecorder` so the engine stays
    #: independent of the obs package): every hook site is one
    #: ``if rec is not None`` check, so a recorder-less run executes
    #: the same arithmetic as before the hooks existed and pinned-seed
    #: reports stay bit-identical (pinned by ``tests/test_obs.py``).
    recorder: Optional[object] = None


@dataclasses.dataclass
class ModeStats:
    """Per-driving-mode slice of a scenario run.

    Chain completions are attributed to the mode active at their
    *source sample time*; tile-second accounting is split exactly at
    ``mode_change`` boundaries (the engine touches every partition when
    the mode switches).
    """

    mode: str
    span_s: float                   # wall time spent in this mode
    n_completed: int                # chain sink completions
    n_violations: int
    p99_s: float                    # E2E p99 over chains in this mode
    effective_frac: float           # of tiles * span_s
    realloc_frac: float

    @property
    def violation_rate(self) -> float:
        return self.n_violations / self.n_completed if self.n_completed else 0.0


@dataclasses.dataclass
class ForecastStats:
    """Pre-stage accounting for predictive replanning.

    Filled by a :class:`~repro.core.runtime.replan.PredictiveReplanner`
    (the engine copies the replanner's counters into the report).  A
    *pre-swap* installs the forecast target's full table ahead of the
    predicted seam; a *blend* installs the low-confidence hedge (old
    partitions, per-task plan choice by slack).  Hits/misses score the
    stage against the seam that actually arrived; ``prestage_stall_s``
    is the swap stall charged *ahead* of seams (it still lands in
    ``realloc_frac`` — pre-staging moves the cost, it does not hide it),
    and ``lead_s_total`` sums the realized seam-minus-stage lead.
    """

    n_forecasts: int = 0
    n_preswaps: int = 0
    n_blends: int = 0
    n_hits: int = 0
    n_misses: int = 0
    n_reverts: int = 0             # wrong stage undone before any seam
    prestage_bytes: float = 0.0    # background-staged weight/feature volume
    prestage_stall_s: float = 0.0
    lead_s_total: float = 0.0

    @property
    def hit_rate(self) -> float:
        staged = self.n_hits + self.n_misses
        return self.n_hits / staged if staged else 0.0


@dataclasses.dataclass
class DegradeStats:
    """Per-degradation-event accounting (docs/degradation.md).

    A window opens when its event begins and closes at *recovery*: the
    first on-time chain completion at/after the platform effect lifts
    (``t_end``).  ``misses_during`` counts every chain violation —
    late, degraded or dropped sinks — between onset and recovery, so a
    fault whose damage outlives the fault itself is charged honestly.
    ``recover_s`` is NaN when the run never recovers inside the
    horizon (permanent faults recover only if the runtime re-plans
    around them).
    """

    kind: str
    t_start: float
    t_end: float                   # when the platform effect lifts
    misses_during: int = 0
    completions_during: int = 0
    recover_s: float = math.nan    # first on-time completion - t_end


@dataclasses.dataclass
class SimReport:
    duration_s: float
    total_tiles: int
    # capacity decomposition (fractions of total processing power)
    effective_frac: float
    realloc_frac: float
    idle_frac: float
    dropped_work_frac: float
    # events
    n_realloc: int
    realloc_bytes: float
    n_jobs: int
    n_dropped: int
    task_miss_rate: float
    # per-chain
    chain_count: Dict[str, int]
    chain_violations: Dict[str, int]
    chain_p99_s: Dict[str, float]
    chain_latencies: Dict[str, List[float]]
    decision_ratios: List[float]
    # scenario runs only: per-mode accounting + switch count
    mode_stats: Dict[str, ModeStats] = dataclasses.field(default_factory=dict)
    n_mode_switches: int = 0
    # predictive replanning only: pre-stage accounting
    forecast: Optional[ForecastStats] = None
    #: tiles the run actually reserved: the maximum ``peak_tiles`` over
    #: every scheduling table active during the run (one table for a
    #: pinned run; the max across hot-swapped per-mode tables
    #: otherwise).  ``total_tiles`` is what the hardware *has*; the gap
    #: is the tile-budget autotuner's headline (figS_budget).
    tiles_used: int = 0
    #: time-weighted mean of the active table's ``peak_tiles`` — what
    #: the scheduler held *on average* over the run.  Per-mode tables
    #: reserve different tile counts, so a drive spending most of its
    #: time in light modes averages well below its peak reservation;
    #: a work-conserving single-bin table holds its full reservation
    #: for the whole drive by construction.
    tiles_reserved_mean: float = 0.0
    #: the initial table's autotuner metadata (``meta["autotune"]``):
    #: selected quantile/budget/predicted miss + the mode's Pareto
    #: frontier of (tiles, miss, q, partitions).  Empty for schedules
    #: compiled outside the autotuner.
    frontier_meta: Dict[str, object] = dataclasses.field(default_factory=dict)
    #: deadline-miss attribution summary
    #: (:func:`~repro.obs.attribution.attribution_report`); filled by
    #: the scenario runner for recorded runs, ``None`` otherwise
    attribution: Optional[Dict[str, object]] = None
    #: degraded-operation runs only: one :class:`DegradeStats` per
    #: injected event, in onset order.  Empty for degradation-free
    #: scenarios (and excluded from the report digest, so pre-existing
    #: pinned digests are unaffected).
    degrade: List[DegradeStats] = dataclasses.field(default_factory=list)

    @property
    def violation_rate(self) -> float:
        tot = sum(self.chain_count.values())
        return sum(self.chain_violations.values()) / tot if tot else 0.0

    def group_p99(self, critical: Dict[str, bool], want_critical: bool) -> float:
        lats: List[float] = []
        for ch, ls in self.chain_latencies.items():
            if critical.get(ch, False) == want_critical:
                lats.extend(ls)
        if not lats:
            return float("nan")
        return float(np.percentile(np.asarray(lats), 99))


class Simulator:
    """Event-driven Tile-stream simulator."""

    def __init__(
        self,
        wf: Workflow,
        model: LatencyModel,
        schedule: Schedule,
        policy: Policy,
        config: Optional[SimConfig] = None,
    ):
        self.wf = wf
        self.model = model
        self.schedule = schedule
        self.policy = policy
        self.cfg = config or SimConfig()
        if self.cfg.duration_s <= 0:
            raise ValueError("SimConfig.duration_s must be > 0")
        # flight recorder (None in production runs: every hook below is
        # a single ``is not None`` check on this local)
        self._rec = self.cfg.recorder
        self.hw: HardwareModel = model.hw

        self.now = 0.0
        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._seq = 0
        self._end_t = self.cfg.duration_s
        # chunk-boundary event gating (fast path), two tiers:
        #  - policies that never act on "chunk" points (Cyc.,
        #    Tp-driven declare uses_chunk_points=False): skipping is
        #    behaviour-identical — those events were pure heap traffic;
        #  - jobs whose task compiles to a single DoP: their boundaries
        #    are skipped even under chunk-using policies.  This one is
        #    an intentional approximation — such a job's boundary was
        #    still a partition-wide scheduling point that could resize
        #    *co-located* jobs between other events.  The bundled
        #    workloads compile no single-DoP task, so stock benchmarks
        #    are unaffected.
        self._chunk_points = (
            bool(getattr(policy, "uses_chunk_points", True))
            and self.cfg.n_chunks > 1
        )
        self._fixed_dop: frozenset = frozenset(
            name for name, t in wf.tasks.items()
            if not t.is_sensor and len(t.dop_candidates()) <= 1
        )

        self.jobs: List[Job] = []
        self.parts: List[_Partition] = [
            _Partition(idx=p.index, capacity=p.capacity)
            for p in schedule.partitions
        ]
        # weight/feature state already staged in the background by a
        # predictive pre-stage: task -> (partition, dop) resident plans
        self._staged_plans: Dict[str, Tuple[int, int]] = {}
        # tile-reservation accounting + autotuner metadata for the report
        self._tiles_used: int = schedule.peak_tiles
        self._reserved_ts: float = 0.0   # peak_tiles-seconds of past tables
        self._reserved_t0: float = 0.0   # when the active table was installed
        self._frontier_meta: Dict[str, object] = dict(
            schedule.meta.get("autotune") or {}
        )
        # drain watch: an opaque payload re-delivered to the policy's
        # on_forecast at every job finish while armed (the predictive
        # replanner's drain-aware activation rides this — allocation
        # only drops at finishes, so polling between them is pointless)
        self._drain_watch: Optional[object] = None
        # scenario state: active mode + per-mode accounting buckets
        self._mode_now: Optional[str] = None
        self._mode_busy: Dict[str, float] = {}
        self._mode_realloc: Dict[str, float] = {}
        self._mode_lats: Dict[str, List[float]] = {}
        # (chain, mode) -> [completions, violations]
        self._sink_by_mode: Dict[Tuple[str, str], List[int]] = {}
        self.n_mode_switches = 0
        # degraded-operation state: injected platform events (duck-typed
        # from scenario.degradations), their per-event accounting, and
        # windows still awaiting recovery.  All empty for
        # degradation-free scenarios — every hook below is a cheap
        # truthiness check, so such runs stay bit-identical.
        scen0 = self.cfg.scenario
        self._degrades: tuple = tuple(
            getattr(scen0, "degradations", ()) or ()
        )
        self._degrade_stats: List[DegradeStats] = []
        self._deg_open: List[DegradeStats] = []
        self._bw_scale: float = 1.0
        #: all in-effect tile faults: event index -> dead tiles.  The
        #: L2P indirection can *re-place* a freshly installed table
        #: around dead tiles (a hot-swap whose table reserves no more
        #: than the surviving tiles absorbs the loss), so a fault is
        #: split into "active" (tiles physically dead) and "applied"
        #: (the loss currently lands on a partition's capacity).
        self._fault_active: Dict[int, int] = {}
        #: tiles currently lost to *applied* faults, per partition index
        self._fault_by_part: Dict[int, int] = {}
        #: per applied event: (partition index, k) so the end event
        #: restores exactly what it took
        self._fault_applied: Dict[int, Tuple[int, int]] = {}
        #: partitions retired by an online morph; kept for tile-second
        #: accounting (the report sums over live + retired)
        self._retired_parts: List[_Partition] = []
        self._build_jobs()
        self.chain_latencies: Dict[str, List[float]] = {
            c.name: [] for c in wf.chains
        }
        self.chain_violations: Dict[str, int] = {c.name: 0 for c in wf.chains}
        self.chain_count: Dict[str, int] = {c.name: 0 for c in wf.chains}
        self.dropped_work_ts = 0.0

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    def _build_jobs(self) -> None:
        """Materialize the job list from the (cached) structural
        skeleton and the (vectorized) sampled trace.

        The piecewise per-rate-regime unrolling, dependency wiring and
        chain-source mapping live in
        :func:`~repro.core.sim.trace.build_skeleton`; the per-job
        random draws follow the counter-based stream contract of
        :mod:`~repro.core.sim.trace`.  This pass only binds the
        schedule's plans (partition, ERT, sub-deadline, planned DoP) to
        each job — the one input that differs between policies
        simulating the same drive.
        """
        wf, cfg = self.wf, self.cfg
        scen = cfg.scenario
        skel = build_skeleton(wf, scen, cfg.duration_s)
        self._regimes = skel.regimes
        trace = cfg.trace
        if trace is None:
            trace = sample_trace(skel, self.model, scen, cfg.seed)
        elif trace.skeleton_key != skel.key:
            raise ValueError(
                "SimConfig.trace was sampled for a different "
                "workflow/scenario/horizon than this run"
            )

        # per-task constants, hoisted out of the per-job loop.  The
        # mode transforms never touch sync_per_tile_s, so the base
        # profile's value is authoritative for every mode.
        plan_of: Dict[str, tuple] = {}
        for name, task in wf.tasks.items():
            ddl = wf.deadline_offset(name)
            if task.is_sensor:
                plan_of[name] = (True, ddl, None)
            else:
                plan = self.schedule.plans[name]
                plan_of[name] = (
                    False, ddl,
                    (
                        plan.partition, plan.ert_s, plan.subdeadline_s,
                        plan.dop, self.model.profiles[name].sync_per_tile_s,
                    ),
                )

        work_l = trace.work.tolist()
        io_l = trace.io.tolist()
        slat_l = trace.sensor_lat.tolist()
        # dropout-storm verdicts (STREAM_DEGRADE draws) fold into the
        # same drop-at-release seam as scenario dropout windows
        drops = skel.drop_at_release
        if getattr(trace, "storm_drop", None) is not None:
            drops = [a or bool(b) for a, b in zip(drops, trace.storm_drop)]
        append = self.jobs.append
        # positional Job construction in dataclass field order (jid,
        # task, cycle, idx, release, is_sensor, work_flops, io_s,
        # sync_s, partition, ert, sub_ddl, e2e_ddl, plan_dop,
        # deps_remaining, succs) — this loop runs once per job and
        # dominates warm build time, so it stays lean
        for i, (t, cyc, ix, rel_t, sen, dep, suc) in enumerate(zip(
            skel.tasks, skel.cycle, skel.idx, skel.release_list,
            skel.is_sensor, skel.deps_remaining, skel.succs,
        )):
            is_sensor, ddl, plan = plan_of[t]
            if is_sensor:
                lat = slat_l[i]
                append(Job(
                    i, t, cyc, ix, rel_t, True, 0.0, lat, 0.0, -1,
                    rel_t, rel_t + lat * 2, rel_t + ddl, 0, dep, suc,
                    drop_at_release=drops[i],
                ))
            else:
                part, ert_s, sub_s, dop, sync = plan
                append(Job(
                    i, t, cyc, ix, rel_t, False, work_l[i], io_l[i],
                    sync, part, rel_t + ert_s, rel_t + sub_s,
                    rel_t + ddl, dop, dep, suc,
                ))

        # chain accounting: (chain name, sink jid) -> absolute source
        # sample time, valid across regime seams (skeleton-shared,
        # read-only)
        self._sink_src: Dict[Tuple[str, int], float] = skel.sink_src

    # ------------------------------------------------------------------
    # event queue
    # ------------------------------------------------------------------
    def _push(self, t: float, kind: str, payload: tuple) -> None:
        if t > self._end_t:
            # the main loop stops at the horizon; events strictly past
            # it are never processed, so skip the heap traffic
            return
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, kind, payload))

    # ------------------------------------------------------------------
    # partition accounting
    # ------------------------------------------------------------------
    def _touch(self, part: _Partition) -> None:
        dt = self.now - part.last_t
        if dt > 0:
            alloc = part.allocated
            if part.stalled:
                part.realloc_ts += alloc * dt
                if self._mode_now is not None:
                    self._mode_realloc[self._mode_now] = (
                        self._mode_realloc.get(self._mode_now, 0.0) + alloc * dt
                    )
            else:
                part.busy_ts += alloc * dt
                if self._mode_now is not None:
                    self._mode_busy[self._mode_now] = (
                        self._mode_busy.get(self._mode_now, 0.0) + alloc * dt
                    )
        part.last_t = self.now

    def _advance_job(self, job: Job) -> None:
        dt = self.now - job.last_t
        if dt > 0 and job.rate > 0:
            job.progress = min(1.0, job.progress + dt * job.rate)
        job.last_t = self.now

    # ------------------------------------------------------------------
    # policy verbs
    # ------------------------------------------------------------------
    def free_tiles(self, partition: int) -> int:
        return self.parts[partition].free()

    def eligible_jobs(
        self, partition: int, admitted_only: bool = True
    ) -> List[Job]:
        """READY jobs of the partition, optionally filtered by ERT
        admission control (§IV-B2)."""
        out = []
        for job in self._ready_sets[partition]:
            if admitted_only and self.now + 1e-12 < job.ert:
                continue
            out.append(job)
        return out

    def start_job(self, job: Job, dop: int) -> None:
        part = self.parts[job.partition]
        assert job.state == JobState.READY, (job.task, job.state)
        assert dop <= part.free(), (
            f"{job.task}: dop {dop} > free {part.free()} in partition {part.idx}"
        )
        self._touch(part)
        self._ready_sets[job.partition].pop(job, None)
        job.state = JobState.RUNNING
        job.start_t = self.now
        job.dop = dop
        job.last_t = self.now
        part.running[job.jid] = dop
        part.alloc += dop
        if self._rec is not None:
            self._rec.emit(
                self.now, "job_start", jid=job.jid, task=job.task,
                partition=job.partition, value=dop,
            )
        if part.stalled:
            job.rate = 0.0  # will start when the stall ends
        else:
            self._set_rate(job)

    def _set_rate(self, job: Job) -> None:
        job.gen += 1
        t_total = job.duration(job.dop, self.hw.tile_flops)
        job.rate = 1.0 / max(t_total, 1e-9)
        rem = (1.0 - job.progress) / job.rate
        self._push(self.now + rem, "finish", (job.jid, job.gen))
        # next chunk boundary
        if not self._chunk_points or job.task in self._fixed_dop:
            return
        n = self.cfg.n_chunks
        nxt = math.floor(job.progress * n + 1e-9) + 1
        if nxt < n:
            dt = (nxt / n - job.progress) / job.rate
            self._push(self.now + dt, "chunk", (job.jid, job.gen))

    def resize(
        self,
        partition: int,
        new_dops: Dict[int, int],
        starts: Optional[Dict[int, int]] = None,
    ) -> float:
        """Apply a reallocation in one partition: resize running jobs per
        ``new_dops`` (jid -> dop) and start READY jobs per ``starts``.

        Returns the stall duration.  The whole partition stalls while
        checkpoints migrate (§IV-D1); migration volume uses the L2P
        minimal-move model.  If nothing actually changes for running
        jobs, new jobs start with zero stall.
        """
        part = self.parts[partition]
        starts = starts or {}
        changed = {
            jid: d for jid, d in new_dops.items()
            if jid in part.running and part.running[jid] != d
        }
        if not changed:
            for jid, d in starts.items():
                self.start_job(self.jobs[jid], d)
            return 0.0

        self._touch(part)
        moved = 0.0
        for jid, d in changed.items():
            job = self.jobs[jid]
            per_tile = self.wf.tasks[job.task].checkpoint_bytes
            old = part.running[jid]
            moved += per_tile * (old if d == 0 else abs(d - old))
            job.n_resizes += 1
        stall = self._realloc_stall(moved, part.capacity)
        if self.cfg.chunk_boundary_realloc:
            # §IV-D2: chunks are unpreemptable — migration waits for the
            # in-flight chunks of the *resized* jobs to drain (checkpoint
            # positions exist only at chunk boundaries)
            n = self.cfg.n_chunks
            drain = 0.0
            for jid in changed:
                job = self.jobs[jid]
                if job.rate <= 0 or jid not in part.running:
                    continue
                self._advance_job(job)
                frac = (job.progress * n) % 1.0
                drain = max(drain, (1.0 - frac) / (n * job.rate))
            stall += drain
        # freeze all running jobs (whole-partition stall, §IV-D1)
        for jid in part.running:
            job = self.jobs[jid]
            self._advance_job(job)
            job.rate = 0.0
            job.gen += 1
        # apply new dops now (tiles occupied during the stall);
        # dop == 0 preempts back to the ready queue
        shrunk = False
        rec = self._rec
        for jid, d in changed.items():
            job = self.jobs[jid]
            old = part.running[jid]
            if d == 0:
                part.alloc -= part.running.pop(jid)
                job.dop = 0
                job.state = JobState.READY
                self._ready_sets[partition][job] = None
                shrunk = True
            else:
                shrunk = shrunk or d < old
                part.alloc += d - old
                part.running[jid] = d
                job.dop = d
            if rec is not None:
                rec.emit(
                    self.now, "job_resize", jid=jid, task=job.task,
                    partition=partition, value=d, data={"old": old},
                )
        if rec is not None:
            rec.emit(
                self.now, "realloc", partition=partition, value=moved,
                data={"stall_s": stall, "n_resized": len(changed)},
            )
        if shrunk:
            self._notify_drain()
        self._begin_stall(part, moved, stall)
        for jid, d in starts.items():
            self.start_job(self.jobs[jid], d)
        return stall

    def _realloc_stall(self, moved: float, tiles: int) -> float:
        """Stop-migrate-restart stall for ``moved`` checkpoint bytes in
        a ``tiles``-tile partition, with any active ``bandwidth_loss``
        degradation stretching the migration (bytes / bandwidth) term.
        The fixed decision/hop overheads are NoC-control traffic and do
        not scale.  ``_bw_scale`` is exactly 1.0 outside degradation
        windows, so degradation-free runs take the untouched
        single-call path and stay bit-identical."""
        if self._bw_scale >= 1.0:
            return self.hw.realloc_latency(moved, tiles)
        base = self.hw.realloc_latency(0.0, tiles)
        full = self.hw.realloc_latency(moved, tiles)
        return base + (full - base) / max(self._bw_scale, 1e-9)

    def _begin_stall(self, part: _Partition, moved: float, stall: float) -> None:
        """Charge one stop-migrate-restart stall on ``part`` — shared by
        DoP resizes and schedule hot-swaps so both reallocation paths
        account identically (events, bytes, decision/migration ratio,
        resume arming)."""
        part.n_realloc += 1
        part.realloc_bytes += moved
        # decision/migration split: clamp migration time to >= 0 and skip
        # degenerate samples (tiny migrations would otherwise produce
        # nonsense ratios)
        mig = max(stall - self.hw.realloc.decision_s, 0.0)
        if mig > 1e-12:
            part.decision_ratios.append(self.hw.realloc.decision_s / mig)
        part.stalled = True
        part.stall_end = max(part.stall_end, self.now + stall)
        self._push(part.stall_end, "resume", (part.idx,))
        if self._rec is not None:
            self._rec.emit(
                self.now, "stall_begin", partition=part.idx, value=stall,
                data={"bytes": moved},
            )
            self._rec.stall_begin(part.idx, self.now)

    def _plan_deltas(self, new: Schedule):
        """Weight/feature stage-in volume per plan of ``new`` that is
        not already resident, in deterministic (sorted-task) order:
        yields ``(task, plan, bytes)``.  A partition move stages the
        full ``checkpoint_bytes x dop``; staying put costs the L2P
        minimal ``checkpoint_bytes x |dop delta|``.  Shared by
        :meth:`prestage_schedule` and :meth:`hotswap_schedule` so
        background and at-seam staging can never diverge."""
        for task in sorted(new.plans):
            plan = new.plans[task]
            if self._staged_plans.get(task) == (plan.partition, plan.dop):
                continue
            old_plan = self.schedule.plans.get(task)
            if old_plan is None or old_plan.partition != plan.partition:
                delta = plan.dop
            else:
                delta = abs(plan.dop - old_plan.dop)
            if delta:
                yield task, plan, self.wf.tasks[task].checkpoint_bytes * delta

    def prestage_schedule(self, new: Schedule, window_s: float) -> float:
        """Background-stage ``new``'s weight/feature state ahead of a
        forecast seam *without* touching the active table.

        For every task whose plan under ``new`` differs from the
        current table, the stage-in volume (``checkpoint_bytes x dop``
        on a partition move, the L2P minimal ``checkpoint_bytes x
        |dop delta|`` otherwise) is copied in the background: the next
        table's state is not live, so the copy is double-buffered and
        freezes nothing.  ``window_s`` is the forecast lead — each
        target partition stages whole tasks greedily until
        ``window_s x migration_bw`` is spent; the residue simply pays
        the ordinary stall at activation time.  Staged bytes are charged
        to ``realloc_bytes`` (the traffic is real, and a wrong forecast
        wastes it honestly), but no partition stalls, no job is touched,
        and no stall event is counted.

        A later :meth:`hotswap_schedule` that installs matching plans
        skips the staged volume — activation at the seam then stalls
        only for live-state preemptions.  Any hot-swap clears the staged
        set (the buffers are overwritten by the installed table).

        Returns the number of bytes staged.
        """
        budget = (
            max(0.0, window_s) * self.hw.realloc.migration_bw * self._bw_scale
        )
        spent: Dict[int, float] = {}
        total = 0.0
        for task, plan, volume in list(self._plan_deltas(new)):
            if spent.get(plan.partition, 0.0) + volume > budget:
                continue
            spent[plan.partition] = spent.get(plan.partition, 0.0) + volume
            self.parts[plan.partition].realloc_bytes += volume
            self._staged_plans[task] = (plan.partition, plan.dop)
            total += volume
        if self._rec is not None:
            self._rec.emit(
                self.now, "prestage", value=total,
                data={
                    "window_s": window_s,
                    "per_partition": {p: b for p, b in sorted(spent.items())},
                },
            )
        return total

    def hotswap_schedule(
        self,
        new: Schedule,
        regime_anchor_s: Optional[float] = None,
        prestage_window_s: float = 0.0,
    ) -> float:
        """Online replanning: swap the active scheduling table (the
        ``mode_change`` reaction of the runtime, §IV-C applied across
        contexts).

        Running jobs keep their tiles; if a partition's capacity shrank
        below its current allocation, running jobs are preempted back to
        the ready queue (largest allocation first) until it fits, and
        their checkpoints count as migration volume.  Every partition
        pays a stop-migrate-restart stall through the same bounded
        reallocation cost model as a DoP resize, so hot-swap cost lands
        in ``realloc_frac`` honestly.  PENDING/READY jobs are retargeted
        to the new plans (partition, ERT, sub-deadline, plan DoP).

        A table swap also *stages weights and features*: every task
        whose plan moved to another partition re-loads its per-tile
        state there (``checkpoint_bytes x plan dop``), and a task that
        stays put but changes planned DoP pays the L2P minimal move
        (``checkpoint_bytes x |dop delta|``).  The volume is charged to
        the task's *target* partition through the same bounded-realloc
        stall as everything else — this is the millisecond-scale cost a
        reactive swap pays exactly when the new mode's load arrives.
        Swapping to a table with identical plans stages nothing.

        ``prestage_window_s`` is the lead a *predictive* swap has before
        its regime actually starts: weight/feature stage-in that fits in
        ``window x migration_bw`` per partition is copied in the
        background (double-buffered — the next table's state is not
        live, so the copy needs no stop-the-world) and contributes **no
        stall**, while the bytes still land in ``realloc_bytes``.  The
        residual volume, and every live-state checkpoint of a preempted
        job (which can never be background-copied), stalls the
        partition as usual.  A reactive swap has no lead: window 0, the
        full volume freezes the partition at the seam.

        The retarget is *rate-aware*: when the incoming table records
        per-task periods (``meta["task_period_s"]``, portfolio compiles
        do) and a task's period differs from the outgoing regime's, the
        *straddling* PENDING jobs of that task — released on the old
        cadence (before ``regime_anchor_s``) but admitted after it —
        re-stagger their ERTs onto the new regime's release grid:
        ``anchor + k * period`` for the smallest ``k`` at/after the
        legacy ``release + plan.ert_s``.  Their old-grid releases would
        otherwise admit them mid-frame of the new cadence, exactly
        where the new table's reservation windows assume no entry.
        Jobs released at/after the anchor already sit on the new grid
        (the piecewise unroll re-anchors sensor timers at the seam) and
        keep the legacy offset, as do READY jobs (they hold data;
        delaying them to the next grid tick would starve admitted
        work).  ``regime_anchor_s`` is where the new regime's timers
        (re-)anchor: the seam itself for a reactive swap (default:
        now), the *forecast* seam for a predictive pre-swap.

        When ``new`` carries a *different partition count* the swap
        first **morphs** the partition set online (split/merge):
        surviving partitions keep their tiles and running jobs; removed
        partitions are retired — their running jobs are preempted and
        their live checkpoints carried to the partitions their tasks
        re-plan into (charged as migration volume there); newly created
        partitions start empty.  Retired partitions keep their
        tile-second accounting in the final report.  This removes the
        old same-partition-count restriction, so per-mode tables no
        longer need a harmonized spatial layout
        (``SchedulePortfolio.compile(harmonize_partitions=False)``).

        Returns the summed stall time across partitions.
        """
        carry: Dict[int, float] = {}
        if len(new.partitions) != len(self.parts):
            carry = self._morph_partitions(new)
        # L2P re-placement around dead tiles: a freshly installed table
        # whose reservation fits the *surviving* tiles maps its logical
        # tiles onto healthy physical ones, absorbing active faults
        # (the fault's end event then finds nothing left to restore).
        # A table that needs more keeps the per-partition loss.
        dead = sum(self._fault_active.values())
        if self._fault_applied and new.peak_tiles <= self.hw.num_tiles - dead:
            self._fault_applied.clear()
            self._fault_by_part.clear()
        elif self._fault_applied:
            # re-attribute losses whose partition was morphed away
            n_now = len(self.parts)
            for fdi, (pi, k) in list(self._fault_applied.items()):
                if pi >= n_now:
                    self._fault_by_part[pi] = self._fault_by_part.get(pi, k) - k
                    if self._fault_by_part.get(pi, 0) <= 0:
                        self._fault_by_part.pop(pi, None)
                    pj = pi % n_now
                    self._fault_applied[fdi] = (pj, k)
                    self._fault_by_part[pj] = self._fault_by_part.get(pj, 0) + k
        self._tiles_used = max(self._tiles_used, new.peak_tiles)
        self._reserved_ts += self.schedule.peak_tiles * max(
            0.0, self.now - self._reserved_t0
        )
        self._reserved_t0 = self.now
        # weight/feature staging volume per target partition (plan
        # deltas); state already background-staged for exactly this
        # (partition, dop) is resident and moves nothing
        staged: Dict[int, float] = {}
        for _task, plan, volume in self._plan_deltas(new):
            staged[plan.partition] = staged.get(plan.partition, 0.0) + volume
        # background-copy budget per partition: stage-in volume that the
        # pre-stage window can overlap with execution (never live state)
        bg_budget = (
            max(0.0, prestage_window_s)
            * self.hw.realloc.migration_bw
            * self._bw_scale
        )
        total_stall = 0.0
        for part in self.parts:
            new_cap = new.partitions[part.idx].capacity
            lost = self._fault_by_part.get(part.idx, 0)
            if lost:
                # active tile faults survive the swap: the new table's
                # nominal capacity is reduced by whatever is still dead
                new_cap = max(1, new_cap - lost)
            self._touch(part)
            stage_in = staged.get(part.idx, 0.0)
            overlapped = min(stage_in, bg_budget)
            moved = stage_in - overlapped   # residual: stalls the partition
            moved += carry.get(part.idx, 0.0)  # live state from retired parts
            if part.allocated > new_cap:
                victims = sorted(part.running, key=lambda j: (part.running[j], j))
                while part.allocated > new_cap and victims:
                    jid = victims.pop()  # largest allocation first
                    job = self.jobs[jid]
                    moved += (
                        self.wf.tasks[job.task].checkpoint_bytes
                        * part.running[jid]
                    )
                    self._advance_job(job)
                    if self._rec is not None:
                        self._rec.emit(
                            self.now, "job_preempt", jid=jid, task=job.task,
                            partition=part.idx, value=part.running[jid],
                            info="hotswap_shrink",
                        )
                    part.alloc -= part.running.pop(jid)
                    job.rate = 0.0
                    job.gen += 1
                    job.dop = 0
                    job.n_resizes += 1
                    job.state = JobState.READY
                    self._ready_sets[part.idx][job] = None
            part.capacity = new_cap
            stall = self._realloc_stall(moved, max(new_cap, 1))
            # freeze whatever keeps running for the swap stall (§IV-D1)
            for jid in part.running:
                frozen = self.jobs[jid]
                self._advance_job(frozen)
                frozen.rate = 0.0
                frozen.gen += 1
            # background-copied bytes are still reallocation traffic —
            # they count, they just do not freeze the partition
            self._begin_stall(part, moved + overlapped, stall)
            total_stall += stall

        # rate-aware ERT re-stagger: tasks whose period changed between
        # the outgoing and incoming tables snap PENDING ERTs onto the
        # new regime's release grid (anchored at the seam)
        anchor = self.now if regime_anchor_s is None else regime_anchor_s
        new_periods = new.meta.get("task_period_s") or {}
        old_periods = self.schedule.meta.get("task_period_s") or {}
        restagger: Dict[str, float] = {}
        for task, p_new in new_periods.items():
            p_old = old_periods.get(task)
            if p_old is None:
                t = self.wf.tasks.get(task)
                if t is None or t.is_sensor:
                    continue
                p_old = 1.0 / self.wf.task_rate_hz(task)
            if p_new > 0 and not math.isclose(p_new, p_old, rel_tol=1e-9):
                restagger[task] = p_new

        # retarget future jobs to the new plans
        for job in self.jobs:
            if job.is_sensor or job.state not in (JobState.PENDING, JobState.READY):
                continue
            plan = new.plans.get(job.task)
            if plan is None:
                continue
            if job.state == JobState.READY and plan.partition != job.partition:
                self._ready_sets[job.partition].pop(job, None)
                self._ready_sets[plan.partition][job] = None
            job.partition = plan.partition
            ert = job.release + plan.ert_s
            period = restagger.get(job.task)
            if (
                period is not None
                and job.state == JobState.PENDING
                and job.release < anchor - 1e-12
                and ert > anchor + 1e-12
            ):
                ert = anchor + math.ceil((ert - anchor) / period - 1e-9) * period
            job.ert = ert
            job.sub_ddl = job.release + plan.subdeadline_s
            job.plan_dop = plan.dop
            if job.state == JobState.READY and job.ert > self.now:
                self._push(job.ert, "ert", (job.jid,))
        self.schedule = new
        # the installed table's state overwrites the staging buffers
        self._staged_plans.clear()
        if self._rec is not None:
            self._rec.emit(
                self.now, "hotswap", value=total_stall,
                info=str(new.meta.get("mode", "")),
                data={
                    "peak_tiles": new.peak_tiles,
                    "prestage_window_s": prestage_window_s,
                },
            )
        return total_stall

    def _morph_partitions(self, new: Schedule) -> Dict[int, float]:
        """Online split/merge of the partition set to match ``new``.

        Shrinking retires the trailing partitions: every job running
        there is preempted (progress preserved) and parked READY in the
        partition its task re-plans into under ``new``; its live
        checkpoint bytes are *carried* — returned per target partition
        so :meth:`hotswap_schedule` charges them into that partition's
        swap stall (live state can never be background-staged).
        Growing appends empty partitions; capacities for every
        surviving partition are set by the caller's per-partition loop.
        Retired partitions stop accounting at the morph instant and are
        kept on ``_retired_parts`` so the report's tile-second and
        reallocation sums stay complete.
        """
        old_n, new_n = len(self.parts), len(new.partitions)
        rec = self._rec
        carry: Dict[int, float] = {}
        parked: List[Tuple[Job, float]] = []
        if new_n < old_n:
            for part in self.parts[new_n:]:
                self._touch(part)
                for jid in sorted(part.running):
                    job = self.jobs[jid]
                    held = part.running[jid]
                    self._advance_job(job)
                    if rec is not None:
                        rec.emit(
                            self.now, "job_preempt", jid=jid, task=job.task,
                            partition=part.idx, value=held,
                            info="morph_retire",
                        )
                    part.alloc -= part.running.pop(jid)
                    job.rate = 0.0
                    job.gen += 1
                    job.dop = 0
                    job.n_resizes += 1
                    job.state = JobState.READY
                    parked.append(
                        (job, self.wf.tasks[job.task].checkpoint_bytes * held)
                    )
                part.stalled = False  # pending "resume" events are moot
                self._retired_parts.append(part)
            for rs in self._ready_sets[new_n:]:
                parked.extend((j, 0.0) for j in rs)
            del self.parts[new_n:]
            del self._ready_sets[new_n:]
        else:
            for i in range(old_n, new_n):
                self.parts.append(_Partition(
                    idx=i,
                    capacity=new.partitions[i].capacity,
                    last_t=self.now,
                ))
                self._ready_sets.append({})
        # re-home displaced READY jobs onto their new-plan partitions
        # (the caller's retarget pass then fixes ERT/sub-deadline/DoP)
        for job, moved in parked:
            plan = new.plans.get(job.task)
            tgt = plan.partition if plan is not None else 0
            job.partition = tgt
            self._ready_sets[tgt][job] = None
            if moved:
                carry[tgt] = carry.get(tgt, 0.0) + moved
        if rec is not None:
            rec.emit(
                self.now, "morph", value=float(new_n),
                data={
                    "old_partitions": old_n,
                    "new_partitions": new_n,
                    "displaced": len(parked),
                },
            )
        return carry

    # ------------------------------------------------------------------
    # degraded operation (docs/degradation.md)
    # ------------------------------------------------------------------
    @property
    def fault_tiles_lost(self) -> int:
        """Tiles currently dead across all active tile faults (what a
        replanner must budget around: the surviving chip is
        ``hw.num_tiles - fault_tiles_lost``)."""
        return sum(self._fault_active.values())

    def _on_degrade(self, di: int, begin: bool) -> None:
        """Apply/lift one injected platform event (``degrade`` events
        seeded by :meth:`_prime` from ``scenario.degradations``)."""
        d = self._degrades[di]
        kind = getattr(d, "kind", type(d).__name__)
        scen = self.cfg.scenario
        rec = self._rec
        if begin:
            st = DegradeStats(
                kind=kind, t_start=self.now, t_end=d.end_s(self._end_t),
            )
            self._degrade_stats.append(st)
            self._deg_open.append(st)
            if kind == "tile_fault":
                self._apply_tile_fault(di, d)
            elif kind == "bandwidth_loss":
                self._bw_scale = scen.bandwidth_scale(self.now)
        else:
            if kind == "tile_fault":
                self._end_tile_fault(di)
            elif kind == "bandwidth_loss":
                # windows are half-open: at the end instant the lifted
                # event no longer contributes
                self._bw_scale = scen.bandwidth_scale(self.now)
        if rec is not None:
            rec.emit(
                self.now, "degrade_begin" if begin else "degrade_end",
                info=kind, value=float(di),
            )
        self.policy.on_degrade(self, d, begin)

    def _apply_tile_fault(self, di: int, d) -> None:
        """Tiles die: shrink the partition's capacity and, if the
        survivors no longer fit, evacuate running jobs (largest
        allocation first) through a stop-migrate-restart stall — their
        checkpoints must come off the dead tiles."""
        pi = d.partition % len(self.parts)
        part = self.parts[pi]
        self._touch(part)
        self._fault_active[di] = d.k_tiles
        self._fault_by_part[pi] = self._fault_by_part.get(pi, 0) + d.k_tiles
        self._fault_applied[di] = (pi, d.k_tiles)
        new_cap = max(
            1,
            self.schedule.partitions[pi].capacity
            - self._fault_by_part[pi],
        ) if pi < len(self.schedule.partitions) else max(
            1, part.capacity - d.k_tiles
        )
        moved = 0.0
        evacuated = False
        if part.allocated > new_cap:
            victims = sorted(part.running, key=lambda j: (part.running[j], j))
            while part.allocated > new_cap and victims:
                jid = victims.pop()  # largest allocation first
                job = self.jobs[jid]
                moved += (
                    self.wf.tasks[job.task].checkpoint_bytes
                    * part.running[jid]
                )
                self._advance_job(job)
                if self._rec is not None:
                    self._rec.emit(
                        self.now, "job_preempt", jid=jid, task=job.task,
                        partition=pi, value=part.running[jid],
                        info="tile_fault",
                    )
                part.alloc -= part.running.pop(jid)
                job.rate = 0.0
                job.gen += 1
                job.dop = 0
                job.n_resizes += 1
                job.state = JobState.READY
                self._ready_sets[pi][job] = None
                evacuated = True
        part.capacity = new_cap
        if evacuated:
            stall = self._realloc_stall(moved, max(new_cap, 1))
            for jid in part.running:
                frozen = self.jobs[jid]
                self._advance_job(frozen)
                frozen.rate = 0.0
                frozen.gen += 1
            self._begin_stall(part, moved, stall)
            self._notify_drain()

    def _end_tile_fault(self, di: int) -> None:
        """Dead tiles come back: restore capacity and give the policy a
        scheduling point to use them."""
        self._fault_active.pop(di, None)
        applied = self._fault_applied.pop(di, None)
        if applied is None:
            return  # absorbed by an L2P re-placement meanwhile
        pi, k = applied
        left = self._fault_by_part.get(pi, 0) - k
        if left > 0:
            self._fault_by_part[pi] = left
        else:
            self._fault_by_part.pop(pi, None)
        if pi >= len(self.parts):
            return  # the partition was morphed away meanwhile
        part = self.parts[pi]
        self._touch(part)
        part.capacity = max(
            1,
            self.schedule.partitions[pi].capacity - max(left, 0),
        ) if pi < len(self.schedule.partitions) else part.capacity + k
        self.policy.on_point(self, pi, self.now, "resume", None)

    def _deg_note(self, violated: bool) -> None:
        """Fold one chain-sink outcome into every open degradation
        window: violations count as misses-during; the first on-time
        completion at/after a window's effect lifts closes it and
        stamps its time-to-recover."""
        now = self.now
        closed = False
        for st in self._deg_open:
            st.completions_during += 1
            if violated:
                st.misses_during += 1
            elif now >= st.t_end - 1e-12:
                st.recover_s = max(0.0, now - st.t_end)
                closed = True
        if closed:
            self._deg_open = [
                st for st in self._deg_open if math.isnan(st.recover_s)
            ]

    def preempt(self, job: Job) -> None:
        """Remove a running job from its tiles back to the ready queue
        (progress preserved; used by work-conserving baselines)."""
        part = self.parts[job.partition]
        assert job.state == JobState.RUNNING
        self._touch(part)
        self._advance_job(job)
        job.rate = 0.0
        job.gen += 1
        job.dop = 0
        freed = part.running.pop(job.jid)
        part.alloc -= freed
        job.state = JobState.READY
        self._ready_sets[job.partition][job] = None
        if self._rec is not None:
            self._rec.emit(
                self.now, "job_preempt", jid=job.jid, task=job.task,
                partition=job.partition, value=freed,
            )
        self._notify_drain()

    def terminate(self, job: Job, reason: str = "deadline") -> None:
        """Drop a job (Cyc. budget overrun / E2E-deadline dequeue)."""
        part = self.parts[job.partition] if job.partition >= 0 else None
        freed = 0
        if job.state == JobState.RUNNING and part is not None:
            self._touch(part)
            self._advance_job(job)
            freed = part.running.pop(job.jid)
            part.alloc -= freed
            self._notify_drain()
        elif job.state == JobState.READY:
            self._ready_sets[job.partition].pop(job, None)
        if self._rec is not None:
            self._rec.emit(
                self.now, "job_drop", jid=job.jid, task=job.task,
                partition=job.partition, value=freed, info=reason,
            )
        job.state = JobState.DROPPED
        job.finish_t = self.now
        job.rate = 0.0
        job.gen += 1
        # account dropped processing power (remaining work at plan DoP);
        # sensors run on the SPE, not on tiles, so they carry none
        if not job.is_sensor:
            rem = job.remaining(max(job.plan_dop, 1), self.hw.tile_flops)
            self.dropped_work_ts += rem * max(job.plan_dop, 1)
        self._propagate(job)
        self._record_dropped_sink(job)
        self.policy.on_point(self, job.partition, self.now, "drop", job)

    def arm_timer(self, partition: int, t: float, job: Optional[Job] = None) -> None:
        self._push(t, "timer", (partition, job.jid if job else -1))

    def arm_forecast(self, t: float, payload: object = None) -> None:
        """Arm a *forecast* scheduling point at ``t``: the engine calls
        ``policy.on_forecast(sim, payload, now)`` when it fires (used by
        the predictive replanner to wake up ahead of a predicted seam).
        ``payload`` is opaque to the engine."""
        if self._rec is not None:
            self._rec.emit(self.now, "forecast_arm", value=t)
        self._push(t, "forecast", (payload,))

    def arm_drain_watch(self, payload: object) -> None:
        """Arm (or re-arm) the drain watch: until cleared, every event
        that drops a partition's allocation — a job finish, a resize
        that shrinks or preempts, a preemption, a drop — re-delivers
        ``payload`` to ``policy.on_forecast`` at that instant, so a
        drain-deferred schedule activation lands at the exact drain
        point instead of on a poll grid.  Finishes deliver inline
        (before the policy can refill the freed tiles); drops from
        within a policy pass are delivered as a same-timestamp event so
        the pass is never re-entered mid-flight."""
        if self._drain_watch is None and self._rec is not None:
            self._rec.emit(self.now, "drain_arm")
        self._drain_watch = payload

    def clear_drain_watch(self) -> None:
        if self._drain_watch is not None and self._rec is not None:
            self._rec.emit(self.now, "drain_clear")
        self._drain_watch = None

    def _notify_drain(self) -> None:
        """Queue a drain-watch delivery at the current instant (fired
        after the in-flight event completes, before time advances)."""
        if self._drain_watch is not None:
            self._push(self.now, "forecast", (self._drain_watch,))

    # ------------------------------------------------------------------
    # dependency propagation
    # ------------------------------------------------------------------
    def _propagate(self, job: Job) -> None:
        for sid in job.succs:
            succ = self.jobs[sid]
            if job.state == JobState.DROPPED or job.degraded:
                succ.degraded = True
            succ.deps_remaining -= 1
            if succ.deps_remaining == 0 and succ.state == JobState.PENDING:
                succ.state = JobState.READY
                succ.ready_t = self.now
                if succ.is_sensor:
                    continue
                self._ready_sets[succ.partition][succ] = None
                if self._rec is not None:
                    self._rec.emit(
                        self.now, "job_ready", jid=succ.jid, task=succ.task,
                        partition=succ.partition,
                    )
                self._push(self.now, "ready", (succ.jid,))
                if succ.ert > self.now:
                    self._push(succ.ert, "ert", (succ.jid,))

    def _finish_job(self, job: Job) -> None:
        part = self.parts[job.partition] if job.partition >= 0 else None
        freed = 0
        if part is not None and job.jid in part.running:
            self._touch(part)
            freed = part.running.pop(job.jid)
            part.alloc -= freed
        job.state = JobState.DONE
        job.progress = 1.0
        job.finish_t = self.now
        job.rate = 0.0
        job.gen += 1
        frec = self._rec
        if frec is not None:
            frec.emit(
                self.now, "job_finish", jid=job.jid, task=job.task,
                partition=job.partition, value=freed,
            )
        self._propagate(job)
        # chain accounting at sinks
        for chain in self.wf.chains_ending_at(job.task):
            t0 = self._sink_src.get((chain.name, job.jid))
            if t0 is None:
                continue
            lat = self.now - t0
            violated = lat > chain.deadline_s + 1e-12 or job.degraded
            if frec is not None:
                frec.emit(
                    self.now, "chain_complete", jid=job.jid, task=job.task,
                    chain=chain.name, value=lat,
                    data={
                        "t0": t0,
                        "deadline_s": chain.deadline_s,
                        "src_task": chain.nodes[0],
                        "violated": violated,
                    },
                )
                if lat > chain.deadline_s + 1e-12:
                    frec.emit(
                        self.now, "deadline_miss", jid=job.jid,
                        task=job.task, chain=chain.name,
                        value=lat - chain.deadline_s,
                    )
            self.chain_count[chain.name] += 1
            if self.cfg.collect_latencies:
                self.chain_latencies[chain.name].append(lat)
            if violated:
                self.chain_violations[chain.name] += 1
            if self._deg_open:
                self._deg_note(violated)
            if self.cfg.scenario is not None:
                # attribute to the mode active at the source sample time
                m = self.cfg.scenario.mode_at(t0)
                rec = self._sink_by_mode.setdefault((chain.name, m), [0, 0])
                rec[0] += 1
                rec[1] += int(violated)
                if self.cfg.collect_latencies:
                    self._mode_lats.setdefault(m, []).append(lat)

    def _record_dropped_sink(self, job: Job) -> None:
        for chain in self.wf.chains_ending_at(job.task):
            if self._rec is not None:
                self._rec.emit(
                    self.now, "chain_drop", jid=job.jid, task=job.task,
                    chain=chain.name,
                )
            self.chain_count[chain.name] += 1
            self.chain_violations[chain.name] += 1
            if self._deg_open:
                self._deg_note(True)
            if self.cfg.scenario is not None:
                t0 = self._sink_src.get((chain.name, job.jid), job.release)
                m = self.cfg.scenario.mode_at(t0)
                rec = self._sink_by_mode.setdefault((chain.name, m), [0, 0])
                rec[0] += 1
                rec[1] += 1

    # ------------------------------------------------------------------
    # main loop
    # ------------------------------------------------------------------
    def run(self) -> SimReport:
        with metrics.phase("engine_run"):
            return self._run()

    def _run(self) -> SimReport:
        self._prime()
        step = self._step
        while step():
            pass
        return self._finalize()

    # The loop is split into pure step functions so an external driver
    # (the batched lockstep engine in batch.py) can interleave many
    # simulators event-by-event: _prime() once, then _step() until it
    # returns False (heap drained or horizon crossed), then _finalize().
    def _prime(self) -> None:
        # insertion-ordered ready sets: Job hashes by identity, so a
        # plain set iterates in address order, which is only
        # accidentally stable. Dict keys preserve insertion order and
        # make tie-breaking in policy sorts reproducible across
        # processes (and mirrorable by the batched engine).
        self._ready_sets: List[Dict[Job, None]] = [{} for _ in self.parts]
        self.policy.setup(self)

        rec = self._rec
        if rec is not None:
            rec.meta.update(
                duration_s=self.cfg.duration_s,
                seed=self.cfg.seed,
                total_tiles=self.hw.num_tiles,
                policy=type(self.policy).__name__,
                partitions=[p.capacity for p in self.parts],
            )
            rec.emit(
                0.0, "schedule", value=self.schedule.peak_tiles,
                data={"partitions": [p.capacity for p in self.parts]},
            )
            # rate-regime seams (the piecewise unroll's boundaries)
            for r0, _r1, wf_r in self._regimes[1:]:
                rec.emit(r0, "rate_seam", value=wf_r.hyper_period_s)

        # seed events: sensor jobs are released by hardware timers
        for job in self.jobs:
            if job.is_sensor:
                self._push(job.release, "sensor", (job.jid,))

        # seed degradation events (docs/degradation.md): begin at the
        # event's onset, end when its platform effect lifts.  Permanent
        # events (end at the horizon) never fire an end event — the
        # heap stops at the horizon anyway.  Dropout storms act purely
        # through the trace (STREAM_DEGRADE drop verdicts) but still
        # open an accounting window here.
        for di, d in enumerate(self._degrades):
            t0 = getattr(d, "start_s", 0.0)
            if t0 >= self._end_t:
                continue
            self._push(t0, "degrade", (di, True))
            t1 = d.end_s(self._end_t)
            if t1 < self._end_t:
                self._push(t1, "degrade", (di, False))

        # seed mode-switch events from the scenario timeline (adjacent
        # equal-mode segments are one context: no event, no switch)
        scen = self.cfg.scenario
        if scen is not None:
            self._mode_now = scen.mode_at(0.0)
            prev = self._mode_now
            for t, mode in scen.boundaries()[1:]:
                if mode != prev and t < self.cfg.duration_s:
                    self._push(t, "mode_change", (mode,))
                prev = mode
            # a predictive replanner needs to arm its first forecast
            # before the clock starts (there is no t=0 mode_change)
            rep = getattr(self.policy, "replanner", None)
            if rep is not None and hasattr(rep, "on_run_start"):
                rep.on_run_start(self, self._mode_now, 0.0)

    def _step(self) -> bool:
        """Pop and dispatch one event. Returns False when drained."""
        heap = self._heap
        if not heap:
            return False
        t, _, kind, payload = heapq.heappop(heap)
        if t > self.cfg.duration_s:
            return False
        self.now = t
        self._dispatch(kind, payload)
        return True

    def _dispatch(self, kind: str, payload: tuple) -> None:
        rec = self._rec
        if kind == "sensor":
            job = self.jobs[payload[0]]
            if job.drop_at_release:
                # scenario dropout: the frame never arrives;
                # downstream jobs run degraded
                self.terminate(job, "sensor_dropout")
                return
            job.state = JobState.RUNNING
            job.start_t = self.now
            if rec is not None:
                rec.emit(
                    self.now, "job_release", jid=job.jid, task=job.task,
                )
            self._push(self.now + job.io_s, "sensor_done", (job.jid,))
        elif kind == "sensor_done":
            self._finish_job(self.jobs[payload[0]])
        elif kind == "ready":
            job = self.jobs[payload[0]]
            if job.state == JobState.READY:
                self.policy.on_point(self, job.partition, self.now, "ready", job)
        elif kind == "ert":
            job = self.jobs[payload[0]]
            if job.state == JobState.READY:
                self.policy.on_point(self, job.partition, self.now, "ert", job)
        elif kind == "finish":
            jid, gen = payload
            job = self.jobs[jid]
            if job.gen != gen or job.state != JobState.RUNNING:
                return
            self._advance_job(job)
            self._finish_job(job)
            if self._drain_watch is not None:
                # drain-aware activation: allocation just dropped —
                # let the replanner re-check before the policy
                # refills the freed tiles under the old table
                self.policy.on_forecast(self, self._drain_watch, self.now)
            self.policy.on_point(self, job.partition, self.now, "finish", job)
        elif kind == "chunk":
            jid, gen = payload
            job = self.jobs[jid]
            if job.gen != gen or job.state != JobState.RUNNING:
                return
            self._advance_job(job)
            # re-arm next chunk boundary (chunk events only exist
            # for resizable jobs under chunk-using policies)
            n = self.cfg.n_chunks
            nxt = math.floor(job.progress * n + 1e-9) + 1
            if nxt < n and job.rate > 0:
                dt = (nxt / n - job.progress) / job.rate
                self._push(self.now + dt, "chunk", (job.jid, job.gen))
            self.policy.on_point(self, job.partition, self.now, "chunk", job)
        elif kind == "resume":
            if payload[0] >= len(self.parts):
                return  # partition retired by an online morph
            part = self.parts[payload[0]]
            if part.stall_end > self.now + 1e-12:
                return  # superseded by a longer stall (hot-swap)
            self._touch(part)
            part.stalled = False
            if rec is not None:
                rec.emit(self.now, "stall_end", partition=part.idx)
                rec.stall_end(part.idx, self.now)
            for jid in list(part.running):
                job = self.jobs[jid]
                self._advance_job(job)
                self._set_rate(job)
            self.policy.on_point(self, part.idx, self.now, "resume", None)
        elif kind == "timer":
            pid, jid = payload
            if pid >= len(self.parts):
                return  # partition retired by an online morph
            job = self.jobs[jid] if jid >= 0 else None
            if job is not None and job.state in (JobState.DONE, JobState.DROPPED):
                return
            self.policy.on_point(self, pid, self.now, "timer", job)
        elif kind == "forecast":
            if rec is not None:
                rec.emit(self.now, "forecast_fire")
            self.policy.on_forecast(self, payload[0], self.now)
        elif kind == "mode_change":
            mode = payload[0]
            # split tile-second accounting exactly at the boundary
            for part in self.parts:
                self._touch(part)
            self._mode_now = mode
            self.n_mode_switches += 1
            if rec is not None:
                rec.emit(self.now, "mode_change", info=mode)
            self.policy.on_mode_change(self, mode, self.now)
        elif kind == "degrade":
            self._on_degrade(payload[0], payload[1])

    def _finalize(self) -> SimReport:
        # drain accounting to end time
        end_t = self.cfg.duration_s
        self.now = end_t
        for part in self.parts:
            self._touch(part)
        if self._rec is not None:
            self._rec.finalize(end_t)
        return self._report()

    # ------------------------------------------------------------------
    def _chain_expectations(self) -> Dict[str, tuple]:
        """chain name -> (expected sink completions within the horizon,
        per-mode expected counts).  A pure function of the skeleton's
        sink map and the scenario timeline — trace- and
        policy-independent, so the batched lockstep engine computes it
        once per batch and injects it into every lane."""
        scen = self.cfg.scenario
        out: Dict[str, tuple] = {}
        for chain in self.wf.chains:
            expected = 0
            exp_mode: Dict[str, int] = {}
            for (cname, _jid), t0 in self._sink_src.items():
                if cname != chain.name:
                    continue
                if t0 + chain.deadline_s <= self.cfg.duration_s:
                    expected += 1
                    if scen is not None:
                        m = scen.mode_at(t0)
                        exp_mode[m] = exp_mode.get(m, 0) + 1
            out[chain.name] = (expected, exp_mode)
        return out

    def _report(self) -> SimReport:
        total = self.hw.num_tiles * self.cfg.duration_s
        # retired (morphed-away) partitions keep their accounting
        all_parts = self.parts + self._retired_parts
        busy = sum(p.busy_ts for p in all_parts)
        realloc = sum(p.realloc_ts for p in all_parts)
        dnn_jobs = [
            j for j in self.jobs
            if not j.is_sensor and j.release <= self.cfg.duration_s
        ]
        considered = [
            j for j in dnn_jobs
            if j.e2e_ddl <= self.cfg.duration_s  # had a chance to finish
        ]
        dropped = [j for j in considered if j.state == JobState.DROPPED]
        late = [
            j for j in considered
            if j.state == JobState.DONE and j.finish_t > j.e2e_ddl
        ]
        unfinished = [
            j for j in considered
            if j.state in (JobState.PENDING, JobState.READY, JobState.RUNNING)
        ]
        n_miss = len(dropped) + len(late) + len(unfinished)

        # chains whose sink never completed within the horizon count as
        # violations (starvation must not look like success)
        scen = self.cfg.scenario
        expectations = self._chain_expectations()
        for chain in self.wf.chains:
            expected, exp_mode = expectations[chain.name]
            have = self.chain_count[chain.name]
            deficit = max(0, expected - have)
            if deficit:
                self.chain_violations[chain.name] += deficit
                self.chain_count[chain.name] = expected
            # mirror per (chain, mode): attribute exactly the chain's
            # global deficit to modes with missing sinks (chronological
            # order), so per-mode totals always reconcile with the
            # global counters — a mode's shortfall can be offset by
            # bonus completions (deadline beyond the horizon) elsewhere
            if scen is not None and deficit:
                for m in scen.modes():
                    if m not in exp_mode:
                        continue
                    rec = self._sink_by_mode.setdefault((chain.name, m), [0, 0])
                    take = min(max(0, exp_mode[m] - rec[0]), deficit)
                    if take:
                        rec[0] += take
                        rec[1] += take
                        deficit -= take
                    if not deficit:
                        break

        p99 = {}
        for ch, lats in self.chain_latencies.items():
            p99[ch] = float(np.percentile(lats, 99)) if lats else float("nan")
        ratios = [r for p in all_parts for r in p.decision_ratios]

        # per-mode report slices
        mode_stats: Dict[str, ModeStats] = {}
        if scen is not None:
            bounds = scen.boundaries()
            ends = [t for t, _m in bounds[1:]]
            # a run longer than the script stays in the final mode, so
            # the last segment's end is the horizon itself
            ends.append(max(self.cfg.duration_s, bounds[-1][0]))
            spans: Dict[str, float] = {}
            for (t0, m), t1 in zip(bounds, ends):
                spans[m] = spans.get(m, 0.0) + max(
                    0.0,
                    min(t1, self.cfg.duration_s) - min(t0, self.cfg.duration_s),
                )
            for m, span in spans.items():
                done = sum(
                    rec[0] for (_c, mm), rec in self._sink_by_mode.items()
                    if mm == m
                )
                viol = sum(
                    rec[1] for (_c, mm), rec in self._sink_by_mode.items()
                    if mm == m
                )
                lats = self._mode_lats.get(m, [])
                denom = self.hw.num_tiles * span
                mode_stats[m] = ModeStats(
                    mode=m,
                    span_s=span,
                    n_completed=done,
                    n_violations=viol,
                    p99_s=(
                        float(np.percentile(np.asarray(lats), 99))
                        if lats else float("nan")
                    ),
                    effective_frac=(
                        self._mode_busy.get(m, 0.0) / denom if denom > 0 else 0.0
                    ),
                    realloc_frac=(
                        self._mode_realloc.get(m, 0.0) / denom if denom > 0 else 0.0
                    ),
                )

        # predictive replanning: copy the replanner's pre-stage counters
        rep = getattr(self.policy, "replanner", None)
        fstats = getattr(rep, "forecast_stats", None)
        if fstats is not None and not isinstance(fstats, ForecastStats):
            fstats = None

        return SimReport(
            duration_s=self.cfg.duration_s,
            total_tiles=self.hw.num_tiles,
            effective_frac=busy / total,
            realloc_frac=realloc / total,
            idle_frac=max(0.0, 1.0 - (busy + realloc) / total),
            dropped_work_frac=self.dropped_work_ts / total,
            n_realloc=sum(p.n_realloc for p in all_parts),
            realloc_bytes=sum(p.realloc_bytes for p in all_parts),
            n_jobs=len(considered),
            n_dropped=len(dropped),
            task_miss_rate=n_miss / max(len(considered), 1),
            chain_count=dict(self.chain_count),
            chain_violations=dict(self.chain_violations),
            chain_p99_s=p99,
            chain_latencies=dict(self.chain_latencies),
            decision_ratios=ratios,
            mode_stats=mode_stats,
            n_mode_switches=self.n_mode_switches,
            forecast=fstats,
            tiles_used=self._tiles_used,
            tiles_reserved_mean=(
                self._reserved_ts
                + self.schedule.peak_tiles
                * max(0.0, self.cfg.duration_s - self._reserved_t0)
            ) / self.cfg.duration_s,
            frontier_meta=self._frontier_meta,
            degrade=self._degrade_stats,
        )
