"""Runtime scheduling policy interface for Tile-stream.

A :class:`Policy` is invoked at *scheduling points* — job data-ready,
ERT reached, job finished, reallocation stall ended, chunk boundary,
or a policy-armed timer — always in the context of one partition
(distributed per-partition control, paper §IV-C).  Policies act through
the simulator's verbs (``start_job`` / ``resize`` / ``terminate``);
the engine owns all accounting (busy / idle / realloc waste).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:  # pragma: no cover
    from .engine import Job, Simulator


class Policy:
    """Base class; concrete policies live in ``core/baselines`` and
    ``core/runtime``."""

    name: str = "base"
    #: optional online replanner (``core.runtime.replan.OnlineReplanner``);
    #: attach one to make the policy react to driving-mode switches
    replanner: Optional[object] = None
    #: whether this policy acts on ``chunk`` scheduling points.  The
    #: engine skips chunk-boundary event pushes entirely when False —
    #: an event-loop fast path for policies (Cyc., Tp-driven) whose
    #: ``on_point`` ignores the "chunk" reason, where those events were
    #: pure heap traffic.  Leave True if your policy reschedules at
    #: chunk boundaries (ADS-Tile's ChkTrigger does).
    uses_chunk_points: bool = True

    def setup(self, sim: "Simulator") -> None:
        """Called once before the clock starts."""

    def on_mode_change(self, sim: "Simulator", mode: str, now: float) -> None:
        """Called when the scenario's driving mode switches (the engine
        fires this for every ``mode_change`` event).  The default
        delegates to the attached :attr:`replanner`, if any — pinned
        policies simply keep their offline schedule."""
        if self.replanner is not None:
            self.replanner.on_mode_change(sim, mode, now)

    def on_forecast(self, sim: "Simulator", payload: object, now: float) -> None:
        """Called when a ``forecast`` scheduling point armed via
        ``sim.arm_forecast`` fires.  The default delegates to the
        attached :attr:`replanner` when it understands forecasts (a
        ``PredictiveReplanner`` does; the reactive one ignores them)."""
        rep = self.replanner
        if rep is not None and hasattr(rep, "on_forecast"):
            rep.on_forecast(sim, payload, now)

    def on_degrade(
        self, sim: "Simulator", event: object, begin: bool
    ) -> None:
        """Called when an injected platform degradation begins
        (``begin=True``) or its effect lifts (``begin=False``); the
        engine applies the physical effect (capacity loss, bandwidth
        scaling, dropped frames) *before* this hook.  ``event`` is the
        scenario's degradation object (duck-typed; see
        ``repro.scenarios.script.DEGRADATION_TYPES``).  The default
        delegates to the attached :attr:`replanner` when it knows how
        to respond (re-selecting a frontier point against the reduced
        tile budget, then restoring on recovery) — pinned policies ride
        out the event on their offline schedule."""
        rep = self.replanner
        if rep is not None and hasattr(rep, "on_degrade"):
            rep.on_degrade(sim, event, begin)

    def on_point(
        self,
        sim: "Simulator",
        partition: int,
        now: float,
        reason: str,
        job: Optional["Job"] = None,
    ) -> None:
        """Called at every scheduling point of ``partition``.

        ``reason`` in {"ready", "ert", "finish", "resume", "chunk",
        "timer", "drop"}.
        """
        raise NotImplementedError
