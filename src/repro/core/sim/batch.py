"""Batched lockstep Monte-Carlo engine (ROADMAP item: vectorize the
event loop itself).

``run_batch`` advances B :class:`~repro.core.sim.engine.Simulator`
lanes of the *same scenario skeleton* in lockstep windows (one window
per scenario segment boundary).  Three layers make the batch axis pay:

1. **Batched trace materialization** — :func:`sample_trace_batch`
   evaluates the counter-based stream contract once for all seeds as
   ``(B, n)`` array ops: the seed only enters the scalar key fold, so
   a ``(B, 1)`` seed-hash column broadcast against the ``(n,)`` per-job
   key arrays yields every lane's uniforms in one pass.  Each row is
   bit-identical to the scalar :func:`~repro.core.sim.trace.sample_trace`
   for that seed (all downstream ops are elementwise).
2. **Batch-shared precomputations** — the per-chain expected-sink
   statics of the report (trace-independent) are computed once and
   injected into every lane (:class:`LaneSimulator`), and the policies'
   per-job DoP duration ladders are prefilled from vectorized
   ``(n_jobs, n_cands)`` kernels instead of lazy per-candidate scalar
   evaluation (:func:`_prefill_ladders`).
3. **Fused per-lane cores** — for the supported configurations
   (``cyc``/``cyc_s``/``tp_driven``/``ads_tile`` with no recorder and at
   most a reactive :class:`~repro.core.runtime.replan.OnlineReplanner`)
   the event dispatch and the policy's scheduling-point body run as one
   fused loop (:class:`_FastLane`) over bound locals — the same
   arithmetic in the same order as the scalar engine + policy pair,
   without the per-event method-call tax.  Everything mid-frequency
   (``start_job``/``resize``/``terminate``/``hotswap``/finish
   accounting) still runs through the engine's own verbs, so the two
   code paths can only diverge in the fused hot loop — which the
   equivalence gate (``benchmarks/check_equivalence.py``) pins
   bit-for-bit against the scalar engine.

Lane divergence is handled *per lane*: a configuration the fused core
does not support (a recorder attached, a predictive replanner, an
unknown policy subclass) falls back to the scalar engine's own
``_prime``/``_step``/``_finalize`` driver (:class:`_ScalarLane`) but
stays inside the lockstep window loop, so mixed batches are legal and
each lane's report is bit-identical either way.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ...obs import metrics
from ..latency_model import (
    _NDTRI_PLOW,
    LatencyModel,
    _ndtri_central,
    _ndtri_tail,
)
from .engine import JobState, Simulator, SimReport
from .trace import (
    _C_CYCLE,
    _C_IDX,
    _GOLDEN,
    _M1 as _M1_INT,
    _M2 as _M2_INT,
    _MASK64,
    _U64,
    STREAM_IO,
    STREAM_SENSOR,
    STREAM_WORK,
    Trace,
    TraceSkeleton,
    _lognormal_from_uniforms,
    _mix64,
    _mix64_int,
    _params_for,
    storm_drops,
)

__all__ = [
    "BatchTrace",
    "sample_trace_batch",
    "LaneSimulator",
    "run_batch",
    "fast_lane_supported",
    "report_digest",
    "reports_identical",
]


# ---------------------------------------------------------------------------
# batched trace materialization
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class BatchTrace:
    """Per-seed randomness for B lanes, aligned to one skeleton.

    Row ``k`` is bit-identical to ``sample_trace(skel, model, scen,
    seeds[k])`` — :meth:`lane` returns it as an ordinary
    :class:`~repro.core.sim.trace.Trace` (row views, no copy).
    """

    skeleton_key: tuple
    seeds: Tuple[int, ...]
    work: np.ndarray        # (B, n) FLOPs per job (0 for sensors)
    io: np.ndarray          # (B, n) seconds per job
    sensor_lat: np.ndarray  # (B, n) seconds per job (0 for DNN jobs)
    #: per-lane sensor-dropout-storm verdicts (see Trace.storm_drop);
    #: None when the scenario has no storms
    storm_drop: Optional[Tuple[Optional[np.ndarray], ...]] = None

    @property
    def batch(self) -> int:
        return len(self.seeds)

    def lane(self, k: int) -> Trace:
        return Trace(
            skeleton_key=self.skeleton_key,
            seed=self.seeds[k],
            work=self.work[k],
            io=self.io[k],
            sensor_lat=self.sensor_lat[k],
            storm_drop=(
                None if self.storm_drop is None else self.storm_drop[k]
            ),
        )


def _uniforms_batch(
    seeds: Sequence[int],
    stream: int,
    task_keys: np.ndarray,
    regime: np.ndarray,
    cycle: np.ndarray,
    idx: np.ndarray,
) -> np.ndarray:
    """(B, n) uniforms under the stream contract: the scalar seed fold
    becomes a (B, 1) column, everything after it broadcasts elementwise
    — so row ``k`` equals the scalar ``_uniforms_from_keys(seeds[k],
    ...)`` bit-for-bit."""
    h = np.asarray(
        [_mix64_int(_mix64_int((s & _MASK64) ^ int(_GOLDEN)) ^ stream) for s in seeds],
        dtype=np.uint64,
    ).reshape(-1, 1)
    v = _mix64(h ^ task_keys)
    v = _mix64(v ^ (regime + _GOLDEN))
    v = _mix64(v ^ (cycle * _C_CYCLE + _U64(1)))
    v = _mix64(v ^ (idx * _C_IDX + _U64(2)))
    return ((v >> _U64(11)).astype(np.float64) + 0.5) * (2.0 ** -53)


# ---------------------------------------------------------------------------
# on-device (jnp) sampling path — used by the SoA backend
# ---------------------------------------------------------------------------
try:  # jax is a runtime dep, but keep the lockstep engine usable without it
    import jax as _jax  # noqa: F401
    import jax.numpy as _jnp
    from jax.experimental import enable_x64 as _enable_x64

    _HAS_JAX = True
except Exception:  # pragma: no cover - exercised on jax-less platforms
    _HAS_JAX = False


def _mix64_jnp(x):
    """splitmix64 finalizer on jnp ``uint64`` (requires x64 mode)."""
    u = _jnp.uint64
    x = x ^ (x >> u(30))
    x = x * u(int(_M1_INT))
    x = x ^ (x >> u(27))
    x = x * u(int(_M2_INT))
    return x ^ (x >> u(31))


def _ndtri_jnp(q):
    """Acklam inverse-normal on jnp arrays, mirroring
    :func:`repro.core.latency_model.ndtri` branch for branch —
    *including* the +-inf boundary clamps: the stream contract's
    uniforms are ``(m + 0.5) * 2**-53`` whose supremum ``1 - 2**-54``
    rounds to exactly 1.0 in binary64, so ``q >= 1.0`` is a reachable
    input (probability ~1e-16 per draw) and must map to ``+inf`` like
    the NumPy path, not to the clip's finite tail value."""
    qc = _jnp.clip(q, 1e-300, 1.0 - 1e-16)
    lo_t = _ndtri_tail(_jnp.sqrt(-2.0 * _jnp.log(qc)))
    hi_t = -_ndtri_tail(_jnp.sqrt(-2.0 * _jnp.log(1.0 - qc)))
    out = _jnp.where(
        q < _NDTRI_PLOW,
        lo_t,
        _jnp.where(q > 1.0 - _NDTRI_PLOW, hi_t, _ndtri_central(qc)),
    )
    return _jnp.where(
        q <= 0.0, -_jnp.inf, _jnp.where(q >= 1.0, _jnp.inf, out)
    )


def _uniforms_batch_jnp(seeds, stream, task_keys, regime, cycle, idx):
    """Device mirror of :func:`_uniforms_batch`: the scalar seed fold
    stays on host (exact Python-int arithmetic), the broadcast mix runs
    as jnp uint64 ops.  The integer pipeline is bit-identical to the
    NumPy path; only the float transforms downstream may differ in the
    last ulp (XLA's exp/log are not libm)."""
    h = _jnp.asarray(
        [_mix64_int(_mix64_int((s & _MASK64) ^ int(_GOLDEN)) ^ stream) for s in seeds],
        dtype=_jnp.uint64,
    ).reshape(-1, 1)
    u = _jnp.uint64
    v = _mix64_jnp(h ^ task_keys)
    v = _mix64_jnp(v ^ (regime + u(int(_GOLDEN))))
    v = _mix64_jnp(v ^ (cycle * u(int(_C_CYCLE)) + u(1)))
    v = _mix64_jnp(v ^ (idx * u(int(_C_IDX)) + u(2)))
    return ((v >> u(11)).astype(_jnp.float64) + 0.5) * (2.0**-53)


def _sample_trace_batch_jnp(skel, par, seeds):
    """All R lanes' draws in one on-device pass (float64 via the x64
    context so the quantile transforms match the NumPy path to the
    ulp).  Returns host ndarrays — BatchTrace consumers are NumPy."""
    B, n = len(seeds), skel.n
    work = np.zeros((B, n), dtype=np.float64)
    io = np.zeros((B, n), dtype=np.float64)
    sensor_lat = np.zeros((B, n), dtype=np.float64)
    with _enable_x64():
        d = skel.dnn_ix
        if d.size and B:
            keys = _jnp.asarray(skel.task_keys[d])
            reg = _jnp.asarray(skel.regime_arr[d])
            cyc = _jnp.asarray(skel.cycle_arr[d])
            idx = _jnp.asarray(skel.idx_arr[d])
            uw = _uniforms_batch_jnp(seeds, STREAM_WORK, keys, reg, cyc, idx)
            ui = _uniforms_batch_jnp(seeds, STREAM_IO, keys, reg, cyc, idx)
            mean = _jnp.asarray(par.mean[d])
            sigma = _jnp.asarray(par.sigma[d])
            vals = _jnp.exp(_jnp.asarray(par.mu[d]) + sigma * _ndtri_jnp(uw))
            w = _jnp.where(mean <= 0.0, 0.0, _jnp.where(sigma <= 0.0, mean, vals))
            work[:, d] = np.asarray(w * _jnp.asarray(skel.burst[d]))
            rate = _jnp.asarray(par.io_rate[d])
            safe = _jnp.where(rate > 0.0, rate, 1.0)
            queue = -_jnp.log(_jnp.maximum(1.0 - ui, 1e-300)) / safe
            io[:, d] = np.asarray(
                _jnp.asarray(par.io_base[d]) + _jnp.where(rate > 0.0, queue, 0.0)
            )

        s = skel.sen_ix
        if s.size and B:
            keys = _jnp.asarray(skel.task_keys[s])
            reg = _jnp.asarray(skel.regime_arr[s])
            cyc = _jnp.asarray(skel.cycle_arr[s])
            idx = _jnp.asarray(skel.idx_arr[s])
            u_ = _uniforms_batch_jnp(seeds, STREAM_SENSOR, keys, reg, cyc, idx)
            u_ = 0.001 + 0.998 * u_
            mean = _jnp.asarray(par.mean[s])
            sigma = _jnp.asarray(par.sigma[s])
            vals = _jnp.exp(_jnp.asarray(par.mu[s]) + sigma * _ndtri_jnp(u_))
            lat = _jnp.where(mean <= 0.0, 0.0, _jnp.where(sigma <= 0.0, mean, vals))
            sensor_lat[:, s] = np.asarray(lat)
    return work, io, sensor_lat


def sample_trace_batch(
    skel: TraceSkeleton,
    model: LatencyModel,
    scenario,
    seeds: Sequence[int],
    device: bool = False,
) -> BatchTrace:
    """Materialize B seeds' traces in one vectorized pass (the batched
    mirror of :func:`~repro.core.sim.trace.sample_trace`).

    ``device=True`` routes the pass through jnp (the SoA backend's
    path): same stream contract, same integer hash bit-for-bit, but
    the float quantile transforms run on-device and may differ from
    the NumPy path in the last ulp — fine under the distributional
    equivalence contract, not for the lockstep engine's bit-identity
    gate.  Falls back to NumPy when jax is unavailable.
    """
    with metrics.phase("trace_sample"):
        seeds = tuple(int(s) for s in seeds)
        B, n = len(seeds), skel.n
        par = _params_for(skel, model, scenario)
        # storm verdicts are host-side per-lane draws (the scalar
        # helper, so each lane is bit-identical to sample_trace)
        drops = tuple(storm_drops(skel, scenario, s) for s in seeds)
        storm = None if all(d is None for d in drops) else drops
        if device and _HAS_JAX:
            work, io, sensor_lat = _sample_trace_batch_jnp(skel, par, seeds)
            return BatchTrace(
                skeleton_key=skel.key,
                seeds=seeds,
                work=work,
                io=io,
                sensor_lat=sensor_lat,
                storm_drop=storm,
            )
        work = np.zeros((B, n), dtype=np.float64)
        io = np.zeros((B, n), dtype=np.float64)
        sensor_lat = np.zeros((B, n), dtype=np.float64)

        d = skel.dnn_ix
        if d.size and B:
            keys, reg = skel.task_keys[d], skel.regime_arr[d]
            cyc, idx = skel.cycle_arr[d], skel.idx_arr[d]
            uw = _uniforms_batch(seeds, STREAM_WORK, keys, reg, cyc, idx)
            ui = _uniforms_batch(seeds, STREAM_IO, keys, reg, cyc, idx)
            work[:, d] = (
                _lognormal_from_uniforms(uw, par.mean[d], par.mu[d], par.sigma[d])
                * skel.burst[d]
            )
            rate = par.io_rate[d]
            safe = np.where(rate > 0.0, rate, 1.0)
            queue = -np.log(np.maximum(1.0 - ui, 1e-300)) / safe
            io[:, d] = par.io_base[d] + np.where(rate > 0.0, queue, 0.0)

        s = skel.sen_ix
        if s.size and B:
            keys, reg = skel.task_keys[s], skel.regime_arr[s]
            cyc, idx = skel.cycle_arr[s], skel.idx_arr[s]
            u = _uniforms_batch(seeds, STREAM_SENSOR, keys, reg, cyc, idx)
            sensor_lat[:, s] = _lognormal_from_uniforms(
                0.001 + 0.998 * u, par.mean[s], par.mu[s], par.sigma[s]
            )
        return BatchTrace(
            skeleton_key=skel.key,
            seeds=seeds,
            work=work,
            io=io,
            sensor_lat=sensor_lat,
            storm_drop=storm,
        )


# ---------------------------------------------------------------------------
# lane simulator: scalar engine + batch-shared statics
# ---------------------------------------------------------------------------
class LaneSimulator(Simulator):
    """One lane of a batch: identical semantics to
    :class:`~repro.core.sim.engine.Simulator`, with the report's
    per-chain expected-sink statics injected once per batch (they are a
    pure function of the shared skeleton + scenario, see
    ``Simulator._chain_expectations``)."""

    _shared_expectations: Optional[Dict[str, tuple]] = None

    def _chain_expectations(self) -> Dict[str, tuple]:
        shared = self._shared_expectations
        if shared is not None:
            return shared
        return super()._chain_expectations()


# ---------------------------------------------------------------------------
# fast-lane eligibility
# ---------------------------------------------------------------------------
def fast_lane_supported(sim: Simulator) -> bool:
    """Whether ``sim`` can run on the fused fast core.

    Exact-type checks on purpose: an unknown policy subclass (or a
    predictive replanner, or an attached recorder, whose hook sites
    live in the engine paths the fused loop inlines) silently falls
    back to the scalar per-lane driver instead of risking divergence.
    """
    from ..baselines.cyclic import CyclicPolicy, ElasticCyclicPolicy
    from ..baselines.tpdriven import TpDrivenPolicy
    from ..runtime.replan import OnlineReplanner
    from ..runtime.scheduler import AdsTilePolicy

    if sim.cfg.recorder is not None:
        return False
    # injected platform degradations route through engine seams
    # (capacity loss, bandwidth scaling, degrade accounting) that the
    # fused loop does not inline — scalar-lane fallback, bit-identical
    # by construction
    if getattr(sim.cfg.scenario, "has_degradations", False):
        return False
    pol = sim.policy
    rep = pol.replanner
    if rep is not None and type(rep) is not OnlineReplanner:
        return False
    return type(pol) in (
        CyclicPolicy,
        ElasticCyclicPolicy,
        TpDrivenPolicy,
        AdsTilePolicy,
    )


# sort keys shared by the fused policy kernels (match the scalar
# policies' lambdas exactly)
def _ddl_key(j):
    return (j.sub_ddl, j.jid)


def _ert_key(j):
    return (j.ert, j.sub_ddl)


_POL_CYC = 0
_POL_TP = 1
_POL_ADS = 2


class _ScalarLane:
    """Fallback lane: the scalar engine driven window-by-window through
    its own ``_step``; bit-identical to ``Simulator._run`` by
    construction."""

    __slots__ = ("sim",)
    fused = False

    def __init__(self, sim: Simulator):
        self.sim = sim

    def advance_until(self, t_hi: float) -> None:
        sim = self.sim
        heap = sim._heap
        step = sim._step
        while heap and heap[0][0] <= t_hi:
            step()


class _FastLane:
    """Fused event loop: scalar-engine dispatch + the policy's
    scheduling-point body inlined over bound locals.

    Every state mutation either replicates the engine's expression
    verbatim (progress advance, event pushes) or calls the engine's own
    verb (``start_job``/``resize``/``terminate``/``_finish_job``/
    ``_set_rate``/``hotswap``), so the lane's state trajectory is the
    scalar engine's, event for event.  Nested scheduling points raised
    from inside engine verbs (e.g. the ``"drop"`` point fired by
    ``terminate``) intentionally run the *real* policy object — they
    are rare, and reusing them keeps this loop small enough to audit
    against the scalar sources line by line.

    In addition to inlining, the ads_tile kernel carries a
    per-partition **quiet-until cache** (``_quiet``) for its dominant
    case: no admissible ready job and no at-risk running job.  There
    the whole Algorithm-2 pass is a no-op, and it stays one until the
    earliest ChkTrigger flip: for a job running steadily at DoP ``c``,
    ``now + (1-progress)*d(c)`` is *constant* (progress advances at
    exactly ``1/d(c)``), so the at-risk inequality cannot trip before
    ``target - remaining`` computed at cache time — a conservative
    horizon, stored minus a 1e-6 s guard band (orders of magnitude
    above float64 rounding at these scales).  Until that horizon,
    repeated chunk/ert scheduling points are skipped outright; the
    scalar engine re-derives the same no-op.  Anything that breaks the
    frozen-inputs argument — a finish, a terminate (whose nested
    ``"drop"`` point runs the real policy), a stall resume, a
    schedule hot-swap — resets the cache, and a ready/ert arrival is
    caught structurally because the admitted-ready check runs *before*
    the cache is consulted.  No horizon is cached for any pass that
    inspects ready jobs or candidate ladders of differing DoPs
    (FitQuota picks are not monotone once progress advances), so
    skipping never changes a decision.
    """

    __slots__ = (
        "sim",
        "pol",
        "pol_kind",
        "tf",
        "elastic",
        "drop_on_subddl",
        "drop_hard",
        "ads_admission",
        "_quiet",
        "_chunk_pts",
        "_fixed_dop",
        "_n_chunks",
        "_sink_chains",
    )
    fused = True

    def __init__(self, sim: Simulator):
        from ..baselines.cyclic import CyclicPolicy
        from ..baselines.tpdriven import TpDrivenPolicy

        self.sim = sim
        self.pol = pol = sim.policy
        if isinstance(pol, TpDrivenPolicy):
            self.pol_kind = _POL_TP
        elif isinstance(pol, CyclicPolicy):
            self.pol_kind = _POL_CYC
        else:
            self.pol_kind = _POL_ADS
        self.tf = sim.hw.tile_flops
        self.elastic = bool(getattr(pol, "elastic", False))
        self.drop_on_subddl = bool(getattr(pol, "drop_on_subddl", False))
        self.drop_hard = sim.cfg.drop_policy == "hard"
        self.ads_admission = bool(getattr(pol, "admission", True))
        #: per-partition no-op horizon (None = must re-evaluate)
        self._quiet: List[Optional[float]] = [None] * len(sim.parts)
        self._chunk_pts = sim._chunk_points
        self._fixed_dop = sim._fixed_dop
        self._n_chunks = sim.cfg.n_chunks
        #: task -> chains ending there (workload keeps this dict; the
        #: per-finish method call is the only thing skipped)
        self._sink_chains = sim.wf._chains_ending

    # -- event push mirrors (engine _push / arm_timer) -------------------
    def _arm(self, partition: int, t: float, jid: int) -> None:
        sim = self.sim
        if t > sim._end_t:
            return
        sim._seq = seq = sim._seq + 1
        heapq.heappush(sim._heap, (t, seq, "timer", (partition, jid)))

    # -- fused engine verbs ----------------------------------------------
    # ``start_job``/``_set_rate``/``_finish_job`` with the recorder
    # guards dropped (fused lanes are recorder-free by construction, see
    # ``fast_lane_supported``), asserts elided, and ``_touch``/
    # ``_propagate``/``_push`` bodies inlined.  Every arithmetic
    # expression is the engine's, verbatim — only call overhead goes.
    def _touch_part(self, part, now: float) -> None:
        dt = now - part.last_t
        if dt > 0:
            sim = self.sim
            alloc = part.alloc
            mode = sim._mode_now
            if part.stalled:
                part.realloc_ts += alloc * dt
                if mode is not None:
                    sim._mode_realloc[mode] = (
                        sim._mode_realloc.get(mode, 0.0) + alloc * dt
                    )
            else:
                part.busy_ts += alloc * dt
                if mode is not None:
                    sim._mode_busy[mode] = sim._mode_busy.get(mode, 0.0) + alloc * dt
        part.last_t = now

    def _rate(self, job) -> None:
        sim = self.sim
        now = sim.now
        job.gen += 1
        c = job.dop
        memo = job._dur
        if memo is None:
            memo = job._dur = {}
        d = memo.get(c)
        if d is None:
            # running jobs are never sensors and dop >= 1
            d = memo[c] = (
                job.work_flops / (c * self.tf)
                + job.io_s
                + job.sync_s * (c - 1)
            )
        job.rate = rate = 1.0 / (d if d > 1e-9 else 1e-9)
        heap = sim._heap
        end_t = sim._end_t
        t = now + (1.0 - job.progress) / rate
        if t <= end_t:
            sim._seq = seq = sim._seq + 1
            heapq.heappush(heap, (t, seq, "finish", (job.jid, job.gen)))
        if not self._chunk_pts or job.task in self._fixed_dop:
            return
        n = self._n_chunks
        nxt = math.floor(job.progress * n + 1e-9) + 1
        if nxt < n:
            t = now + (nxt / n - job.progress) / rate
            if t <= end_t:
                sim._seq = seq = sim._seq + 1
                heapq.heappush(heap, (t, seq, "chunk", (job.jid, job.gen)))

    def _start(self, job, dop: int) -> None:
        sim = self.sim
        now = sim.now
        part = sim.parts[job.partition]
        self._touch_part(part, now)
        sim._ready_sets[job.partition].pop(job, None)
        job.state = JobState.RUNNING
        job.start_t = now
        job.dop = dop
        job.last_t = now
        part.running[job.jid] = dop
        part.alloc += dop
        if part.stalled:
            job.rate = 0.0  # will start when the stall ends
        else:
            self._rate(job)

    def _finish(self, job) -> None:
        sim = self.sim
        now = sim.now
        jp = job.partition
        if jp >= 0:
            part = sim.parts[jp]
            if job.jid in part.running:
                self._touch_part(part, now)
                part.alloc -= part.running.pop(job.jid)
        job.state = JobState.DONE
        job.progress = 1.0
        job.finish_t = now
        job.rate = 0.0
        job.gen += 1
        # _propagate (job.state is DONE here, so the DROPPED test in the
        # engine's degradation check reduces to job.degraded)
        succs = job.succs
        if succs:
            jobs = sim.jobs
            rsets = sim._ready_sets
            heap = sim._heap
            end_t = sim._end_t
            jdeg = job.degraded
            PENDING = JobState.PENDING
            READY = JobState.READY
            for sid in succs:
                succ = jobs[sid]
                if jdeg:
                    succ.degraded = True
                succ.deps_remaining -= 1
                if succ.deps_remaining == 0 and succ.state is PENDING:
                    succ.state = READY
                    succ.ready_t = now
                    if succ.is_sensor:
                        continue
                    rsets[succ.partition][succ] = None
                    if now <= end_t:
                        sim._seq = seq = sim._seq + 1
                        heapq.heappush(heap, (now, seq, "ready", (succ.jid,)))
                    ert = succ.ert
                    if ert > now and ert <= end_t:
                        sim._seq = seq = sim._seq + 1
                        heapq.heappush(heap, (ert, seq, "ert", (succ.jid,)))
        # chain accounting at sinks
        chains = self._sink_chains[job.task]
        if chains:
            sink_src = sim._sink_src
            cfg = sim.cfg
            collect = cfg.collect_latencies
            scenario = cfg.scenario
            for chain in chains:
                t0 = sink_src.get((chain.name, job.jid))
                if t0 is None:
                    continue
                lat = now - t0
                violated = lat > chain.deadline_s + 1e-12 or job.degraded
                sim.chain_count[chain.name] += 1
                if collect:
                    sim.chain_latencies[chain.name].append(lat)
                if violated:
                    sim.chain_violations[chain.name] += 1
                if scenario is not None:
                    m = scenario.mode_at(t0)
                    rec = sim._sink_by_mode.setdefault((chain.name, m), [0, 0])
                    rec[0] += 1
                    rec[1] += int(violated)
                    if collect:
                        sim._mode_lats.setdefault(m, []).append(lat)

    # -- fused policy scheduling points ----------------------------------
    def _cyc_try_start(self, partition: int) -> None:
        sim = self.sim
        part = sim.parts[partition]
        rs = sim._ready_sets[partition]
        if self.elastic:
            ready = list(rs)
        else:
            lim = sim.now + 1e-12
            ready = [j for j in rs if j.ert <= lim]
        if not ready:
            return
        ready.sort(key=_ert_key)
        elastic = self.elastic
        drop_hard = self.drop_hard
        start = self._start
        for job in ready:
            if job.plan_dop <= part.capacity - part.alloc:
                start(job, job.plan_dop)
                if not elastic:
                    self._arm(partition, job.sub_ddl, job.jid)
                elif drop_hard:
                    self._arm(partition, job.e2e_ddl, job.jid)

    def _tp_reallocate(self, partition: int) -> None:
        sim = self.sim
        part = sim.parts[partition]
        if part.stalled:
            return
        now = sim.now
        tf = self.tf
        jobs = sim.jobs
        cands_of = self.pol._cands
        running = [jobs[jid] for jid in part.running]
        queue = running + list(sim._ready_sets[partition])
        queue.sort(key=_ddl_key)

        # EDF quota pass (tpdriven._reallocate, verbatim arithmetic)
        alloc: Dict[int, int] = {}
        left = part.capacity
        for job in queue:
            cands = cands_of[job.task]
            slack = job.sub_ddl - now
            rem = 1.0 - job.progress
            lad = job._ladder
            if lad is None or lad[0] is not cands:
                lad = job._ladder = (
                    cands,
                    tuple(job.duration(c, tf) for c in cands),
                )
            durs = lad[1]
            pick = 0
            i = 0
            for c in cands:
                if c > left:
                    break
                pick = c
                if rem * durs[i] <= slack:
                    break
                i += 1
            alloc[job.jid] = pick
            left -= pick

        # work-conserving bump pass
        bumped = True
        while left > 0 and bumped:
            bumped = False
            for job in queue:
                cands = cands_of[job.task]
                cur = alloc.get(job.jid, 0)
                for c in cands:
                    if c > cur:
                        if c - cur <= left:
                            alloc[job.jid] = c
                            left -= c - cur
                            bumped = True
                        break

        resize: Dict[int, int] = {}
        starts: Dict[int, int] = {}
        RUN = JobState.RUNNING
        for job in queue:
            a = alloc.get(job.jid, 0)
            if job.state is RUN:
                if a != job.dop:
                    resize[job.jid] = a
            elif a > 0:
                starts[job.jid] = a
        if resize or starts:
            sim.resize(partition, resize, starts)

    def _ads_quota(self, job, cap: int, now: float) -> int:
        pol = self.pol
        cands = pol._cands[job.task]
        if not pol.quota_control:
            fit = [c for c in cands if c <= cap]
            return max(fit) if fit else 0
        # _target + fit_quota inlined (candidate tuples are identical
        # objects to the policy's cache, so the ladder memo is shared
        # with any nested real-policy pass)
        tgt = job.sub_ddl
        if pol.slack_sharing:
            eff = job.e2e_ddl - pol._down.get(job.task, 0.0)
            if eff > tgt:
                tgt = eff
        lad = job._ladder
        if lad is None or lad[0] is not cands:
            tf = self.tf
            lad = job._ladder = (
                cands,
                tuple(job.duration(c, tf) for c in cands),
            )
        durs = lad[1]
        slack = tgt - now
        rem = 1.0 - job.progress
        pick = 0
        i = 0
        for c in cands:
            if c > cap:
                break
            pick = c
            if rem * durs[i] <= slack:
                return c
            i += 1
        return pick

    def _ads_empty_ready(self, part, partition, now, tf, pol, jobs) -> None:
        """The scalar ``_schedule`` body specialised to an empty
        admitted-ready list: the start loop and ``blocked`` are
        vacuous, so ChkTrigger reduces to the at-risk scan and Quota
        Control (if it fires) can only resize running jobs (shrinks
        need ``blocked``; starts need ready jobs).  Each exit stores
        the earliest time any of the evaluated inequalities can flip.
        """
        cmax = pol._cmax
        slack_sharing = pol.slack_sharing
        down = pol._down
        at_risk = False
        min_thr = math.inf
        for jid in part.running:
            job = jobs[jid]
            if cmax[job.task] <= job.dop:
                continue
            # Per-rate-epoch margin memo.  The scalar scan evaluates
            # ``now + (1-progress)*d > tgt`` with progress *stale*
            # (last updated at the job's own event, ``last_t``), so the
            # scan value decays linearly between the job's events —
            # what IS constant per rate epoch is ``M = tgt - projected
            # finish`` with the projection anchored at ``last_t``.  The
            # memo stores ``(gen, M)``; a read reconstructs the scan
            # value as ``M - (now - last_t)`` and trusts its sign only
            # outside a 1e-6 band around zero (reconstruction and
            # stepwise-progress float drift are orders of magnitude
            # below the band); inside the band it falls through to the
            # scalar expression verbatim.
            gen = job.gen
            mg = job._margin
            if mg is not None and mg[0] == gen:
                mm = mg[1]
                m = mm - (now - job.last_t)
                if m > 1e-6:
                    thr = (job.last_t + mm) - 1e-6
                    if thr < min_thr:
                        min_thr = thr
                    continue
                if m < -1e-6:
                    at_risk = True
                    break
            tgt = job.sub_ddl
            if slack_sharing:
                eff = job.e2e_ddl - down.get(job.task, 0.0)
                if eff > tgt:
                    tgt = eff
            c = job.dop
            memo = job._dur
            if memo is None:
                memo = job._dur = {}
            d = memo.get(c)
            if d is None:
                d = memo[c] = (
                    job.work_flops / (c * tf)
                    + job.io_s
                    + job.sync_s * (c - 1)
                )
            proj = (1.0 - job.progress) * d
            job._margin = (gen, (tgt - proj) - job.last_t)
            if now + proj > tgt:
                at_risk = True
                break
            thr = (tgt - proj) - 1e-6
            if thr < min_thr:
                min_thr = thr
        if not at_risk:
            self._quiet[partition] = min_thr
            return

        # ChkTrigger fired: run the start-less Quota Control pass.  No
        # horizon is cached here — pick thresholds are not monotone
        # once progress advances (a smaller candidate's ``rem*d``
        # shrinks faster than slack), so only the exact pass is safe.
        self._quiet[partition] = None
        queue = [jobs[jid] for jid in part.running]
        queue.sort(key=_ddl_key)
        cap_full = part.capacity
        cap_left = cap_full
        want: Dict[int, int] = {}
        quota = self._ads_quota
        for job in queue:
            c = quota(job, cap_left, now)
            if c == 0:
                c = min(job.dop, cap_left)
            want[job.jid] = c
            cap_left -= c

        resize: Dict[int, int] = {}
        gate = pol.realloc_gate
        n_running = len(queue)
        tasks_map = self.sim.wf.tasks
        realloc_latency = self.sim.hw.realloc_latency
        for job in queue:
            c = want[job.jid]
            if c == job.dop or c == 0:
                continue
            if c > job.dop:
                per_tile = tasks_map[job.task].checkpoint_bytes
                stall = realloc_latency(per_tile * abs(c - job.dop), cap_full)
                benefit = job.remaining(job.dop, tf) - job.remaining(c, tf)
                cost = stall * max(1, n_running) * gate
                if benefit > cost:
                    resize[job.jid] = c
            # shrink requires a blocked job — none without ready jobs

        if resize:
            self.sim.resize(partition, resize, {})

    def _ads_schedule(self, partition: int) -> None:
        sim = self.sim
        now = sim.now
        # Quiet horizon: a non-None entry proves the last pass saw no
        # admissible ready job and no at-risk running job, and that
        # nothing observable changed since — every event that can admit
        # a job or perturb running state resets the entry *before* its
        # scheduling point (see advance_until), so the skip is exactly
        # the no-op the scalar engine would re-derive.
        q = self._quiet[partition]
        if q is not None and now < q:
            return
        part = sim.parts[partition]
        if part.stalled:
            return
        tf = self.tf
        pol = self.pol
        jobs = sim.jobs
        quota = self._ads_quota

        rs = sim._ready_sets[partition]
        if pol.admission:
            lim = now + 1e-12
            ready = [j for j in rs if j.ert <= lim] if rs else []
        else:
            ready = list(rs)

        if not ready:
            # the dominant case: nothing admissible.  The start loop
            # and ``blocked`` are vacuous, so only ChkTrigger's at-risk
            # scan (and, if it fires, a start-less Quota Control pass)
            # can matter — and if no job is at risk the pass is a no-op
            # with a provable quiet horizon (see class docstring).
            self._ads_empty_ready(part, partition, now, tf, pol, jobs)
            return
        self._quiet[partition] = None
        running = [jobs[jid] for jid in part.running]

        # fast path: start ready jobs at their quota (scheduler._schedule)
        ready.sort(key=_ddl_key)
        drop_hard = self.drop_hard
        started = True
        while started:
            started = False
            free = part.capacity - part.alloc
            for job in ready:
                c = quota(job, free, now)
                if c > 0:
                    self._start(job, c)
                    if drop_hard:
                        self._arm(partition, job.e2e_ddl, job.jid)
                    ready.remove(job)
                    started = True
                    break

        # ChkTrigger
        free = part.capacity - part.alloc
        cap_full = part.capacity
        blocked = [j for j in ready if quota(j, cap_full, now) > free]
        at_risk = False
        cmax = pol._cmax
        slack_sharing = pol.slack_sharing
        down = pol._down
        for job in running:
            if cmax[job.task] <= job.dop:
                continue
            # same per-rate-epoch margin memo as _ads_empty_ready
            gen = job.gen
            mg = job._margin
            if mg is not None and mg[0] == gen:
                m = mg[1] - (now - job.last_t)
                if m > 1e-6:
                    continue
                if m < -1e-6:
                    at_risk = True
                    break
            tgt = job.sub_ddl
            if slack_sharing:
                eff = job.e2e_ddl - down.get(job.task, 0.0)
                if eff > tgt:
                    tgt = eff
            # job.remaining(job.dop, tf) inlined (running jobs are
            # never sensors; dop >= 1 while running)
            c = job.dop
            memo = job._dur
            if memo is None:
                memo = job._dur = {}
            d = memo.get(c)
            if d is None:
                d = memo[c] = (
                    job.work_flops / (c * tf)
                    + job.io_s
                    + job.sync_s * (c - 1)
                )
            proj = (1.0 - job.progress) * d
            job._margin = (gen, (tgt - proj) - job.last_t)
            if now + proj > tgt:
                at_risk = True
                break
        if not blocked and not at_risk:
            return

        # Quota Control pass
        queue = running + ready
        queue.sort(key=_ddl_key)
        cap_left = cap_full
        want: Dict[int, int] = {}
        RUN = JobState.RUNNING
        for job in queue:
            c = quota(job, cap_left, now)
            if job.state is RUN and c == 0:
                c = min(job.dop, cap_left)
            want[job.jid] = c
            cap_left -= c

        # apply with benefit/cost gating
        resize: Dict[int, int] = {}
        starts: Dict[int, int] = {}
        n_running = len(running)
        gate = pol.realloc_gate
        tasks_map = sim.wf.tasks
        realloc_latency = sim.hw.realloc_latency
        for job in queue:
            c = want[job.jid]
            if job.state is RUN:
                if c == job.dop or c == 0:
                    continue
                per_tile = tasks_map[job.task].checkpoint_bytes
                stall = realloc_latency(per_tile * abs(c - job.dop), cap_full)
                if c > job.dop:
                    benefit = job.remaining(job.dop, tf) - job.remaining(c, tf)
                    cost = stall * max(1, n_running) * gate
                    if benefit > cost:
                        resize[job.jid] = c
                else:
                    if blocked:
                        resize[job.jid] = c
            elif c > 0:
                starts[job.jid] = c

        if resize or starts:
            part_running = part.running
            freed = 0
            for j, d in resize.items():
                freed += part_running[j] - d
            avail = (part.capacity - part.alloc) + freed
            for jid in sorted(starts, key=lambda j: jobs[j].sub_ddl):
                if starts[jid] > avail:
                    starts.pop(jid)
                else:
                    avail -= starts[jid]
            sim.resize(partition, resize, starts)
            if drop_hard:
                for jid in starts:
                    self._arm(partition, jobs[jid].e2e_ddl, jid)

    # -- fused dispatch loop ---------------------------------------------
    def advance_until(self, t_hi: float) -> None:
        sim = self.sim
        heap = sim._heap
        jobs = sim.jobs
        parts = sim.parts
        end_t = sim._end_t
        pop = heapq.heappop
        push = heapq.heappush
        pk = self.pol_kind
        elastic = self.elastic
        drop_on_subddl = self.drop_on_subddl
        drop_hard = self.drop_hard
        RUN = JobState.RUNNING
        READY = JobState.READY
        DONE = JobState.DONE
        DROPPED = JobState.DROPPED
        floor = math.floor
        quiet = self._quiet
        n_parts = len(quiet)
        n_chunks = sim.cfg.n_chunks
        ads_admission = self.ads_admission
        ads_sched = self._ads_schedule
        tp_realloc = self._tp_reallocate
        cyc_start = self._cyc_try_start
        finish = self._finish
        rsets = sim._ready_sets

        while heap:
            t = heap[0][0]
            if t > t_hi:
                break
            t, _, kind, payload = pop(heap)
            sim.now = t

            if kind == "finish":
                jid, gen = payload
                job = jobs[jid]
                if job.gen != gen or job.state is not RUN:
                    continue
                dt = t - job.last_t
                if dt > 0 and job.rate > 0:
                    p = job.progress + dt * job.rate
                    job.progress = p if p < 1.0 else 1.0
                job.last_t = t
                jp = job.partition
                if pk == _POL_ADS:
                    rs_jp = rsets[jp]
                    n0 = len(rs_jp)
                finish(job)
                if sim._drain_watch is not None:
                    sim.policy.on_forecast(sim, sim._drain_watch, t)
                    # a drain delivery can commit a staged hot-swap
                    for i in range(n_parts):
                        quiet[i] = None
                if pk == _POL_ADS:
                    # A finish removes one running job (the min over the
                    # survivors' at-risk horizons can only rise) and
                    # frees tiles (invisible to an empty-ready pass), so
                    # a valid quiet horizon survives it — unless the
                    # finish released a same-partition successor, or an
                    # already-queued ready job sits inside the 1e-12
                    # admission window ahead of its pending ert event.
                    q = quiet[jp]
                    if q is None or t >= q or len(rs_jp) != n0:
                        quiet[jp] = None
                        ads_sched(jp)
                    else:
                        lim = t + 1e-12
                        for j in rs_jp:
                            if j.ert <= lim:
                                quiet[jp] = None
                                ads_sched(jp)
                                break
                elif pk == _POL_TP:
                    tp_realloc(jp)
                else:
                    cyc_start(jp)

            elif kind == "chunk":
                # second in the chain: chunk boundaries are the most
                # frequent event for the ads_tile lanes (the only fused
                # policy with ``uses_chunk_points``); quiet check
                # inlined to spare the call on the dominant skip path
                jid, gen = payload
                job = jobs[jid]
                if job.gen != gen or job.state is not RUN:
                    continue
                dt = t - job.last_t
                if dt > 0 and job.rate > 0:
                    p = job.progress + dt * job.rate
                    job.progress = p if p < 1.0 else 1.0
                job.last_t = t
                nxt = floor(job.progress * n_chunks + 1e-9) + 1
                if nxt < n_chunks and job.rate > 0:
                    t2 = t + (nxt / n_chunks - job.progress) / job.rate
                    if t2 <= end_t:
                        sim._seq = seq = sim._seq + 1
                        push(heap, (t2, seq, "chunk", (job.jid, job.gen)))
                jp = job.partition
                q = quiet[jp]
                if q is None or t >= q:
                    ads_sched(jp)

            elif kind == "ready":
                job = jobs[payload[0]]
                if job.state is not READY:
                    continue
                partition = job.partition
                if pk == _POL_ADS:
                    if drop_hard:
                        self._arm(partition, job.e2e_ddl, job.jid)
                    if not ads_admission or job.ert <= t + 1e-12:
                        # the arrival is admissible right away
                        quiet[partition] = None
                    ads_sched(partition)
                elif pk == _POL_TP:
                    if drop_on_subddl:
                        self._arm(partition, job.sub_ddl, job.jid)
                    elif drop_hard:
                        self._arm(partition, job.e2e_ddl, job.jid)
                    self._tp_reallocate(partition)
                else:
                    if not elastic:
                        self._arm(partition, job.sub_ddl, job.jid)
                    self._cyc_try_start(partition)

            elif kind == "ert":
                job = jobs[payload[0]]
                if job.state is not READY:
                    continue
                # "ert" is a scheduling point for ads/cyc only
                # (tp_driven's on_point ignores it)
                if pk == _POL_ADS:
                    jp = job.partition
                    quiet[jp] = None  # the job just crossed admission
                    ads_sched(jp)
                elif pk == _POL_CYC:
                    cyc_start(job.partition)

            elif kind == "sensor":
                job = jobs[payload[0]]
                if job.drop_at_release:
                    sim.terminate(job, "sensor_dropout")
                    for i in range(n_parts):
                        quiet[i] = None
                    continue
                job.state = RUN
                job.start_t = t
                t2 = t + job.io_s
                if t2 <= end_t:
                    sim._seq = seq = sim._seq + 1
                    push(heap, (t2, seq, "sensor_done", (job.jid,)))

            elif kind == "sensor_done":
                finish(jobs[payload[0]])

            elif kind == "timer":
                pid, jid = payload
                job = jobs[jid] if jid >= 0 else None
                if job is not None and (job.state is DONE or job.state is DROPPED):
                    continue
                if job is None:
                    continue
                if pk == _POL_ADS:
                    if drop_hard and t >= job.e2e_ddl - 1e-12:
                        sim.terminate(job, "e2e_deadline")
                        # the nested "drop" point ran the real policy
                        for i in range(n_parts):
                            quiet[i] = None
                elif pk == _POL_TP:
                    if drop_on_subddl and t >= job.sub_ddl - 1e-12:
                        sim.terminate(job, "subddl_drop")
                    elif drop_hard and t >= job.e2e_ddl - 1e-12:
                        sim.terminate(job, "e2e_deadline")
                else:
                    if not elastic:
                        if t >= job.sub_ddl - 1e-12:
                            sim.terminate(job, "budget_overrun")
                    elif drop_hard and t >= job.e2e_ddl - 1e-12:
                        sim.terminate(job, "e2e_deadline")
                    self._cyc_try_start(pid)

            elif kind == "resume":
                part = parts[payload[0]]
                if part.stall_end > t + 1e-12:
                    continue
                self._touch_part(part, t)
                part.stalled = False
                for jid in list(part.running):
                    job = jobs[jid]
                    dt = t - job.last_t
                    if dt > 0 and job.rate > 0:
                        p = job.progress + dt * job.rate
                        job.progress = p if p < 1.0 else 1.0
                    job.last_t = t
                    self._rate(job)
                # the stall froze progress while time advanced, so the
                # cached at-risk horizon no longer holds
                pidx = part.idx
                quiet[pidx] = None
                if pk == _POL_ADS:
                    ads_sched(pidx)
                elif pk == _POL_TP:
                    tp_realloc(pidx)
                else:
                    cyc_start(pidx)

            elif kind == "forecast":
                sim.policy.on_forecast(sim, payload[0], t)
                for i in range(n_parts):
                    quiet[i] = None

            elif kind == "mode_change":
                mode = payload[0]
                for part in parts:
                    sim._touch(part)
                sim._mode_now = mode
                sim.n_mode_switches += 1
                sim.policy.on_mode_change(sim, mode, t)
                for i in range(n_parts):
                    quiet[i] = None


# ---------------------------------------------------------------------------
# batch-shared precomputations
# ---------------------------------------------------------------------------
def _prefill_ladders(sims: Sequence[Simulator]) -> None:
    """Prefill every lane's per-job DoP duration ladders from
    vectorized per-task kernels.

    The scalar engine computes each ladder lazily per candidate (the
    policies' FitQuota/EDF walks); here one ``(n_jobs, n_cands)`` array
    expression per task replaces those scalar evaluations.  The
    expression tree matches ``Job.duration`` exactly (``work / (c *
    tile_flops) + io + sync * (c - 1)`` with Python-float ``c *
    tile_flops``), so the prefilled values are bit-identical to what
    the lazy path would produce — lanes whose candidate tuples differ
    from the policy cache (or change after a hot-swap re-setup) simply
    fall back to the lazy path via the ladder's identity check.
    """
    base = sims[0]
    jids_by_task: Dict[str, List[int]] = {}
    for job in base.jobs:
        if not job.is_sensor:
            jids_by_task.setdefault(job.task, []).append(job.jid)

    for sim in sims:
        pol = sim.policy
        cands_of = getattr(pol, "_cands", None)
        trace = sim.cfg.trace
        if not cands_of or trace is None:
            continue
        tf = sim.hw.tile_flops
        jobs = sim.jobs
        W, IO = trace.work, trace.io
        for task, jids in jids_by_task.items():
            cands = cands_of.get(task)
            if not cands:
                continue
            ix = np.asarray(jids, dtype=np.intp)
            w, io = W[ix], IO[ix]
            sync = jobs[jids[0]].sync_s
            cols = [(w / (c * tf) + io + sync * (c - 1)).tolist() for c in cands]
            rows = zip(*cols)
            for jid, row in zip(jids, rows):
                jobs[jid]._ladder = (cands, row)


# ---------------------------------------------------------------------------
# lockstep driver
# ---------------------------------------------------------------------------
def _windows(sim: Simulator) -> List[float]:
    """Lockstep window boundaries: one per scenario segment seam, plus
    the horizon.  Windows only partition each lane's event sequence —
    events are still processed strictly in per-lane heap order — so
    any boundary set is semantics-preserving; seams are where lane
    state naturally synchronizes."""
    dur = sim.cfg.duration_s
    scen = sim.cfg.scenario
    cuts = set()
    if scen is not None:
        for t, _m in scen.boundaries():
            if 0.0 < t < dur:
                cuts.add(t)
    return sorted(cuts) + [dur]


def run_batch(sims: Sequence[Simulator]) -> List[SimReport]:
    """Advance B simulator lanes of one scenario skeleton in lockstep
    and return their reports (bit-identical to ``sim.run()`` per lane).

    Preconditions: every lane shares the first lane's skeleton (same
    workflow structure, scenario, horizon) — seeds, schedules, policies
    and replanners may differ per lane.  Lanes the fused core supports
    run fused; the rest fall back to the scalar engine's own step
    driver inside the same window loop.
    """
    if not sims:
        return []
    base = sims[0]
    for sim in sims[1:]:
        if sim._sink_src is not base._sink_src:
            raise ValueError(
                "run_batch lanes must share one scenario skeleton "
                "(same workflow/scenario/horizon)"
            )

    lanes = []
    for sim in sims:
        sim._prime()
        lanes.append(_FastLane(sim) if fast_lane_supported(sim) else _ScalarLane(sim))

    # batch-shared statics: chain expectations (once) + duration ladders
    shared = Simulator._chain_expectations(base)
    for sim in sims:
        if isinstance(sim, LaneSimulator):
            sim._shared_expectations = shared
    _prefill_ladders(sims)

    with metrics.phase("engine_run"):
        for w in _windows(base):
            for lane in lanes:
                lane.advance_until(w)
    return [sim._finalize() for sim in sims]


# ---------------------------------------------------------------------------
# report equivalence
# ---------------------------------------------------------------------------
def report_digest(report: SimReport) -> dict:
    """Canonical comparable form of a :class:`SimReport`: every numeric
    field verbatim (floats kept exact for bit-identity checks), NaNs
    mapped to a sentinel so equality is well-defined."""

    def _f(x):
        if isinstance(x, float) and math.isnan(x):
            return "nan"
        return x

    fc = report.forecast
    out = {
        "duration_s": report.duration_s,
        "total_tiles": report.total_tiles,
        "effective_frac": report.effective_frac,
        "realloc_frac": report.realloc_frac,
        "idle_frac": report.idle_frac,
        "dropped_work_frac": report.dropped_work_frac,
        "n_realloc": report.n_realloc,
        "realloc_bytes": report.realloc_bytes,
        "n_jobs": report.n_jobs,
        "n_dropped": report.n_dropped,
        "task_miss_rate": report.task_miss_rate,
        "chain_count": dict(report.chain_count),
        "chain_violations": dict(report.chain_violations),
        "chain_p99_s": {k: _f(v) for k, v in report.chain_p99_s.items()},
        "chain_latencies": {k: tuple(v) for k, v in report.chain_latencies.items()},
        "decision_ratios": tuple(report.decision_ratios),
        "mode_stats": {
            m: (
                s.mode,
                s.span_s,
                s.n_completed,
                s.n_violations,
                _f(s.p99_s),
                s.effective_frac,
                s.realloc_frac,
            )
            for m, s in report.mode_stats.items()
        },
        "n_mode_switches": report.n_mode_switches,
        "forecast": None if fc is None else dataclasses.astuple(fc),
        "tiles_used": report.tiles_used,
        "tiles_reserved_mean": report.tiles_reserved_mean,
    }
    # degraded-operation section only when present, so digests (and the
    # pinned hashes derived from them) of degradation-free runs are
    # unchanged from before the degradation seams existed
    if report.degrade:
        out["degrade"] = tuple(
            tuple(_f(v) for v in dataclasses.astuple(st))
            for st in report.degrade
        )
    return out


def reports_identical(a: SimReport, b: SimReport) -> bool:
    """Bit-identity predicate between two reports (the batched engine's
    contract against the scalar engine)."""
    return report_digest(a) == report_digest(b)
