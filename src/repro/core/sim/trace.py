"""Batched trace generation for the Tile-stream simulator.

Monte-Carlo sweeps simulate thousands of drives; before this module the
engine sampled every job's workload ``W`` (F1) and I/O latency ``I``
(F2) with one scalar ``RandomState`` call per job, so fleet-scale
sweeps were bottlenecked on per-job Python overhead rather than on
simulation logic.  This module splits job construction into three
cacheable layers:

1. **Skeleton** (:func:`build_skeleton`) — the schedule- and
   seed-independent structure of a run: unrolled task instances per
   rate regime, absolute release times, the dependency CSR, chain
   source maps, per-job driving mode and burst scales.  Memoized on
   ``(workflow signature, scenario token, horizon)``, so every policy,
   replan variant and seed of the same drive shares one skeleton.
2. **Trace** (:func:`sample_trace`) — the per-seed random draws, made
   as a handful of vectorized NumPy array ops per ``(task, mode)``
   bucket instead of per-job scalar calls.
3. **Materialization** (engine ``_build_jobs``) — the cheap per-run
   pass that binds a skeleton + trace to a schedule's plans.

Counter-based stream contract
-----------------------------
Draws do **not** come from a sequential RNG.  Every job's uniforms are
computed by a counter-based construction (splitmix64 mixing, the same
key-to-stream idea as ``Philox``/``Threefry``) keyed on::

    (seed, task name, stream, regime index, cycle, instance index)

with ``stream`` in {WORK, IO, SENSOR}, and are pushed through the
distributions' inverse CDFs (lognormal work via the shared vectorized
:func:`~repro.core.latency_model.ndtri`, shifted-exponential I/O,
lognormal sensor latency).  Consequences, which tests pin:

* a job's draw is independent of build order, of the policy/schedule,
  and of the simulation horizon — two runs of the same scenario seed
  see bit-identical ``work_flops``/``io_s`` per job, so policy
  comparisons are exactly paired at the job level;
* truncating or extending the horizon never shifts the draws of the
  jobs both runs share;
* the draws are *distribution-equivalent* to the retired scalar
  ``RandomState`` path (same inverse CDFs, uniform inputs): the KS
  tests in ``tests/test_trace.py`` pin each stream's distribution
  against the analytic CDFs directly.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import weakref
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from ...obs import metrics
from ..latency_model import LatencyModel, ndtri
from ..workload import Workflow, unroll_hyperperiod

__all__ = [
    "STREAM_WORK",
    "STREAM_IO",
    "STREAM_SENSOR",
    "STREAM_DEGRADE",
    "counter_uniforms",
    "chain_sources",
    "TraceSkeleton",
    "Trace",
    "build_skeleton",
    "sample_trace",
    "storm_drops",
    "clear_skeleton_cache",
]

STREAM_WORK = 0
STREAM_IO = 1
STREAM_SENSOR = 2
#: platform-degradation draws (sensor-dropout storms).  A dedicated
#: stream keeps degraded scenarios on the counter contract *without*
#: perturbing any draw of a degradation-free scenario: the work/io/
#: sensor streams are keyed identically whether or not this one is
#: ever sampled, so existing seeds stay bit-reproducible.
STREAM_DEGRADE = 3

_MASK64 = 0xFFFFFFFFFFFFFFFF
_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)
_C_CYCLE = np.uint64(0xD1342543DE82EF95)
_C_IDX = np.uint64(0x2545F4914F6CDD1D)
_U64 = np.uint64


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer (bijective 64-bit mix) on ``uint64`` arrays;
    overflow wraps, which is the point (NumPy wraps unsigned array
    arithmetic silently)."""
    x = x ^ (x >> _U64(30))
    x = x * _M1
    x = x ^ (x >> _U64(27))
    x = x * _M2
    return x ^ (x >> _U64(31))


_MIX_M1 = 0xBF58476D1CE4E5B9
_MIX_M2 = 0x94D049BB133111EB


def _mix64_int(x: int) -> int:
    """The same splitmix64 finalizer on Python ints (exact arithmetic,
    no NumPy scalar-overflow warnings; used for the scalar key fold)."""
    x &= _MASK64
    x ^= x >> 30
    x = (x * _MIX_M1) & _MASK64
    x ^= x >> 27
    x = (x * _MIX_M2) & _MASK64
    return x ^ (x >> 31)


_task_key_cache: Dict[str, int] = {}


def _task_key(task: str) -> int:
    """Stable 64-bit key for a task name (blake2b, platform/run
    independent — ``hash()`` is salted per process and unusable)."""
    k = _task_key_cache.get(task)
    if k is None:
        k = int.from_bytes(
            hashlib.blake2b(task.encode(), digest_size=8).digest(), "little"
        )
        _task_key_cache[task] = k
    return k


def _uniforms_from_keys(
    seed: int,
    stream: int,
    task_keys: np.ndarray,
    regime: np.ndarray,
    cycle: np.ndarray,
    idx: np.ndarray,
) -> np.ndarray:
    """Vectorized core of the stream contract: ``task_keys`` is the
    per-element 64-bit task key (so one call covers jobs of *different*
    tasks).  All array inputs are uint64 of equal length."""
    h = _mix64_int(_mix64_int((seed & _MASK64) ^ int(_GOLDEN)) ^ stream)
    v = _mix64(_U64(h) ^ task_keys)
    v = _mix64(v ^ (regime + _GOLDEN))
    v = _mix64(v ^ (cycle * _C_CYCLE + _U64(1)))
    v = _mix64(v ^ (idx * _C_IDX + _U64(2)))
    # 53 mantissa bits, offset by half an ulp: never exactly 0 or 1
    return ((v >> _U64(11)).astype(np.float64) + 0.5) * (2.0 ** -53)


def counter_uniforms(
    seed: int,
    task: str,
    stream: int,
    regime,
    cycle,
    idx,
) -> np.ndarray:
    """Open-interval (0, 1) uniforms under the stream contract.

    ``regime``/``cycle``/``idx`` are broadcast integer arrays (or
    scalars); the result has their broadcast shape.  Each element is a
    pure function of ``(seed, task, stream, regime, cycle, idx)`` —
    the reference entry point for the contract (tests pin it;
    :func:`sample_trace` uses the same mixing via per-job key arrays).
    """
    regime, cycle, idx = np.broadcast_arrays(
        np.asarray(regime, dtype=np.uint64),
        np.asarray(cycle, dtype=np.uint64),
        np.asarray(idx, dtype=np.uint64),
    )
    keys = np.full(regime.shape, _task_key(task), dtype=np.uint64)
    return _uniforms_from_keys(seed, stream, keys, regime, cycle, idx)


# ---------------------------------------------------------------------------
# chain sources (moved from the engine so the skeleton can cache them)
# ---------------------------------------------------------------------------
def chain_sources(wf: Workflow, insts) -> Dict[Tuple[str, int], float]:
    """(chain name, sink instance index) -> source sample time, by
    walking each sink's predecessor chain through the unrolled instance
    graph (same units as the instances' releases)."""
    inst_by_key = {(i.task, i.index): i for i in insts}
    release_of = {(i.task, i.index): i.release_s for i in insts}

    def trace(chain, sink_idx: int) -> Optional[int]:
        node_i = len(chain.nodes) - 1
        cur = inst_by_key.get((chain.nodes[node_i], sink_idx))
        while cur is not None and node_i > 0:
            prev = chain.nodes[node_i - 1]
            nxt = None
            for (pt, pj) in cur.preds:
                if pt == prev:
                    nxt = inst_by_key.get((pt, pj))
                    break
            cur = nxt
            node_i -= 1
        return cur.index if cur is not None else None

    out: Dict[Tuple[str, int], float] = {}
    for chain in wf.chains:
        sink = chain.nodes[-1]
        n_sink = sum(1 for i in insts if i.task == sink)
        for k in range(n_sink):
            src_idx = trace(chain, k)
            if src_idx is None:
                continue
            out[(chain.name, k)] = release_of[(chain.nodes[0], src_idx)]
    return out


# ---------------------------------------------------------------------------
# local (one-segment) structure, shared by all full cycles of a regime
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _LocalStructure:
    """Per-segment unroll digested into offset-relocatable arrays."""

    tasks: List[str]
    is_sensor: List[bool]
    release: np.ndarray                 # absolute within the segment
    cycle_idx: List[int]                # TaskInstance.index per position
    deps_remaining: List[int]
    succs_local: List[Tuple[int, ...]]  # local successor positions
    sinks: List[Tuple[str, int, float]]  # (chain, local sink pos, src t)
    n: int


def _local_structure(wf: Workflow, insts, src_of) -> _LocalStructure:
    pos_of = {(i.task, i.index): p for p, i in enumerate(insts)}
    sensors = {n for n, t in wf.tasks.items() if t.is_sensor}
    succ_lists: List[List[int]] = [[] for _ in insts]
    deps = [0] * len(insts)
    for p, inst in enumerate(insts):
        deps[p] = len(inst.preds)
        for pred in inst.preds:
            succ_lists[pos_of[pred]].append(p)
    sink_of = {c.name: c.nodes[-1] for c in wf.chains}
    sinks: List[Tuple[str, int, float]] = []
    for (cname, k), src_t in src_of.items():
        sp = pos_of.get((sink_of[cname], k))
        if sp is not None:
            sinks.append((cname, sp, src_t))
    return _LocalStructure(
        tasks=[i.task for i in insts],
        is_sensor=[i.task in sensors for i in insts],
        release=np.asarray([i.release_s for i in insts], dtype=np.float64),
        cycle_idx=[i.index for i in insts],
        deps_remaining=deps,
        succs_local=[tuple(s) for s in succ_lists],
        sinks=sinks,
        n=len(insts),
    )


# ---------------------------------------------------------------------------
# skeleton
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TraceSkeleton:
    """Schedule- and seed-independent structure of one simulated run.

    Job order and numbering are identical to the engine's historical
    build order (regime-major, cycle-major, unroll order within a
    cycle), so ``jid == array index`` everywhere.  Instances are
    immutable once built — skeletons are shared across Simulators.
    """

    key: tuple
    n: int
    # per-job structure (Python lists for cheap materialization,
    # parallel NumPy arrays for vectorized sampling)
    tasks: List[str]
    cycle: List[int]
    idx: List[int]
    is_sensor: List[bool]
    release_list: List[float]
    drop_at_release: List[bool]
    deps_remaining: List[int]
    succs: List[Tuple[int, ...]]        # absolute jids
    release: np.ndarray
    regime_arr: np.ndarray              # uint64, for the stream contract
    cycle_arr: np.ndarray
    idx_arr: np.ndarray
    task_keys: np.ndarray               # uint64 blake2b task key per job
    dnn_ix: np.ndarray                  # indices of DNN jobs
    sen_ix: np.ndarray                  # indices of sensor jobs
    burst: np.ndarray                   # work multiplier per job (1.0 default)
    mode: List[Optional[str]]           # driving mode at release
    #: (task, mode) -> job index array; the sampling buckets
    buckets: Dict[Tuple[str, Optional[str]], np.ndarray]
    sink_src: Dict[Tuple[str, int], float]
    regimes: List[Tuple[float, float, Workflow]]
    #: model -> (profile token, sampling-parameter arrays) memo
    #: (weakly keyed; see _params_for)
    params_memo: "weakref.WeakKeyDictionary" = dataclasses.field(
        default_factory=lambda: weakref.WeakKeyDictionary(), repr=False
    )


_SKELETON_CACHE: "OrderedDict[tuple, TraceSkeleton]" = OrderedDict()
_SKELETON_CACHE_MAX = 64


def clear_skeleton_cache() -> None:
    """Drop memoized skeletons (test isolation hook)."""
    _SKELETON_CACHE.clear()


def _scenario_token(scenario) -> object:
    if scenario is None:
        return None
    tok = getattr(scenario, "cache_token", None)
    return tok() if callable(tok) else scenario


def build_skeleton(
    wf: Workflow, scenario, duration_s: float
) -> TraceSkeleton:
    """Build (or fetch) the structural skeleton of one run.

    Mirrors the engine's historical ``_build_jobs`` structure exactly:
    piecewise per-rate-regime unrolling, full cycles relocated from one
    segment unroll, truncated seam cycles unrolled separately, and
    within-cycle dependency wiring.
    """
    key = (wf.structural_signature, _scenario_token(scenario), duration_s)
    cached = _SKELETON_CACHE.get(key)
    if cached is not None:
        _SKELETON_CACHE.move_to_end(key)
        metrics.count("skeleton_cache_hit")
        return cached
    with metrics.phase("skeleton_build"):
        skel = _build_skeleton(wf, scenario, duration_s, key)
    _SKELETON_CACHE[key] = skel
    while len(_SKELETON_CACHE) > _SKELETON_CACHE_MAX:
        _SKELETON_CACHE.popitem(last=False)
    return skel


def _build_skeleton(
    wf: Workflow, scenario, duration_s: float, key: tuple
) -> TraceSkeleton:
    """Uncached skeleton construction (see :func:`build_skeleton`)."""
    if scenario is not None and hasattr(scenario, "rate_regimes"):
        regimes = [
            r for r in scenario.rate_regimes(wf, duration_s)
            if r[0] < duration_s - 1e-12
        ]
    else:
        regimes = [(0.0, duration_s, wf)]

    tasks: List[str] = []
    cycle_l: List[int] = []
    idx_l: List[int] = []
    is_sensor: List[bool] = []
    deps: List[int] = []
    succs: List[Tuple[int, ...]] = []
    regime_codes: List[np.ndarray] = []
    cycle_codes: List[np.ndarray] = []
    releases: List[np.ndarray] = []
    sink_src: Dict[Tuple[str, int], float] = {}

    # per-sensor timer anchors (absolute): a rate seam restarts only
    # the *modulated* sensors' hardware timers; an unmodulated sensor
    # keeps its own cadence across the seam.  ``anchors[s]`` is the
    # absolute time sensor s's current grid is anchored at; the phase
    # passed to the unroll is the anchor normalised into the regime
    # start (snapped to 0 within 1e-9 so on-grid seams — every bundled
    # scenario — reproduce the legacy phase-0 unroll bit-for-bit).
    anchors: Dict[str, float] = {}
    prev_periods: Dict[str, float] = {}
    for ri, (r0, r1, wf_r) in enumerate(regimes):
        thp = wf_r.hyper_period_s
        final = ri == len(regimes) - 1
        span = (duration_s - r0) if final else (r1 - r0)
        phases: Dict[str, float] = {}
        for sname, stask in wf_r.tasks.items():
            if not stask.is_sensor:
                continue
            period = stask.period_s
            if prev_periods.get(sname) != period:
                anchors[sname] = r0    # modulated (or first regime): re-anchor
            ph = (anchors[sname] - r0) % period
            if ph < 1e-9 or period - ph < 1e-9:
                ph = 0.0
            if ph:
                phases[sname] = ph
            prev_periods[sname] = period
        # empty mapping -> scalar 0.0: the exact legacy unroll-cache key
        phase_arg = phases if phases else 0.0
        # the - 1e-9 absorbs float accumulation in segment bounds
        # (0.4 + 0.8 > 1.2), which would otherwise add an empty cycle
        n_cycles = max(1, int(math.ceil(span / thp - 1e-9)))
        insts_full = unroll_hyperperiod(
            wf_r, t0=r0, t1=r0 + thp, phase_s=phase_arg
        )
        local_full = _local_structure(wf_r, insts_full, chain_sources(wf_r, insts_full))
        for cycle in range(n_cycles):
            off = cycle * thp
            base = r0 + off
            t1 = base + thp if final else min(base + thp, r1)
            if t1 - base <= 1e-12:
                continue
            if t1 >= base + thp - 1e-12:   # full cycle: relocate
                local = local_full
                rel = local.release + off
                src_off = off
            else:                           # truncated seam cycle
                # the r0-relative phases stay valid at ``base``: thp is
                # a multiple of every sensor period, so the grid offset
                # is congruent modulo each period
                insts = unroll_hyperperiod(
                    wf_r, t0=base, t1=t1, phase_s=phase_arg
                )
                local = _local_structure(wf_r, insts, chain_sources(wf_r, insts))
                rel = local.release
                src_off = 0.0
            base_jid = len(tasks)
            tasks.extend(local.tasks)
            is_sensor.extend(local.is_sensor)
            cycle_l.extend([cycle] * local.n)
            idx_l.extend(local.cycle_idx)
            deps.extend(local.deps_remaining)
            succs.extend(
                tuple(s + base_jid for s in sl) if sl else ()
                for sl in local.succs_local
            )
            releases.append(rel)
            regime_codes.append(np.full(local.n, ri, dtype=np.uint64))
            cycle_codes.append(np.full(local.n, cycle, dtype=np.uint64))
            for cname, sp, src_t in local.sinks:
                sink_src[(cname, base_jid + sp)] = src_t + src_off

    n = len(tasks)
    release = (
        np.concatenate(releases) if releases else np.zeros(0, dtype=np.float64)
    )
    regime_arr = (
        np.concatenate(regime_codes) if regime_codes else np.zeros(0, np.uint64)
    )
    cycle_arr = (
        np.concatenate(cycle_codes) if cycle_codes else np.zeros(0, np.uint64)
    )
    idx_arr = np.asarray(idx_l, dtype=np.uint64)

    # driving mode at release (vectorized mode_at)
    mode: List[Optional[str]]
    if scenario is not None:
        bounds = scenario.boundaries()
        starts = np.asarray([t for t, _m in bounds], dtype=np.float64)
        names = [m for _t, m in bounds]
        seg = np.searchsorted(starts, release, side="right") - 1
        seg = np.clip(seg, 0, len(names) - 1)
        mode = [names[int(s)] for s in seg]
    else:
        mode = [None] * n

    # burst multipliers (work only; sensor entries stay 1 and unused)
    burst = np.ones(n, dtype=np.float64)
    by_task: Dict[str, List[int]] = {}
    for i, t in enumerate(tasks):
        by_task.setdefault(t, []).append(i)
    by_task_arr = {t: np.asarray(ix, dtype=np.intp) for t, ix in by_task.items()}
    if scenario is not None and getattr(scenario, "bursts", ()):
        for b in scenario.bursts:
            for t, ix in by_task_arr.items():
                if is_sensor[ix[0]]:
                    continue
                if b.tasks and t.split("#")[0] not in b.tasks:
                    continue
                r = release[ix]
                m = (r >= b.start_s) & (r < b.start_s + b.duration_s)
                if m.any():
                    burst[ix[m]] *= b.work_scale

    # thermal throttling stretches DNN durations by a deterministic
    # release-time factor, exactly like a burst work multiplier (the
    # draw itself stays on the work stream; docs/degradation.md)
    throttles = getattr(scenario, "throttles", None)
    for th in (throttles() if callable(throttles) else ()):
        for t, ix in by_task_arr.items():
            if is_sensor[ix[0]]:
                continue
            r = release[ix]
            t0, t1 = th.start_s, th.start_s + th.duration_s
            m = (r >= t0) & (r < t1)
            if not m.any():
                continue
            if th.ramp_s > 0.0:
                rise = np.minimum(1.0, (r[m] - t0) / th.ramp_s)
                fall = np.minimum(1.0, (t1 - r[m]) / th.ramp_s)
                f = 1.0 + (th.scale - 1.0) * np.minimum(rise, fall)
            else:
                f = th.scale
            burst[ix[m]] *= f

    # sensor dropout windows
    drop = [False] * n
    if scenario is not None and getattr(scenario, "dropouts", ()):
        for t, ix in by_task_arr.items():
            if not is_sensor[ix[0]]:
                continue
            for i in ix:
                if scenario.dropped(t, float(release[i])):
                    drop[int(i)] = True

    # sampling buckets + per-job stream keys
    buckets: Dict[Tuple[str, Optional[str]], List[int]] = {}
    for i, t in enumerate(tasks):
        buckets.setdefault((t, mode[i]), []).append(i)
    task_keys = np.empty(n, dtype=np.uint64)
    for t, ix in by_task_arr.items():
        task_keys[ix] = _task_key(t)
    sensor_mask = np.asarray(is_sensor, dtype=bool)
    dnn_ix = np.flatnonzero(~sensor_mask)
    sen_ix = np.flatnonzero(sensor_mask)

    skel = TraceSkeleton(
        key=key,
        n=n,
        tasks=tasks,
        cycle=cycle_l,
        idx=idx_l,
        is_sensor=is_sensor,
        release_list=release.tolist(),
        drop_at_release=drop,
        deps_remaining=deps,
        succs=succs,
        release=release,
        regime_arr=regime_arr,
        cycle_arr=cycle_arr,
        idx_arr=idx_arr,
        task_keys=task_keys,
        dnn_ix=dnn_ix,
        sen_ix=sen_ix,
        burst=burst,
        mode=mode,
        buckets={
            k: np.asarray(ix, dtype=np.intp) for k, ix in buckets.items()
        },
        sink_src=sink_src,
        regimes=regimes,
    )
    return skel


# ---------------------------------------------------------------------------
# trace sampling
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Trace:
    """Per-seed sampled randomness, aligned to a skeleton's job order.

    A trace is valid for any Simulator whose (workflow, scenario,
    horizon) matches ``skeleton_key`` *and* whose latency model equals
    the one it was sampled from — the engine verifies the former; the
    caller owns the latter (the scenario runner shares traces only
    across policies of one spec group, which share the model).
    """

    skeleton_key: tuple
    seed: int
    work: np.ndarray        # FLOPs per job (0 for sensors)
    io: np.ndarray          # seconds per job (0 for sensors)
    sensor_lat: np.ndarray  # seconds per job (0 for DNN jobs)
    #: per-job sensor-dropout-storm losses (bool per job, sensors only;
    #: drawn on STREAM_DEGRADE).  None for scenarios without storms —
    #: the common case pays nothing.
    storm_drop: Optional[np.ndarray] = None

    @property
    def n(self) -> int:
        return len(self.work)


def _mode_profiles(model: LatencyModel, scenario):
    if scenario is None:
        return None
    return scenario.profiles_for(model)


@dataclasses.dataclass
class _SampleParams:
    """Per-job distribution parameters flattened to arrays (one entry
    per job; sensor jobs carry the sensor-latency lognormal, DNN jobs
    the work lognormal + I/O shifted exponential)."""

    mean: np.ndarray
    mu: np.ndarray
    sigma: np.ndarray
    io_base: np.ndarray
    io_rate: np.ndarray


def _profile_token(scenario):
    tok = getattr(scenario, "profile_token", None)
    return tok() if callable(tok) else None


def _params_for(skel: TraceSkeleton, model: LatencyModel, scenario) -> _SampleParams:
    """Flatten the (task, mode) profile table into per-job parameter
    arrays, memoized per latency model on the (cached) skeleton — the
    profile lookup work is then paid once per (skeleton, model), not
    once per seed.  The memo also carries the scenario's profile token
    (the mode objects, value-compared): a mode re-registered with
    different profile transforms must not reuse stale parameters even
    though the structural skeleton is rightly still valid."""
    token = _profile_token(scenario)
    hit = skel.params_memo.get(model)
    if hit is not None and hit[0] == token:
        return hit[1]
    n = skel.n
    par = _SampleParams(
        mean=np.zeros(n), mu=np.zeros(n), sigma=np.zeros(n),
        io_base=np.zeros(n), io_rate=np.zeros(n),
    )
    profs = _mode_profiles(model, scenario)
    for (task, mode), ix in skel.buckets.items():
        prof = model.profiles[task] if profs is None else profs[mode][task]
        dist = prof.sensor_latency if prof.is_sensor else prof.work
        par.mean[ix] = dist.mean
        par.mu[ix] = dist.mu
        par.sigma[ix] = dist.sigma
        if not prof.is_sensor:
            par.io_base[ix] = prof.io.base
            par.io_rate[ix] = prof.io.rate
    skel.params_memo[model] = (token, par)
    return par


def _lognormal_from_uniforms(
    u: np.ndarray, mean: np.ndarray, mu: np.ndarray, sigma: np.ndarray
) -> np.ndarray:
    """Inverse-CDF lognormal, matching ``LogNormal.quantiles`` exactly:
    zero for zero-mean, the mean for zero sigma, else exp(mu+sigma z)."""
    with np.errstate(invalid="ignore"):
        vals = np.exp(mu + sigma * ndtri(u))
    return np.where(mean <= 0.0, 0.0, np.where(sigma <= 0.0, mean, vals))


def sample_trace(
    skel: TraceSkeleton,
    model: LatencyModel,
    scenario,
    seed: int,
) -> Trace:
    """Draw every job's randomness as a handful of whole-trace array
    ops: one uniform + inverse-CDF pass per stream (work, I/O, sensor
    latency), with per-job distribution parameters gathered once per
    (skeleton, model).  Uniform inputs follow the counter-based stream
    contract (module docstring) — bit-identical to per-bucket
    :func:`counter_uniforms` calls.
    """
    with metrics.phase("trace_sample"):
        return _sample_trace(skel, model, scenario, seed)


def _sample_trace(
    skel: TraceSkeleton,
    model: LatencyModel,
    scenario,
    seed: int,
) -> Trace:
    n = skel.n
    work = np.zeros(n, dtype=np.float64)
    io = np.zeros(n, dtype=np.float64)
    sensor_lat = np.zeros(n, dtype=np.float64)
    par = _params_for(skel, model, scenario)

    d = skel.dnn_ix
    if d.size:
        keys, reg = skel.task_keys[d], skel.regime_arr[d]
        cyc, idx = skel.cycle_arr[d], skel.idx_arr[d]
        uw = _uniforms_from_keys(seed, STREAM_WORK, keys, reg, cyc, idx)
        ui = _uniforms_from_keys(seed, STREAM_IO, keys, reg, cyc, idx)
        work[d] = _lognormal_from_uniforms(
            uw, par.mean[d], par.mu[d], par.sigma[d]
        ) * skel.burst[d]
        rate = par.io_rate[d]
        safe = np.where(rate > 0.0, rate, 1.0)
        queue = -np.log(np.maximum(1.0 - ui, 1e-300)) / safe
        io[d] = par.io_base[d] + np.where(rate > 0.0, queue, 0.0)

    s = skel.sen_ix
    if s.size:
        keys, reg = skel.task_keys[s], skel.regime_arr[s]
        cyc, idx = skel.cycle_arr[s], skel.idx_arr[s]
        u = _uniforms_from_keys(seed, STREAM_SENSOR, keys, reg, cyc, idx)
        # legacy range: uniform(0.001, 0.999) into the quantile
        sensor_lat[s] = _lognormal_from_uniforms(
            0.001 + 0.998 * u, par.mean[s], par.mu[s], par.sigma[s]
        )
    return Trace(
        skeleton_key=skel.key, seed=seed,
        work=work, io=io, sensor_lat=sensor_lat,
        storm_drop=storm_drops(skel, scenario, seed),
    )


def storm_drops(
    skel: TraceSkeleton, scenario, seed: int
) -> Optional[np.ndarray]:
    """Per-job sensor-dropout-storm verdicts for one seed.

    One uniform per sensor release inside any storm window, drawn on
    ``STREAM_DEGRADE`` — scenarios without storms draw nothing (and
    return ``None``), so their work/io/sensor streams are untouched and
    existing seeds stay bit-reproducible.  Overlapping storms compose
    as independent loss processes (complement product), evaluated at
    the frame's release time.
    """
    storms = getattr(scenario, "storms", None)
    storms = storms() if callable(storms) else ()
    s = skel.sen_ix
    if not storms or not s.size:
        return None
    rel = skel.release[s]
    base = [skel.tasks[int(j)].split("#")[0] for j in s]
    keep = np.ones(s.size, dtype=np.float64)
    for st in storms:
        m = (rel >= st.start_s) & (rel < st.start_s + st.duration_s)
        if st.sensors:
            m &= np.asarray([b in st.sensors for b in base], dtype=bool)
        keep[m] *= 1.0 - st.drop_frac
    frac = 1.0 - keep
    cand = frac > 0.0
    if not cand.any():
        return None
    ix = s[cand]
    u = _uniforms_from_keys(
        seed, STREAM_DEGRADE, skel.task_keys[ix], skel.regime_arr[ix],
        skel.cycle_arr[ix], skel.idx_arr[ix],
    )
    out = np.zeros(skel.n, dtype=bool)
    out[ix] = u < frac[cand]
    return out
