"""Structure-of-arrays Monte-Carlo backend: host orchestration.

This module turns one scenario cell — (workflow, scenario, schedule
portfolio, policy, horizon) — into a *SoA problem*: the set of
lane-independent arrays that :mod:`repro.core.sim.soa_kernels` advances
for **R seeds simultaneously**.  The division of labour:

* **host (here, NumPy)** — job ordering (release-sorted), dependency
  columns into the finish-code array, the discrete round grid
  (seam-aligned, ``SoaOptions.dt_s`` cadence), per-round active job
  windows, per-round EDF permutations, per-segment schedule bindings
  (ERT / sub-deadline / slack-shared target / planned DoP / partition /
  DoP-candidate ladders), hot-swap capacities and staging volumes, and
  — after the kernel returns — assembly of one
  :class:`~repro.core.sim.engine.SimReport` per lane;
* **device (jax)** — everything per-lane: readiness, drops, policy
  quota/EDF decisions, reallocation stalls, tile-second accounting.

Fidelity contract (enforced by ``benchmarks.check_equivalence --mode
distributional`` and ``tests/test_soa.py``): the scalar engine remains
the semantics oracle; this backend reproduces it **distributionally**
(KS on chain-latency distributions, CI agreement on violation rate /
realloc waste / tiles reserved) and **exactly** on structural
invariants (job counts, seam times/spans, chain universe).  The known
approximations — discrete scheduling rounds instead of an event heap,
bounded fixed-point allocation passes instead of the exact sequential
queue walk, current-segment deadline bindings for not-yet-started
straddlers — are documented in ``docs/performance.md#soa-backend``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .engine import ModeStats, SimReport
from .trace import build_skeleton
from . import soa_kernels as K

__all__ = [
    "SoaOptions",
    "SoaUnsupported",
    "SoaWindowOverflow",
    "soa_available",
    "soa_supported",
    "build_problem",
    "run_problem",
    "ks_statistic",
    "mean_ci",
    "intervals_overlap",
    "structural_invariants",
]

_TOL = 1e-9


def soa_available() -> bool:
    """True when jax is importable (the backend's only extra dep)."""
    return K.HAS_JAX


class SoaUnsupported(ValueError):
    """The requested cell is outside the SoA backend's support set."""


class SoaWindowOverflow(SoaUnsupported):
    """A job slid out of the sliding job window still unresolved.

    The window lifetime bound assumes every job resolves within its E2E
    deadline plus the drop-cascade slack; under ``drop_policy="soft"``
    (the runner's default) an overloaded cell legally queues/runs jobs
    past their E2E deadline, and a job that exits the window while
    still PEND/READY/RUN would silently freeze — counted as a miss with
    all its successors starved.  :func:`run_problem` detects this on
    the final state planes and raises instead of returning truncated
    results; callers either widen :attr:`SoaOptions.life_pad_s` (the
    runner's SoA path (``run(spec, seeds=..., backend="soa")``)
    retries with a doubled window automatically) or fall back to the
    scalar/lockstep engines.
    """


def soa_supported(
    policy: str,
    replan_mode: str = "reactive",
    detection_delay_s: float = 0.0,
    drop_policy: str = "soft",
    record: bool = False,
) -> bool:
    """Support predicate mirroring ``batch.fast_lane_supported``'s role:
    the SoA kernels cover the three paper policies (+ elastic cyc) with
    reactive zero-delay replanning under both drop policies; anything
    else (predictive replanning, recorders) must run on the scalar or
    lockstep engines."""
    return (
        policy in K.POLICY_IDS
        and replan_mode == "reactive"
        and abs(detection_delay_s) < _TOL
        and drop_policy in ("soft", "hard")
        and not record
    )


def _drop_mode(policy_name: str, drop_policy: str) -> int:
    """Map (policy, drop_policy) onto the kernel's drop regime.  cyc
    terminates budget overruns at the sub-deadline unconditionally; the
    elastic/tp/ads policies only arm e2e dequeue timers under
    ``drop_policy="hard"`` (the scenario runner defaults to soft)."""
    if policy_name == "cyc":
        return 1
    return 2 if drop_policy == "hard" else 0


@dataclasses.dataclass(frozen=True)
class SoaOptions:
    """Tuning knobs of the discrete-round approximation.

    ``dt_s`` is the scheduling-round cadence: smaller tracks the scalar
    engine's event cadence more closely (the bundled workloads see
    ~one scheduling event per partition per 2-4 ms), larger is faster.
    Event *times* are exact regardless (backdated); dt only quantizes
    when decisions are taken.
    """

    dt_s: float = 1e-3
    window_round: int = 16      # round the job window up to a multiple
    #: extra seconds added to the job-window lifetime bound (how long a
    #: job may stay unresolved past its release before it slides out of
    #: the window).  The default bound assumes jobs resolve by their
    #: E2E deadline; under ``drop_policy="soft"`` overload queues jobs
    #: past it — :class:`SoaWindowOverflow` reports when the bound was
    #: too tight and the runner retries with a doubled window.  The
    #: effective lifetime is capped at the horizon (full coverage).
    life_pad_s: float = 0.0
    #: EDF fixed-point refinement steps; None resolves per policy —
    #: tp_driven's event walk needs the exact sequential fixed point
    #: (8), cyc/ads converge by 3 (measured KS-identical vs 8)
    alloc_iters: Optional[int] = None
    bump_passes: int = 8        # tp work-conserving refinement steps
    use_pallas: bool = False    # route the grant select through Pallas
    pallas_interpret: bool = True


@dataclasses.dataclass
class SoaProblem:
    """One compiled-shape scenario cell plus report-assembly side data."""

    cfg: K.KernelConfig
    const: Dict[str, np.ndarray]
    # job-axis mapping
    jids: np.ndarray            # soa pos -> global skeleton jid (real jobs)
    n_real: int
    n_pad: int
    sen_jids: np.ndarray
    sen_release: np.ndarray
    sen_drop: np.ndarray
    # report side data
    duration: float
    num_tiles: int
    considered: np.ndarray      # (n_pad,) bool
    e2e_host: np.ndarray        # (n_pad,) float64 exact
    sinks: List[Tuple[str, int, float, float, str]]  # (chain, pos, t0, ddl, mode)
    chain_names: List[str]
    expected: Dict[str, int]
    expected_mode: Dict[str, Dict[str, int]]
    mode_order: List[str]
    seg_mode: List[str]
    seg_span: List[Tuple[float, float]]
    spans: Dict[str, float]
    n_mode_switches: int
    tiles_used: int
    tiles_reserved_mean: float
    frontier_meta: Dict[str, object]
    skeleton_key: tuple
    life: float                 # job-window lifetime bound (seconds)
    win_lo_final: int           # highest window lower bound over rounds


def _policy_knobs(policy) -> Tuple[bool, bool, bool, float]:
    """(admission, quota_control, slack_sharing, realloc_gate) of a
    policy *instance* (ads ablation flags ride into the kernel config)."""
    return (
        bool(getattr(policy, "admission", True)),
        bool(getattr(policy, "quota_control", True)),
        bool(getattr(policy, "slack_sharing", True)),
        float(getattr(policy, "realloc_gate", 1.0)),
    )


def _downstream_budget(wf, sched) -> Dict[str, float]:
    """ads slack sharing: tightest downstream budget per task under one
    table (AdsTilePolicy.setup's ``_down``)."""
    down: Dict[str, float] = {}
    for t, task in wf.tasks.items():
        if task.is_sensor:
            continue
        tight = math.inf
        for chain in wf.chain_for(t):
            i = chain.nodes.index(t)
            after = [
                n for n in chain.nodes[i + 1:] if not wf.tasks[n].is_sensor
            ]
            tight = min(tight, sum(sched.plans[n].budget_s for n in after))
        down[t] = 0.0 if tight is math.inf else tight
    return down


def _candidate_table(wf, sched, policy_name) -> Dict[str, Tuple[int, ...]]:
    """Per-task DoP ladders as the policy instance would resolve them:
    ads follows an autotuned table's compiled candidate set
    (``meta["task_dop_candidates"]``), tp always uses the workload
    ladder, cyc only ever uses the planned DoP."""
    src = sched.meta.get("task_dop_candidates") if policy_name == "ads_tile" else None
    out = {}
    for name, t in wf.tasks.items():
        if t.is_sensor:
            continue
        if src is not None:
            out[name] = tuple(src.get(name, t.dop_candidates()))
        else:
            out[name] = t.dop_candidates()
    return out


def _segments(scenario, duration, schedule0, portfolio, replan):
    """Scenario boundary spans clipped to the horizon, each carrying the
    schedule table active during it and whether its entry performs a
    hot-swap (mirrors the reactive replanner: swap only when the
    portfolio's table for the new mode differs from the active one)."""
    bounds = list(scenario.boundaries())
    segs = []
    active = schedule0
    for i, (t, m) in enumerate(bounds):
        if t >= duration - _TOL and i > 0:
            break
        t_end = bounds[i + 1][0] if i + 1 < len(bounds) else max(duration, t)
        t_end = min(t_end, duration)
        swap = False
        if i > 0 and replan and portfolio is not None:
            tbl = portfolio.get(m)
            if tbl is not None and tbl is not active:
                active = tbl
                swap = True
        segs.append((max(0.0, t), t_end, m, active, swap))
    return segs


def _plan_deltas_staged(wf, old, new, P) -> np.ndarray:
    """Hot-swap stage-in volume per *target* partition (engine
    ``_plan_deltas``): full checkpoint x dop on a partition move, the
    L2P minimal checkpoint x |dop delta| on a DoP change in place."""
    staged = np.zeros(P, dtype=np.float64)
    for task, np_plan in new.plans.items():
        op = old.plans.get(task)
        if op is None:
            continue
        ckpt = wf.tasks[task].checkpoint_bytes
        if np_plan.partition != op.partition:
            staged[np_plan.partition] += ckpt * np_plan.dop
        elif np_plan.dop != op.dop:
            staged[np_plan.partition] += ckpt * abs(np_plan.dop - op.dop)
    return staged


def build_problem(
    wf,
    model,
    schedule0,
    portfolio,
    policy,
    scenario,
    duration: float,
    replan: bool = True,
    n_lanes: int = 8,
    drop_policy: str = "soft",
    options: Optional[SoaOptions] = None,
) -> SoaProblem:
    """Precompute one scenario cell's lane-independent arrays.

    ``policy`` may be a policy instance (ads ablation flags are read
    off it) or a policy name string.
    """
    opt = options or SoaOptions()
    hw = model.hw
    policy_name = policy if isinstance(policy, str) else policy.name
    if policy_name not in K.POLICY_IDS:
        raise SoaUnsupported(f"policy {policy_name!r} not supported by soa")
    admission, quota_control, slack_sharing, gate = (
        (True, True, True, 1.0)
        if isinstance(policy, str)
        else _policy_knobs(policy)
    )
    if getattr(policy, "drop_on_subddl", False):
        raise SoaUnsupported("tp_driven drop_on_subddl is scalar-only")

    skel = build_skeleton(wf, scenario, duration)
    rel_all = np.asarray(skel.release, dtype=np.float64)
    dnn = np.asarray(skel.dnn_ix, dtype=np.int64)
    sen = np.asarray(skel.sen_ix, dtype=np.int64)

    order = np.lexsort((dnn, rel_all[dnn]))
    jids = dnn[order]
    n_real = len(jids)
    rel = rel_all[jids]

    tasks_pos = [skel.tasks[j] for j in jids]
    task_names = sorted({t for t in tasks_pos})
    tid = {t: i for i, t in enumerate(task_names)}
    task_idx = np.array([tid[t] for t in tasks_pos], dtype=np.int64)

    ddl_off = np.array(
        [wf.deadline_offset(t) for t in task_names], dtype=np.float64
    )
    e2e = rel + ddl_off[task_idx]
    if not np.all(np.isfinite(e2e)):
        raise SoaUnsupported(
            "DNN task without a finite E2E deadline (unbounded job "
            "lifetime breaks the windowed job axis)"
        )
    sync_t = np.array(
        [model.profiles[t].sync_per_tile_s for t in task_names],
        dtype=np.float64,
    )
    ckpt_t = np.array(
        [wf.tasks[t].checkpoint_bytes for t in task_names], dtype=np.float64
    )

    # ---- segments, tables, partitions --------------------------------
    segs = _segments(scenario, duration, schedule0, portfolio, replan)
    S = len(segs)
    tables = [s[3] for s in segs]
    P = max(
        max((pp.index for pp in tbl.partitions), default=0) + 1
        for tbl in tables
    )

    # ---- round grid ---------------------------------------------------
    dt = float(opt.dt_s)
    t0s, t1s, seg_ix, entry = [], [], [], []
    for s, (a, b, _m, _tbl, _sw) in enumerate(segs):
        n = max(1, int(math.ceil((b - a) / dt - 1e-9)))
        edges = a + (b - a) * np.arange(n + 1) / n
        for k in range(n):
            t0s.append(edges[k])
            t1s.append(edges[k + 1])
            seg_ix.append(s)
            entry.append(k == 0)
    t0s = np.asarray(t0s)
    t1s = np.asarray(t1s)
    n_rounds = len(t0s)

    # ---- job windows --------------------------------------------------
    # lifetime bound: jobs normally resolve by their E2E deadline (plus
    # one dependency hop per round for the drop cascade).  Under
    # drop_mode 0 overload legally queues jobs past the E2E deadline:
    # ``life_pad_s`` widens the bound, the cap at the horizon makes a
    # wide-enough retry always possible, and run_problem's post-check
    # raises SoaWindowOverflow if the bound still proved too tight
    # (never silently truncates).
    max_hops = max((len(c.nodes) for c in wf.chains), default=4)
    cascade = (max_hops + 4) * dt
    life = (
        float(np.max(ddl_off[np.isfinite(ddl_off)]))
        + cascade
        + float(opt.life_pad_s)
    )
    life = min(max(life, 2 * dt), duration + cascade)
    lo = np.searchsorted(rel, t1s - life, side="left")
    hi = np.searchsorted(rel, t1s, side="right")
    wr = int(opt.window_round)
    W = int(max(8, ((int(np.max(hi - lo)) + wr - 1) // wr) * wr))
    lo = np.minimum(lo, np.maximum(hi - W, 0)).astype(np.int32)
    n_pad = int(max(n_real, int(np.max(lo)) + W))

    def padf(a, fill):
        out = np.full(n_pad, fill, dtype=np.float64)
        out[:n_real] = a
        return out

    rel_p = padf(rel, np.inf)
    e2e_p = padf(e2e, np.inf)
    sync_p = padf(sync_t[task_idx], 0.0)
    ckpt_p = padf(ckpt_t[task_idx], 0.0)

    # ---- finish-code columns (jobs, then sensors, then dummy) --------
    n_sen = len(sen)
    A1 = n_pad + n_sen + 1
    col_of = np.full(int(max(rel_all.shape[0], 1)), A1 - 1, dtype=np.int64)
    col_of[jids] = np.arange(n_real)
    col_of[sen] = n_pad + np.arange(n_sen)

    # predecessors from the skeleton's successor lists
    preds_l: List[List[int]] = [[] for _ in range(n_real)]
    pos_of = np.full_like(col_of, -1)
    pos_of[jids] = np.arange(n_real)
    for j, succs in enumerate(skel.succs):
        for sjid in succs:
            p = pos_of[sjid]
            if p >= 0:
                preds_l[p].append(int(col_of[j]))
    PM = max(1, max((len(p) for p in preds_l), default=1))
    preds = np.full((n_pad, PM), A1 - 1, dtype=np.int32)
    for p, lst in enumerate(preds_l):
        preds[p, : len(lst)] = lst

    # ---- per-segment schedule bindings --------------------------------
    cand_tbl = [_candidate_table(wf, tbl, policy_name) for tbl in tables]
    C = max(
        1, max(len(c) for ct in cand_tbl for c in ct.values())
    ) if policy_name in ("tp_driven", "ads_tile") else 1

    T = len(task_names)
    ert = np.full((S, n_pad), np.inf, dtype=np.float64)
    sub = np.full((S, n_pad), np.inf, dtype=np.float64)
    tgt = np.full((S, n_pad), np.inf, dtype=np.float64)
    pdop = np.ones((S, n_pad), dtype=np.float64)
    part = np.zeros((S, n_pad), dtype=np.float64)
    cands = np.ones((S, n_pad, C), dtype=np.float64)
    caps = np.zeros((S, P), dtype=np.float64)
    hops = np.ones((S, P), dtype=np.float64)
    staged = np.zeros((S, P), dtype=np.float64)
    swap = np.zeros(S, dtype=bool)

    for s, (a, b, m, tbl, sw) in enumerate(segs):
        ert_o = np.zeros(T)
        sub_o = np.zeros(T)
        dop_o = np.ones(T)
        par_o = np.zeros(T)
        dwn_o = np.zeros(T)
        cnd_o = np.ones((T, C))
        down = _downstream_budget(wf, tbl) if policy_name == "ads_tile" else {}
        for t, i in tid.items():
            plan = tbl.plans[t]
            ert_o[i] = plan.ert_s
            sub_o[i] = plan.subdeadline_s
            dop_o[i] = plan.dop
            par_o[i] = plan.partition
            dwn_o[i] = down.get(t, 0.0)
            if C > 1 or policy_name in ("tp_driven", "ads_tile"):
                ladder = cand_tbl[s][t]
                cnd_o[i, : len(ladder)] = ladder
                cnd_o[i, len(ladder):] = ladder[-1]
        ert[s, :n_real] = rel + ert_o[task_idx]
        sub[s, :n_real] = rel + sub_o[task_idx]
        if policy_name == "ads_tile" and slack_sharing:
            tgt[s, :n_real] = np.maximum(sub[s, :n_real], e2e - dwn_o[task_idx])
        else:
            tgt[s, :n_real] = sub[s, :n_real]
        pdop[s, :n_real] = dop_o[task_idx]
        part[s, :n_real] = par_o[task_idx]
        cands[s, :n_real, :] = cnd_o[task_idx]
        for pp in tbl.partitions:
            caps[s, pp.index] = pp.capacity
            hops[s, pp.index] = hw.avg_hops_to_mc(max(pp.capacity, 1))
        if sw:
            swap[s] = True
            staged[s] = _plan_deltas_staged(wf, tables[s - 1], tbl, P)

    # ---- per-round EDF permutations -----------------------------------
    perm = np.zeros((n_rounds, W), dtype=np.int32)
    iperm = np.zeros((n_rounds, W), dtype=np.int32)
    arangeW = np.arange(W)
    for r in range(n_rounds):
        if policy_name in ("cyc", "cyc_s"):
            key = ert[seg_ix[r], lo[r]: lo[r] + W]
            key2 = sub[seg_ix[r], lo[r]: lo[r] + W]
            o = np.lexsort((arangeW, key2, key))
        else:
            key = sub[seg_ix[r], lo[r]: lo[r] + W]
            o = np.lexsort((arangeW, key))
        perm[r] = o
        iperm[r][o] = arangeW

    f4 = np.float32
    const = {
        "release": rel_p.astype(f4),
        "e2e": e2e_p.astype(f4),
        "sync": sync_p.astype(f4),
        "ckpt": ckpt_p.astype(f4),
        "preds": preds,
        "ert": ert.astype(f4),
        "sub": sub.astype(f4),
        "tgt": tgt.astype(f4),
        "pdop": pdop.astype(f4),
        "part": part.astype(f4),
        "cands": cands.astype(f4),
        "caps": caps.astype(f4),
        "hops": hops.astype(f4),
        "staged": staged.astype(f4),
        "swap": swap,
        "t0": t0s.astype(f4),
        "t1": t1s.astype(f4),
        "seg": np.asarray(seg_ix, dtype=np.int32),
        "lo": lo.astype(np.int32),
        "entry": np.asarray(entry, dtype=bool),
        "perm": perm,
        "iperm": iperm,
    }

    cfg = K.KernelConfig(
        policy=K.POLICY_IDS[policy_name],
        R=int(n_lanes),
        W=W,
        C=C,
        PM=PM,
        P=P,
        tile_flops=float(hw.tile_flops),
        fixed_s=float(hw.realloc.fixed_s),
        decision_s=float(hw.realloc.decision_s),
        per_hop_s=float(hw.realloc.per_hop_s),
        inv_bw=float(1.0 / hw.realloc.migration_bw),
        realloc_gate=gate,
        admission=admission,
        quota_control=quota_control,
        drop_mode=_drop_mode(policy_name, drop_policy),
        alloc_iters=int(
            opt.alloc_iters
            if opt.alloc_iters is not None
            else (8 if policy_name == "tp_driven" else 3)
        ),
        bump_passes=int(opt.bump_passes),
        use_pallas=bool(opt.use_pallas and K.HAS_PALLAS),
        pallas_interpret=bool(opt.pallas_interpret),
    )

    # ---- report-assembly side data ------------------------------------
    considered = np.zeros(n_pad, dtype=bool)
    # strict comparisons to mirror the scalar report exactly: float64
    # release/deadline arithmetic lands on the same values in both
    # backends, so a tolerance here would only *dis*agree at boundaries
    # (e.g. 1.9 + 0.1 > 2.0 in binary64)
    considered[:n_real] = (rel <= duration) & (e2e <= duration)

    chain_ddl = {c.name: c.deadline_s for c in wf.chains}
    sinks = []
    for (cname, jid), t0 in skel.sink_src.items():
        p = int(pos_of[jid]) if jid < len(pos_of) else -1
        if p < 0:
            continue
        sinks.append(
            (cname, p, float(t0), float(chain_ddl[cname]), scenario.mode_at(t0))
        )
    sinks.sort(key=lambda x: x[2])
    expected: Dict[str, int] = {c.name: 0 for c in wf.chains}
    expected_mode: Dict[str, Dict[str, int]] = {c.name: {} for c in wf.chains}
    for cname, _p, t0, ddl, m in sinks:
        if t0 + ddl <= duration:
            expected[cname] += 1
            em = expected_mode[cname]
            em[m] = em.get(m, 0) + 1

    bounds = list(scenario.boundaries())
    ends = [t for t, _m in bounds[1:]]
    ends.append(max(duration, bounds[-1][0]))
    spans: Dict[str, float] = {}
    for (bt0, m), bt1 in zip(bounds, ends):
        spans[m] = spans.get(m, 0.0) + max(
            0.0, min(bt1, duration) - min(bt0, duration)
        )
    n_switch = sum(1 for t, _m in bounds[1:] if t <= duration + _TOL)

    reserved = sum((b - a) * tbl.peak_tiles for a, b, _m, tbl, _sw in segs)
    tiles_used = max(tbl.peak_tiles for tbl in [schedule0] + tables)

    return SoaProblem(
        cfg=cfg,
        const=const,
        jids=jids,
        n_real=n_real,
        n_pad=n_pad,
        sen_jids=sen,
        sen_release=rel_all[sen],
        sen_drop=np.array(
            [skel.drop_at_release[j] for j in sen], dtype=bool
        ),
        duration=float(duration),
        num_tiles=int(hw.num_tiles),
        considered=considered,
        e2e_host=e2e_p,
        sinks=sinks,
        chain_names=[c.name for c in wf.chains],
        expected=expected,
        expected_mode=expected_mode,
        mode_order=[m for m in scenario.modes()],
        seg_mode=[m for _a, _b, m, _t, _s in segs],
        seg_span=[(a, b) for a, b, _m, _t, _s in segs],
        spans=spans,
        n_mode_switches=n_switch,
        tiles_used=int(tiles_used),
        tiles_reserved_mean=float(reserved / duration),
        frontier_meta=dict(schedule0.meta.get("autotune") or {}),
        skeleton_key=skel.key,
        life=float(life),
        win_lo_final=int(lo.max()) if n_rounds else 0,
    )


# ---------------------------------------------------------------------------
# lane data + execution
# ---------------------------------------------------------------------------
def _lanes(problem: SoaProblem, btrace) -> Dict[str, np.ndarray]:
    R = len(btrace.seeds)
    f4 = np.float32
    work = np.zeros((R, problem.n_pad), dtype=f4)
    io = np.zeros((R, problem.n_pad), dtype=f4)
    work[:, : problem.n_real] = btrace.work[:, problem.jids]
    io[:, : problem.n_real] = btrace.io[:, problem.jids]

    n_sen = len(problem.sen_jids)
    A1 = problem.n_pad + n_sen + 1
    codes0 = np.full((R, A1), np.inf, dtype=f4)
    codes0[:, A1 - 1] = 0.0
    lat = btrace.sensor_lat[:, problem.sen_jids]
    fin = problem.sen_release[None, :] + lat
    codes0[:, problem.n_pad: A1 - 1] = np.where(
        problem.sen_drop[None, :],
        -problem.sen_release[None, :] - 1.0,
        fin,
    )
    return {"work": work, "io": io, "codes0": codes0}


def run_problem(
    problem: SoaProblem, btrace, seeds: Sequence[int]
) -> List[SimReport]:
    """Advance all lanes through the compiled round loop and assemble
    one scalar-shaped :class:`SimReport` per seed."""
    if not K.HAS_JAX:
        raise SoaUnsupported("jax is not available; use backend='lockstep'")
    if problem.cfg.R != len(seeds):
        raise ValueError(
            f"problem compiled for R={problem.cfg.R}, got {len(seeds)} seeds"
        )
    out = K.simulate(problem.cfg, problem.const, _lanes(problem, btrace))
    # jobs below the final window lower bound had their window close
    # before the horizon end; any still unresolved there froze mid-queue
    # (overload past the lifetime bound) and the lane's report would
    # silently miscount it as a miss and starve its successors
    cut = min(problem.win_lo_final, problem.n_real)
    if cut > 0:
        stuck = out["state"][:, :cut] < K.DONE
        if np.any(stuck):
            n_lanes = int(np.sum(np.any(stuck, axis=1)))
            n_jobs = int(np.max(np.sum(stuck, axis=1)))
            raise SoaWindowOverflow(
                f"up to {n_jobs} job(s) per lane slid out of the "
                f"{problem.life:.3f}s SoA job window unresolved "
                f"({n_lanes}/{problem.cfg.R} lanes affected): the cell "
                "queues jobs past the E2E-deadline lifetime bound "
                "(overload under drop_policy='soft').  Widen "
                "SoaOptions.life_pad_s (the runner's SoA path retries with a "
                "doubled window automatically) or use the scalar/"
                "lockstep backend for this cell."
            )
    return _assemble_reports(problem, out)


def _assemble_reports(problem: SoaProblem, out: Dict[str, np.ndarray]):
    R = problem.cfg.R
    dur = problem.duration
    total = problem.num_tiles * dur
    cons = problem.considered
    n_jobs = int(np.sum(cons))
    state = out["state"]
    fin = out["fin"].astype(np.float64)
    deg = out["deg"] > 0.5

    dropped = (state == K.DROP) & cons[None, :]
    late = (state == K.DONE) & cons[None, :] & (fin > problem.e2e_host[None, :] + 1e-6)
    unfinished = (state < K.DONE) & cons[None, :]
    n_dropped = dropped.sum(axis=1)
    n_miss = n_dropped + late.sum(axis=1) + unfinished.sum(axis=1)

    # per-sink vectors across lanes
    sink_pos = np.array([p for _c, p, _t, _d, _m in problem.sinks], dtype=np.int64)
    sink_t0 = np.array([t for _c, _p, t, _d, _m in problem.sinks])
    sink_ddl = np.array([d for _c, _p, _t, d, _m in problem.sinks])
    st_s = state[:, sink_pos] if len(sink_pos) else np.zeros((R, 0))
    fin_s = fin[:, sink_pos] if len(sink_pos) else np.zeros((R, 0))
    deg_s = deg[:, sink_pos] if len(sink_pos) else np.zeros((R, 0), bool)
    lat_s = fin_s - sink_t0[None, :]
    done_s = st_s == K.DONE
    drop_s = st_s == K.DROP
    viol_s = done_s & ((lat_s > sink_ddl[None, :] + 1e-9) | deg_s)

    seg_mode = problem.seg_mode
    busy_seg = out["busy"]
    rel_seg = out["realloc"]
    busy_tot = busy_seg.sum(axis=1)
    rel_tot = rel_seg.sum(axis=1)
    mode_busy: Dict[str, np.ndarray] = {}
    mode_rel: Dict[str, np.ndarray] = {}
    for s, m in enumerate(seg_mode):
        mode_busy[m] = mode_busy.get(m, 0.0) + busy_seg[:, s]
        mode_rel[m] = mode_rel.get(m, 0.0) + rel_seg[:, s]

    reports: List[SimReport] = []
    for k in range(R):
        chain_count = {c: 0 for c in problem.chain_names}
        chain_viol = {c: 0 for c in problem.chain_names}
        chain_lats: Dict[str, List[float]] = {c: [] for c in problem.chain_names}
        sink_by_mode: Dict[Tuple[str, str], List[int]] = {}
        mode_lats: Dict[str, List[float]] = {}
        for i, (cname, _p, t0, _ddl, m) in enumerate(problem.sinks):
            if done_s[k, i]:
                chain_count[cname] += 1
                chain_viol[cname] += int(viol_s[k, i])
                chain_lats[cname].append(float(lat_s[k, i]))
                rec = sink_by_mode.setdefault((cname, m), [0, 0])
                rec[0] += 1
                rec[1] += int(viol_s[k, i])
                mode_lats.setdefault(m, []).append(float(lat_s[k, i]))
            elif drop_s[k, i]:
                chain_count[cname] += 1
                chain_viol[cname] += 1
                rec = sink_by_mode.setdefault((cname, m), [0, 0])
                rec[0] += 1
                rec[1] += 1

        # starvation deficits, reconciled chronologically per mode
        for cname in problem.chain_names:
            deficit = max(0, problem.expected[cname] - chain_count[cname])
            if not deficit:
                continue
            chain_viol[cname] += deficit
            chain_count[cname] = problem.expected[cname]
            em = problem.expected_mode[cname]
            for m in problem.mode_order:
                if m not in em:
                    continue
                rec = sink_by_mode.setdefault((cname, m), [0, 0])
                take = min(max(0, em[m] - rec[0]), deficit)
                if take:
                    rec[0] += take
                    rec[1] += take
                    deficit -= take
                if not deficit:
                    break

        p99 = {
            c: (float(np.percentile(ls, 99)) if ls else float("nan"))
            for c, ls in chain_lats.items()
        }
        mode_stats: Dict[str, ModeStats] = {}
        for m, span in problem.spans.items():
            done_m = sum(
                rec[0] for (_c, mm), rec in sink_by_mode.items() if mm == m
            )
            viol_m = sum(
                rec[1] for (_c, mm), rec in sink_by_mode.items() if mm == m
            )
            lats = mode_lats.get(m, [])
            denom = problem.num_tiles * span
            mb = float(np.asarray(mode_busy.get(m, 0.0))[k]) if m in mode_busy else 0.0
            mr = float(np.asarray(mode_rel.get(m, 0.0))[k]) if m in mode_rel else 0.0
            mode_stats[m] = ModeStats(
                mode=m,
                span_s=span,
                n_completed=done_m,
                n_violations=viol_m,
                p99_s=(
                    float(np.percentile(np.asarray(lats), 99))
                    if lats else float("nan")
                ),
                effective_frac=mb / denom if denom > 0 else 0.0,
                realloc_frac=mr / denom if denom > 0 else 0.0,
            )

        busy = float(busy_tot[k])
        rel_ts = float(rel_tot[k])
        reports.append(SimReport(
            duration_s=dur,
            total_tiles=problem.num_tiles,
            effective_frac=busy / total,
            realloc_frac=rel_ts / total,
            idle_frac=max(0.0, 1.0 - (busy + rel_ts) / total),
            dropped_work_frac=float(out["dropped_work"][k]) / total,
            n_realloc=int(round(float(out["n_realloc"][k]))),
            realloc_bytes=float(out["realloc_bytes"][k]),
            n_jobs=n_jobs,
            n_dropped=int(n_dropped[k]),
            task_miss_rate=float(n_miss[k]) / max(n_jobs, 1),
            chain_count=chain_count,
            chain_violations=chain_viol,
            chain_p99_s=p99,
            chain_latencies=chain_lats,
            decision_ratios=[],
            mode_stats=mode_stats,
            n_mode_switches=problem.n_mode_switches,
            forecast=None,
            tiles_used=problem.tiles_used,
            tiles_reserved_mean=problem.tiles_reserved_mean,
            frontier_meta=dict(problem.frontier_meta),
        ))
    return reports


# ---------------------------------------------------------------------------
# distributional-equivalence machinery
# ---------------------------------------------------------------------------
def ks_statistic(a: Sequence[float], b: Sequence[float]) -> float:
    """Two-sample Kolmogorov-Smirnov statistic (sup ECDF distance)."""
    a = np.sort(np.asarray(a, dtype=np.float64))
    b = np.sort(np.asarray(b, dtype=np.float64))
    if len(a) == 0 or len(b) == 0:
        return 0.0 if len(a) == len(b) else 1.0
    pool = np.concatenate([a, b])
    ca = np.searchsorted(a, pool, side="right") / len(a)
    cb = np.searchsorted(b, pool, side="right") / len(b)
    return float(np.max(np.abs(ca - cb)))


def mean_ci(xs: Sequence[float], z: float = 1.96) -> Tuple[float, float]:
    """Normal-approximation confidence interval of the mean."""
    x = np.asarray(xs, dtype=np.float64)
    m = float(np.mean(x))
    if len(x) < 2:
        return m, m
    half = z * float(np.std(x, ddof=1)) / math.sqrt(len(x))
    return m - half, m + half


def intervals_overlap(
    a: Tuple[float, float], b: Tuple[float, float], pad: float = 0.0
) -> bool:
    return a[0] - pad <= b[1] and b[0] - pad <= a[1]


def structural_invariants(report: SimReport) -> Dict[str, object]:
    """The exactly-matched facts of a run: job universe, seam structure,
    chain universe and reservation footprint.  Both engines must agree
    on these bit-for-bit (they are schedule/skeleton facts, not
    sampling outcomes)."""
    return {
        "n_jobs": report.n_jobs,
        "n_mode_switches": report.n_mode_switches,
        "chains": tuple(sorted(report.chain_count)),
        "mode_spans": tuple(
            sorted((m, round(s.span_s, 9)) for m, s in report.mode_stats.items())
        ),
        "total_tiles": report.total_tiles,
        "tiles_used": report.tiles_used,
        "tiles_reserved_mean": round(report.tiles_reserved_mean, 6),
        "duration_s": report.duration_s,
    }
