"""Tile-stream — the event-driven system simulator (paper §V-A).

Models streaming data from periodic sensors, DAG-driven task activation,
scheduler decisions and stop-migrate-restart reallocation stalls at
microsecond granularity; reports per-task progress, resource-occupancy
decomposition (idle / effective / realloc waste) and E2E latency
distributions under the F1/F2 variation factors.
"""
from .engine import (
    ForecastStats,
    Job,
    JobState,
    ModeStats,
    Simulator,
    SimConfig,
    SimReport,
)
from .policy import Policy
from .soa import (
    SoaOptions,
    SoaUnsupported,
    SoaWindowOverflow,
    soa_available,
    soa_supported,
)
from .trace import Trace, build_skeleton, counter_uniforms, sample_trace

__all__ = [
    "ForecastStats",
    "Job",
    "JobState",
    "ModeStats",
    "Simulator",
    "SimConfig",
    "SimReport",
    "Policy",
    "SoaOptions",
    "SoaUnsupported",
    "SoaWindowOverflow",
    "soa_available",
    "soa_supported",
    "Trace",
    "build_skeleton",
    "counter_uniforms",
    "sample_trace",
]
