"""Logical-to-physical (L2P) tile mapping within a partition
(paper §IV-D3, inspired by AuRORA [30]).

Decouples a task's logical tiles from physical tiles so the runtime can
remap flexibly; on rescheduling the new placement maximises overlap
with the previous one, so only ``|c_new - c_old|`` tiles' worth of
state moves — the migration-volume model the engine charges.
"""
from __future__ import annotations

from typing import Dict, List, Set

__all__ = ["L2PMap"]


class L2PMap:
    """Physical-tile bookkeeping for one partition."""

    def __init__(self, num_tiles: int):
        self.num_tiles = num_tiles
        self.owner: List[int] = [-1] * num_tiles  # -1 = free
        self.holdings: Dict[int, Set[int]] = {}

    def free_tiles(self) -> List[int]:
        return [i for i, o in enumerate(self.owner) if o < 0]

    def allocate(self, jid: int, count: int) -> Set[int]:
        """(Re)allocate ``count`` physical tiles to job ``jid``,
        maximising overlap with its previous holding.  Returns the new
        tile set; raises if the partition lacks capacity."""
        prev = self.holdings.get(jid, set())
        keep = set(list(prev)[:count]) if len(prev) >= count else set(prev)
        need = count - len(keep)
        pool = [i for i in self.free_tiles() if i not in keep]
        if need > len(pool):
            raise ValueError(
                f"partition out of tiles: need {need}, free {len(pool)}"
            )
        new = keep | set(pool[:need])
        for t in prev - new:
            self.owner[t] = -1
        for t in new:
            self.owner[t] = jid
        if new:
            self.holdings[jid] = new
        else:
            self.holdings.pop(jid, None)
        return new

    def release(self, jid: int) -> None:
        for t in self.holdings.pop(jid, set()):
            self.owner[t] = -1

    def moved_tiles(self, jid: int, new_count: int) -> int:
        """Number of tile-states that must migrate for a resize —
        |c_new - c_old| under maximal-overlap placement."""
        prev = len(self.holdings.get(jid, set()))
        return abs(new_count - prev)
