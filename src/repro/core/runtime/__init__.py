"""ADS-Tile runtime scheduler (paper §IV).

The spatio-temporal isolation-sharing space is spanned by two
mechanisms: *configurable isolation* (partition-local tile pools bound
**where** reallocation propagates — the partitions come from GHA Phase
II) and *elastic reservation* (ERT admission + minimum-quota control
bound **when** tasks enter colocation).  Within that space the
DAG-aware scheduler (Algorithm 2) shares tiles across co-active paths
and slack along DAG edges.
"""
from .reservation import fit_quota
from .scheduler import AdsTilePolicy
from .l2p import L2PMap
from .replan import OnlineReplanner, SchedulePortfolio

__all__ = [
    "AdsTilePolicy", "fit_quota", "L2PMap",
    "OnlineReplanner", "SchedulePortfolio",
]
