"""ADS-Tile runtime scheduler (paper §IV).

The spatio-temporal isolation-sharing space is spanned by two
mechanisms: *configurable isolation* (partition-local tile pools bound
**where** reallocation propagates — the partitions come from GHA Phase
II) and *elastic reservation* (ERT admission + minimum-quota control
bound **when** tasks enter colocation).  Within that space the
DAG-aware scheduler (Algorithm 2) shares tiles across co-active paths
and slack along DAG edges.
"""
from .reservation import fit_quota, plan_slack
from .scheduler import AdsTilePolicy
from .l2p import L2PMap
from .forecast import ModeForecast, ModeForecaster
from .replan import (
    OnlineReplanner,
    PredictiveReplanner,
    SchedulePortfolio,
    blend_schedules,
)

__all__ = [
    "AdsTilePolicy", "fit_quota", "plan_slack", "L2PMap",
    "ModeForecast", "ModeForecaster",
    "OnlineReplanner", "PredictiveReplanner", "SchedulePortfolio",
    "blend_schedules",
]
