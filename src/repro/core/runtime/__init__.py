"""ADS-Tile runtime scheduler (paper §IV).

The spatio-temporal isolation-sharing space is spanned by two
mechanisms: *configurable isolation* (partition-local tile pools bound
**where** reallocation propagates — the partitions come from GHA Phase
II) and *elastic reservation* (ERT admission + minimum-quota control
bound **when** tasks enter colocation).  Within that space the
DAG-aware scheduler (Algorithm 2) shares tiles across co-active paths
and slack along DAG edges.
"""
from .reservation import fit_quota, most_urgent_plan, plan_slack
from .scheduler import AdsTilePolicy
from .l2p import L2PMap
from .forecast import ModeForecast, ModeForecaster
from .autotune import (
    FrontierPoint,
    ModeFrontier,
    autotune_mode,
    predict_miss,
)
from .replan import (
    OnlineReplanner,
    PredictiveReplanner,
    SchedulePortfolio,
    blend_schedules,
)

__all__ = [
    "AdsTilePolicy", "fit_quota", "plan_slack", "most_urgent_plan", "L2PMap",
    "ModeForecast", "ModeForecaster",
    "FrontierPoint", "ModeFrontier", "autotune_mode", "predict_miss",
    "OnlineReplanner", "PredictiveReplanner", "SchedulePortfolio",
    "blend_schedules",
]
