"""ADS-Tile DAG-aware runtime scheduler — Algorithm 2 (paper §IV-C).

Per-partition colocation and allocation under the two bounding
mechanisms:

* configurable isolation — this policy only ever touches its own
  partition's tile pool (the engine enforces it structurally);
* elastic reservation — ERT admission + minimum-quota allocation with
  residual capacity left idle for incoming tasks.

DAG-awareness appears as two forms of sharing (§IV-C):

* *spatial* — admitted jobs of co-active paths share the partition
  pool, allocated in sub-deadline order;
* *temporal* — sub-deadlines are soft references: a delayed job's
  target extends to ``e2e_ddl - downstream_budget`` (slack borrowed
  from adjacent stages while the E2E deadline still permits).

``ChkTrigger`` reschedules running tasks only when the latency benefit
outweighs the stop-migrate-restart cost (§III-D).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from ..sim.engine import Job, JobState, Simulator
from ..sim.policy import Policy
from .reservation import fit_quota

__all__ = ["AdsTilePolicy"]


class AdsTilePolicy(Policy):
    name = "ads_tile"

    def __init__(
        self,
        admission: bool = True,
        quota_control: bool = True,
        slack_sharing: bool = True,
        realloc_gate: float = 1.0,
    ):
        #: disable flags reproduce the ablation variants (§V-B)
        self.admission = admission
        self.quota_control = quota_control
        self.slack_sharing = slack_sharing
        #: reallocation fires only if benefit > gate * partition stall cost
        self.realloc_gate = realloc_gate
        self._down: Dict[str, float] = {}
        self._cands: Dict[str, tuple] = {}
        self._cmax: Dict[str, int] = {}
        self._cands_src: object = ()

    # ------------------------------------------------------------------
    def setup(self, sim: Simulator) -> None:
        # per-task DoP candidate cache (hot: FitQuota walks the ladder
        # at every scheduling point).  Normally workflow-derived, so it
        # survives re-setups after schedule hot-swaps — predictive
        # replanning re-runs setup() at every stage/commit/revert, and
        # only the schedule-derived state below actually changes.  A
        # table compiled by the tile-budget autotuner with DoP pruning
        # carries its *multi-version candidate set* instead
        # (meta["task_dop_candidates"], §IV-D2: the runtime can only
        # pick among the versions actually compiled), so the ladder
        # follows the installed table across swaps.
        src = sim.schedule.meta.get("task_dop_candidates")
        if src is not self._cands_src or not self._cands:
            if src is not None:
                self._cands = {
                    name: tuple(src.get(name, t.dop_candidates()))
                    for name, t in sim.wf.tasks.items() if not t.is_sensor
                }
            else:
                self._cands = {
                    name: t.dop_candidates()
                    for name, t in sim.wf.tasks.items() if not t.is_sensor
                }
            self._cmax = {name: max(c) for name, c in self._cands.items()}
            self._cands_src = src
        # downstream budget per task: tightest over chains (Getddl's
        # relative-timing data, precomputed offline)
        sched = sim.schedule
        for t in sim.wf.tasks:
            if sim.wf.tasks[t].is_sensor:
                continue
            tight = math.inf
            for chain in sim.wf.chain_for(t):
                i = chain.nodes.index(t)
                after = [
                    n for n in chain.nodes[i + 1:]
                    if not sim.wf.tasks[n].is_sensor
                ]
                s = sum(sched.plans[n].budget_s for n in after)
                tight = min(tight, s)
            self._down[t] = 0.0 if tight is math.inf else tight

    # ------------------------------------------------------------------
    def _target(self, job: Job) -> float:
        """Soft sub-deadline with DAG slack sharing (§IV-C, ③)."""
        if not self.slack_sharing:
            return job.sub_ddl
        eff = job.e2e_ddl - self._down.get(job.task, 0.0)
        return max(job.sub_ddl, eff)

    def _quota(self, sim: Simulator, job: Job, cap: int, now: float) -> int:
        cands = self._cands[job.task]
        if not self.quota_control:
            # degenerate: latency-greedy (largest candidate fitting cap)
            fit = [c for c in cands if c <= cap]
            return max(fit) if fit else 0
        return fit_quota(job, cands, self._target(job), now, sim.hw.tile_flops, cap)

    # ------------------------------------------------------------------
    def _schedule(self, sim: Simulator, partition: int, now: float) -> None:
        """Algorithm 2 body."""
        part = sim.parts[partition]
        if part.stalled:
            return
        tf = sim.hw.tile_flops

        # -- Admission Control: admit by ERT (line 3) -------------------
        ready = sim.eligible_jobs(partition, admitted_only=self.admission)
        running = [sim.jobs[jid] for jid in part.running]

        # -- fast path: start ready jobs on free tiles at their quota
        #    (a job past its target still starts — fit_quota degrades to
        #    the fastest candidate, minimising tardiness).  ``ready``
        #    only shrinks, so one sort serves every restart pass.
        ready.sort(key=lambda j: (j.sub_ddl, j.jid))
        started = True
        while started:
            started = False
            free = part.free()
            for job in ready:
                c = self._quota(sim, job, free, now)
                if c > 0:
                    sim.start_job(job, c)
                    if sim.cfg.drop_policy == "hard":
                        sim.arm_timer(partition, job.e2e_ddl, job)
                    ready.remove(job)
                    started = True
                    break

        # -- ChkTrigger (line 4): is rescheduling of running tasks
        #    worth it? ----------------------------------------------------
        free = part.free()
        blocked = [
            j for j in ready
            if self._quota(sim, j, part.capacity, now) > free
        ]
        at_risk = []
        slack_sharing, down = self.slack_sharing, self._down
        cmax = self._cmax
        for job in running:
            if cmax[job.task] <= job.dop:
                continue  # already at the largest candidate: cannot grow
            # _target() inlined (hot: every running job, every point)
            tgt = job.sub_ddl
            if slack_sharing:
                eff = job.e2e_ddl - down.get(job.task, 0.0)
                if eff > tgt:
                    tgt = eff
            if now + job.remaining(job.dop, tf) > tgt:
                at_risk.append(job)
        if not blocked and not at_risk:
            return

        # -- Quota Control: DDL order with reserved residual capacity ---
        queue: List[Job] = sorted(
            running + ready, key=lambda j: (j.sub_ddl, j.jid)
        )
        cap_left = part.capacity
        want: Dict[int, int] = {}
        for job in queue:
            c = self._quota(sim, job, cap_left, now)
            if job.state == JobState.RUNNING and c == 0:
                c = min(job.dop, cap_left)
            want[job.jid] = c
            cap_left -= c
        # residual cap_left stays idle for incoming tasks (line 13)

        # -- apply with benefit/cost gating ------------------------------
        resize: Dict[int, int] = {}
        starts: Dict[int, int] = {}
        n_running = len(running)
        for job in queue:
            c = want[job.jid]
            if job.state == JobState.RUNNING:
                if c == job.dop or c == 0:
                    continue
                per_tile = sim.wf.tasks[job.task].checkpoint_bytes
                stall = sim.hw.realloc_latency(
                    per_tile * abs(c - job.dop), part.capacity
                )
                if c > job.dop:
                    benefit = job.remaining(job.dop, tf) - job.remaining(c, tf)
                    # the stall freezes every co-located job (§IV-D1)
                    cost = stall * max(1, n_running) * self.realloc_gate
                    if benefit > cost:
                        resize[job.jid] = c
                else:
                    # shrink only when a blocked job needs the tiles
                    if blocked:
                        resize[job.jid] = c
            elif c > 0:
                starts[job.jid] = c

        if resize or starts:
            # verify the start set fits once resizes are applied
            freed = sum(
                part.running[j] - d for j, d in resize.items()
            )
            avail = part.free() + freed
            for jid in sorted(starts, key=lambda j: sim.jobs[j].sub_ddl):
                if starts[jid] > avail:
                    starts.pop(jid)
                else:
                    avail -= starts[jid]
            sim.resize(partition, resize, starts)
            if sim.cfg.drop_policy == "hard":
                for jid in starts:
                    sim.arm_timer(partition, sim.jobs[jid].e2e_ddl, sim.jobs[jid])

    # ------------------------------------------------------------------
    def on_point(
        self, sim: Simulator, partition: int, now: float, reason: str,
        job: Optional[Job] = None,
    ) -> None:
        if partition < 0:
            return
        if reason == "timer" and job is not None:
            # Getddl-driven dequeue: E2E deadline passed (§IV-C)
            if (
                sim.cfg.drop_policy == "hard"
                and job.state not in (JobState.DONE, JobState.DROPPED)
                and now >= job.e2e_ddl - 1e-12
            ):
                sim.terminate(job, "e2e_deadline")
            return
        if reason == "ready" and job is not None and sim.cfg.drop_policy == "hard":
            sim.arm_timer(partition, job.e2e_ddl, job)
        if reason in ("ready", "ert", "finish", "drop", "resume", "chunk"):
            self._schedule(sim, partition, now)
